//! Property: **incremental graph mutation is exact.** Applying a random
//! delta sequence through `runtime::mutate::apply` leaves the operand
//! set — raw matrices, band partition, and every cached checksum vector
//! (`s_c`, per-band `s_c`, `w_r`, `x_r1`, `h_c1`) — **bit-identical**
//! to a from-scratch rebuild of the mutated graph, on dense and CSR
//! representations alike. Fused and split forwards over the patched
//! operands match the rebuilt operands bit for bit, raise no serving
//! alarm, and the f64 engine stays quiet at all four paper thresholds.
//!
//! Plus the shard tier: routing the same deltas to resident row bands
//! over both transports (`inproc` and one-worker-process-per-band
//! `proc`) keeps sharded serving bit-identical to unsharded — including
//! across node additions, where every band boundary moves and the proc
//! transport re-ships all bands.
//!
//! Plus epoch isolation end to end: a delta applied mid-stream never
//! changes the answer of a request admitted against the previous graph
//! version — every response stamped epoch 0 is identical to the same
//! request served by a static-graph run.

// The proc transport runs on Unix domain sockets.
#![cfg(unix)]

use gcn_abft::abft::{
    engine::widen, fused_forward_checked, weight_row_sums, CheckPolicy, EngineModel,
};
use gcn_abft::coordinator::net::TcpTransport;
use gcn_abft::coordinator::shard::{
    InProcTransport, ProcTransport, ShardTransport, ShardedBackend,
};
use gcn_abft::coordinator::{
    run_server, run_server_with_updates, InferenceRequest, InferenceResponse, ModelState,
    ServePolicy, ServerConfig, VerifyStatus,
};
use gcn_abft::gcn::{Activation, GcnModel};
use gcn_abft::graph::synth::{generate, SynthSpec};
use gcn_abft::graph::DatasetId;
use gcn_abft::runtime::{
    mutate, ChecksumScheme, GcnBackend, GcnOperands, GcnOutputs, GraphDelta, NativeBanded,
    NativeDense, Operand,
};
use gcn_abft::tensor::NopHook;
use gcn_abft::util::proptest::{check, no_shrink, Config};
use gcn_abft::util::rng::Pcg64;
use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::Arc;

fn worker_bin() -> PathBuf {
    PathBuf::from(env!("CARGO_BIN_EXE_gcn-abft"))
}

fn bits(out: &GcnOutputs) -> Vec<u32> {
    out.logits.data().iter().map(|v| v.to_bits()).collect()
}

#[derive(Debug, Clone)]
struct Case {
    spec: SynthSpec,
    graph_seed: u64,
    model_seed: u64,
    delta_seed: u64,
    n_deltas: usize,
    bands: usize,
}

fn gen_case(rng: &mut Pcg64) -> Case {
    let n = 16 + rng.gen_index(32);
    Case {
        spec: SynthSpec {
            name: "prop-incr".into(),
            num_nodes: n,
            num_edges: 2 * n + rng.gen_index(n),
            feat_dim: 6 + rng.gen_index(10),
            feat_nnz: 4 * n,
            num_classes: 2 + rng.gen_index(4),
            homophily: 0.8,
            binary_features: rng.gen_bool(0.5),
            feature_scale: 1.0,
        },
        graph_seed: rng.next_u64(),
        model_seed: rng.next_u64(),
        delta_seed: rng.next_u64(),
        n_deltas: 1 + rng.gen_index(5),
        bands: 1 + rng.gen_index(3),
    }
}

fn build_sparse(case: &Case, bands: usize) -> Result<GcnOperands, String> {
    let graph = generate(&case.spec, case.graph_seed);
    let model = GcnModel::two_layer(&graph, 8, case.model_seed);
    GcnOperands::sparse(
        graph.features.clone(),
        &model.adjacency,
        model.layers[0].weights.clone(),
        model.layers[1].weights.clone(),
        bands,
    )
    .map_err(|e| format!("sparse operand build: {e}"))
}

fn build_dense(case: &Case) -> Result<GcnOperands, String> {
    let graph = generate(&case.spec, case.graph_seed);
    let model = GcnModel::two_layer(&graph, 8, case.model_seed);
    GcnOperands::dense(
        graph.features.to_dense(),
        model.adjacency.to_dense(),
        model.layers[0].weights.clone(),
        model.layers[1].weights.clone(),
    )
    .map_err(|e| format!("dense operand build: {e}"))
}

/// The case's delta sequence. Regenerated from the same seed for every
/// representation — the node count evolves identically, so the deltas
/// are identical too.
fn next_delta(rng: &mut Pcg64, ops: &GcnOperands) -> GraphDelta {
    mutate::random_delta(
        rng,
        ops.n_nodes(),
        ops.feat_dim(),
        ops.hidden_dim(),
        ops.num_classes(),
    )
}

/// Forward the operands with both native executables appropriate to
/// their representation, under both schemes.
fn forward(ops: &GcnOperands, scheme: ChecksumScheme) -> Result<GcnOutputs, String> {
    let out = match &ops.features {
        Operand::Dense(_) => NativeDense::new(2, scheme).run(ops, &[]),
        Operand::Sparse(_) => NativeBanded::new(2, scheme).run(ops, &[]),
    };
    out.map_err(|e| format!("forward ({scheme:?}): {e}"))
}

#[test]
fn prop_incremental_patch_is_bit_identical_to_rebuild() {
    check(
        &Config {
            cases: 10,
            seed: 0x1C4E,
            ..Default::default()
        },
        gen_case,
        |case| {
            let dense = build_dense(case)?;
            let sparse = build_sparse(case, case.bands)?;
            for mut ops in [dense, sparse] {
                let sparse_rep = matches!(ops.features, Operand::Sparse(_));
                let mut rng = Pcg64::from_seed(case.delta_seed);
                for step in 0..case.n_deltas {
                    let delta = next_delta(&mut rng, &ops);
                    mutate::apply(&mut ops, &delta)
                        .map_err(|e| format!("apply step {step}: {e:#}"))?;
                    // The tentpole invariant, after EVERY step: patched
                    // state is bit-identical to a from-scratch rebuild.
                    let rebuilt =
                        mutate::rebuild(&ops).map_err(|e| format!("rebuild step {step}: {e}"))?;
                    mutate::bit_identical(&ops, &rebuilt).map_err(|e| {
                        format!(
                            "step {step} ({}, sparse={sparse_rep}): patched state diverged \
                             from rebuild: {e}",
                            delta.kind()
                        )
                    })?;
                }

                // Forwards over patched vs rebuilt operands: bit-equal
                // logits and checksum words, zero fault-free alarms.
                let rebuilt = mutate::rebuild(&ops).map_err(|e| format!("final rebuild: {e}"))?;
                for scheme in [ChecksumScheme::Fused, ChecksumScheme::Split] {
                    let a = forward(&ops, scheme)?;
                    let b = forward(&rebuilt, scheme)?;
                    if bits(&a) != bits(&b) {
                        return Err(format!(
                            "{scheme:?} (sparse={sparse_rep}): patched-operand logits \
                             diverge from rebuilt-operand logits"
                        ));
                    }
                    if a.predicted
                        .iter()
                        .zip(&b.predicted)
                        .any(|(x, y)| x.to_bits() != y.to_bits())
                        || a.actual
                            .iter()
                            .zip(&b.actual)
                            .any(|(x, y)| x.to_bits() != y.to_bits())
                    {
                        return Err(format!(
                            "{scheme:?} (sparse={sparse_rep}): checksum words diverge \
                             between patched and rebuilt operands"
                        ));
                    }
                    let report = ServePolicy::default().verify(&a);
                    if !report.ok {
                        return Err(format!(
                            "{scheme:?} (sparse={sparse_rep}): fault-free forward over \
                             the mutated graph alarmed: {report:?}"
                        ));
                    }
                }

                // The f64 engine over the mutated graph: zero fault-free
                // alarms at every paper threshold.
                if sparse_rep {
                    let Operand::Sparse(features) = &ops.features else {
                        unreachable!("sparse_rep checked above");
                    };
                    let weights = vec![widen(&ops.w1), widen(&ops.w2)];
                    let adjacency = ops.s.to_csr();
                    let em = EngineModel {
                        s_c: adjacency.col_sums_f64(),
                        w_r: weight_row_sums(&weights),
                        adjacency,
                        weights,
                        activations: vec![Activation::Relu, Activation::None],
                    };
                    let mut nop = NopHook;
                    let (_, checks) = fused_forward_checked(&em, features, &mut nop);
                    for &tau in &CheckPolicy::PAPER_THRESHOLDS {
                        let policy = CheckPolicy::new(tau);
                        for c in &checks {
                            if policy.fires(c.predicted, c.actual) {
                                return Err(format!(
                                    "fault-free alarm over the mutated graph at \
                                     tau={tau:.0e}: {c:?}"
                                ));
                            }
                        }
                    }
                }
            }
            Ok(())
        },
        no_shrink,
    );
}

#[test]
fn prop_shard_tier_serves_deltas_bit_identically_over_all_transports() {
    check(
        &Config {
            cases: 4,
            seed: 0x5D17,
            ..Default::default()
        },
        gen_case,
        |case| {
            for shards in [2usize, 4] {
                let mut ops = build_sparse(case, shards)?;
                let inproc =
                    Arc::new(InProcTransport::new(&ops).map_err(|e| format!("inproc: {e}"))?);
                let proc = Arc::new(
                    ProcTransport::spawn(&ops, Some(worker_bin().as_path()))
                        .map_err(|e| format!("proc spawn: {e}"))?,
                );
                let tcp = Arc::new(
                    TcpTransport::spawn(&ops, Some(worker_bin().as_path()), 0)
                        .map_err(|e| format!("tcp spawn: {e}"))?,
                );

                // Route the delta sequence to the resident bands over
                // every transport — edge churn patches just the touched
                // bands (delta/ack frames over both wire transports);
                // node adds move every band boundary and force a full
                // re-ship.
                let mut rng = Pcg64::from_seed(case.delta_seed);
                for step in 0..case.n_deltas {
                    let delta = next_delta(&mut rng, &ops);
                    let outcome = mutate::apply(&mut ops, &delta)
                        .map_err(|e| format!("apply step {step}: {e:#}"))?;
                    inproc
                        .apply_delta(&ops, &outcome)
                        .map_err(|e| format!("inproc delta step {step}: {e:#}"))?;
                    proc.apply_delta(&ops, &outcome)
                        .map_err(|e| format!("proc delta step {step}: {e:#}"))?;
                    tcp.apply_delta(&ops, &outcome)
                        .map_err(|e| format!("tcp delta step {step}: {e:#}"))?;
                }

                let want = forward(&ops, ChecksumScheme::Fused)?;
                let want_bits = bits(&want);
                if !ServePolicy::default().verify(&want).ok {
                    return Err("fault-free unsharded forward alarmed".into());
                }
                let mut per_transport = Vec::new();
                for transport in [
                    inproc as Arc<dyn ShardTransport>,
                    proc as Arc<dyn ShardTransport>,
                    tcp as Arc<dyn ShardTransport>,
                ] {
                    let tname = transport.name();
                    let exe = ShardedBackend::new(transport, ChecksumScheme::Fused, 2);
                    let got = exe
                        .run(&ops, &[])
                        .map_err(|e| format!("{tname} run after deltas: {e:#}"))?;
                    if bits(&got) != want_bits {
                        return Err(format!(
                            "shards={shards} {tname}: post-delta logits are not \
                             bit-identical to unsharded"
                        ));
                    }
                    if !ServePolicy::default().verify(&got).ok {
                        return Err(format!(
                            "shards={shards} {tname}: fault-free post-delta pass alarmed"
                        ));
                    }
                    per_transport.push(got);
                }
                let a = &per_transport[0];
                for (name, b) in ["proc", "tcp"].iter().zip(&per_transport[1..]) {
                    if a.predicted
                        .iter()
                        .zip(&b.predicted)
                        .any(|(x, y)| x.to_bits() != y.to_bits())
                        || a.actual
                            .iter()
                            .zip(&b.actual)
                            .any(|(x, y)| x.to_bits() != y.to_bits())
                    {
                        return Err(format!(
                            "shards={shards}: {name} checksum words diverged from \
                             inproc after deltas"
                        ));
                    }
                }
            }
            Ok(())
        },
        no_shrink,
    );
}

fn collect(rx: std::sync::mpsc::Receiver<InferenceResponse>) -> BTreeMap<u64, InferenceResponse> {
    let mut out = BTreeMap::new();
    while let Ok(r) = rx.recv() {
        out.insert(r.id, r);
    }
    out
}

#[test]
fn mid_stream_delta_never_changes_an_epoch0_answer() {
    let cfg = ServerConfig {
        dataset: DatasetId::Tiny,
        workers: 1,
        train_epochs: 2,
        ..Default::default()
    };
    let state = ModelState::build(&cfg).unwrap();
    let requests: Vec<InferenceRequest> = (0..16u64)
        .map(|id| InferenceRequest::new(id, vec![(id as usize * 3) % 64], vec![]))
        .collect();

    // Static reference: the same requests against the unmutated graph.
    let (req_tx, req_rx) = std::sync::mpsc::channel();
    let (resp_tx, resp_rx) = std::sync::mpsc::channel();
    for r in &requests {
        req_tx.send(r.clone()).unwrap();
    }
    drop(req_tx);
    run_server(&cfg, &state, req_rx, resp_tx).unwrap();
    let want = collect(resp_rx);
    assert_eq!(want.len(), 16);
    assert!(want.values().all(|r| r.epoch == 0 && r.status == VerifyStatus::Clean));

    // Dynamic run: first half, then a delta mid-stream, then the rest.
    let (req_tx, req_rx) = std::sync::mpsc::channel();
    let (resp_tx, resp_rx) = std::sync::mpsc::channel();
    let (delta_tx, delta_rx) = std::sync::mpsc::channel();
    let metrics = std::thread::scope(|scope| {
        let server = scope.spawn(|| {
            run_server_with_updates(&cfg, &state, req_rx, resp_tx, None, Some(delta_rx))
        });
        let mut got = BTreeMap::new();
        for r in &requests[..8] {
            req_tx.send(r.clone()).unwrap();
        }
        while got.len() < 8 {
            let r = resp_rx.recv().expect("first-half response");
            got.insert(r.id, r);
        }
        delta_tx
            .send(GraphDelta::Edges {
                add: vec![(0, 7, 0.35), (7, 0, 0.35)],
                remove: vec![],
            })
            .unwrap();
        drop(delta_tx);
        for r in &requests[8..] {
            req_tx.send(r.clone()).unwrap();
        }
        drop(req_tx);
        while let Ok(r) = resp_rx.recv() {
            got.insert(r.id, r);
        }
        let metrics = server.join().expect("server thread").unwrap();
        (metrics, got)
    });
    let (m, got) = metrics;
    assert_eq!(got.len(), 16, "every request answered across the delta");
    assert_eq!(m.deltas_applied, 1, "the mid-stream delta was applied: {m:?}");
    assert_eq!(m.delta_failures, 0, "{m:?}");
    assert_eq!(m.epoch, 1, "{m:?}");

    for (id, r) in &got {
        assert_eq!(r.status, VerifyStatus::Clean, "request {id} not clean: {r:?}");
        if r.epoch == 0 {
            // Epoch isolation: an answer computed on graph version 0 is
            // identical to the static run's answer — the delta that
            // landed mid-stream never leaked into it.
            assert_eq!(
                r.classes, want[id].classes,
                "epoch-0 answer for request {id} changed under a mid-stream delta"
            );
        }
    }
    // The first half was answered before the delta was even submitted.
    for id in 0..8u64 {
        assert_eq!(got[&id].epoch, 0, "request {id} pre-delta must be epoch 0");
    }
}
