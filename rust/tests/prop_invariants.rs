//! Property-based invariant tests (using the in-repo `util::proptest`
//! substrate; the `proptest` crate is unavailable offline).
//!
//! The properties are the mathematical heart of the paper:
//! * the checksum identity eᵀ(SHW)e = s_c·H·w_r on random matrices;
//! * split and fused checkers compute identical true outputs;
//! * fused check-op count < split check-op count for every shape;
//! * any single non-trivial data corruption is caught at end of layer
//!   unless annihilated by a zero column of S;
//! * CSR algebra matches dense algebra on random sparse patterns.

use gcn_abft::abft::{
    fused_forward_checked, fused_layer_checked, split_forward_checked, split_layer_checked,
    CheckPolicy, EngineInput, EngineModel,
};
use gcn_abft::fault::{FaultPlan, PlannedFault};
use gcn_abft::gcn::GcnModel;
use gcn_abft::graph::synth::{generate, SynthSpec};
use gcn_abft::sparse::Csr;
use gcn_abft::tensor::instrumented::{matmul_hooked, CountingHook};
use gcn_abft::tensor::{Dense, Dense64, NopHook};
use gcn_abft::util::proptest::{check, gen_dim, gen_matrix, no_shrink, Config};
use gcn_abft::util::rng::Pcg64;

/// Random layer shapes: (n, f, h) with sparse-ish S and dense H, W.
#[derive(Clone, Debug)]
struct LayerCase {
    s: Csr,
    h: Dense64,
    w: Dense64,
}

fn gen_layer_case(rng: &mut Pcg64) -> LayerCase {
    let n = gen_dim(rng, 24).max(2);
    let f = gen_dim(rng, 20);
    let h = gen_dim(rng, 12);
    // Sparse S: each row gets 1..=3 entries (self-loop always present so
    // no zero columns in this generator).
    let mut coo = Vec::new();
    for r in 0..n {
        coo.push((r, r, rng.gen_f32_range(0.2, 1.0)));
        for _ in 0..rng.gen_index(3) {
            coo.push((r, rng.gen_index(n), rng.gen_f32_range(-1.0, 1.0)));
        }
    }
    let s = Csr::from_coo(n, n, coo);
    let hm = Dense64::from_dense(&Dense::from_vec(n, f, gen_matrix(rng, n, f, 4.0)));
    let w = Dense64::from_dense(&Dense::from_vec(f, h, gen_matrix(rng, f, h, 1.0)));
    LayerCase { s, h: hm, w }
}

fn offline(case: &LayerCase) -> (Vec<f64>, Vec<f64>) {
    let s_c = case.s.col_sums_f64();
    let w_r: Vec<f64> = (0..case.w.rows())
        .map(|r| case.w.row(r).iter().sum::<f64>())
        .collect();
    (s_c, w_r)
}

#[test]
fn prop_fused_checksum_identity() {
    check(
        &Config {
            cases: 80,
            seed: 0xE401,
            ..Default::default()
        },
        gen_layer_case,
        |case| {
            let (s_c, w_r) = offline(case);
            let mut nop = NopHook;
            let (out, rec) = fused_layer_checked(
                &case.s,
                &s_c,
                &EngineInput::Dense(case.h.clone()),
                &case.w,
                &w_r,
                0,
                &mut nop,
            );
            // (1) predicted == actual to rounding, (2) actual == eᵀ·out·e.
            let scale = rec.actual.abs().max(1.0);
            if rec.residual() / scale > 1e-9 {
                return Err(format!("identity violated: {rec:?}"));
            }
            let direct = out.checksum();
            if (direct - rec.actual).abs() / scale > 1e-9 {
                return Err(format!(
                    "actual checksum {} != block sum {}",
                    rec.actual, direct
                ));
            }
            Ok(())
        },
        no_shrink,
    );
}

#[test]
fn prop_split_and_fused_outputs_identical() {
    check(
        &Config {
            cases: 60,
            seed: 0xE402,
            ..Default::default()
        },
        gen_layer_case,
        |case| {
            let (s_c, w_r) = offline(case);
            let mut nop = NopHook;
            let (fused_out, _) = fused_layer_checked(
                &case.s,
                &s_c,
                &EngineInput::Dense(case.h.clone()),
                &case.w,
                &w_r,
                0,
                &mut nop,
            );
            let (split_out, recs) = split_layer_checked(
                &case.s,
                &s_c,
                &EngineInput::Dense(case.h.clone()),
                &case.w,
                &w_r,
                None,
                0,
                &mut nop,
            );
            if !fused_out.identical(&split_out) {
                return Err("true outputs differ between checkers".into());
            }
            // Split's own checks hold fault-free.
            for r in &recs {
                if r.residual() / r.actual.abs().max(1.0) > 1e-9 {
                    return Err(format!("split check violated: {r:?}"));
                }
            }
            Ok(())
        },
        no_shrink,
    );
}

#[test]
fn prop_fused_always_cheaper_to_check() {
    check(
        &Config {
            cases: 60,
            seed: 0xE403,
            ..Default::default()
        },
        gen_layer_case,
        |case| {
            let (s_c, w_r) = offline(case);
            let mut cf = CountingHook::default();
            fused_layer_checked(
                &case.s,
                &s_c,
                &EngineInput::Dense(case.h.clone()),
                &case.w,
                &w_r,
                0,
                &mut cf,
            );
            let mut cs = CountingHook::default();
            split_layer_checked(
                &case.s,
                &s_c,
                &EngineInput::Dense(case.h.clone()),
                &case.w,
                &w_r,
                None,
                0,
                &mut cs,
            );
            if cf.data_ops != cs.data_ops {
                return Err(format!(
                    "true-output data ops differ: {} vs {}",
                    cf.data_ops, cs.data_ops
                ));
            }
            if cf.checksum_ops >= cs.checksum_ops {
                return Err(format!(
                    "fused checker not cheaper: {} vs {}",
                    cf.checksum_ops, cs.checksum_ops
                ));
            }
            Ok(())
        },
        no_shrink,
    );
}

#[test]
fn prop_single_corruption_detected_when_s_has_no_zero_columns() {
    check(
        &Config {
            cases: 60,
            seed: 0xE404,
            ..Default::default()
        },
        |rng| {
            let case = gen_layer_case(rng);
            // A corruption magnitude comfortably above threshold and an
            // op somewhere in the layer's data path.
            let op = rng.gen_range(1_000_000) as u64;
            (case, op)
        },
        |(case, op_seed)| {
            let (s_c, w_r) = offline(case);
            // Count data ops to place the corruption on the true-output
            // path (phase-1 matmul region only, where S-annihilation via
            // zero columns is impossible by construction).
            let mut cnt = CountingHook::default();
            matmul_hooked(&case.h, &case.w, &mut cnt);
            let phase1_ops = cnt.data_ops;
            let target = op_seed % phase1_ops;

            struct Corrupt {
                at: u64,
                n: u64,
            }
            impl gcn_abft::tensor::ExecHook for Corrupt {
                fn mul(&mut self, v: f64) -> f64 {
                    let out = if self.n == self.at { v + 1e6 } else { v };
                    self.n += 1;
                    out
                }
                fn add(&mut self, v: f64) -> f64 {
                    let out = if self.n == self.at { v + 1e6 } else { v };
                    self.n += 1;
                    out
                }
                fn csum(&mut self, v: f64) -> f64 {
                    v
                }
            }
            let mut hook = Corrupt { at: target, n: 0 };
            let (_, rec) = fused_layer_checked(
                &case.s,
                &s_c,
                &EngineInput::Dense(case.h.clone()),
                &case.w,
                &w_r,
                0,
                &mut hook,
            );
            let policy = CheckPolicy::new(1e-4);
            // The +1e6 corruption must surface in the end-of-layer check:
            // every X row is read by S (self-loops ⇒ no zero columns).
            if !policy.fires(rec.predicted, rec.actual) {
                return Err(format!("corruption at op {target} missed: {rec:?}"));
            }
            Ok(())
        },
        no_shrink,
    );
}

/// The paper's core identity on *whole random synthetic graphs*: the
/// fused checksum `s_c·H·w_r` equals the split scheme's end-of-layer
/// `eᵀ(S·H·W)e` on every layer; fault-free runs raise zero alarms under
/// all four paper thresholds; and a single injected bit flip on the data
/// path is detected by both schemes.
#[test]
fn prop_fused_equals_split_on_random_synthetic_graphs() {
    check(
        &Config {
            cases: 24,
            seed: 0xE406,
            ..Default::default()
        },
        |rng| {
            let n = 20 + rng.gen_index(40);
            let classes = 2 + rng.gen_index(4);
            let spec = SynthSpec {
                name: "prop".into(),
                num_nodes: n,
                num_edges: 2 * n,
                feat_dim: 8 + rng.gen_index(24),
                feat_nnz: 4 * n,
                num_classes: classes,
                homophily: 0.8,
                binary_features: rng.gen_bool(0.5),
                feature_scale: 1.0,
            };
            let graph_seed = rng.next_u64();
            let model_seed = rng.next_u64();
            let flip_seed = rng.next_u64();
            (spec, graph_seed, model_seed, flip_seed)
        },
        |(spec, graph_seed, model_seed, flip_seed)| {
            let graph = generate(spec, *graph_seed);
            let model = GcnModel::two_layer(&graph, 8, *model_seed);
            let em = EngineModel::from_model(&model);
            let h_c = graph.features.col_sums_f64();

            // --- fault-free: identical outputs, matching checksums, no
            // alarms at any paper threshold --------------------------------
            let mut nop = NopHook;
            let (fused_out, fused_checks) = fused_forward_checked(&em, &graph.features, &mut nop);
            let (split_out, split_checks) =
                split_forward_checked(&em, &graph.features, &h_c, &mut nop);
            for (f, s) in fused_out.iter().zip(&split_out) {
                if !f.identical(s) {
                    return Err("checkers computed different true outputs".into());
                }
            }
            // Fused end-of-layer records coincide with split's (the same
            // ops in the same order): layer ℓ fused == split[2ℓ+1].
            for (l, f) in fused_checks.iter().enumerate() {
                let s = &split_checks[2 * l + 1];
                if f.predicted != s.predicted || f.actual != s.actual {
                    return Err(format!(
                        "fused/split end-of-layer checksums diverge at layer {l}: \
                         {f:?} vs {s:?}"
                    ));
                }
            }
            for &tau in &CheckPolicy::PAPER_THRESHOLDS {
                let policy = CheckPolicy::new(tau);
                for c in fused_checks.iter().chain(&split_checks) {
                    if policy.fires(c.predicted, c.actual) {
                        return Err(format!("fault-free alarm at tau={tau:.0e}: {c:?}"));
                    }
                }
            }

            // --- one injected bit flip on the data path is detected by
            // both schemes -------------------------------------------------
            // Target an op inside the layer-1 combination matmul (the
            // first 2·nnz(H)·h data ops of either scheme's timeline) and
            // flip the top exponent bit, which is visible at any operand
            // magnitude (value shrinks or explodes by 2^128).
            let phase1_ops = 2 * graph.features.nnz() as u64 * 8;
            let target = flip_seed % phase1_ops;
            let policy = CheckPolicy::new(1e-4);
            for scheme_is_fused in [true, false] {
                let plan = FaultPlan {
                    faults: vec![PlannedFault {
                        op_index: target,
                        bit32: 30,
                        bit64: 62,
                    }],
                };
                let mut hook = plan.hook();
                let checks = if scheme_is_fused {
                    fused_forward_checked(&em, &graph.features, &mut hook).1
                } else {
                    split_forward_checked(&em, &graph.features, &h_c, &mut hook).1
                };
                if !hook.exhausted() {
                    return Err(format!("planned fault at op {target} never fired"));
                }
                if !checks.iter().any(|c| policy.fires(c.predicted, c.actual)) {
                    let scheme = if scheme_is_fused { "fused" } else { "split" };
                    return Err(format!(
                        "{scheme} missed an exponent-bit flip at op {target}: {checks:?}"
                    ));
                }
            }
            Ok(())
        },
        no_shrink,
    );
}

#[test]
fn prop_csr_matches_dense_algebra() {
    check(
        &Config {
            cases: 80,
            seed: 0xE405,
            ..Default::default()
        },
        |rng| {
            let rows = gen_dim(rng, 20);
            let cols = gen_dim(rng, 20);
            let inner = gen_dim(rng, 16);
            let density = rng.gen_f64_range(0.05, 0.6);
            let mut coo = Vec::new();
            for r in 0..rows {
                for c in 0..cols {
                    if rng.gen_bool(density) {
                        coo.push((r, c, rng.gen_f32_range(-2.0, 2.0)));
                    }
                }
            }
            let m = Csr::from_coo(rows, cols, coo);
            let b = Dense::from_vec(cols, inner, gen_matrix(rng, cols, inner, 2.0));
            (m, b)
        },
        |(m, b)| {
            let sparse = m.spmm(b);
            let dense = gcn_abft::tensor::ops::matmul(&m.to_dense(), b);
            if sparse.max_abs_diff(&dense) > 1e-4 {
                return Err(format!(
                    "spmm diverges from dense matmul by {}",
                    sparse.max_abs_diff(&dense)
                ));
            }
            // Checksum identity on the sparse product.
            let lhs = sparse.checksum_f64();
            let rhs = gcn_abft::tensor::ops::dot_f64(&m.col_sums(), &b.row_sums());
            if (lhs - rhs).abs() / lhs.abs().max(1.0) > 1e-5 {
                return Err(format!("sparse checksum identity violated: {lhs} vs {rhs}"));
            }
            // Transpose involution.
            if m.transpose().transpose() != *m {
                return Err("transpose not an involution".into());
            }
            Ok(())
        },
        no_shrink,
    );
}
