//! The analyze pass applied to this repository itself.
//!
//! This is what turns the architectural contracts from documentation
//! into an enforced invariant: the tier-1 test suite fails the moment a
//! raw clock read, a nondeterministic iteration, an f32 checksum
//! accumulation, a float equality, a coordinator panic path, or a
//! detached thread lands in the tree without a reasoned
//! `// gcn-lint: allow(...)` suppression. CI runs the same sweep via
//! `gcn-abft analyze --json`.

use gcn_abft::analysis::{analyze_paths, SCHEMA_VERSION};
use gcn_abft::util::json::Json;
use std::path::{Path, PathBuf};

fn crate_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
}

#[test]
fn the_tree_passes_its_own_contracts() {
    let report = analyze_paths(&[crate_root().join("src"), crate_root().join("tests")])
        .expect("analyzing the real tree");
    // Guard the walk itself: an empty scan would vacuously "pass".
    assert!(
        report.files_scanned > 40,
        "suspiciously few files scanned ({}) — did the walk break?",
        report.files_scanned
    );
    assert!(
        report.clean(),
        "contract violations in the tree:\n{}",
        report.render()
    );
    // The sweep leans on inline suppressions, and the analyzer only
    // accepts them with a reason — double-check none slipped through
    // empty (the parser should already reject these as LINT findings).
    assert!(
        !report.suppressed.is_empty(),
        "expected the tree's reasoned suppressions to be visible"
    );
    for s in &report.suppressed {
        assert!(
            !s.reason.trim().is_empty(),
            "reasonless suppression at {}:{}",
            s.path,
            s.line
        );
    }
}

#[test]
fn analyzer_flags_a_seeded_violation() {
    // End-to-end negative control over a real temp file: the self-scan
    // above proves "clean tree exits clean"; this proves the same
    // `analyze_paths` entry point still *finds* things.
    let dir = std::env::temp_dir().join(format!("gcn-abft-analyze-test-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let bad = dir.join("seeded.rs");
    std::fs::write(
        &bad,
        "pub fn f() -> std::time::Instant { std::time::Instant::now() }\n",
    )
    .unwrap();
    let report = analyze_paths(&[&bad]).expect("analyzing the seeded file");
    std::fs::remove_file(&bad).ok();
    std::fs::remove_dir(&dir).ok();
    assert!(!report.clean(), "seeded D1 violation must be found");
    assert_eq!(report.findings.len(), 1);
    assert_eq!(report.findings[0].rule, "D1");
}

#[test]
fn checked_in_sample_matches_the_live_schema() {
    let sample_path = crate_root().join("docs/analyze.sample.json");
    let text = std::fs::read_to_string(&sample_path)
        .unwrap_or_else(|e| panic!("reading {}: {e}", sample_path.display()));
    let sample = Json::parse(&text).expect("sample must be valid JSON");
    assert_eq!(
        sample.get("type").and_then(Json::as_str),
        Some("analysis_report")
    );
    let sample_data = sample.get("data").expect("sample data");
    assert_eq!(
        sample_data.get("version").and_then(Json::as_f64),
        Some(SCHEMA_VERSION as f64),
        "sample documents a stale schema version — regenerate it"
    );

    // A live report serializes with exactly the top-level and summary
    // keys the sample documents, in the same order.
    let live = analyze_paths(&[crate_root().join("src/analysis")])
        .expect("analyzing src/analysis")
        .to_json();
    let keys = |j: &Json, path: &[&str]| -> Vec<String> {
        let mut node = j.clone();
        for k in path {
            node = node.get(k).unwrap_or_else(|| panic!("missing {k}")).clone();
        }
        node.entries()
            .expect("object")
            .iter()
            .map(|(k, _)| k.clone())
            .collect()
    };
    assert_eq!(keys(&live, &["data"]), keys(&sample, &["data"]));
    assert_eq!(
        keys(&live, &["data", "summary", "data"]),
        keys(&sample, &["data", "summary", "data"])
    );
    assert_eq!(
        keys(&live, &["data", "summary", "data", "by_rule"]),
        keys(&sample, &["data", "summary", "data", "by_rule"])
    );
}

#[test]
fn default_roots_resolve_from_the_crate_root() {
    // `gcn-abft analyze` with no paths must find the same tree the
    // self-scan covers, wherever it is launched from.
    let roots = gcn_abft::analysis::default_roots();
    assert!(!roots.is_empty());
    assert!(
        roots.iter().any(|r| r.ends_with(Path::new("src")) && r.is_dir()),
        "default roots {roots:?} must include an existing src dir"
    );
}
