//! Backend-parity property tests: every [`GcnBackend`] implementation
//! must compute the same forward. On random synthetic graphs, the
//! `instrumented` backend with the no-op fault model must match
//! `native-dense`/`native-banded` logits within f32→f64 tolerance and
//! produce **identical fused-vs-split alarm decisions** under the
//! serving policy — the trait-level statement of the paper's claim that
//! the checksum checks the product, not the execution strategy.

use gcn_abft::coordinator::ServePolicy;
use gcn_abft::gcn::GcnModel;
use gcn_abft::graph::synth::{generate, SynthSpec};
use gcn_abft::runtime::{
    ChecksumScheme, GcnBackend, GcnOperands, Instrumented, NativeBanded, NativeDense,
};
use gcn_abft::util::proptest::{check, no_shrink, Config};
use gcn_abft::util::rng::Pcg64;

fn gen_case(rng: &mut Pcg64) -> (SynthSpec, u64, u64, usize) {
    let n = 20 + rng.gen_index(40);
    let spec = SynthSpec {
        name: "prop-backend".into(),
        num_nodes: n,
        num_edges: 2 * n,
        feat_dim: 8 + rng.gen_index(24),
        feat_nnz: 4 * n,
        num_classes: 2 + rng.gen_index(4),
        homophily: 0.8,
        binary_features: rng.gen_bool(0.5),
        feature_scale: 1.0,
    };
    (spec, rng.next_u64(), rng.next_u64(), 2 + rng.gen_index(4))
}

#[test]
fn prop_instrumented_matches_native_backends() {
    check(
        &Config {
            cases: 16,
            seed: 0xBAC7,
            ..Default::default()
        },
        gen_case,
        |(spec, graph_seed, model_seed, bands)| {
            let graph = generate(spec, *graph_seed);
            let model = GcnModel::two_layer(&graph, 8, *model_seed);
            let w1 = model.layers[0].weights.clone();
            let w2 = model.layers[1].weights.clone();
            let dense = GcnOperands::dense(
                graph.features.to_dense(),
                model.adjacency.to_dense(),
                w1.clone(),
                w2.clone(),
            )
            .map_err(|e| format!("dense operands: {e}"))?;
            let sparse =
                GcnOperands::sparse(graph.features.clone(), &model.adjacency, w1, w2, *bands)
                    .map_err(|e| format!("sparse operands: {e}"))?;

            for scheme in [
                ChecksumScheme::Fused,
                ChecksumScheme::Split,
                ChecksumScheme::Auto,
            ] {
                let nd = NativeDense::new(2, scheme)
                    .run(&dense, &[])
                    .map_err(|e| format!("native-dense: {e}"))?;
                let nb = NativeBanded::new(2, scheme)
                    .run(&sparse, &[])
                    .map_err(|e| format!("native-banded: {e}"))?;
                let inst = Instrumented::for_operands(&sparse, scheme, 2)
                    .and_then(|b| b.run(&sparse, &[]))
                    .map_err(|e| format!("instrumented: {e}"))?;

                let expect_checks = match scheme {
                    ChecksumScheme::Split => 4,
                    // Auto resolves to the check-op argmin — fused on
                    // both current profiles — so it serves fused-shaped
                    // outputs; the parity assertions below then hold it
                    // to the same logits and alarm decisions.
                    _ => 2,
                };
                for (name, out) in [("dense", &nd), ("banded", &nb), ("instrumented", &inst)] {
                    if out.predicted.len() != expect_checks {
                        return Err(format!(
                            "{name}: {} checks under {scheme:?}, want {expect_checks}",
                            out.predicted.len()
                        ));
                    }
                }

                // Logits: f64 engine vs f32 kernels within f32 tolerance.
                let scale = nd
                    .logits
                    .data()
                    .iter()
                    .fold(0f32, |m, &v| m.max(v.abs()))
                    .max(1.0);
                let d_inst = inst.logits.max_abs_diff(&nd.logits);
                if d_inst / scale > 1e-4 {
                    return Err(format!(
                        "instrumented logits diverge from native by {d_inst} \
                         (scale {scale}, {scheme:?})"
                    ));
                }
                let d_band = nb.logits.max_abs_diff(&nd.logits);
                if d_band / scale > 1e-5 {
                    return Err(format!(
                        "banded logits diverge from dense by {d_band} ({scheme:?})"
                    ));
                }

                // Identical alarm decisions on the fault-free pass.
                let policy = ServePolicy::default();
                let decisions = [
                    policy.verify(&nd).ok,
                    policy.verify(&nb).ok,
                    policy.verify(&inst).ok,
                ];
                if decisions != [true, true, true] {
                    return Err(format!(
                        "fault-free alarm decisions diverge under {scheme:?}: \
                         dense={} banded={} instrumented={}",
                        decisions[0], decisions[1], decisions[2]
                    ));
                }
            }
            Ok(())
        },
        no_shrink,
    );
}

#[test]
fn prop_plans_agree_on_true_ops_across_backends() {
    // plan() is the analytic side of the trait: every backend sees the
    // same true-output work, and fused strictly undercuts split on
    // checking ops for every backend.
    check(
        &Config {
            cases: 24,
            seed: 0xBAC8,
            ..Default::default()
        },
        gen_case,
        |(spec, graph_seed, model_seed, bands)| {
            let graph = generate(spec, *graph_seed);
            let model = GcnModel::two_layer(&graph, 8, *model_seed);
            let sparse = GcnOperands::sparse(
                graph.features.clone(),
                &model.adjacency,
                model.layers[0].weights.clone(),
                model.layers[1].weights.clone(),
                *bands,
            )
            .map_err(|e| format!("operands: {e}"))?;
            let mut true_ops = Vec::new();
            for scheme in [ChecksumScheme::Fused, ChecksumScheme::Split] {
                let nb = NativeBanded::new(1, scheme)
                    .plan(&sparse)
                    .map_err(|e| format!("plan: {e}"))?;
                let inst = Instrumented::for_operands(&sparse, scheme, 1)
                    .and_then(|b| b.plan(&sparse))
                    .map_err(|e| format!("plan: {e}"))?;
                if nb.true_ops != inst.true_ops {
                    return Err(format!(
                        "true ops disagree: native {} vs instrumented {}",
                        nb.true_ops, inst.true_ops
                    ));
                }
                true_ops.push((nb.check_ops, inst.check_ops));
            }
            let (fused_native, fused_inst) = true_ops[0];
            let (split_native, split_inst) = true_ops[1];
            if fused_native >= split_native || fused_inst >= split_inst {
                return Err(format!(
                    "fused must undercut split: native {fused_native}/{split_native}, \
                     instrumented {fused_inst}/{split_inst}"
                ));
            }
            Ok(())
        },
        no_shrink,
    );
}
