//! Property: **coalescing is semantically invisible.** Under random
//! arrival orders, priorities, per-request deadlines and batch policies,
//! every request's logits and its fused/split alarm decisions are
//! bit-identical to serving that request alone.
//!
//! The scheduling side runs on a [`VirtualClock`] (random submit /
//! advance / poll interleavings, zero real sleeps); the execution side
//! replays the server's own overlay-equivalence grouping
//! ([`overlay_groups`]): requests with bit-identical perturbation sets
//! share one forward, so a member's outputs are exactly the solo
//! outputs. This is the serving-path analogue of the paper's overlay
//! patching guarantee — the checksum scheme must not care *how* the
//! product was batched.

use gcn_abft::coordinator::{
    overlay_groups, AdmissionControl, BatchPolicy, InferenceRequest, Perturbation, Priority,
    Scheduler, ServePolicy, VirtualClock,
};
use gcn_abft::gcn::GcnModel;
use gcn_abft::graph::synth::{generate, SynthSpec};
use gcn_abft::runtime::{
    backend, BackendKind, ChecksumScheme, GcnBackend, GcnOperands, GcnOutputs, Overlay,
};
use gcn_abft::util::proptest::{check, no_shrink, Config};
use gcn_abft::util::rng::Pcg64;
use std::time::Duration;

#[derive(Debug, Clone)]
struct Case {
    spec: SynthSpec,
    graph_seed: u64,
    model_seed: u64,
    traffic_seed: u64,
    sparse: bool,
    bands: usize,
    max_batch: usize,
    max_wait_us: u64,
    starvation_factor: u32,
}

fn gen_case(rng: &mut Pcg64) -> Case {
    let n = 16 + rng.gen_index(32);
    Case {
        spec: SynthSpec {
            name: "prop-batch-eq".into(),
            num_nodes: n,
            num_edges: 2 * n,
            feat_dim: 6 + rng.gen_index(16),
            feat_nnz: 4 * n,
            num_classes: 2 + rng.gen_index(4),
            homophily: 0.8,
            binary_features: rng.gen_bool(0.5),
            feature_scale: 1.0,
        },
        graph_seed: rng.next_u64(),
        model_seed: rng.next_u64(),
        traffic_seed: rng.next_u64(),
        sparse: rng.gen_bool(0.5),
        bands: 1 + rng.gen_index(4),
        max_batch: 1 + rng.gen_index(4),
        max_wait_us: 200 + rng.gen_range(5_000),
        starvation_factor: 1 + rng.gen_index(4) as u32,
    }
}

/// Exact bit patterns of one forward's outputs.
fn bits(out: &GcnOutputs) -> (Vec<u32>, Vec<u32>, Vec<u32>) {
    (
        out.logits.data().iter().map(|v| v.to_bits()).collect(),
        out.predicted.iter().map(|v| v.to_bits()).collect(),
        out.actual.iter().map(|v| v.to_bits()).collect(),
    )
}

fn request_overlays(req: &InferenceRequest) -> Vec<Overlay<'_>> {
    req.perturbations
        .iter()
        .map(|p| Overlay {
            node: p.node,
            row: p.features.as_slice(),
        })
        .collect()
}

#[test]
fn prop_coalesced_serving_is_bit_identical_to_solo() {
    check(
        &Config {
            cases: 14,
            seed: 0xBA7C,
            ..Default::default()
        },
        gen_case,
        |case| {
            let graph = generate(&case.spec, case.graph_seed);
            let model = GcnModel::two_layer(&graph, 8, case.model_seed);
            let w1 = model.layers[0].weights.clone();
            let w2 = model.layers[1].weights.clone();
            let ops = if case.sparse {
                GcnOperands::sparse(
                    graph.features.clone(),
                    &model.adjacency,
                    w1,
                    w2,
                    case.bands,
                )
            } else {
                GcnOperands::dense(
                    graph.features.to_dense(),
                    model.adjacency.to_dense(),
                    w1,
                    w2,
                )
            }
            .map_err(|e| format!("operand build failed: {e}"))?;

            let mut rng = Pcg64::from_seed(case.traffic_seed);
            let n_nodes = graph.num_nodes;
            let feat_dim = graph.feat_dim();

            // Random traffic: priorities, deadlines, perturbation sets —
            // with deliberate duplicates so overlay groups get shared.
            let n_requests = 5 + rng.gen_index(7);
            let mut requests: Vec<InferenceRequest> = Vec::new();
            for id in 0..n_requests {
                let perturbations = if !requests.is_empty() && rng.gen_bool(0.3) {
                    // Clone an earlier request's exact perturbation set.
                    requests[rng.gen_index(requests.len())].perturbations.clone()
                } else {
                    (0..rng.gen_index(3))
                        .map(|_| Perturbation {
                            node: rng.gen_index(n_nodes),
                            features: (0..feat_dim)
                                .map(|_| rng.gen_f32_range(-4.0, 4.0))
                                .collect(),
                        })
                        .collect()
                };
                let mut req = InferenceRequest::new(
                    id as u64,
                    vec![rng.gen_index(n_nodes)],
                    perturbations,
                )
                .with_priority(Priority::ALL[rng.gen_index(3)]);
                if rng.gen_bool(0.2) {
                    req = req.with_deadline(Duration::from_micros(rng.gen_range(2_000)));
                }
                requests.push(req);
            }

            // Solo references: each request served alone, per scheme.
            let schemes = [ChecksumScheme::Fused, ChecksumScheme::Split];
            let mut solo: Vec<Vec<((Vec<u32>, Vec<u32>, Vec<u32>), bool)>> = Vec::new();
            for scheme in schemes {
                let exe = backend::for_operands(BackendKind::Native, scheme, &ops, 2, None)
                    .map_err(|e| format!("backend build failed: {e}"))?;
                let mut per_req = Vec::new();
                for req in &requests {
                    let out = exe
                        .run(&ops, &request_overlays(req))
                        .map_err(|e| format!("solo run failed: {e}"))?;
                    let ok = ServePolicy::default().verify(&out).ok;
                    per_req.push((bits(&out), ok));
                }
                solo.push(per_req);
            }

            // Scheduled side: random arrival order and poll interleaving
            // on a virtual clock.
            let sched = Scheduler::new(
                VirtualClock::new(),
                BatchPolicy {
                    max_batch: case.max_batch,
                    max_wait: Duration::from_micros(case.max_wait_us),
                    starvation_factor: case.starvation_factor,
                    adaptive: None,
                    admission: None,
                },
            );
            let mut order: Vec<usize> = (0..n_requests).collect();
            rng.shuffle(&mut order);
            let mut batches = Vec::new();
            for &i in &order {
                sched.submit(requests[i].clone());
                if rng.gen_bool(0.5) {
                    sched
                        .clock()
                        .advance(Duration::from_micros(rng.gen_range(3_000)));
                }
                if rng.gen_bool(0.4) {
                    while let Some(b) = sched.poll() {
                        batches.push(b);
                    }
                }
            }
            sched.shutdown();
            while let Some(b) = sched.poll() {
                batches.push(b);
            }

            // No request lost or duplicated by the scheduler.
            let mut seen: Vec<u64> = batches
                .iter()
                .flat_map(|b| b.requests.iter().map(|r| r.id))
                .collect();
            seen.sort_unstable();
            let expect: Vec<u64> = (0..n_requests as u64).collect();
            if seen != expect {
                return Err(format!("requests lost/duplicated: {seen:?}"));
            }

            // Replay the server's execution: one forward per overlay
            // group, compared bitwise against each member's solo run.
            for (sidx, scheme) in schemes.iter().enumerate() {
                let exe =
                    backend::for_operands(BackendKind::Native, *scheme, &ops, 2, None)
                        .map_err(|e| format!("backend build failed: {e}"))?;
                for batch in &batches {
                    // One forward per overlay group, through the batched
                    // call boundary (contract: result[i] == run(groups[i])).
                    let groups = overlay_groups(batch);
                    let group_overlays: Vec<Vec<Overlay<'_>>> = groups
                        .iter()
                        .map(|members| request_overlays(&batch.requests[members[0]]))
                        .collect();
                    let group_refs: Vec<&[Overlay<'_>]> =
                        group_overlays.iter().map(|g| g.as_slice()).collect();
                    let outs = exe
                        .run_groups(&ops, &group_refs)
                        .map_err(|e| format!("group run failed: {e}"))?;
                    for (members, out) in groups.iter().zip(&outs) {
                        let got = bits(out);
                        let got_ok = ServePolicy::default().verify(out).ok;
                        for &mi in members {
                            let id = batch.requests[mi].id as usize;
                            let (want, want_ok) = &solo[sidx][id];
                            if got != *want {
                                return Err(format!(
                                    "request {id} ({scheme:?}): batched outputs are not \
                                     bit-identical to solo (batch of {}, group of {})",
                                    batch.len(),
                                    members.len()
                                ));
                            }
                            if got_ok != *want_ok {
                                return Err(format!(
                                    "request {id} ({scheme:?}): alarm decision changed \
                                     under batching: solo {want_ok} vs batched {got_ok}"
                                ));
                            }
                        }
                    }
                }
            }
            Ok(())
        },
        no_shrink,
    );
}

#[test]
fn prop_shedding_never_changes_admitted_outputs() {
    // Overload extension of the property above: with bounded admission
    // (tiny caps, random early rejection) the scheduler may shed
    // requests, but a shed request never appears in any batch and every
    // *admitted* request's logits and alarm decision stay bit-identical
    // to serving it alone — load shedding is invisible to the answers
    // that do go out.
    check(
        &Config {
            cases: 8,
            seed: 0x5EDD,
            ..Default::default()
        },
        gen_case,
        |case| {
            let graph = generate(&case.spec, case.graph_seed);
            let model = GcnModel::two_layer(&graph, 8, case.model_seed);
            let w1 = model.layers[0].weights.clone();
            let w2 = model.layers[1].weights.clone();
            let ops = if case.sparse {
                GcnOperands::sparse(
                    graph.features.clone(),
                    &model.adjacency,
                    w1,
                    w2,
                    case.bands,
                )
            } else {
                GcnOperands::dense(
                    graph.features.to_dense(),
                    model.adjacency.to_dense(),
                    w1,
                    w2,
                )
            }
            .map_err(|e| format!("operand build failed: {e}"))?;

            let mut rng = Pcg64::from_seed(case.traffic_seed ^ 0x5EED);
            let n_nodes = graph.num_nodes;
            let feat_dim = graph.feat_dim();
            let n_requests = 8 + rng.gen_index(8);
            let mut requests: Vec<InferenceRequest> = Vec::new();
            for id in 0..n_requests {
                let perturbations = (0..rng.gen_index(2))
                    .map(|_| Perturbation {
                        node: rng.gen_index(n_nodes),
                        features: (0..feat_dim)
                            .map(|_| rng.gen_f32_range(-4.0, 4.0))
                            .collect(),
                    })
                    .collect();
                let mut req = InferenceRequest::new(
                    id as u64,
                    vec![rng.gen_index(n_nodes)],
                    perturbations,
                )
                .with_priority(Priority::ALL[rng.gen_index(3)]);
                if rng.gen_bool(0.3) {
                    req = req.with_deadline(Duration::from_micros(rng.gen_range(2_000)));
                }
                requests.push(req);
            }

            // Solo references (fused scheme; the fused/split cross-check
            // is the first property's job).
            let exe =
                backend::for_operands(BackendKind::Native, ChecksumScheme::Fused, &ops, 2, None)
                    .map_err(|e| format!("backend build failed: {e}"))?;
            let mut solo = Vec::new();
            for req in &requests {
                let out = exe
                    .run(&ops, &request_overlays(req))
                    .map_err(|e| format!("solo run failed: {e}"))?;
                let ok = ServePolicy::default().verify(&out).ok;
                solo.push((bits(&out), ok));
            }

            let sched = Scheduler::new(
                VirtualClock::new(),
                BatchPolicy {
                    max_batch: case.max_batch,
                    max_wait: Duration::from_micros(case.max_wait_us),
                    starvation_factor: case.starvation_factor,
                    adaptive: None,
                    admission: Some(AdmissionControl {
                        total_cap: 1 + rng.gen_index(4),
                        class_caps: [usize::MAX; 3],
                        early_reject: rng.gen_bool(0.5),
                    }),
                },
            );
            let mut shed_ids: Vec<u64> = Vec::new();
            let mut batches = Vec::new();
            for req in &requests {
                for sh in sched.submit(req.clone()).into_shed() {
                    shed_ids.push(sh.req.id);
                }
                if rng.gen_bool(0.3) {
                    sched.record_service(Duration::from_micros(300 + rng.gen_range(1_500)));
                }
                if rng.gen_bool(0.5) {
                    sched
                        .clock()
                        .advance(Duration::from_micros(rng.gen_range(3_000)));
                }
                if rng.gen_bool(0.4) {
                    while let Some(b) = sched.poll() {
                        batches.push(b);
                    }
                }
            }
            sched.shutdown();
            while let Some(b) = sched.poll() {
                batches.push(b);
            }

            // Every request has exactly one fate, and a shed request
            // never executes.
            let mut executed: Vec<u64> = batches
                .iter()
                .flat_map(|b| b.requests.iter().map(|r| r.id))
                .collect();
            shed_ids.extend(batches.iter().flat_map(|b| b.shed.iter().map(|s| s.req.id)));
            let mut all: Vec<u64> = executed.iter().chain(&shed_ids).copied().collect();
            all.sort_unstable();
            let expect: Vec<u64> = (0..n_requests as u64).collect();
            if all != expect {
                return Err(format!("requests lost or double-fated: {all:?}"));
            }
            executed.sort_unstable();
            for id in &shed_ids {
                if executed.binary_search(id).is_ok() {
                    return Err(format!("request {id} was both shed and executed"));
                }
            }

            // Admitted members stay bit-identical to solo, shed or not.
            for batch in &batches {
                if batch.is_empty() {
                    continue; // pure rejection work: nothing executed
                }
                let groups = overlay_groups(batch);
                let group_overlays: Vec<Vec<Overlay<'_>>> = groups
                    .iter()
                    .map(|members| request_overlays(&batch.requests[members[0]]))
                    .collect();
                let group_refs: Vec<&[Overlay<'_>]> =
                    group_overlays.iter().map(|g| g.as_slice()).collect();
                let outs = exe
                    .run_groups(&ops, &group_refs)
                    .map_err(|e| format!("group run failed: {e}"))?;
                for (members, out) in groups.iter().zip(&outs) {
                    let got = (bits(out), ServePolicy::default().verify(out).ok);
                    for &mi in members {
                        let id = batch.requests[mi].id as usize;
                        if got != solo[id] {
                            return Err(format!(
                                "request {id}: shedding changed an admitted answer"
                            ));
                        }
                    }
                }
            }
            Ok(())
        },
        no_shrink,
    );
}
