//! Property: **the shard wire codec never panics and never lies.**
//!
//! `shard_proto` is the single frame codec both worker transports
//! (Unix-socket proc and TCP) speak, so its decode path sees every
//! byte an external peer can send. The properties pinned here:
//!
//! * round trip — any frame encodes then decodes bit-exactly (floats
//!   cross the wire as raw bit patterns, so checksum words survive);
//! * every truncation of a valid frame decodes to a typed
//!   [`FrameError`] (or `Ok(None)` at the empty boundary) — never a
//!   panic, never a silent partial decode;
//! * random bit flips decode to `Ok` or a typed error — never a panic;
//! * implausible header/payload length fields are rejected before any
//!   allocation is attempted.

use gcn_abft::coordinator::shard_proto::{
    encode_band_frame, encode_frame, parse_band_frame, push_f32s, push_f64s, read_frame,
    FrameError, Wire, MAX_HEADER_BYTES, MAX_PAYLOAD_BYTES,
};
use gcn_abft::runtime::RowBand;
use gcn_abft::sparse::Csr;
use gcn_abft::util::json::Json;
use gcn_abft::util::proptest::{check, no_shrink, Config};
use gcn_abft::util::rng::Pcg64;
use std::io::Cursor;

#[derive(Debug, Clone)]
struct FrameCase {
    seed: u64,
}

fn gen_case(rng: &mut Pcg64) -> FrameCase {
    FrameCase {
        seed: rng.next_u64(),
    }
}

/// A random but well-formed frame: agg-shaped header + float payload.
fn random_frame(rng: &mut Pcg64) -> (Json, Vec<u8>) {
    let n32 = rng.gen_index(40);
    let n64 = rng.gen_index(8);
    let mut payload = Vec::new();
    let f32s: Vec<f32> = (0..n32).map(|_| rng.gen_f32_range(-1e6, 1e6)).collect();
    let f64s: Vec<f64> = (0..n64).map(|_| rng.gen_f64_range(-1e12, 1e12)).collect();
    push_f32s(&mut payload, &f32s);
    push_f64s(&mut payload, &f64s);
    let header = Json::obj(vec![
        ("type", Json::from("agg")),
        ("shard", Json::from(rng.gen_index(8))),
        ("rows", Json::from(n32)),
        ("payload", Json::from(payload.len())),
    ]);
    (header, payload)
}

#[test]
fn prop_frames_round_trip_bit_exactly() {
    check(
        &Config {
            cases: 32,
            seed: 0xF4A3,
            ..Default::default()
        },
        gen_case,
        |case| {
            let mut rng = Pcg64::from_seed(case.seed);
            let (header, payload) = random_frame(&mut rng);
            let bytes = encode_frame(&header, &payload);
            let mut cur = Cursor::new(bytes);
            let (h, p) = read_frame(&mut cur)
                .map_err(|e| format!("decode of a valid frame failed: {e}"))?
                .ok_or("valid frame decoded as EOF")?;
            if h.to_string() != header.to_string() {
                return Err(format!("header drifted: {h} != {header}"));
            }
            if p != payload {
                return Err("payload bytes drifted through the codec".into());
            }
            // A second read on the drained cursor is a clean EOF.
            match read_frame(&mut cur) {
                Ok(None) => Ok(()),
                other => Err(format!("expected clean EOF, got {other:?}")),
            }
        },
        no_shrink,
    );
}

#[test]
fn prop_every_truncation_is_a_typed_error() {
    check(
        &Config {
            cases: 16,
            seed: 0x7121C,
            ..Default::default()
        },
        gen_case,
        |case| {
            let mut rng = Pcg64::from_seed(case.seed);
            let (header, payload) = random_frame(&mut rng);
            let bytes = encode_frame(&header, &payload);
            for cut in 0..bytes.len() {
                let mut cur = Cursor::new(&bytes[..cut]);
                match read_frame(&mut cur) {
                    // The empty prefix is a clean no-next-frame EOF.
                    Ok(None) if cut == 0 => {}
                    Ok(None) => {
                        return Err(format!(
                            "{cut}-byte truncation of a {}-byte frame read as a \
                             clean boundary",
                            bytes.len()
                        ));
                    }
                    Ok(Some(_)) => {
                        return Err(format!(
                            "{cut}-byte truncation of a {}-byte frame decoded as \
                             a whole frame",
                            bytes.len()
                        ));
                    }
                    // Typed failure — exactly the contract. read_exact
                    // on a short reader surfaces as Io(UnexpectedEof);
                    // a cut inside the length prefix as ClosedMidFrame.
                    Err(
                        FrameError::ClosedMidFrame
                        | FrameError::Io(_)
                        | FrameError::BadHeader(_),
                    ) => {}
                    Err(e) => {
                        return Err(format!("unexpected error class at cut {cut}: {e}"));
                    }
                }
            }
            Ok(())
        },
        no_shrink,
    );
}

#[test]
fn prop_bit_flips_never_panic() {
    check(
        &Config {
            cases: 24,
            seed: 0xB17F,
            ..Default::default()
        },
        gen_case,
        |case| {
            let mut rng = Pcg64::from_seed(case.seed);
            let (header, payload) = random_frame(&mut rng);
            let bytes = encode_frame(&header, &payload);
            for _ in 0..32 {
                let mut fuzzed = bytes.clone();
                let byte = rng.gen_index(fuzzed.len());
                let bit = rng.gen_index(8) as u32;
                fuzzed[byte] ^= 1u8 << bit;
                // Any outcome but a panic is acceptable; the assertion
                // is that this call returns.
                let _ = read_frame(&mut Cursor::new(fuzzed));
            }
            Ok(())
        },
        no_shrink,
    );
}

#[test]
fn implausible_length_fields_are_rejected() {
    // Header length beyond the ceiling (or zero) — typed, no allocation
    // of the claimed size is attempted.
    for hlen in [0u32, (MAX_HEADER_BYTES as u32) + 1, u32::MAX] {
        let mut bytes = hlen.to_le_bytes().to_vec();
        bytes.extend_from_slice(&[b'{'; 8]);
        match read_frame(&mut Cursor::new(bytes)) {
            Err(FrameError::BadHeaderLen(n)) => assert_eq!(n, hlen as usize),
            other => panic!("hlen {hlen}: expected BadHeaderLen, got {other:?}"),
        }
    }
    // Payload length field beyond the ceiling.
    let header = Json::obj(vec![
        ("type", Json::from("agg")),
        ("payload", Json::from(MAX_PAYLOAD_BYTES + 1)),
    ]);
    let bytes = encode_frame(&header, &[]);
    match read_frame(&mut Cursor::new(bytes)) {
        Err(FrameError::BadPayloadLen(n)) => assert_eq!(n, MAX_PAYLOAD_BYTES + 1),
        other => panic!("expected BadPayloadLen, got {other:?}"),
    }
}

#[test]
fn band_frames_round_trip_and_reject_bad_payloads() {
    let band = RowBand {
        row0: 3,
        s: Csr::from_raw_parts(
            2,
            5,
            vec![0, 2, 3],
            vec![0, 4, 2],
            vec![0.5f32, -1.25, 3.75],
        )
        .unwrap(),
        s_c: vec![0.5, 0.0, 3.75, 0.0, -1.25],
    };
    let bytes = encode_band_frame("init", 1, &band);
    let (hdr, body) = read_frame(&mut Cursor::new(bytes)).unwrap().unwrap();
    assert_eq!(hdr.get("type").and_then(Json::as_str), Some("init"));
    assert_eq!(hdr.get("row0").and_then(Json::as_usize), Some(3));
    let (rows, cols, got) = parse_band_frame(&hdr, &body).unwrap();
    assert_eq!((rows, cols), (2, 5));
    // The worker stores the band in local coordinates…
    assert_eq!(got.row0, 0);
    // …with every float bit-preserved.
    assert_eq!(
        got.s.values().iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        band.s.values().iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
    );
    assert_eq!(
        got.s_c.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        band.s_c.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
    );

    // Truncated body → Truncated; padded body → TrailingBytes.
    match parse_band_frame(&hdr, &body[..body.len() - 1]) {
        Err(FrameError::Truncated { .. }) => {}
        other => panic!("expected Truncated, got {other:?}"),
    }
    let mut padded = body.clone();
    padded.push(0);
    match parse_band_frame(&hdr, &padded) {
        Err(FrameError::TrailingBytes(1)) => {}
        other => panic!("expected TrailingBytes(1), got {other:?}"),
    }
    // A header that lies about its CSR shape → typed, never a panic.
    let lying = Json::obj(vec![
        ("type", Json::from("init")),
        ("rows", Json::from(7usize)),
        ("cols", Json::from(5usize)),
        ("nnz", Json::from(3usize)),
    ]);
    assert!(parse_band_frame(&lying, &body).is_err());
}

#[test]
fn wire_reader_is_exactly_sized() {
    let mut payload = Vec::new();
    push_f32s(&mut payload, &[1.0, 2.0]);
    let mut w = Wire(&payload);
    assert_eq!(w.f32s(2).unwrap(), vec![1.0, 2.0]);
    w.done().unwrap();
    // Asking for more than the buffer holds is Truncated.
    let mut short = Wire(&payload);
    match short.f32s(3) {
        Err(FrameError::Truncated { have, want }) => {
            assert_eq!(have, 8);
            assert_eq!(want, 12);
        }
        other => panic!("expected Truncated, got {other:?}"),
    }
}
