//! Determinism contract of the band-parallel instrumented backend: a
//! fixed-seed fault campaign reports **bit-identical detections** at any
//! band-worker count. This is the property that lets fault studies scale
//! across cores without losing reproducibility — the op-index timeline
//! is split at fixed logical-band prefix offsets, so a fault plan lands
//! on the same logical op serial or parallel. CI runs this test on
//! every push.

use gcn_abft::fault::{run_campaigns, CampaignConfig, FaultModelKind};
use gcn_abft::gcn::GcnModel;
use gcn_abft::graph::DatasetId;
use gcn_abft::runtime::{ChecksumScheme, InstrumentedEngine};

fn engine(seed: u64) -> InstrumentedEngine {
    let g = DatasetId::Tiny.build(seed);
    let m = GcnModel::two_layer(&g, 8, seed);
    InstrumentedEngine::from_model(&m, &g.features)
}

fn campaign_cfg(scheme: ChecksumScheme, model: FaultModelKind, workers: usize) -> CampaignConfig {
    CampaignConfig {
        scheme,
        fault_model: model,
        campaigns: 80,
        faults_per_campaign: 1,
        seed: 0xD37E,
        threads: 1,
        band_workers: workers,
        ..Default::default()
    }
}

#[test]
fn fixed_seed_campaign_is_bit_identical_across_workers() {
    let engine = engine(3);
    for scheme in [ChecksumScheme::Fused, ChecksumScheme::Split] {
        for model in [
            FaultModelKind::BitFlip,
            FaultModelKind::MultiBit { bits: 2 },
            FaultModelKind::StuckAt { duration: 1024 },
        ] {
            let serial = run_campaigns(&engine, &campaign_cfg(scheme, model, 1));
            for workers in [2, 4] {
                let parallel = run_campaigns(&engine, &campaign_cfg(scheme, model, workers));
                assert_eq!(
                    serial.per_threshold, parallel.per_threshold,
                    "{scheme:?}/{model:?}: detections changed at band_workers={workers}"
                );
                assert_eq!(serial.critical, parallel.critical, "{scheme:?}/{model:?}");
                assert_eq!(serial.class_critical, parallel.class_critical);
                assert_eq!(serial.data_faults, parallel.data_faults);
                assert_eq!(serial.checksum_faults, parallel.checksum_faults);
                assert_eq!(serial.timeline_ops, parallel.timeline_ops);
            }
        }
    }
}

#[test]
fn forward_outputs_and_hits_are_bit_identical_across_workers() {
    // Stronger than tally equality: the raw preactivations, check
    // records and fault hits of a single faulty forward must match bit
    // for bit.
    let engine = engine(11);
    let total = engine.timeline_ops(ChecksumScheme::Fused);
    let mut rng = gcn_abft::util::rng::Pcg64::from_seed(42);
    let events = FaultModelKind::BitFlip.sample(&mut rng, total, 4);
    let base = engine.forward(ChecksumScheme::Fused, &events, 1);
    assert_eq!(base.timeline_ops, total);
    for workers in [2, 3, 4, 16] {
        let par = engine.forward(ChecksumScheme::Fused, &events, workers);
        assert_eq!(base.hits, par.hits, "workers={workers}");
        assert_eq!(base.timeline_ops, par.timeline_ops);
        for (a, b) in base.preacts.iter().zip(&par.preacts) {
            assert!(a.identical(b), "workers={workers}: preacts diverged");
        }
        for (a, b) in base.checks.iter().zip(&par.checks) {
            assert_eq!(a.predicted.to_bits(), b.predicted.to_bits(), "workers={workers}");
            assert_eq!(a.actual.to_bits(), b.actual.to_bits(), "workers={workers}");
        }
    }
}
