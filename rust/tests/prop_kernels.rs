//! Per-lane-width bit-identity of the dispatched kernels — the property
//! that makes vectorization *free* for every other contract in the
//! tree.
//!
//! Two layers of pinning:
//! * the `*_with` per-lane primitives against the scalar reference on
//!   random lengths/values (exercised directly, no dispatch involved);
//! * the full hot-path operations (dense matmul, CSR spmm, the f64
//!   column-sum reduction, the f64 checksum row) under the *global*
//!   dispatch override [`kernels::force`] — the exact mechanism CI uses
//!   via `GCN_ABFT_KERNEL` — on random shapes including ragged tails.
//!
//! Plus the detection-side acceptance check: a fault-injection campaign
//! under `ChecksumScheme::Auto` reports detections identical to the
//! concrete scheme Auto resolves to — adaptive placement changes where
//! checks sit on the cost model, never what they catch.

use std::sync::Mutex;

use gcn_abft::fault::{run_campaigns, CampaignConfig, FaultModelKind};
use gcn_abft::gcn::GcnModel;
use gcn_abft::graph::DatasetId;
use gcn_abft::runtime::{ChecksumScheme, InstrumentedEngine};
use gcn_abft::sparse::Csr;
use gcn_abft::tensor::{kernels, ops, Dense};
use gcn_abft::util::proptest::{check, gen_dim, no_shrink, Config};
use gcn_abft::util::rng::Pcg64;

/// [`kernels::force`] is process-global (it must bind scoped band
/// workers), so tests that flip it serialize here and always restore
/// the environment dispatch before releasing the lock.
static FORCE_LOCK: Mutex<()> = Mutex::new(());

fn rand_vec_f32(rng: &mut Pcg64, len: usize) -> Vec<f32> {
    (0..len).map(|_| rng.gen_f32_range(-2.0, 2.0)).collect()
}

fn rand_dense(rng: &mut Pcg64, rows: usize, cols: usize) -> Dense {
    Dense::from_vec(rows, cols, rand_vec_f32(rng, rows * cols))
}

fn rand_csr(rng: &mut Pcg64, rows: usize, cols: usize, density: f64) -> Csr {
    let mut d = Dense::zeros(rows, cols);
    for r in 0..rows {
        for c in 0..cols {
            if rng.gen_bool(density) {
                d.set(r, c, rng.gen_f32_range(-1.0, 1.0));
            }
        }
    }
    Csr::from_dense(&d)
}

fn bits_f32(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

fn bits_f64(v: &[f64]) -> Vec<u64> {
    v.iter().map(|x| x.to_bits()).collect()
}

#[test]
fn prop_primitives_match_scalar_reference_on_random_lengths() {
    check(
        &Config {
            cases: 64,
            seed: 0x5EED_14E5,
            ..Default::default()
        },
        |rng: &mut Pcg64| {
            // Bias toward ragged tails around the 8-lane boundary.
            let len = match rng.gen_index(4) {
                0 => rng.gen_index(8),
                1 => 8 + rng.gen_index(8),
                _ => rng.gen_index(200),
            };
            let src = rand_vec_f32(rng, len);
            let base = rand_vec_f32(rng, len);
            let coeff = rng.gen_f32_range(-3.0, 3.0);
            (src, base, coeff)
        },
        |(src, base, coeff)| {
            let base_f64: Vec<f64> = base.iter().map(|&v| v as f64 * 1.0000001).collect();
            let mut ref_axpy = base.clone();
            kernels::axpy_f32_with(kernels::Lanes::Scalar, &mut ref_axpy, *coeff, src);
            let mut ref_wide = base_f64.clone();
            kernels::axpy_f32_to_f64_with(kernels::Lanes::Scalar, &mut ref_wide, *coeff as f64, src);
            let mut ref_col = base_f64.clone();
            kernels::col_acc_f64_with(kernels::Lanes::Scalar, &mut ref_col, src);
            for lanes in kernels::Lanes::ALL {
                let mut out = base.clone();
                kernels::axpy_f32_with(lanes, &mut out, *coeff, src);
                if bits_f32(&out) != bits_f32(&ref_axpy) {
                    return Err(format!("axpy_f32 {lanes:?} diverged at len {}", src.len()));
                }
                let mut acc = base_f64.clone();
                kernels::axpy_f32_to_f64_with(lanes, &mut acc, *coeff as f64, src);
                if bits_f64(&acc) != bits_f64(&ref_wide) {
                    return Err(format!(
                        "axpy_f32_to_f64 {lanes:?} diverged at len {}",
                        src.len()
                    ));
                }
                let mut acc = base_f64.clone();
                kernels::col_acc_f64_with(lanes, &mut acc, src);
                if bits_f64(&acc) != bits_f64(&ref_col) {
                    return Err(format!("col_acc_f64 {lanes:?} diverged at len {}", src.len()));
                }
            }
            Ok(())
        },
        no_shrink,
    );
}

#[test]
fn prop_full_ops_bit_identical_under_every_forced_dispatch() {
    let _guard = FORCE_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    check(
        &Config {
            cases: 24,
            seed: 0x5EED_14E6,
            ..Default::default()
        },
        |rng: &mut Pcg64| {
            let m = gen_dim(rng, 40);
            let k = gen_dim(rng, 40);
            let n = gen_dim(rng, 40);
            let a = rand_dense(rng, m, k);
            let b = rand_dense(rng, k, n);
            let s = rand_csr(rng, m, m, 0.15);
            let v = rand_vec_f32(rng, k);
            let threads = 1 + rng.gen_index(3);
            (a, b, s, v, threads)
        },
        |(a, b, s, v, threads)| {
            // Scalar dispatch is the reference for every full op.
            kernels::force(Some(kernels::Lanes::Scalar));
            let mm_ref = ops::matmul_par(a, b, *threads);
            let sp_ref = s.spmm_par(a, *threads);
            let col_ref = a.col_sums_f64();
            let vm_ref = ops::vecmat_f64(v, b);
            for lanes in kernels::Lanes::ALL {
                kernels::force(Some(lanes));
                let mm = ops::matmul_par(a, b, *threads);
                if bits_f32(mm.data()) != bits_f32(mm_ref.data()) {
                    kernels::force(None);
                    return Err(format!("matmul_par diverged under {lanes:?}"));
                }
                let sp = s.spmm_par(a, *threads);
                if bits_f32(sp.data()) != bits_f32(sp_ref.data()) {
                    kernels::force(None);
                    return Err(format!("spmm_par diverged under {lanes:?}"));
                }
                let col = a.col_sums_f64();
                if bits_f64(&col) != bits_f64(&col_ref) {
                    kernels::force(None);
                    return Err(format!("col_sums_f64 diverged under {lanes:?}"));
                }
                let vm = ops::vecmat_f64(v, b);
                if bits_f32(&vm) != bits_f32(&vm_ref) {
                    kernels::force(None);
                    return Err(format!("vecmat_f64 diverged under {lanes:?}"));
                }
            }
            kernels::force(None);
            Ok(())
        },
        no_shrink,
    );
    kernels::force(None);
}

fn campaign_cfg(scheme: ChecksumScheme) -> CampaignConfig {
    CampaignConfig {
        scheme,
        fault_model: FaultModelKind::BitFlip,
        campaigns: 120,
        faults_per_campaign: 1,
        seed: 0xA070_14E5,
        threads: 1,
        band_workers: 2,
        ..Default::default()
    }
}

#[test]
fn auto_scheme_campaign_detections_match_the_resolved_scheme() {
    let g = DatasetId::Tiny.build(3);
    let m = GcnModel::two_layer(&g, 8, 3);
    let engine = InstrumentedEngine::from_model(&m, &g.features);

    let fused = run_campaigns(&engine, &campaign_cfg(ChecksumScheme::Fused));
    let split = run_campaigns(&engine, &campaign_cfg(ChecksumScheme::Split));
    let auto = run_campaigns(&engine, &campaign_cfg(ChecksumScheme::Auto));

    // Auto resolves on the engine's own timeline accounting: the scheme
    // with the shorter checked timeline (= lower check-op cost).
    let resolved = if split.timeline_ops < fused.timeline_ops {
        &split
    } else {
        &fused
    };
    assert_eq!(
        auto.timeline_ops, resolved.timeline_ops,
        "auto must sample faults from the resolved scheme's timeline"
    );
    // Same seed + same timeline → the identical fault plan hits the
    // identical execution: detection is unchanged tally for tally.
    assert_eq!(auto.per_threshold, resolved.per_threshold);
    assert_eq!(auto.critical, resolved.critical);
    assert_eq!(auto.class_critical, resolved.class_critical);
    assert_eq!(auto.data_faults, resolved.data_faults);
    assert_eq!(auto.checksum_faults, resolved.checksum_faults);
    // And the decision is the cost argmin, not a coin flip.
    assert!(resolved.timeline_ops <= fused.timeline_ops.min(split.timeline_ops));
}

#[test]
fn forced_dispatch_does_not_change_campaign_detections() {
    // The instrumented engine stays scalar by design (its MAC-hook op
    // timeline is the product), but it *consumes* kernel outputs via
    // its operands' checksum state; a forced width must leave every
    // detection tally untouched.
    let _guard = FORCE_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let g = DatasetId::Tiny.build(5);
    let m = GcnModel::two_layer(&g, 8, 5);
    let engine = InstrumentedEngine::from_model(&m, &g.features);
    kernels::force(Some(kernels::Lanes::Scalar));
    let scalar = run_campaigns(&engine, &campaign_cfg(ChecksumScheme::Auto));
    kernels::force(Some(kernels::Lanes::X8));
    let x8 = run_campaigns(&engine, &campaign_cfg(ChecksumScheme::Auto));
    kernels::force(None);
    assert_eq!(scalar.per_threshold, x8.per_threshold);
    assert_eq!(scalar.timeline_ops, x8.timeline_ops);
    assert_eq!(scalar.critical, x8.critical);
}
