//! Deterministic scheduler-invariant tests on a [`VirtualClock`]: every
//! close decision — priority ordering within a batch window, the
//! deadline-triggered close, the starvation bound, the
//! already-expired-request edge, the shutdown drain, and every overload
//! shedding decision (bounded admission, priority eviction ordering,
//! deadline-aware early rejection) — is checked by advancing a virtual
//! clock and polling, with **zero real sleeps**. (The one blocking
//! `next_batch` call below exercises the drain path, which returns
//! without consulting time at all.)

use gcn_abft::coordinator::{
    AdaptiveWait, Admission, AdmissionControl, BatchPolicy, CloseReason, InferenceRequest,
    Priority, Scheduler, ShedReason, VirtualClock,
};
use gcn_abft::util::rng::Pcg64;
use std::time::Duration;

fn ms(x: u64) -> Duration {
    Duration::from_millis(x)
}

fn req(id: u64, priority: Priority) -> InferenceRequest {
    InferenceRequest::new(id, vec![0], vec![]).with_priority(priority)
}

fn sched(max_batch: usize, max_wait_ms: u64, k: u32) -> Scheduler<VirtualClock> {
    Scheduler::new(
        VirtualClock::new(),
        BatchPolicy {
            max_batch,
            max_wait: ms(max_wait_ms),
            starvation_factor: k,
            adaptive: None,
            admission: None,
        },
    )
}

fn capped(max_batch: usize, max_wait_ms: u64, ac: AdmissionControl) -> Scheduler<VirtualClock> {
    Scheduler::new(
        VirtualClock::new(),
        BatchPolicy {
            max_batch,
            max_wait: ms(max_wait_ms),
            starvation_factor: 4,
            adaptive: None,
            admission: Some(ac),
        },
    )
}

fn ids(b: &gcn_abft::coordinator::Batch) -> Vec<u64> {
    b.requests.iter().map(|r| r.id).collect()
}

#[test]
fn size_close_fires_without_any_time_passing() {
    let s = sched(3, 5, 4);
    s.submit(req(0, Priority::Interactive));
    s.submit(req(1, Priority::Interactive));
    assert!(s.poll().is_none(), "2 < max_batch and no deadline passed");
    s.submit(req(2, Priority::Interactive));
    let b = s.poll().expect("max_batch reached");
    assert_eq!(b.closed_by, CloseReason::Size);
    assert_eq!(ids(&b), vec![0, 1, 2]);
}

#[test]
fn deadline_close_tracks_the_oldest_waiter() {
    let s = sched(100, 5, 4);
    s.submit(req(0, Priority::Interactive));
    s.clock().advance(ms(3));
    s.submit(req(1, Priority::Interactive));
    assert!(s.poll().is_none(), "oldest waiter at 3 ms < 5 ms");
    s.clock().advance(ms(2));
    // Request 0 hits its hold deadline; the batch takes both waiters.
    let b = s.poll().expect("oldest waiter at 5 ms closes the batch");
    assert_eq!(b.closed_by, CloseReason::Deadline);
    assert_eq!(ids(&b), vec![0, 1]);
    assert!(s.poll().is_none(), "queue drained");
}

#[test]
fn priority_orders_members_within_a_batch_window() {
    let s = sched(8, 5, 4);
    s.submit(req(0, Priority::Background));
    s.submit(req(1, Priority::Batch));
    s.submit(req(2, Priority::Interactive));
    s.submit(req(3, Priority::Background));
    s.submit(req(4, Priority::Interactive));
    s.clock().advance(ms(5));
    let b = s.poll().unwrap();
    assert_eq!(b.closed_by, CloseReason::Deadline);
    assert_eq!(
        ids(&b),
        vec![2, 4, 1, 0, 3],
        "priority rank first, FIFO within each rank"
    );
}

#[test]
fn size_pressure_defers_low_priority_to_the_next_batch() {
    let s = sched(2, 5, 4);
    s.submit(req(0, Priority::Background));
    s.submit(req(1, Priority::Interactive));
    s.submit(req(2, Priority::Interactive));
    let b = s.poll().unwrap();
    assert_eq!(b.closed_by, CloseReason::Size);
    assert_eq!(ids(&b), vec![1, 2], "interactive wins the size-closed batch");
    assert_eq!(s.pending(), 1);
    // The deferred background request is not dropped: it closes alone
    // once its own hold deadline passes.
    s.clock().advance(ms(5));
    let b = s.poll().unwrap();
    assert_eq!(b.closed_by, CloseReason::Deadline);
    assert_eq!(ids(&b), vec![0]);
}

#[test]
fn starvation_bound_holds_under_interactive_flood() {
    // max_wait 5 ms, K = 3 → no request may wait in the admission queue
    // past 15 ms, no matter how much interactive pressure arrives.
    let (max_wait_ms, k) = (5u64, 3u32);
    let s = sched(2, max_wait_ms, k);
    s.submit(req(999, Priority::Background));
    let submitted_at = s.clock().now();

    let mut next_id = 0u64;
    let mut included_at = None;
    // Flood: three fresh interactive requests per millisecond against a
    // drain rate of one size-closed batch of two — the admission queue
    // always holds more interactive work than a batch can take, so the
    // background request keeps losing the priority cut until the
    // starvation bound promotes it.
    for step in 0..40 {
        for _ in 0..3 {
            s.submit(req(next_id, Priority::Interactive));
            next_id += 1;
        }
        let b = s.poll().expect("flooded queue always closes by size");
        if b.requests.iter().any(|r| r.id == 999) {
            assert_eq!(
                b.closed_by,
                CloseReason::Starvation,
                "a promoted member marks the batch"
            );
            included_at = Some(s.clock().now());
            break;
        }
        assert!(
            step < 39,
            "background request never included under flood"
        );
        s.clock().advance(ms(1));
    }

    let waited = included_at.unwrap().since(submitted_at);
    let bound = ms(max_wait_ms * k as u64);
    assert!(
        waited <= bound,
        "waited {waited:?} past the starvation bound {bound:?}"
    );
    assert!(s.stats().starvation_promotions >= 1);
}

#[test]
fn already_expired_request_closes_immediately() {
    // The old `next_batch` idle-spin edge: a batch whose first member
    // arrived already past its deadline still waited out a full
    // `recv_timeout`. With deadlines anchored at arrival, a zero hold
    // budget closes at the admission tick — no clock advance needed.
    let s = sched(8, 5, 4);
    s.submit(req(0, Priority::Interactive).with_deadline(Duration::ZERO));
    let b = s.poll().expect("expired member must close the batch now");
    assert_eq!(b.closed_by, CloseReason::Deadline);
    assert_eq!(ids(&b), vec![0]);

    // The expired member also pulls already-queued fresh requests into
    // the same pass instead of leaving them to wait out their window.
    s.submit(req(1, Priority::Interactive));
    assert!(s.poll().is_none());
    s.submit(req(2, Priority::Interactive).with_deadline(Duration::ZERO));
    let b = s.poll().unwrap();
    assert_eq!(b.closed_by, CloseReason::Deadline);
    assert_eq!(b.len(), 2);
}

#[test]
fn expired_explicit_deadline_is_promoted_under_size_pressure() {
    // A caller-declared deadline is honored in member *selection*, not
    // only in close timing: once it expires, size pressure from
    // higher-priority traffic can no longer exclude the request (the
    // starvation bound — 500 ms here — is not what saves it).
    let s = sched(2, 5, 100);
    s.submit(req(9, Priority::Background).with_deadline(ms(1)));
    s.submit(req(0, Priority::Interactive));
    s.submit(req(1, Priority::Interactive));
    // Budget not yet spent: the size close picks the interactive pair.
    let b = s.poll().unwrap();
    assert_eq!(b.closed_by, CloseReason::Size);
    assert_eq!(ids(&b), vec![0, 1]);
    // Budget expired: the next size close must take the request even
    // though fresh interactive arrivals would otherwise fill the batch.
    s.clock().advance(ms(1));
    s.submit(req(2, Priority::Interactive));
    s.submit(req(3, Priority::Interactive));
    let b = s.poll().unwrap();
    assert!(
        b.requests.iter().any(|r| r.id == 9),
        "expired-deadline member must be force-included: {:?}",
        ids(&b)
    );
    assert_eq!(
        b.closed_by,
        CloseReason::Size,
        "deadline promotion keeps the close reason (Starvation is for the bound)"
    );
    assert_eq!(s.stats().starvation_promotions, 1);
}

#[test]
fn loose_deadline_does_not_jump_priority_before_it_expires() {
    // The promotion condition is the *declared* deadline, not the
    // max_wait-capped hold budget: a Background request with a generous
    // 100 ms deadline must keep losing the priority cut long after
    // max_wait (5 ms) — otherwise any deadline-bearing bulk request
    // would preempt interactive traffic after just max_wait.
    let s = sched(2, 5, 1_000); // starvation bound 5 s: out of the picture
    s.submit(req(9, Priority::Background).with_deadline(ms(100)));
    for step in 0..20u64 {
        s.submit(req(step * 2, Priority::Interactive));
        s.submit(req(step * 2 + 1, Priority::Interactive));
        let b = s.poll().expect("size pressure closes every wave");
        assert!(
            !b.requests.iter().any(|r| r.id == 9),
            "loose deadline jumped priority at t = {step} ms"
        );
        s.clock().advance(ms(1));
    }
    // Once the declared budget expires, the next close takes it.
    s.clock().advance(ms(80)); // t = 100 ms
    s.submit(req(1_000, Priority::Interactive));
    s.submit(req(1_001, Priority::Interactive));
    let b = s.poll().unwrap();
    assert!(
        b.requests.iter().any(|r| r.id == 9),
        "expired declared deadline must promote: {:?}",
        b.requests.iter().map(|r| r.id).collect::<Vec<_>>()
    );
}

#[test]
fn per_request_deadline_tightens_the_window() {
    let s = sched(8, 10, 4);
    s.submit(req(0, Priority::Interactive).with_deadline(ms(2)));
    s.clock().advance(ms(1));
    assert!(s.poll().is_none());
    s.clock().advance(ms(1));
    let b = s.poll().expect("2 ms request deadline beats 10 ms max_wait");
    assert_eq!(b.closed_by, CloseReason::Deadline);
    // A deadline looser than max_wait is capped by the policy.
    s.submit(req(1, Priority::Interactive).with_deadline(ms(60_000)));
    s.clock().advance(ms(10));
    let b = s.poll().expect("policy max_wait still applies");
    assert_eq!(ids(&b), vec![1]);
}

#[test]
fn shutdown_drains_cleanly_and_then_yields_none() {
    let s = sched(4, 1_000_000, 4); // deadline far away: only drain closes
    for i in 0..6 {
        s.submit(req(i, Priority::Interactive));
    }
    s.shutdown();
    // First close is by size (6 > 4), the leftover pair by drain; the
    // blocking next_batch calls return immediately in both cases.
    let b = s.next_batch().unwrap();
    assert_eq!(b.closed_by, CloseReason::Size);
    assert_eq!(b.len(), 4);
    let b = s.next_batch().unwrap();
    assert_eq!(b.closed_by, CloseReason::Drain);
    assert_eq!(b.len(), 2);
    assert!(s.next_batch().is_none(), "drained scheduler yields None");
    assert!(s.next_batch().is_none(), "... and stays drained");
    assert_eq!(s.stats().submitted, 6);
    assert_eq!(s.stats().batches, 2);
}

#[test]
fn random_schedules_lose_and_duplicate_nothing() {
    // Mini-property on the virtual clock: under random arrival orders,
    // priorities, deadlines and poll interleavings, every submitted
    // request is emitted exactly once, no batch exceeds max_batch, and
    // members never outstay the starvation bound while polls keep
    // happening.
    let mut rng = Pcg64::from_seed(0x5CED);
    for case in 0..50 {
        let max_batch = 1 + rng.gen_index(5);
        let max_wait = 1 + rng.gen_index(8) as u64;
        let k = 1 + rng.gen_index(4) as u32;
        let s = sched(max_batch, max_wait, k);
        let n = 5 + rng.gen_index(20) as u64;

        let mut emitted: Vec<u64> = Vec::new();
        let mut check_batch = |b: &gcn_abft::coordinator::Batch| {
            assert!(b.len() <= max_batch, "case {case}: oversized batch");
            assert!(!b.is_empty(), "case {case}: empty batch emitted");
            emitted.extend(b.requests.iter().map(|r| r.id));
        };

        for id in 0..n {
            let priority = Priority::ALL[rng.gen_index(3)];
            let mut r = req(id, priority);
            if rng.gen_bool(0.2) {
                r = r.with_deadline(Duration::from_millis(rng.gen_range(6)));
            }
            s.submit(r);
            if rng.gen_bool(0.5) {
                s.clock().advance(Duration::from_micros(rng.gen_range(3000)));
            }
            if rng.gen_bool(0.4) {
                while let Some(b) = s.poll() {
                    check_batch(&b);
                }
            }
        }
        s.shutdown();
        while let Some(b) = s.poll() {
            check_batch(&b);
        }
        assert!(s.poll().is_none());

        emitted.sort_unstable();
        let expect: Vec<u64> = (0..n).collect();
        assert_eq!(emitted, expect, "case {case}: requests lost or duplicated");
        assert_eq!(s.stats().submitted, n);
    }
}

#[test]
fn adaptive_wait_ewma_is_pinned_on_the_virtual_clock() {
    // --adaptive-wait: the hold budget is ewma(interarrival) ×
    // (max_batch − 1), clamped to [min_wait, max_wait]. Every update is
    // deterministic on the virtual clock, so the exact EWMA values are
    // pinned here.
    let s = Scheduler::new(
        VirtualClock::new(),
        BatchPolicy {
            max_batch: 4,
            max_wait: ms(20),
            starvation_factor: 4,
            adaptive: Some(AdaptiveWait {
                alpha: 0.25,
                min_wait: ms(1),
            }),
            admission: None,
        },
    );
    // No interval observed yet: the configured ceiling governs.
    assert_eq!(s.effective_wait(), ms(20));
    s.submit(req(0, Priority::Interactive));
    assert_eq!(s.effective_wait(), ms(20));
    // First gap seeds the EWMA: 4 ms → budget 4 × 3 = 12 ms.
    s.clock().advance(ms(4));
    s.submit(req(1, Priority::Interactive));
    assert_eq!(s.effective_wait(), ms(12));
    // Second gap folds in: 0.25·8 + 0.75·4 = 5 ms → 15 ms.
    s.clock().advance(ms(8));
    s.submit(req(2, Priority::Interactive));
    assert_eq!(s.effective_wait(), ms(15));
    // A long quiet period pushes the raw budget past the ceiling — it
    // clamps to max_wait, so the worst case never regresses.
    s.clock().advance(ms(4000));
    s.submit(req(3, Priority::Interactive));
    assert_eq!(s.effective_wait(), ms(20));
    // Size close still wins over any budget: max_batch reached.
    let b = s.poll().expect("four queued requests close by size");
    assert_eq!(b.closed_by, CloseReason::Size);
    assert_eq!(b.len(), 4);
}

#[test]
fn total_cap_sheds_from_the_bottom_up() {
    let s = capped(
        3,
        1_000,
        AdmissionControl {
            total_cap: 3,
            ..Default::default()
        },
    );
    assert!(s.submit(req(0, Priority::Background)).is_admitted());
    assert!(s.submit(req(1, Priority::Batch)).is_admitted());
    assert!(s.submit(req(2, Priority::Interactive)).is_admitted());

    // Queue full: an Interactive arrival evicts Background first.
    let out = s.submit(req(3, Priority::Interactive));
    assert!(out.is_admitted());
    let evicted: Vec<(u64, ShedReason)> =
        out.evicted.iter().map(|e| (e.req.id, e.reason)).collect();
    assert_eq!(evicted, vec![(0, ShedReason::Evicted)], "Background sheds first");

    // Still full: the next eviction reaches into Batch — bottom-up.
    let out = s.submit(req(4, Priority::Interactive));
    assert!(out.is_admitted());
    assert_eq!(out.evicted[0].req.id, 1, "Batch sheds once Background is gone");

    // An all-Interactive queue is never preempted for a peer: the
    // arrival itself is refused instead.
    let out = s.submit(req(5, Priority::Interactive));
    assert!(out.evicted.is_empty(), "a peer never evicts a peer");
    match out.admission {
        Admission::Shed(sh) => {
            assert_eq!(sh.req.id, 5);
            assert_eq!(sh.reason, ShedReason::QueueFull);
        }
        Admission::Admitted => panic!("full queue of peers must refuse the arrival"),
    }

    assert_eq!(s.stats().shed, [1, 1, 1]);
    let b = s.poll().expect("max_batch reached");
    assert_eq!(b.closed_by, CloseReason::Size);
    assert_eq!(ids(&b), vec![2, 3, 4], "only admitted requests execute");
    assert!(b.shed.is_empty());
}

#[test]
fn unmeetable_deadline_is_refused_at_admission() {
    let s = capped(
        2,
        1_000,
        AdmissionControl {
            total_cap: 64,
            early_reject: true,
            ..Default::default()
        },
    );
    // Before any service-time signal, nothing is provably unmeetable.
    let r = req(0, Priority::Interactive).with_deadline(ms(1));
    assert!(s.submit(r).is_admitted());
    assert!(s.submit(req(1, Priority::Interactive)).is_admitted());
    assert_eq!(ids(&s.poll().expect("size close")), vec![0, 1]);

    // Executors report a 10 ms service time; the EWMA seeds directly.
    s.record_service(ms(10));
    assert_eq!(s.ewma_service(), Some(ms(10)));

    // Empty queue still means one full service time ahead: a 5 ms
    // budget cannot be met, so the request is refused at admission.
    let out = s.submit(req(2, Priority::Interactive).with_deadline(ms(5)));
    match out.admission {
        Admission::Shed(sh) => assert_eq!(sh.reason, ShedReason::DeadlineUnmeetable),
        Admission::Admitted => panic!("5 ms budget cannot survive a 10 ms service time"),
    }

    // A 15 ms budget clears one batch. Queue depth feeds the estimate:
    // the third peer would ride the *second* size-2 batch (20 ms of
    // service ahead), so the same budget is now refused.
    assert!(s.submit(req(3, Priority::Interactive).with_deadline(ms(15))).is_admitted());
    assert!(s.submit(req(4, Priority::Interactive).with_deadline(ms(15))).is_admitted());
    let out = s.submit(req(5, Priority::Interactive).with_deadline(ms(15)));
    assert!(!out.is_admitted(), "queue depth feeds the estimate");

    // Requests that declare no deadline are never early-rejected.
    assert!(s.submit(req(6, Priority::Interactive)).is_admitted());
    assert_eq!(s.stats().shed, [2, 0, 0]);
}

#[test]
fn expired_members_are_shed_at_close_not_executed_late() {
    let s = capped(
        8,
        5,
        AdmissionControl {
            total_cap: 64,
            early_reject: true,
            ..Default::default()
        },
    );
    let r = req(0, Priority::Interactive).with_deadline(ms(2));
    assert!(s.submit(r).is_admitted());
    assert!(s.submit(req(1, Priority::Interactive)).is_admitted());
    s.clock().advance(ms(2));
    // Request 0's budget is spent the moment the window closes: it is
    // handed back in `Batch::shed` and never executes, while the fresh
    // member still rides. (Without `early_reject` the same expiry
    // *promotes* — pinned by the tests above.)
    let b = s.poll().expect("expired deadline closes the window");
    assert_eq!(b.closed_by, CloseReason::Deadline);
    assert_eq!(ids(&b), vec![1]);
    assert_eq!(b.shed.len(), 1);
    assert_eq!(b.shed[0].req.id, 0);
    assert_eq!(b.shed[0].reason, ShedReason::DeadlineUnmeetable);
    assert_eq!(s.stats().shed, [1, 0, 0]);
    assert_eq!(s.stats().batches, 1);

    // An all-expired queue closes into pure rejection work: no members,
    // no forward, no batch counted — but the queue still drains.
    let r = req(2, Priority::Interactive).with_deadline(Duration::ZERO);
    assert!(s.submit(r).is_admitted());
    let b = s.poll().expect("an unmeetable member still closes");
    assert!(b.is_empty());
    assert_eq!(b.shed[0].req.id, 2);
    assert_eq!(s.stats().batches, 1, "pure rejection work is not a batch");
    assert!(s.poll().is_none(), "queue fully drained");
}

#[test]
fn overload_conserves_every_request_exactly_once() {
    // Under bounded admission every submitted request has exactly one
    // fate: refused at admission, evicted by policy, shed at close for
    // an unmeetable deadline (each with a `Shed` hand-back), or
    // executed as a batch member — never lost, never duplicated, and
    // never shed after admission except through those policy paths.
    let mut rng = Pcg64::from_seed(0x0BED);
    for case in 0..50 {
        let max_batch = 1 + rng.gen_index(4);
        let s = capped(
            max_batch,
            1 + rng.gen_index(8) as u64,
            AdmissionControl {
                total_cap: 1 + rng.gen_index(6),
                class_caps: [usize::MAX, usize::MAX, 1 + rng.gen_index(3)],
                early_reject: rng.gen_bool(0.5),
            },
        );
        let n = 10 + rng.gen_index(30) as u64;
        let mut executed: Vec<u64> = Vec::new();
        let mut shed_ids: Vec<u64> = Vec::new();
        for id in 0..n {
            let mut r = req(id, Priority::ALL[rng.gen_index(3)]);
            if rng.gen_bool(0.3) {
                r = r.with_deadline(Duration::from_millis(rng.gen_range(6)));
            }
            for sh in s.submit(r).into_shed() {
                shed_ids.push(sh.req.id);
            }
            if rng.gen_bool(0.3) {
                s.record_service(Duration::from_micros(200 + rng.gen_range(2_000)));
            }
            if rng.gen_bool(0.5) {
                s.clock().advance(Duration::from_micros(rng.gen_range(3_000)));
            }
            if rng.gen_bool(0.4) {
                while let Some(b) = s.poll() {
                    assert!(b.len() <= max_batch, "case {case}: oversized batch");
                    executed.extend(b.requests.iter().map(|r| r.id));
                    shed_ids.extend(b.shed.iter().map(|sh| sh.req.id));
                }
            }
        }
        s.shutdown();
        while let Some(b) = s.poll() {
            assert!(b.len() <= max_batch, "case {case}: oversized batch");
            executed.extend(b.requests.iter().map(|r| r.id));
            shed_ids.extend(b.shed.iter().map(|sh| sh.req.id));
        }
        let mut all: Vec<u64> = executed.iter().chain(&shed_ids).copied().collect();
        all.sort_unstable();
        let expect: Vec<u64> = (0..n).collect();
        assert_eq!(all, expect, "case {case}: a request was lost or double-fated");
        assert_eq!(s.stats().shed_total(), shed_ids.len() as u64, "case {case}");
        assert_eq!(s.stats().submitted, n, "case {case}");
    }
}
