//! Coordinator integration: full serving pipeline — batching,
//! verification, fault injection + recovery, metrics. Runs on the native
//! runtime backend, so no artifacts are required; when
//! `artifacts/manifest.json` exists the same path additionally validates
//! shapes against it.

use gcn_abft::coordinator::{serve_synthetic, BatchPolicy, ServerConfig, VerifyStatus};
use gcn_abft::graph::DatasetId;
use gcn_abft::runtime::{BackendKind, ChecksumScheme, ExecMode, OperandPlan};

fn base_cfg() -> ServerConfig {
    ServerConfig {
        dataset: DatasetId::Tiny,
        artifacts_dir: "artifacts".into(),
        batch: BatchPolicy {
            max_batch: 4,
            ..Default::default()
        },
        workers: 2,
        inject_every: None,
        seed: 7,
        ..Default::default()
    }
}

#[test]
fn clean_serving_answers_every_request() {
    let s = serve_synthetic(&base_cfg(), 40).unwrap();
    assert_eq!(s.responses, 40);
    assert_eq!(s.metrics.requests, 40);
    assert_eq!(s.clean, 40, "{s:?}");
    assert_eq!(s.failed, 0);
    assert_eq!(s.metrics.checks_fired, 0, "no faults -> no alarms");
    assert!(s.metrics.batches >= 10); // 40 requests / max_batch 4
    assert!(s.metrics.p50_secs > 0.0 && s.metrics.p99_secs >= s.metrics.p50_secs);
}

#[test]
fn injected_faults_are_detected_and_recovered() {
    let mut cfg = base_cfg();
    cfg.inject_every = Some(2); // every 2nd batch corrupted
    let s = serve_synthetic(&cfg, 32).unwrap();
    assert!(s.metrics.injected_faults > 0);
    assert_eq!(
        s.metrics.checks_fired, s.metrics.injected_faults,
        "every injected corruption must fire exactly one check: {s:?}"
    );
    assert_eq!(s.failed, 0, "retries must recover: {s:?}");
    assert!(s.recovered > 0);
    // Retried batches re-executed: executions > batches.
    assert!(s.metrics.executions > s.metrics.batches);
}

#[test]
fn single_worker_is_deterministic_in_counts() {
    let mut cfg = base_cfg();
    cfg.workers = 1;
    let a = serve_synthetic(&cfg, 24).unwrap();
    let b = serve_synthetic(&cfg, 24).unwrap();
    assert_eq!(a.metrics.requests, b.metrics.requests);
    assert_eq!(a.clean, b.clean);
}

#[test]
fn verify_status_taxonomy_is_consistent() {
    let mut cfg = base_cfg();
    cfg.inject_every = Some(3);
    let s = serve_synthetic(&cfg, 30).unwrap();
    assert_eq!(s.clean + s.recovered + s.failed, s.responses);
    let _ = VerifyStatus::Clean; // type is part of the public API
}

#[test]
fn instrumented_backend_serves_and_verifies() {
    // --backend instrumented: the MAC-level f64 engine behind the same
    // coordinator; fault-free passes must verify under both schemes.
    for scheme in [ChecksumScheme::Fused, ChecksumScheme::Split] {
        let mut cfg = base_cfg();
        cfg.backend = BackendKind::Instrumented;
        cfg.scheme = scheme;
        let s = serve_synthetic(&cfg, 16).unwrap();
        assert_eq!(s.backend, "instrumented");
        assert_eq!(s.scheme, scheme.name());
        assert_eq!(s.responses, 16);
        assert_eq!(s.clean, 16, "{s:?}");
        assert_eq!(s.metrics.checks_fired, 0, "fault-free must not alarm");
    }
}

#[test]
fn auto_scheme_serves_as_a_concrete_scheme() {
    // --scheme auto: the coordinator resolves to the measured check-op
    // argmin before serving; the summary and metrics report the
    // concrete decision, never "auto", and detection still works.
    let mut cfg = base_cfg();
    cfg.scheme = ChecksumScheme::Auto;
    cfg.inject_every = Some(3);
    let s = serve_synthetic(&cfg, 24).unwrap();
    assert_ne!(s.scheme, "auto", "a requested auto must resolve: {s:?}");
    assert_eq!(s.scheme, s.metrics.scheme);
    assert!(!s.metrics.kernel.is_empty(), "kernel dispatch recorded");
    assert!(s.metrics.injected_faults > 0);
    assert_eq!(
        s.metrics.checks_fired, s.metrics.injected_faults,
        "auto must detect exactly like its resolved scheme: {s:?}"
    );
    assert_eq!(s.failed, 0, "retries must recover: {s:?}");
}

#[test]
fn split_scheme_detects_and_recovers_on_native_backend() {
    // The split baseline is selectable at the API and its four check
    // points drive the same detect→retry→release loop.
    let mut cfg = base_cfg();
    cfg.scheme = ChecksumScheme::Split;
    cfg.inject_every = Some(2);
    let s = serve_synthetic(&cfg, 24).unwrap();
    assert_eq!(s.scheme, "split");
    assert!(s.metrics.injected_faults > 0);
    assert_eq!(
        s.metrics.checks_fired, s.metrics.injected_faults,
        "every injected corruption must fire exactly one check: {s:?}"
    );
    assert_eq!(s.failed, 0, "retries must recover: {s:?}");
    assert!(s.recovered > 0);
}

#[test]
fn mixed_priority_serving_reports_per_class_percentiles() {
    // Priority-aware continuous batching: a mixed open-loop arrival
    // stream still answers everything cleanly, and the per-priority
    // latency percentiles land in ServeMetrics (indexed by rank).
    let mut cfg = base_cfg();
    cfg.priority_mix = [0.5, 0.3, 0.2];
    let s = serve_synthetic(&cfg, 60).unwrap();
    assert_eq!(s.responses, 60);
    assert_eq!(s.clean, 60, "{s:?}");
    let m = &s.metrics;
    let classed: u64 = m.by_priority.iter().map(|p| p.requests).sum();
    assert_eq!(classed, 60, "every request lands in exactly one class");
    assert!(
        m.by_priority.iter().filter(|p| p.requests > 0).count() >= 2,
        "the mix must actually produce multiple classes: {:?}",
        m.by_priority
    );
    for p in m.by_priority.iter().filter(|p| p.requests > 0) {
        assert!(p.p50_secs > 0.0 && p.p99_secs >= p.p50_secs, "{p:?}");
    }
    // Overlay-equivalence grouping: batches of coalesced requests run
    // at least one forward per batch, and the group count is what the
    // execution tally is based on.
    assert!(m.overlay_groups >= m.batches);
    assert!(m.executions >= m.overlay_groups);
}

#[test]
fn single_priority_runs_keep_other_classes_empty() {
    let s = serve_synthetic(&base_cfg(), 24).unwrap();
    let m = &s.metrics;
    assert_eq!(m.by_priority[0].requests, 24, "default mix is all-interactive");
    assert_eq!(m.by_priority[1].requests, 0);
    assert_eq!(m.by_priority[2].requests, 0);
    assert!(m.by_priority[1].p50_secs.is_nan());
}

#[test]
fn pjrt_backend_refuses_cleanly_without_the_feature() {
    #[cfg(not(feature = "pjrt"))]
    {
        let mut cfg = base_cfg();
        cfg.backend = BackendKind::Pjrt;
        let err = serve_synthetic(&cfg, 4).unwrap_err();
        assert!(
            format!("{err:#}").contains("pjrt"),
            "unexpected error: {err:#}"
        );
    }
}

#[test]
fn pubmed_reduced_scale_serves_on_sparse_operands() {
    // Before sparse-aware serving this dataset was refused up front
    // (the dense path would have needed a ~1.5 GB S at full scale).
    let cfg = ServerConfig {
        dataset: DatasetId::Pubmed,
        scale: 0.05,
        mode: ExecMode::Sparse,
        workers: 3,
        train_epochs: 3,
        batch: BatchPolicy {
            max_batch: 4,
            ..Default::default()
        },
        ..Default::default()
    };
    let s = serve_synthetic(&cfg, 24).unwrap();
    assert!(s.sparse, "forced-sparse run must use CSR operands");
    assert_eq!(s.bands, 3, "S sharded into one row band per worker");
    assert_eq!(s.responses, 24);
    assert_eq!(s.clean, 24, "{s:?}");
    assert_eq!(s.failed, 0);
    assert_eq!(s.metrics.checks_fired, 0, "fault-free passes must not alarm");
    // Percentiles now live in ServeMetrics directly and must have been
    // aggregated across the row-band topology.
    assert!(s.metrics.p50_secs > 0.0);
    assert!(s.metrics.p99_secs >= s.metrics.p50_secs);
}

#[test]
fn sparse_path_detects_and_recovers_injected_faults() {
    let cfg = ServerConfig {
        dataset: DatasetId::Pubmed,
        scale: 0.03,
        mode: ExecMode::Sparse,
        workers: 2,
        train_epochs: 2,
        inject_every: Some(2),
        batch: BatchPolicy {
            max_batch: 4,
            ..Default::default()
        },
        ..Default::default()
    };
    let s = serve_synthetic(&cfg, 16).unwrap();
    assert!(s.metrics.injected_faults > 0);
    assert_eq!(
        s.metrics.checks_fired, s.metrics.injected_faults,
        "every injected corruption must fire exactly one check: {s:?}"
    );
    assert_eq!(s.failed, 0, "retries must recover: {s:?}");
    assert!(s.recovered > 0);
}

#[test]
fn nell_reduced_scale_serves_on_sparse_operands() {
    let cfg = ServerConfig {
        dataset: DatasetId::Nell,
        scale: 0.02,
        mode: ExecMode::Sparse,
        workers: 2,
        train_epochs: 1,
        ..Default::default()
    };
    let s = serve_synthetic(&cfg, 8).unwrap();
    assert!(s.sparse);
    assert_eq!(s.responses, 8);
    assert_eq!(s.failed, 0);
    assert_eq!(s.metrics.checks_fired, 0, "{s:?}");
}

#[test]
fn full_scale_pubmed_and_nell_plan_sparse_under_default_budget() {
    // Plan-only (no graph build): the operand-memory estimate that
    // replaced the hard-coded dataset refusal. nnz figures are the
    // synthetic-spec statistics (S nnz = 2E + N).
    for (n, f, s_nnz, feat_nnz) in [
        (19_717usize, 500usize, 108_393usize, 988_031usize), // pubmed
        (65_755, 5414, 598_043, 32_300_000),                 // nell
    ] {
        let plan =
            OperandPlan::choose(n, f, s_nnz, feat_nnz, ExecMode::Auto, 512 << 20).unwrap();
        assert!(plan.sparse, "auto must pick CSR for n={n}: {plan:?}");
        assert!(
            OperandPlan::choose(n, f, s_nnz, feat_nnz, ExecMode::Dense, 512 << 20).is_err(),
            "forcing dense at n={n} must refuse, not OOM"
        );
    }
    // Cora still densifies under the same budget.
    let plan =
        OperandPlan::choose(2708, 1433, 13_566, 49_216, ExecMode::Auto, 512 << 20).unwrap();
    assert!(!plan.sparse);
}
