//! Coordinator integration: full serving pipeline — batching,
//! verification, fault injection + recovery, metrics. Runs on the native
//! runtime backend, so no artifacts are required; when
//! `artifacts/manifest.json` exists the same path additionally validates
//! shapes against it.

use gcn_abft::coordinator::{serve_synthetic, BatchPolicy, ServerConfig, VerifyStatus};
use gcn_abft::graph::DatasetId;

fn base_cfg() -> ServerConfig {
    ServerConfig {
        dataset: DatasetId::Tiny,
        artifacts_dir: "artifacts".into(),
        batch: BatchPolicy {
            max_batch: 4,
            ..Default::default()
        },
        workers: 2,
        inject_every: None,
        seed: 7,
        ..Default::default()
    }
}

#[test]
fn clean_serving_answers_every_request() {
    let s = serve_synthetic(&base_cfg(), 40).unwrap();
    assert_eq!(s.responses, 40);
    assert_eq!(s.metrics.requests, 40);
    assert_eq!(s.clean, 40, "{s:?}");
    assert_eq!(s.failed, 0);
    assert_eq!(s.metrics.checks_fired, 0, "no faults -> no alarms");
    assert!(s.metrics.batches >= 10); // 40 requests / max_batch 4
    assert!(s.p50 > 0.0 && s.p99 >= s.p50);
}

#[test]
fn injected_faults_are_detected_and_recovered() {
    let mut cfg = base_cfg();
    cfg.inject_every = Some(2); // every 2nd batch corrupted
    let s = serve_synthetic(&cfg, 32).unwrap();
    assert!(s.metrics.injected_faults > 0);
    assert_eq!(
        s.metrics.checks_fired, s.metrics.injected_faults,
        "every injected corruption must fire exactly one check: {s:?}"
    );
    assert_eq!(s.failed, 0, "retries must recover: {s:?}");
    assert!(s.recovered > 0);
    // Retried batches re-executed: executions > batches.
    assert!(s.metrics.executions > s.metrics.batches);
}

#[test]
fn single_worker_is_deterministic_in_counts() {
    let mut cfg = base_cfg();
    cfg.workers = 1;
    let a = serve_synthetic(&cfg, 24).unwrap();
    let b = serve_synthetic(&cfg, 24).unwrap();
    assert_eq!(a.metrics.requests, b.metrics.requests);
    assert_eq!(a.clean, b.clean);
}

#[test]
fn verify_status_taxonomy_is_consistent() {
    let mut cfg = base_cfg();
    cfg.inject_every = Some(3);
    let s = serve_synthetic(&cfg, 30).unwrap();
    assert_eq!(s.clean + s.recovered + s.failed, s.responses);
    let _ = VerifyStatus::Clean; // type is part of the public API
}
