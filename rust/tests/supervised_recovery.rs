//! The kill-and-recover drill, pinned end to end.
//!
//! Contract (the supervised extension of PR 5's fail-stop rule): with
//! `--supervise`, killing a shard mid-campaign yields only `Failed`
//! responses during the recovery window — never a wrong or silent
//! answer — the supervisor re-spawns (proc/tcp-local) or re-connects
//! (tcp-remote) the shard, re-ships its resident band + `s_c` through
//! the epoch fence, replays the in-flight requests, and post-recovery
//! results are bit-identical to a run that was never killed.

// The proc transport (and the worker binary plumbing both wire
// transports share) runs on Unix.
#![cfg(unix)]

use gcn_abft::coordinator::net::{TcpTransport, WORKER_READY_PREFIX};
use gcn_abft::coordinator::shard::{
    ProcTransport, RecoveryKind, ShardTransport, ShardTransportKind, ShardedBackend,
};
use gcn_abft::coordinator::{serve_synthetic, BatchPolicy, ServePolicy, ServerConfig};
use gcn_abft::gcn::GcnModel;
use gcn_abft::graph::DatasetId;
use gcn_abft::runtime::{ChecksumScheme, GcnBackend, GcnOperands, GcnOutputs};
use std::io::BufRead;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

fn worker_bin() -> PathBuf {
    PathBuf::from(env!("CARGO_BIN_EXE_gcn-abft"))
}

fn bits(out: &GcnOutputs) -> Vec<u32> {
    out.logits.data().iter().map(|v| v.to_bits()).collect()
}

/// Tiny banded operand set shared by the transport-level drills.
fn build_ops(bands: usize) -> GcnOperands {
    let graph = DatasetId::Tiny.build(11);
    let model = GcnModel::two_layer(&graph, 8, 3);
    GcnOperands::sparse(
        graph.features.clone(),
        &model.adjacency,
        model.layers[0].weights.clone(),
        model.layers[1].weights.clone(),
        bands,
    )
    .unwrap()
}

/// Kill shard 0 of `transport`, drive the trait-level recovery hooks
/// directly, and require the post-recovery forward to be bit-identical
/// to the pre-kill one. Returns the recovery kind for the caller to
/// pin.
fn kill_recover_drill(
    ops: &GcnOperands,
    transport: Arc<dyn ShardTransport>,
) -> (RecoveryKind, Vec<u32>, Vec<u32>) {
    let exe = ShardedBackend::new(transport.clone(), ChecksumScheme::Fused, 1);
    let want = exe.run(ops, &[]).expect("healthy run");
    assert!(ServePolicy::default().verify(&want).ok);

    assert!(transport.kill_shard(0));
    // Fail-stop during the outage: the forward errors, never a partial
    // stitch.
    assert!(exe.run(ops, &[]).is_err(), "dead shard must fail stop");
    let probe = transport.probe();
    assert!(!probe[0], "probe must see the dead shard");
    assert!(probe[1..].iter().all(|&alive| alive));

    let kind = transport.recover(0, ops).expect("recovery");
    assert!(transport.probe().iter().all(|&alive| alive));
    let got = exe.run(ops, &[]).expect("post-recovery run");
    assert!(ServePolicy::default().verify(&got).ok);
    (kind, bits(&want), bits(&got))
}

#[test]
fn proc_kill_recover_respawns_and_matches_the_unkilled_run() {
    let ops = build_ops(2);
    let transport = Arc::new(
        ProcTransport::spawn(&ops, Some(worker_bin().as_path())).unwrap(),
    );
    let pid_before = transport.worker_pids()[0];
    let (kind, want, got) = kill_recover_drill(&ops, transport.clone());
    assert_eq!(kind, RecoveryKind::Respawned);
    assert_ne!(
        transport.worker_pids()[0],
        pid_before,
        "respawn must be a new process"
    );
    assert_eq!(want, got, "post-recovery logits must match the unkilled run");
}

#[test]
fn proc_warm_standby_adoption_needs_no_reship() {
    let ops = build_ops(2);
    let transport = Arc::new(
        ProcTransport::spawn_with_standby(&ops, Some(worker_bin().as_path()), 1).unwrap(),
    );
    assert_eq!(transport.standby_count(), 1);
    // The single standby holds band 0 (round-robin pre-ship).
    let (kind, want, got) = kill_recover_drill(&ops, transport.clone());
    assert_eq!(kind, RecoveryKind::StandbyAdopted);
    assert_eq!(transport.standby_count(), 0, "the standby was consumed");
    assert_eq!(want, got);
}

#[test]
fn tcp_kill_recover_respawns_and_matches_the_unkilled_run() {
    let ops = build_ops(2);
    let transport = Arc::new(
        TcpTransport::spawn(&ops, Some(worker_bin().as_path()), 0).unwrap(),
    );
    let (kind, want, got) = kill_recover_drill(&ops, transport);
    assert_eq!(kind, RecoveryKind::Respawned);
    assert_eq!(want, got);
}

/// Spawn a real external `shard-worker --listen` process and return
/// `(child, addr)` once it prints its bound address.
fn external_worker() -> (std::process::Child, String) {
    let mut child = std::process::Command::new(worker_bin())
        .args(["shard-worker", "--listen", "127.0.0.1:0"])
        .stdout(std::process::Stdio::piped())
        .spawn()
        .expect("spawn external worker");
    let stdout = child.stdout.take().expect("worker stdout");
    let mut line = String::new();
    std::io::BufReader::new(stdout)
        .read_line(&mut line)
        .expect("worker ready line");
    let addr = line
        .trim()
        .strip_prefix(WORKER_READY_PREFIX)
        .expect("ready prefix")
        .to_string();
    (child, addr)
}

#[test]
fn tcp_remote_worker_survives_the_coordinator_and_reconnects() {
    let ops = build_ops(2);
    let (mut w0, a0) = external_worker();
    let (mut w1, a1) = external_worker();
    {
        let transport =
            Arc::new(TcpTransport::connect(&ops, &[a0.clone(), a1.clone()]).unwrap());
        assert_eq!(transport.worker_addrs(), vec![a0, a1]);
        // kill_shard on a remote worker severs the coordinator-side
        // link (the worker is not ours to kill); the worker re-accepts
        // and recovery is a reconnect, not a respawn.
        let (kind, want, got) = kill_recover_drill(&ops, transport);
        assert_eq!(kind, RecoveryKind::Reconnected);
        assert_eq!(want, got);
    }
    let _ = w0.kill();
    let _ = w1.kill();
    let _ = w0.wait();
    let _ = w1.wait();
}

/// Drive the REAL coordinator with `--supervise` over all three
/// transports: shard 0 dies before batch 3, the supervisor heals the
/// tier, the in-flight request replays, and the campaign ends with
/// every request answered — statuses only Clean or fail-stop Failed,
/// never wrong/silent, and with recovery observable in the metrics.
#[test]
fn supervised_server_heals_the_tier_and_replays_inflight_requests() {
    for transport in [
        ShardTransportKind::InProc,
        ShardTransportKind::Proc,
        ShardTransportKind::Tcp,
    ] {
        let requests = 10usize;
        let cfg = ServerConfig {
            dataset: DatasetId::Tiny,
            shards: 2,
            shard_transport: transport,
            shard_worker_bin: Some(worker_bin()),
            kill_shard_after: Some(3),
            supervise: true,
            heartbeat_ms: 20,
            batch: BatchPolicy {
                max_batch: 1,
                max_wait: Duration::from_millis(1),
                ..Default::default()
            },
            workers: 1,
            train_epochs: 2,
            ..Default::default()
        };
        let s = serve_synthetic(&cfg, requests).unwrap_or_else(|e| {
            panic!("{transport:?}: supervised coordinator must survive: {e:#}")
        });
        assert_eq!(s.responses, requests, "{transport:?}: every request answered");
        assert_eq!(
            s.recovered, 0,
            "{transport:?}: no injected faults, so no verify-retry recoveries"
        );
        assert_eq!(
            s.clean + s.failed,
            requests,
            "{transport:?}: statuses are only Clean or fail-stop Failed"
        );
        assert!(
            s.metrics.shard_respawns >= 1,
            "{transport:?}: the supervisor must have healed shard 0: {s:?}"
        );
        assert_eq!(
            s.clean, requests,
            "{transport:?}: recovery + replay answers the killed batch Clean: {s:?}"
        );
        assert!(
            s.metrics.replayed_requests >= 1,
            "{transport:?}: the in-flight request must be replayed: {s:?}"
        );
        assert!(s.supervised, "{transport:?}: summary records supervision");
        assert!(
            s.metrics.respawn_secs >= 0.0,
            "{transport:?}: recovery time is recorded"
        );
    }
}

/// Without `--supervise` the PR 5 contract is untouched: the same kill
/// leaves the tier down and everything after the kill fails stop. (The
/// full unsupervised drill lives in prop_shard_equivalence.rs; this
/// pins that merely *linking* the supervisor changes nothing.)
#[test]
fn unsupervised_kill_still_fails_stop() {
    let cfg = ServerConfig {
        dataset: DatasetId::Tiny,
        shards: 2,
        shard_transport: ShardTransportKind::InProc,
        kill_shard_after: Some(2),
        batch: BatchPolicy {
            max_batch: 1,
            max_wait: Duration::from_millis(1),
            ..Default::default()
        },
        workers: 1,
        train_epochs: 2,
        ..Default::default()
    };
    let s = serve_synthetic(&cfg, 6).unwrap();
    assert_eq!(s.clean, 2);
    assert_eq!(s.failed, 4);
    assert_eq!(s.metrics.shard_respawns, 0);
    assert_eq!(s.metrics.replayed_requests, 0);
    assert!(!s.supervised);
}
