//! Property tests for the sparse serving path: on random synthetic
//! graphs, the CSR (row-band sharded) executable must agree with the
//! dense executable — logits within 1e-5 relative (in fact bit-identical,
//! since both kernels fold each output row in the same nonzero order) —
//! and fault-free passes must raise zero alarms: under the serving
//! policy on the f32 path, and under all four paper thresholds on the
//! f64 engine for the same workload.

use gcn_abft::abft::{fused_forward_checked, CheckPolicy, EngineModel};
use gcn_abft::gcn::GcnModel;
use gcn_abft::graph::synth::{generate, SynthSpec};
use gcn_abft::coordinator::ServePolicy;
use gcn_abft::runtime::{GcnOperands, ModelEntry, Runtime, SOperand};
use gcn_abft::tensor::NopHook;
use gcn_abft::util::proptest::{check, no_shrink, Config};
use gcn_abft::util::rng::Pcg64;

fn gen_case(rng: &mut Pcg64) -> (SynthSpec, u64, u64, usize) {
    let n = 20 + rng.gen_index(40);
    let spec = SynthSpec {
        name: "prop-serve".into(),
        num_nodes: n,
        num_edges: 2 * n,
        feat_dim: 8 + rng.gen_index(24),
        feat_nnz: 4 * n,
        num_classes: 2 + rng.gen_index(4),
        homophily: 0.8,
        binary_features: rng.gen_bool(0.5),
        feature_scale: 1.0,
    };
    let graph_seed = rng.next_u64();
    let model_seed = rng.next_u64();
    let bands = 2 + rng.gen_index(4); // 2..=5 row bands
    (spec, graph_seed, model_seed, bands)
}

#[test]
fn prop_sparse_executable_matches_dense() {
    check(
        &Config {
            cases: 24,
            seed: 0xE407,
            ..Default::default()
        },
        gen_case,
        |(spec, graph_seed, model_seed, bands)| {
            let graph = generate(spec, *graph_seed);
            let model = GcnModel::two_layer(&graph, 8, *model_seed);
            let w1 = model.layers[0].weights.clone();
            let w2 = model.layers[1].weights.clone();
            let entry = ModelEntry {
                name: spec.name.clone(),
                file: String::new(),
                n: graph.num_nodes,
                f: graph.feat_dim(),
                hidden: 8,
                classes: graph.num_classes,
            };
            let exe = Runtime::native(2).load_entry(entry);

            let dense_out = exe
                .run(
                    &graph.features.to_dense(),
                    &model.adjacency.to_dense(),
                    &w1,
                    &w2,
                )
                .map_err(|e| format!("dense run failed: {e}"))?;

            for nbands in [1usize, *bands] {
                let ops = GcnOperands::sparse(
                    graph.features.clone(),
                    &model.adjacency,
                    w1.clone(),
                    w2.clone(),
                    nbands,
                )
                .map_err(|e| format!("operand build failed: {e}"))?;
                let sparse_out = exe
                    .run_operands(&ops, &[])
                    .map_err(|e| format!("sparse run failed: {e}"))?;

                // Logits within 1e-5 relative of the dense executable.
                let scale = dense_out
                    .logits
                    .data()
                    .iter()
                    .fold(0f32, |m, &v| m.max(v.abs()))
                    .max(1.0);
                let diff = sparse_out.logits.max_abs_diff(&dense_out.logits);
                if diff / scale > 1e-5 {
                    return Err(format!(
                        "sparse logits diverge from dense by {diff} (scale {scale}, \
                         nbands={nbands})"
                    ));
                }
                // Stitched fused checksums agree with the dense ones.
                for l in 0..2 {
                    let (a, b) = (sparse_out.predicted[l], dense_out.predicted[l]);
                    if (a - b).abs() > 1e-5 * b.abs().max(1.0) {
                        return Err(format!(
                            "layer-{l} predicted checksum diverges: {a} vs {b} \
                             (nbands={nbands})"
                        ));
                    }
                }
                // Fault-free pass raises no serving alarm.
                let report = ServePolicy::default().verify(&sparse_out);
                if !report.ok {
                    return Err(format!(
                        "fault-free sparse pass alarmed (nbands={nbands}): {report:?}"
                    ));
                }
            }

            // The same workload through the f64 engine raises zero
            // fault-free alarms at every paper threshold.
            let em = EngineModel::from_model(&model);
            let mut nop = NopHook;
            let (_, checks) = fused_forward_checked(&em, &graph.features, &mut nop);
            for &tau in &CheckPolicy::PAPER_THRESHOLDS {
                let policy = CheckPolicy::new(tau);
                for c in &checks {
                    if policy.fires(c.predicted, c.actual) {
                        return Err(format!("fault-free alarm at tau={tau:.0e}: {c:?}"));
                    }
                }
            }
            Ok(())
        },
        no_shrink,
    );
}

#[test]
fn prop_row_band_stitching_is_exact() {
    check(
        &Config {
            cases: 40,
            seed: 0xE408,
            ..Default::default()
        },
        gen_case,
        |(spec, graph_seed, model_seed, bands)| {
            let graph = generate(spec, *graph_seed);
            let s = graph.normalized_adjacency();
            // A dense right-hand side standing in for X = H·W.
            let mut rng = Pcg64::from_seed(*model_seed);
            let x = gcn_abft::tensor::Dense::from_fn(s.cols(), 6, |_, _| {
                rng.gen_f32_range(-2.0, 2.0)
            });
            let x_r = x.row_sums();
            let s_c = s.col_sums_f64();

            let banded = SOperand::banded(&s, *bands);
            // Band column sums stitch to the global s_c exactly.
            if banded.col_sums_f64() != s_c {
                return Err("band s_c vectors do not sum to the global s_c".into());
            }
            // Band-stitched aggregation is bit-identical to the unsharded
            // SpMM, and the stitched checksum pair satisfies Eq. (4).
            let reference = s.spmm(&x);
            let (z, pred, actual) = banded.aggregate(&x, &x_r, &s_c, 1);
            if z != reference {
                return Err(format!(
                    "stitched aggregation differs from unsharded SpMM ({} bands)",
                    banded.band_count()
                ));
            }
            let scale = actual.abs().max(1.0);
            if (pred - actual).abs() / scale > 1e-6 {
                return Err(format!(
                    "stitched fused check violated: pred {pred} vs actual {actual}"
                ));
            }
            if (actual - reference.checksum_f64()).abs() / scale > 1e-9 {
                return Err("stitched actual checksum diverges from block sum".into());
            }
            Ok(())
        },
        no_shrink,
    );
}
