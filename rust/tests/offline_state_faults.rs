//! Campaign over corrupted **offline check state** (`s_c`, `w_r`, the
//! base `x_r`, and the split baseline's `h_c`) — the state the paper
//! assumes is protected (e.g. by ECC), which this repo caches in
//! [`GcnOperands::check`] at model build.
//!
//! The pinned-down behavior (documented in `rust/README.md`):
//!
//! * the data path never reads the check state — corrupted state leaves
//!   the logits **bit-identical** to a clean forward;
//! * a flip large enough to move a predicted checksum past the serving
//!   tolerance raises a **persistent false alarm**: every retry fires
//!   again, so the server answers `VerifyStatus::Failed` and withholds a
//!   response that was actually correct (fail-stop, an availability
//!   loss — never a silent wrong answer);
//! * a flip below the tolerance (low mantissa bits) is benign.
//!
//! So an unprotected checker state converts hardware faults into false
//! alarms, not into undetected errors — the reason the paper's
//! "offline state is protected" assumption costs availability, not
//! integrity, when it breaks.

use gcn_abft::coordinator::{
    run_server, InferenceRequest, ModelState, ServePolicy, ServerConfig, VerifyStatus,
};
use gcn_abft::gcn::GcnModel;
use gcn_abft::graph::DatasetId;
use gcn_abft::runtime::{
    mutate, ChecksumScheme, GcnBackend, GcnOperands, GcnOutputs, NativeBanded, NativeDense,
    SOperand,
};
use gcn_abft::util::rng::Pcg64;

fn flip64(v: &mut f64, bit: u32) {
    *v = f64::from_bits(v.to_bits() ^ (1u64 << bit));
}

fn flip32(v: &mut f32, bit: u32) {
    *v = f32::from_bits(v.to_bits() ^ (1u32 << bit));
}

fn dense_ops() -> GcnOperands {
    let g = DatasetId::Tiny.build(11);
    let m = GcnModel::two_layer(&g, 8, 12);
    GcnOperands::dense(
        g.features.to_dense(),
        m.adjacency.to_dense(),
        m.layers[0].weights.clone(),
        m.layers[1].weights.clone(),
    )
    .unwrap()
}

fn banded_ops(bands: usize) -> GcnOperands {
    let g = DatasetId::Tiny.build(11);
    let m = GcnModel::two_layer(&g, 8, 12);
    GcnOperands::sparse(
        g.features.clone(),
        &m.adjacency,
        m.layers[0].weights.clone(),
        m.layers[1].weights.clone(),
        bands,
    )
    .unwrap()
}

fn logits_bits(out: &GcnOutputs) -> Vec<u32> {
    out.logits.data().iter().map(|v| v.to_bits()).collect()
}

/// Index where `|s_c[i] · x_r1[i]|` is largest: flipping a high bit of
/// `s_c` there is guaranteed to move the layer-1 predicted checksum
/// (a huge-but-finite corrupted operand times an exactly-zero checksum
/// column entry would contribute nothing).
fn loudest_s_c_index(s_c: &[f64], x_r1: &[f32]) -> usize {
    let mut best = 0;
    let mut best_v = -1.0f64;
    for (i, (s, x)) in s_c.iter().zip(x_r1).enumerate() {
        let v = (s * *x as f64).abs();
        if v > best_v {
            best_v = v;
            best = i;
        }
    }
    assert!(
        best_v > 0.0,
        "degenerate workload: every s_c·x_r product is zero"
    );
    best
}

/// Run one corrupted-state forward and classify the outcome. Asserts
/// the two campaign invariants: logits untouched, and a fired alarm is
/// persistent (fires again on re-execution with the same state).
fn classify(ops: &GcnOperands, scheme: ChecksumScheme, clean_logits: &[u32]) -> bool {
    let exe = NativeDense::new(2, scheme);
    let out = exe.run(ops, &[]).unwrap();
    assert_eq!(
        logits_bits(&out),
        clean_logits,
        "check-state corruption must never reach the data path ({scheme:?})"
    );
    let report = ServePolicy::default().verify(&out);
    if !report.ok {
        // The alarm is a deterministic function of the corrupted state:
        // the bounded re-execution the server would attempt fires too.
        let retry = exe.run(ops, &[]).unwrap();
        assert!(
            !ServePolicy::default().verify(&retry).ok,
            "a check-state alarm must persist across retries ({scheme:?})"
        );
    }
    !report.ok
}

#[test]
fn campaign_random_bit_flips_in_offline_state_are_fail_stop() {
    let base = dense_ops();
    let n = base.n_nodes();
    let f = base.feat_dim();
    let h = base.hidden_dim();
    let mut clean = Vec::new();
    for scheme in [ChecksumScheme::Fused, ChecksumScheme::Split] {
        let out = NativeDense::new(2, scheme).run(&base, &[]).unwrap();
        assert!(ServePolicy::default().verify(&out).ok, "clean baseline alarmed");
        clean.push(logits_bits(&out));
    }

    let mut rng = Pcg64::from_seed(0x0FF57A7E);
    let mut detected = 0usize;
    let mut benign = 0usize;
    for _trial in 0..96 {
        let mut ops = base.clone();
        match rng.gen_index(5) {
            0 => flip64(
                &mut ops.check.s_c[rng.gen_index(n)],
                rng.gen_index(64) as u32,
            ),
            1 => flip32(
                &mut ops.check.w_r1[rng.gen_index(f)],
                rng.gen_index(32) as u32,
            ),
            2 => flip32(
                &mut ops.check.w_r2[rng.gen_index(h)],
                rng.gen_index(32) as u32,
            ),
            3 => flip32(
                &mut ops.check.x_r1[rng.gen_index(n)],
                rng.gen_index(32) as u32,
            ),
            _ => flip64(
                &mut ops.check.h_c1[rng.gen_index(f)],
                rng.gen_index(64) as u32,
            ),
        }
        for (sidx, scheme) in [ChecksumScheme::Fused, ChecksumScheme::Split]
            .into_iter()
            .enumerate()
        {
            if classify(&ops, scheme, &clean[sidx]) {
                detected += 1;
            } else {
                benign += 1;
            }
        }
    }
    // Both outcomes must occur across the campaign: high bits of a
    // checksum operand push the predicted value past tolerance (false
    // alarm → fail-stop), low mantissa bits stay below it (benign).
    assert!(detected > 0, "no corruption was ever detected");
    assert!(benign > 0, "every flip alarmed — tolerance model is off");
    println!(
        "offline-state campaign: {detected} detected (persistent false alarms), \
         {benign} benign of {} scheme-trials",
        detected + benign
    );
}

#[test]
fn forced_exponent_flip_in_s_c_always_alarms_and_mantissa_lsb_never_does() {
    let base = dense_ops();
    for scheme in [ChecksumScheme::Fused, ChecksumScheme::Split] {
        let clean = logits_bits(&NativeDense::new(2, scheme).run(&base, &[]).unwrap());
        // Top exponent bit of the loudest column sum: the predicted
        // checksum explodes, so the check must fire.
        let mut ops = base.clone();
        let i = loudest_s_c_index(&ops.check.s_c, &ops.check.x_r1);
        flip64(&mut ops.check.s_c[i], 62);
        assert!(
            classify(&ops, scheme, &clean),
            "{scheme:?}: top-exponent s_c flip must alarm"
        );
        // The same entry's mantissa LSB: a ~1 ulp wobble, far below the
        // serving tolerance — must stay quiet.
        let mut ops = base.clone();
        flip64(&mut ops.check.s_c[i], 0);
        assert!(
            !classify(&ops, scheme, &clean),
            "{scheme:?}: 1-ulp s_c flip must be benign"
        );
    }
}

#[test]
fn corrupted_band_s_c_alarms_on_the_banded_backend() {
    // The row-band-sharded path caches a per-band s_c; corrupting one
    // band's vector must poison the stitched predicted checksum the
    // same fail-stop way.
    let base = banded_ops(3);
    for scheme in [ChecksumScheme::Fused, ChecksumScheme::Split] {
        let exe = NativeBanded::new(2, scheme);
        let clean_out = exe.run(&base, &[]).unwrap();
        assert!(ServePolicy::default().verify(&clean_out).ok);
        let clean = logits_bits(&clean_out);

        let mut ops = base.clone();
        let x_r1 = ops.check.x_r1.clone();
        let SOperand::Banded(bands) = &mut ops.s else {
            panic!("banded operands expected");
        };
        let j = loudest_s_c_index(&bands[1].s_c, &x_r1);
        flip64(&mut bands[1].s_c[j], 62);

        let out = exe.run(&ops, &[]).unwrap();
        assert_eq!(
            logits_bits(&out),
            clean,
            "{scheme:?}: band s_c corruption must never reach the logits"
        );
        assert!(
            !ServePolicy::default().verify(&out).ok,
            "{scheme:?}: corrupted band s_c must alarm"
        );
    }
}

#[test]
fn campaign_bit_flips_in_incrementally_patched_state_are_fail_stop() {
    // The dynamic-graph path (runtime::mutate) patches `s_c`, the
    // per-band `s_c`, and `x_r1` in place instead of rebuilding them;
    // the fail-stop story must survive that. Evolve a banded operand
    // set through a random delta sequence, then run the same flip
    // campaign over the *patched* check state: corruption still never
    // reaches the logits, alarms are still persistent, and both
    // detected and benign outcomes still occur.
    let mut base = banded_ops(2);
    let mut rng = Pcg64::from_seed(0xD17F_11F5);
    for step in 0..6 {
        let delta = mutate::random_delta(
            &mut rng,
            base.n_nodes(),
            base.feat_dim(),
            base.hidden_dim(),
            base.num_classes(),
        );
        if let Err(e) = mutate::apply(&mut base, &delta) {
            panic!("delta {step} ({}) rejected: {e:#}", delta.kind());
        }
    }
    // The campaign baseline really is incrementally patched state, not
    // something a rebuild would fix up silently.
    mutate::bit_identical(&base, &mutate::rebuild(&base).unwrap())
        .expect("patched operands must match a rebuild before the campaign");
    let n = base.n_nodes();

    let mut clean = Vec::new();
    for scheme in [ChecksumScheme::Fused, ChecksumScheme::Split] {
        let out = NativeBanded::new(2, scheme).run(&base, &[]).unwrap();
        assert!(
            ServePolicy::default().verify(&out).ok,
            "fault-free patched baseline alarmed ({scheme:?})"
        );
        clean.push(logits_bits(&out));
    }

    let mut detected = 0usize;
    let mut benign = 0usize;
    for _trial in 0..64 {
        let mut ops = base.clone();
        match rng.gen_index(3) {
            0 => flip64(
                &mut ops.check.s_c[rng.gen_index(n)],
                rng.gen_index(64) as u32,
            ),
            1 => flip32(
                &mut ops.check.x_r1[rng.gen_index(n)],
                rng.gen_index(32) as u32,
            ),
            _ => {
                let SOperand::Banded(bands) = &mut ops.s else {
                    panic!("banded operands expected");
                };
                let band = rng.gen_index(bands.len());
                let j = rng.gen_index(bands[band].s_c.len());
                flip64(&mut bands[band].s_c[j], rng.gen_index(64) as u32);
            }
        }
        for (sidx, scheme) in [ChecksumScheme::Fused, ChecksumScheme::Split]
            .into_iter()
            .enumerate()
        {
            let exe = NativeBanded::new(2, scheme);
            let out = exe.run(&ops, &[]).unwrap();
            assert_eq!(
                logits_bits(&out),
                clean[sidx],
                "patched-state corruption must never reach the data path ({scheme:?})"
            );
            if ServePolicy::default().verify(&out).ok {
                benign += 1;
            } else {
                let retry = exe.run(&ops, &[]).unwrap();
                assert!(
                    !ServePolicy::default().verify(&retry).ok,
                    "a patched-state alarm must persist across retries ({scheme:?})"
                );
                detected += 1;
            }
        }
    }
    assert!(detected > 0, "no patched-state corruption was ever detected");
    assert!(
        benign > 0,
        "every patched-state flip alarmed — tolerance model is off"
    );
    println!(
        "patched-state campaign: {detected} detected (persistent false alarms), \
         {benign} benign of {} scheme-trials",
        detected + benign
    );
}

#[test]
fn serving_with_corrupted_state_fails_stop_instead_of_answering_wrong() {
    // End to end: a server whose cached s_c took a high-bit hit detects
    // every pass, exhausts its retry budget, and withholds the answers —
    // responses come back Failed, never silently wrong.
    let cfg = ServerConfig {
        dataset: DatasetId::Tiny,
        workers: 1,
        train_epochs: 3,
        max_retries: 1,
        ..Default::default()
    };
    let mut state = ModelState::build(&cfg).unwrap();
    let i = loudest_s_c_index(&state.ops.check.s_c, &state.ops.check.x_r1);
    flip64(&mut state.ops.check.s_c[i], 62);

    let (req_tx, req_rx) = std::sync::mpsc::channel();
    let (resp_tx, resp_rx) = std::sync::mpsc::channel();
    for id in 0..8u64 {
        req_tx.send(InferenceRequest::new(id, vec![0], vec![])).unwrap();
    }
    drop(req_tx);
    let m = run_server(&cfg, &state, req_rx, resp_tx).unwrap();

    let mut responses = 0;
    while let Ok(r) = resp_rx.recv() {
        responses += 1;
        assert_eq!(
            r.status,
            VerifyStatus::Failed,
            "corrupted check state must fail stop, not answer"
        );
    }
    assert_eq!(responses, 8);
    assert_eq!(
        m.checks_fired, m.executions,
        "every execution over corrupted state alarms: {m:?}"
    );
    assert_eq!(
        m.failures, m.overlay_groups,
        "every forward exhausts its retries: {m:?}"
    );
    assert_eq!(m.retries, m.batches, "one retry per group before giving up");
}
