//! Runtime integration: HLO-text artifact → PJRT compile → execute →
//! numerics match the native engine. Skips gracefully when artifacts are
//! absent (`make artifacts` builds them).

use gcn_abft::graph::DatasetId;
use gcn_abft::report::{build_workload, ExperimentOpts};
use gcn_abft::runtime::{Manifest, Runtime};
use std::path::Path;

fn artifacts_dir() -> Option<&'static Path> {
    let p = Path::new("artifacts");
    if p.join("manifest.json").exists() {
        Some(p)
    } else {
        eprintln!("SKIP: run `make artifacts` first");
        None
    }
}

#[test]
fn manifest_loads_and_matches_dataset_specs() {
    let Some(dir) = artifacts_dir() else { return };
    let m = Manifest::load(dir).unwrap();
    for entry in &m.models {
        let id = DatasetId::parse(&entry.name).expect("manifest names a known dataset");
        let spec = id.spec();
        assert_eq!(entry.n, spec.num_nodes, "{}", entry.name);
        assert_eq!(entry.f, spec.feat_dim, "{}", entry.name);
        assert_eq!(entry.classes, spec.num_classes, "{}", entry.name);
        assert_eq!(entry.hidden, id.hidden_dim(), "{}", entry.name);
        assert!(m.hlo_path(entry).exists());
    }
}

#[test]
fn tiny_artifact_executes_and_matches_native_engine() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::cpu().unwrap();
    let manifest = Manifest::load(dir).unwrap();
    let exe = rt.load_model(&manifest, "tiny").unwrap();

    let opts = ExperimentOpts {
        datasets: vec![DatasetId::Tiny],
        seed: 7,
        scale: 1.0,
        train_epochs: 10,
    };
    let (graph, model) = build_workload(DatasetId::Tiny, &opts);
    let features = graph.features.to_dense();
    let s = model.adjacency.to_dense();
    let out = exe
        .run(
            &features,
            &s,
            &model.layers[0].weights,
            &model.layers[1].weights,
        )
        .unwrap();

    // Shape contract.
    assert_eq!(out.logits.shape(), (64, 4));
    assert_eq!(out.predicted.len(), 2);
    assert_eq!(out.actual.len(), 2);

    // Checksums agree in-graph (fault-free run).
    for (p, a) in out.predicted.iter().zip(&out.actual) {
        let scale = a.abs().max(1.0);
        assert!(
            (p - a).abs() / scale < 1e-3,
            "in-graph checksum mismatch: {p} vs {a}"
        );
    }

    // Logits match the Rust-native f32 forward within f32 tolerance.
    let native = model.forward(&graph.features, gcn_abft::gcn::Dataflow::CombinationFirst);
    let max_native = native
        .logits
        .data()
        .iter()
        .fold(0f32, |m, &v| m.max(v.abs()));
    let diff = out.logits.max_abs_diff(&native.logits);
    assert!(
        diff / max_native.max(1.0) < 1e-3,
        "XLA vs native logits diverge: {diff} (scale {max_native})"
    );
}

#[test]
fn shape_validation_rejects_wrong_inputs() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::cpu().unwrap();
    let manifest = Manifest::load(dir).unwrap();
    let exe = rt.load_model(&manifest, "tiny").unwrap();
    let bad = gcn_abft::tensor::Dense::zeros(10, 10);
    let ok = gcn_abft::tensor::Dense::zeros(64, 64);
    let w1 = gcn_abft::tensor::Dense::zeros(32, 8);
    let w2 = gcn_abft::tensor::Dense::zeros(8, 4);
    let err = exe.run(&bad, &ok, &w1, &w2).unwrap_err();
    assert!(format!("{err}").contains("shape"), "{err}");
}

#[test]
fn missing_model_is_a_clean_error() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::cpu().unwrap();
    let manifest = Manifest::load(dir).unwrap();
    assert!(rt.load_model(&manifest, "nope").is_err());
}
