//! Runtime integration: the native serving backend against the reference
//! engine, plus the artifact-manifest contract checks (which skip
//! gracefully until `python -m compile.aot` has produced artifacts).

use gcn_abft::graph::DatasetId;
use gcn_abft::report::{build_workload, ExperimentOpts};
use gcn_abft::runtime::{Manifest, ModelEntry, Runtime};
use std::path::Path;

fn artifacts_dir() -> Option<&'static Path> {
    let p = Path::new("artifacts");
    if p.join("manifest.json").exists() {
        Some(p)
    } else {
        eprintln!("SKIP: run `python -m compile.aot` to build artifacts first");
        None
    }
}

#[test]
fn manifest_loads_and_matches_dataset_specs() {
    let Some(dir) = artifacts_dir() else { return };
    let m = Manifest::load(dir).unwrap();
    for entry in &m.models {
        let id = DatasetId::parse(&entry.name).expect("manifest names a known dataset");
        let spec = id.spec();
        assert_eq!(entry.n, spec.num_nodes, "{}", entry.name);
        assert_eq!(entry.f, spec.feat_dim, "{}", entry.name);
        assert_eq!(entry.classes, spec.num_classes, "{}", entry.name);
        assert_eq!(entry.hidden, id.hidden_dim(), "{}", entry.name);
        assert!(m.hlo_path(entry).exists());
    }
}

#[test]
fn native_runtime_executes_without_artifacts() {
    // The serving path must work on a fresh checkout: synthesize the
    // shape entry the AOT pipeline would have written and run natively.
    let exe = Runtime::native(2).load_entry(ModelEntry::for_dataset(DatasetId::Tiny));
    run_and_check_against_engine(&exe);
}

#[test]
fn synthesized_entry_matches_dataset_specs() {
    for id in [DatasetId::Tiny, DatasetId::Cora, DatasetId::Nell] {
        let e = ModelEntry::for_dataset(id);
        let spec = id.spec();
        assert_eq!(e.name, id.name());
        assert_eq!(e.n, spec.num_nodes);
        assert_eq!(e.f, spec.feat_dim);
        assert_eq!(e.hidden, id.hidden_dim());
        assert_eq!(e.classes, spec.num_classes);
    }
}

#[test]
fn manifest_entry_drives_native_executable() {
    // Exercises the manifest → executable path. Note: without the `pjrt`
    // feature the HLO text itself is never parsed or executed — only the
    // manifest's shape contract is consumed; the native backend computes
    // the forward. Executing the artifact requires a vendored `xla`
    // crate (see runtime::client::pjrt).
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::cpu().unwrap();
    let manifest = Manifest::load(dir).unwrap();
    let exe = rt.load_model(&manifest, "tiny").unwrap();
    run_and_check_against_engine(&exe);
}

fn run_and_check_against_engine(exe: &gcn_abft::runtime::GcnExecutable) {
    let opts = ExperimentOpts {
        datasets: vec![DatasetId::Tiny],
        seed: 7,
        scale: 1.0,
        train_epochs: 10,
    };
    let (graph, model) = build_workload(DatasetId::Tiny, &opts);
    let features = graph.features.to_dense();
    let s = model.adjacency.to_dense();
    let out = exe
        .run(
            &features,
            &s,
            &model.layers[0].weights,
            &model.layers[1].weights,
        )
        .unwrap();

    // Shape contract.
    assert_eq!(out.logits.shape(), (64, 4));
    assert_eq!(out.predicted.len(), 2);
    assert_eq!(out.actual.len(), 2);

    // Checksums agree in-graph (fault-free run).
    for (p, a) in out.predicted.iter().zip(&out.actual) {
        let scale = a.abs().max(1.0);
        assert!(
            (p - a).abs() / scale < 1e-3,
            "in-graph checksum mismatch: {p} vs {a}"
        );
    }

    // Logits match the Rust-native f32 forward within f32 tolerance.
    let native = model.forward(&graph.features, gcn_abft::gcn::Dataflow::CombinationFirst);
    let max_native = native
        .logits
        .data()
        .iter()
        .fold(0f32, |m, &v| m.max(v.abs()));
    let diff = out.logits.max_abs_diff(&native.logits);
    assert!(
        diff / max_native.max(1.0) < 1e-3,
        "XLA vs native logits diverge: {diff} (scale {max_native})"
    );
}

#[test]
fn shape_validation_rejects_wrong_inputs() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::cpu().unwrap();
    let manifest = Manifest::load(dir).unwrap();
    let exe = rt.load_model(&manifest, "tiny").unwrap();
    let bad = gcn_abft::tensor::Dense::zeros(10, 10);
    let ok = gcn_abft::tensor::Dense::zeros(64, 64);
    let w1 = gcn_abft::tensor::Dense::zeros(32, 8);
    let w2 = gcn_abft::tensor::Dense::zeros(8, 4);
    let err = exe.run(&bad, &ok, &w1, &w2).unwrap_err();
    assert!(format!("{err}").contains("shape"), "{err}");
}

#[test]
fn missing_model_is_a_clean_error() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::cpu().unwrap();
    let manifest = Manifest::load(dir).unwrap();
    assert!(rt.load_model(&manifest, "nope").is_err());
}
