//! Cross-module integration: datasets → models → both checkers → fault
//! campaigns, at realistic (Cora) scale.

use gcn_abft::abft::{
    fused_forward_checked, split_forward_checked, CheckPolicy, EngineModel, Scheme,
};
use gcn_abft::fault::{run_campaigns, CampaignConfig};
use gcn_abft::gcn::{train_two_layer, GcnModel, TrainConfig};
use gcn_abft::graph::DatasetId;
use gcn_abft::opcount::ModelOps;
use gcn_abft::runtime::InstrumentedEngine;
use gcn_abft::tensor::{CountingHook, NopHook};

#[test]
fn cora_fault_free_checks_pass_under_tightest_threshold() {
    let g = DatasetId::Cora.build(3);
    let m = GcnModel::two_layer(&g, 16, 3);
    let em = EngineModel::from_model(&m);
    let policy = CheckPolicy::new(1e-7);
    let mut nop = NopHook;
    let (_, fused) = fused_forward_checked(&em, &g.features, &mut nop);
    for c in &fused {
        assert!(
            !policy.fires(c.predicted, c.actual),
            "fault-free fused check fired at 1e-7: {c:?}"
        );
    }
    let h_c = g.features.col_sums_f64();
    let (_, split) = split_forward_checked(&em, &g.features, &h_c, &mut nop);
    for c in &split {
        assert!(
            !policy.fires(c.predicted, c.actual),
            "fault-free split check fired at 1e-7: {c:?}"
        );
    }
}

#[test]
fn cora_analytic_opcounts_match_measured_exactly() {
    let g = DatasetId::Cora.build(3);
    let m = GcnModel::two_layer(&g, 16, 3);
    let em = EngineModel::from_model(&m);
    let row = ModelOps::two_layer(&g, 16).table_row();

    let h_c = g.features.col_sums_f64();
    let mut cs = CountingHook::default();
    split_forward_checked(&em, &g.features, &h_c, &mut cs);
    assert_eq!(cs.total(), row.split_total());

    let mut cf = CountingHook::default();
    fused_forward_checked(&em, &g.features, &mut cf);
    assert_eq!(cf.total(), row.fused_total());

    // The headline claim, at real Cora shape: double-digit check savings.
    assert!(row.check_saving() > 0.15, "saving {}", row.check_saving());
}

#[test]
fn trained_model_still_verifies() {
    // Training changes weight magnitudes; the checker must stay tight.
    let g = DatasetId::Tiny.build(5);
    let mut m = GcnModel::two_layer(&g, 8, 5);
    train_two_layer(&mut m, &g.features, &g.labels, &TrainConfig::default());
    let em = EngineModel::from_model(&m);
    let mut nop = NopHook;
    let (_, checks) = fused_forward_checked(&em, &g.features, &mut nop);
    let policy = CheckPolicy::new(1e-7);
    for c in &checks {
        assert!(!policy.fires(c.predicted, c.actual), "{c:?}");
    }
}

#[test]
fn campaign_invariants_on_citeseer_subset() {
    let g = DatasetId::Citeseer.build_scaled(5, 0.2);
    let mut m = GcnModel::two_layer(&g, 16, 5);
    train_two_layer(
        &mut m,
        &g.features,
        &g.labels,
        &TrainConfig {
            epochs: 5,
            ..Default::default()
        },
    );
    let engine = InstrumentedEngine::from_model(&m, &g.features);
    for scheme in [Scheme::Split, Scheme::Fused] {
        let cfg = CampaignConfig {
            scheme,
            campaigns: 120,
            seed: 11,
            threads: 1,
            ..Default::default()
        };
        let r = run_campaigns(&engine, &cfg);
        // Partition invariant at every threshold.
        for (tau, t) in &r.per_threshold {
            assert_eq!(t.total(), 120, "tau {tau}: {t:?}");
        }
        // Monotonicity: silent non-increasing, detected non-decreasing.
        for w in r.per_threshold.windows(2) {
            assert!(w[1].1.silent <= w[0].1.silent);
            assert!(w[1].1.detected >= w[0].1.detected);
        }
        // Near-zero silent at the tightest threshold (paper: zero).
        let tight = r.per_threshold.last().unwrap().1;
        assert!(tight.silent_rate() < 0.03, "{scheme:?}: {tight:?}");
    }
}

#[test]
fn multi_fault_campaigns_flag_almost_everything() {
    // §IV-B: with >1 fault per campaign both schemes reach ~100%.
    let g = DatasetId::Tiny.build(9);
    let m = GcnModel::two_layer(&g, 8, 9);
    let engine = InstrumentedEngine::from_model(&m, &g.features);
    let cfg = CampaignConfig {
        scheme: Scheme::Fused,
        campaigns: 150,
        faults_per_campaign: 3,
        seed: 13,
        threads: 1,
        ..Default::default()
    };
    let r = run_campaigns(&engine, &cfg);
    let t = r.per_threshold.last().unwrap().1;
    let flagged = (t.detected + t.false_positive) as f64 / t.total() as f64;
    assert!(flagged > 0.9, "multi-fault flag rate {flagged}: {t:?}");
    assert!(t.silent_rate() < 0.02, "{t:?}");
}

#[test]
fn deeper_models_are_checkable_too() {
    // The fused scheme is per-layer, so depth just adds checks.
    let g = DatasetId::Tiny.build(21);
    let m = GcnModel::with_dims(&g, &[32, 16, 8, 4], 21);
    let em = EngineModel::from_model(&m);
    let mut nop = NopHook;
    let (preacts, checks) = fused_forward_checked(&em, &g.features, &mut nop);
    assert_eq!(preacts.len(), 3);
    assert_eq!(checks.len(), 3);
    let policy = CheckPolicy::new(1e-7);
    for c in &checks {
        assert!(!policy.fires(c.predicted, c.actual), "{c:?}");
    }
    // And campaigns run on it (the instrumented engine is layer-count
    // agnostic, not just the 2-layer serving shape).
    let engine = InstrumentedEngine::from_model(&m, &g.features);
    let cfg = CampaignConfig {
        scheme: Scheme::Fused,
        campaigns: 60,
        seed: 21,
        threads: 1,
        ..Default::default()
    };
    let r = run_campaigns(&engine, &cfg);
    for (_, t) in &r.per_threshold {
        assert_eq!(t.total(), 60);
    }
}

#[test]
fn zero_column_masking_edge_case() {
    // §III trade-off: a fault in a row of X that S never reads is
    // invisible to the fused end-of-layer check but caught by split's
    // phase-1 check. Verify the mechanism on a crafted graph where node 0
    // is isolated except for its self-loop... a truly all-zero S column
    // cannot arise from S = D^{-1/2}(A+I)D^{-1/2} (self-loops), so we
    // check the checker-level property directly on matrices.
    use gcn_abft::sparse::Csr;
    use gcn_abft::tensor::Dense64;

    // S with an all-zero column 1 (hand-built, not a normalized graph).
    let s = Csr::from_coo(3, 3, vec![(0, 0, 1.0), (1, 0, 1.0), (2, 2, 1.0)]);
    assert_eq!(s.zero_columns(), vec![1]);

    let h = Dense64::from_vec(3, 2, vec![1., 2., 3., 4., 5., 6.]);
    let w = Dense64::from_vec(2, 2, vec![1., 0., 0., 1.]);
    let w_r = vec![1.0, 1.0];
    let s_c: Vec<f64> = s.col_sums_f64();

    // Corrupt X row 1 (the row S never reads) between the two phases by
    // simulating with a hook that hits a phase-1 op writing X[1][*].
    struct CorruptRow1 {
        count: i64,
    }
    impl gcn_abft::tensor::ExecHook for CorruptRow1 {
        fn mul(&mut self, v: f64) -> f64 {
            self.count += 1;
            // op 9 is the first product of X[1][0] for this shape
            // (row 0 occupies data ops 1..8: 2 k-steps × 2 cols × 2 ops)
            if self.count == 9 {
                v + 100.0
            } else {
                v
            }
        }
        fn add(&mut self, v: f64) -> f64 {
            self.count += 1;
            v
        }
        fn csum(&mut self, v: f64) -> f64 {
            v
        }
    }

    let policy = CheckPolicy::new(1e-6);
    let mut hook = CorruptRow1 { count: 0 };
    let (_, fused_check) = gcn_abft::abft::fused_layer_checked(
        &s,
        &s_c,
        &gcn_abft::abft::EngineInput::Dense(h.clone()),
        &w,
        &w_r,
        0,
        &mut hook,
    );
    // The fused check misses it: the corrupted X row is annihilated by S.
    assert!(
        !policy.fires(fused_check.predicted, fused_check.actual),
        "fused check unexpectedly caught a masked fault: {fused_check:?}"
    );

    // Split's phase-1 check catches the same corruption.
    let mut hook = CorruptRow1 { count: 0 };
    let (_, split_checks) = gcn_abft::abft::split_layer_checked(
        &s,
        &s_c,
        &gcn_abft::abft::EngineInput::Dense(h),
        &w,
        &w_r,
        None,
        0,
        &mut hook,
    );
    assert!(
        policy.fires(split_checks[0].predicted, split_checks[0].actual),
        "split phase-1 check should catch the X corruption: {split_checks:?}"
    );
}
