//! Property: **sharding is location-transparent.** Serving through the
//! shard tier — any shard count, any transport — is bit-identical to
//! unsharded serving: logits match bit for bit and the fused/split
//! alarm decisions are identical. The three transports (inproc, proc,
//! tcp) are additionally bit-identical to *each other* including the
//! stitched checksum bits (every worker computes each band with the
//! same serial kernel the in-proc scoped threads run, and floats cross
//! both wires as raw bit patterns).
//!
//! Plus the fail-stop contract: killing a shard worker mid-campaign
//! turns the affected requests into `Failed` responses while the
//! coordinator survives and keeps answering.

// The proc transport runs on Unix domain sockets.
#![cfg(unix)]

use gcn_abft::coordinator::net::TcpTransport;
use gcn_abft::coordinator::shard::{
    InProcTransport, ProcTransport, ShardPlan, ShardTransport, ShardTransportKind,
    ShardedBackend,
};
use gcn_abft::coordinator::{
    serve_synthetic, BatchPolicy, ServePolicy, ServerConfig, VerifyStatus,
};
use gcn_abft::gcn::GcnModel;
use gcn_abft::graph::synth::{generate, SynthSpec};
use gcn_abft::graph::DatasetId;
use gcn_abft::runtime::{
    backend, BackendKind, ChecksumScheme, GcnBackend, GcnOperands, GcnOutputs, Overlay,
};
use gcn_abft::util::proptest::{check, no_shrink, Config};
use gcn_abft::util::rng::Pcg64;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

/// The `gcn-abft` binary the proc transport spawns as shard workers
/// (the test executable itself has no `shard-worker` subcommand).
fn worker_bin() -> PathBuf {
    PathBuf::from(env!("CARGO_BIN_EXE_gcn-abft"))
}

fn bits(out: &GcnOutputs) -> Vec<u32> {
    out.logits.data().iter().map(|v| v.to_bits()).collect()
}

#[derive(Debug, Clone)]
struct Case {
    spec: SynthSpec,
    graph_seed: u64,
    model_seed: u64,
    overlay_seed: u64,
    /// Band count of the unsharded reference operands — deliberately
    /// allowed to differ from every shard count under test.
    ref_bands: usize,
}

fn gen_case(rng: &mut Pcg64) -> Case {
    let n = 16 + rng.gen_index(40);
    Case {
        spec: SynthSpec {
            name: "prop-shard-eq".into(),
            num_nodes: n,
            num_edges: 2 * n + rng.gen_index(n),
            feat_dim: 6 + rng.gen_index(14),
            feat_nnz: 4 * n,
            num_classes: 2 + rng.gen_index(4),
            homophily: 0.8,
            binary_features: rng.gen_bool(0.5),
            feature_scale: 1.0,
        },
        graph_seed: rng.next_u64(),
        model_seed: rng.next_u64(),
        overlay_seed: rng.next_u64(),
        ref_bands: 1 + rng.gen_index(3),
    }
}

/// Build the operand set of one case at a given band count.
fn build_ops(case: &Case, bands: usize) -> Result<GcnOperands, String> {
    let graph = generate(&case.spec, case.graph_seed);
    let model = GcnModel::two_layer(&graph, 8, case.model_seed);
    GcnOperands::sparse(
        graph.features.clone(),
        &model.adjacency,
        model.layers[0].weights.clone(),
        model.layers[1].weights.clone(),
        bands,
    )
    .map_err(|e| format!("operand build failed: {e}"))
}

fn random_overlay_rows(case: &Case, n_nodes: usize, feat_dim: usize) -> Vec<(usize, Vec<f32>)> {
    let mut rng = Pcg64::from_seed(case.overlay_seed);
    (0..rng.gen_index(3))
        .map(|_| {
            (
                rng.gen_index(n_nodes),
                (0..feat_dim).map(|_| rng.gen_f32_range(-4.0, 4.0)).collect(),
            )
        })
        .collect()
}

#[test]
fn prop_sharded_serving_is_bit_identical_to_unsharded() {
    check(
        &Config {
            cases: 6,
            seed: 0x5A4D,
            ..Default::default()
        },
        gen_case,
        |case| {
            let ops_ref = build_ops(case, case.ref_bands)?;
            let rows = random_overlay_rows(case, case.spec.num_nodes, case.spec.feat_dim);
            let overlays: Vec<Overlay<'_>> = rows
                .iter()
                .map(|(node, row)| Overlay {
                    node: *node,
                    row: row.as_slice(),
                })
                .collect();

            for scheme in [ChecksumScheme::Fused, ChecksumScheme::Split] {
                // Unsharded reference: exactly what `serve` without
                // --shards runs (native backend over banded CSR ops).
                let reference =
                    backend::for_operands(BackendKind::Native, scheme, &ops_ref, 2, None)
                        .map_err(|e| format!("reference backend: {e}"))?;
                let want = reference
                    .run(&ops_ref, &overlays)
                    .map_err(|e| format!("reference run: {e}"))?;
                let want_bits = bits(&want);
                let want_ok = ServePolicy::default().verify(&want).ok;
                if !want_ok {
                    return Err("fault-free reference run alarmed".into());
                }

                for shards in [1usize, 2, 4] {
                    let ops = build_ops(case, shards)?;
                    let plan = ShardPlan::for_operands(&ops)
                        .map_err(|e| format!("plan: {e}"))?;
                    if plan.shards != shards.min(case.spec.num_nodes) {
                        return Err(format!(
                            "plan has {} shards, wanted {shards}",
                            plan.shards
                        ));
                    }

                    let inproc: Arc<dyn ShardTransport> = Arc::new(
                        InProcTransport::new(&ops).map_err(|e| format!("inproc: {e}"))?,
                    );
                    let proc: Arc<dyn ShardTransport> = Arc::new(
                        ProcTransport::spawn(&ops, Some(worker_bin().as_path()))
                            .map_err(|e| format!("proc spawn: {e}"))?,
                    );
                    let tcp: Arc<dyn ShardTransport> = Arc::new(
                        TcpTransport::spawn(&ops, Some(worker_bin().as_path()), 0)
                            .map_err(|e| format!("tcp spawn: {e}"))?,
                    );
                    let mut per_transport = Vec::new();
                    for transport in [inproc, proc, tcp] {
                        let tname = transport.name();
                        let exe = ShardedBackend::new(transport, scheme, 2);
                        let got = exe
                            .run(&ops, &overlays)
                            .map_err(|e| format!("{tname} run: {e}"))?;
                        // Logits: bit-identical to unsharded serving.
                        if bits(&got) != want_bits {
                            return Err(format!(
                                "{scheme:?} shards={shards} {tname}: logits are not \
                                 bit-identical to unsharded"
                            ));
                        }
                        // Alarm decisions: identical (fault-free ⇒ quiet).
                        let ok = ServePolicy::default().verify(&got).ok;
                        if ok != want_ok {
                            return Err(format!(
                                "{scheme:?} shards={shards} {tname}: alarm decision \
                                 diverged from unsharded"
                            ));
                        }
                        per_transport.push(got);
                    }
                    // The transports are bit-identical to each other,
                    // checksum bits included (same band partition, same
                    // per-band kernel, raw-bit wire format on both the
                    // Unix-socket and TCP paths).
                    let a = &per_transport[0];
                    for (name, b) in ["proc", "tcp"].iter().zip(&per_transport[1..]) {
                        if a.logits != b.logits
                            || a.predicted
                                .iter()
                                .zip(&b.predicted)
                                .any(|(x, y)| x.to_bits() != y.to_bits())
                            || a.actual
                                .iter()
                                .zip(&b.actual)
                                .any(|(x, y)| x.to_bits() != y.to_bits())
                        {
                            return Err(format!(
                                "{scheme:?} shards={shards}: {name} transport \
                                 diverged from inproc"
                            ));
                        }
                    }
                }
            }
            Ok(())
        },
        no_shrink,
    );
}

#[test]
fn killed_proc_worker_fails_the_aggregation_not_the_process() {
    let case = Case {
        spec: SynthSpec {
            name: "kill-proc".into(),
            num_nodes: 48,
            num_edges: 96,
            feat_dim: 12,
            feat_nnz: 192,
            num_classes: 3,
            homophily: 0.8,
            binary_features: false,
            feature_scale: 1.0,
        },
        graph_seed: 11,
        model_seed: 12,
        overlay_seed: 13,
        ref_bands: 1,
    };
    let ops = build_ops(&case, 2).unwrap();
    let transport =
        Arc::new(ProcTransport::spawn(&ops, Some(worker_bin().as_path())).unwrap());
    assert_eq!(transport.shards(), 2);
    assert_eq!(transport.worker_pids().len(), 2);
    let exe = ShardedBackend::new(
        transport.clone() as Arc<dyn ShardTransport>,
        ChecksumScheme::Fused,
        1,
    );
    // Healthy: serves and verifies.
    let out = exe.run(&ops, &[]).unwrap();
    assert!(ServePolicy::default().verify(&out).ok);
    // Kill worker 1 (the real subprocess dies); the next forward must
    // error — never a silently stitched partial answer.
    assert!(transport.kill_shard(1));
    let err = exe.run(&ops, &[]).unwrap_err().to_string();
    assert!(
        err.contains("shard 1") || err.contains("down"),
        "unexpected error: {err}"
    );
    // And it stays failed (the shard is marked down).
    assert!(exe.run(&ops, &[]).is_err());
    let tm = transport.timings();
    assert!(tm.aggregates >= 2, "healthy run = two aggregation phases");
}

/// Drive the REAL coordinator — scheduler, executor, verification —
/// with a shard being torn down mid-campaign, over both transports.
/// Requests answered before the kill are Clean; everything after is
/// fail-stop `Failed`; the coordinator survives to the end (run returns
/// metrics, every request gets a response).
#[test]
fn killed_shard_mid_campaign_fail_stops_and_coordinator_survives() {
    for transport in [
        ShardTransportKind::InProc,
        ShardTransportKind::Proc,
        ShardTransportKind::Tcp,
    ] {
        let requests = 10usize;
        let kill_after = 3u64;
        let cfg = ServerConfig {
            dataset: DatasetId::Tiny,
            shards: 2,
            shard_transport: transport,
            shard_worker_bin: Some(worker_bin()),
            kill_shard_after: Some(kill_after),
            // One request per batch so "batches before the kill" maps
            // 1:1 onto requests.
            batch: BatchPolicy {
                max_batch: 1,
                max_wait: Duration::from_millis(1),
                ..Default::default()
            },
            workers: 1,
            train_epochs: 2,
            ..Default::default()
        };
        let s = serve_synthetic(&cfg, requests).unwrap_or_else(|e| {
            panic!("{:?}: coordinator must survive a dead shard: {e:#}", transport)
        });
        assert_eq!(s.responses, requests, "{transport:?}: every request answered");
        assert_eq!(
            s.clean, kill_after as usize,
            "{transport:?}: requests before the kill are clean: {s:?}"
        );
        assert_eq!(
            s.failed,
            requests - kill_after as usize,
            "{transport:?}: requests after the kill fail stop: {s:?}"
        );
        assert_eq!(s.recovered, 0, "{transport:?}: a dead shard is not retryable");
        assert!(
            s.metrics.shard_failures >= 1,
            "{transport:?}: shard failures must be observable: {s:?}"
        );
        assert_eq!(s.shards, 2);
        assert_eq!(s.shard_transport, transport.name());
        assert_eq!(s.metrics.shard_wait_secs.len(), 2);
        let _ = VerifyStatus::Failed; // part of the pinned contract
    }
}
