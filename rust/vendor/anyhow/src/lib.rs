//! Minimal offline-compatible subset of the `anyhow` error API.
//!
//! See README.md in this directory for scope and rationale. Semantics
//! match the real crate for everything this repo uses: `?` conversion
//! from any std error, message construction via `anyhow!`/`bail!`,
//! context layering, `{}` printing the outermost message and `{:#}`
//! printing the full cause chain.

use std::error::Error as StdError;
use std::fmt;

/// `Result` specialized to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// An opaque error: an outermost message plus a cause chain.
///
/// Deliberately *not* `std::error::Error` itself (same as the real
/// `anyhow::Error`) so the blanket `From<E: std::error::Error>` impl
/// does not collide with the reflexive `From<T> for T`.
pub struct Error {
    /// Outermost message first, root cause last.
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Self {
            chain: vec![message.to_string()],
        }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The cause chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The root cause (innermost message).
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` — the whole chain, colon-joined (anyhow-compatible).
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Self { chain }
    }
}

/// Attach context to fallible values (`Result` and `Option`).
pub trait Context<T> {
    /// Wrap the error with a fixed context message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    /// Wrap the error with a lazily evaluated context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::from(e).context(context))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Result<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.context(context))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return ::std::result::Result::Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "file missing")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert_eq!(format!("{e}"), "file missing");
    }

    #[test]
    fn context_layers_and_alternate_format() {
        let e: Result<()> = Err(io_err()).with_context(|| "reading manifest".to_string());
        let e = e.unwrap_err();
        assert_eq!(format!("{e}"), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: file missing");
        assert_eq!(e.root_cause(), "file missing");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("nothing there").unwrap_err();
        assert_eq!(format!("{e}"), "nothing there");
        assert_eq!(Some(5).context("unused").unwrap(), 5);
    }

    #[test]
    fn macros() {
        let e = anyhow!("bad value {}", 3);
        assert_eq!(format!("{e}"), "bad value 3");
        fn f(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            if x > 100 {
                bail!("x too large: {x}");
            }
            Ok(x)
        }
        assert!(f(-1).is_err());
        assert!(f(200).is_err());
        assert_eq!(f(5).unwrap(), 5);
    }

    #[test]
    fn debug_shows_cause_chain() {
        let e = Error::from(io_err()).context("outer");
        let d = format!("{e:?}");
        assert!(d.contains("outer"));
        assert!(d.contains("Caused by"));
        assert!(d.contains("file missing"));
    }
}
