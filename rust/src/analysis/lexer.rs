//! Lexer-level Rust source scanning for the architectural lint pass.
//!
//! This is deliberately *not* a parser: the lint rules in
//! [`super::rules`] only need a comment/string-stripped token stream
//! with line numbers, plus two side channels — the lint directives
//! hiding in `//` comments and the line ranges covered by
//! `#[cfg(test)]` items (so test-only code can be exempted from the
//! production-path rules). A full AST (`syn`) would pull in a
//! dependency tree the offline workspace cannot resolve; a token
//! stream is enough to match the handful of idioms the contracts
//! forbid (`Instant :: now`, `. unwrap`, `as f32`, `== 0.0`, …).

/// Kind of a lexed token. Only the distinctions the rules need.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword (`unwrap`, `as`, `HashMap`, …).
    Ident,
    /// Numeric literal. `is_float_literal` refines this for D4.
    Num,
    /// Punctuation, including the two-char combinations the rules
    /// match on (`::`, `==`, `!=`, `->`, `..`, …).
    Punct,
    /// Lifetime (`'a`, `'static`) — lexed so `'` disambiguation is
    /// explicit, never matched by any rule.
    Lifetime,
}

/// One token with its 1-based source line.
#[derive(Debug, Clone)]
pub struct Tok {
    pub text: String,
    pub kind: TokKind,
    pub line: u32,
}

/// A `//` comment captured during lexing (block comments are dropped —
/// lint directives must be line comments so they attach to a line).
#[derive(Debug, Clone)]
pub struct LineComment {
    pub text: String,
    pub line: u32,
}

/// Result of lexing one file.
#[derive(Debug, Default)]
pub struct Lexed {
    pub tokens: Vec<Tok>,
    pub comments: Vec<LineComment>,
    /// Inclusive 1-based line ranges covered by `#[cfg(test)]` items
    /// (attribute line through the matching closing brace).
    pub test_ranges: Vec<(u32, u32)>,
}

impl Lexed {
    /// Is `line` inside any `#[cfg(test)]` item?
    pub fn in_test_region(&self, line: u32) -> bool {
        self.test_ranges.iter().any(|&(a, b)| a <= line && line <= b)
    }
}

/// Two-character punctuation tokens the rules care about (or that must
/// not be split so single-char matching stays unambiguous — e.g. `=>`
/// must not lex as `=`,`>`, and `..` must not look like a float dot).
const PUNCT2: &[&str] = &[
    "::", "==", "!=", "<=", ">=", "=>", "->", "..", "&&", "||", "<<", ">>", "+=", "-=", "*=",
    "/=", "%=", "^=", "|=", "&=",
];

/// Lex `src`, stripping comments and string/char literals.
pub fn lex(src: &str) -> Lexed {
    let chars: Vec<char> = src.chars().collect();
    let mut out = Lexed::default();
    let mut i = 0usize;
    let mut line: u32 = 1;

    // Advance over `n` chars, counting newlines.
    macro_rules! bump {
        ($n:expr) => {{
            for _ in 0..$n {
                if i < chars.len() {
                    if chars[i] == '\n' {
                        line += 1;
                    }
                    i += 1;
                }
            }
        }};
    }

    while i < chars.len() {
        let c = chars[i];
        let c1 = chars.get(i + 1).copied();

        // Whitespace.
        if c.is_whitespace() {
            bump!(1);
            continue;
        }

        // Line comment — captured for directive parsing.
        if c == '/' && c1 == Some('/') {
            let start_line = line;
            let mut text = String::new();
            while i < chars.len() && chars[i] != '\n' {
                text.push(chars[i]);
                i += 1;
            }
            out.comments.push(LineComment {
                text,
                line: start_line,
            });
            continue;
        }

        // Block comment, possibly nested (Rust nests them).
        if c == '/' && c1 == Some('*') {
            let mut depth = 1usize;
            bump!(2);
            while i < chars.len() && depth > 0 {
                if chars[i] == '/' && chars.get(i + 1) == Some(&'*') {
                    depth += 1;
                    bump!(2);
                } else if chars[i] == '*' && chars.get(i + 1) == Some(&'/') {
                    depth -= 1;
                    bump!(2);
                } else {
                    bump!(1);
                }
            }
            continue;
        }

        // Identifier / keyword — may turn out to prefix a string
        // literal (r"", b"", br#""#, c"", cr#""#).
        if c.is_alphabetic() || c == '_' {
            let start_line = line;
            let mut text = String::new();
            while i < chars.len() && (chars[i].is_alphanumeric() || chars[i] == '_') {
                text.push(chars[i]);
                i += 1;
            }
            let is_str_prefix = matches!(text.as_str(), "r" | "b" | "br" | "c" | "cr");
            if is_str_prefix && matches!(chars.get(i), Some('"') | Some('#')) {
                // Raw/byte/C string: swallow it whole, emit nothing.
                let mut hashes = 0usize;
                while chars.get(i) == Some(&'#') {
                    hashes += 1;
                    bump!(1);
                }
                if chars.get(i) == Some(&'"') {
                    bump!(1);
                    skip_string_body(&chars, &mut i, &mut line, hashes, text.starts_with('r') || text.starts_with("br") || text.starts_with("cr"));
                }
                continue;
            }
            out.tokens.push(Tok {
                text,
                kind: TokKind::Ident,
                line: start_line,
            });
            continue;
        }

        // Plain string literal.
        if c == '"' {
            bump!(1);
            skip_string_body(&chars, &mut i, &mut line, 0, false);
            continue;
        }

        // `'`: char literal or lifetime.
        if c == '\'' {
            let start_line = line;
            if c1 == Some('\\') {
                // Escaped char literal: '\n', '\u{..}', '\'', …
                bump!(2); // ' and backslash
                // consume escape body up to closing quote
                while i < chars.len() && chars[i] != '\'' {
                    bump!(1);
                }
                bump!(1); // closing '
                continue;
            }
            if chars.get(i + 2) == Some(&'\'') && c1.is_some() {
                // Simple char literal 'x' (including '"' and ' ').
                bump!(3);
                continue;
            }
            // Lifetime: 'ident
            bump!(1);
            let mut text = String::from("'");
            while i < chars.len() && (chars[i].is_alphanumeric() || chars[i] == '_') {
                text.push(chars[i]);
                i += 1;
            }
            out.tokens.push(Tok {
                text,
                kind: TokKind::Lifetime,
                line: start_line,
            });
            continue;
        }

        // Numeric literal. `.` is folded in only when followed by a
        // digit (so ranges `0..n` and method calls `1.max(x)` lex
        // as separate tokens); `e`/`E` exponents may carry a sign.
        if c.is_ascii_digit() {
            let start_line = line;
            let mut text = String::new();
            while i < chars.len() {
                let d = chars[i];
                if d.is_alphanumeric() || d == '_' {
                    text.push(d);
                    i += 1;
                    // signed exponent: `1e-9`, `2.5E+3` (decimal only)
                    if (d == 'e' || d == 'E')
                        && !text.starts_with("0x")
                        && !text.starts_with("0b")
                        && !text.starts_with("0o")
                        && matches!(chars.get(i), Some('+') | Some('-'))
                        && chars.get(i + 1).is_some_and(|n| n.is_ascii_digit())
                    {
                        text.push(chars[i]);
                        i += 1;
                    }
                } else if d == '.' && chars.get(i + 1).is_some_and(|n| n.is_ascii_digit()) {
                    text.push(d);
                    i += 1;
                } else {
                    break;
                }
            }
            out.tokens.push(Tok {
                text,
                kind: TokKind::Num,
                line: start_line,
            });
            continue;
        }

        // Punctuation: longest-match against the two-char table.
        let start_line = line;
        if let Some(n1) = c1 {
            let pair: String = [c, n1].iter().collect();
            if PUNCT2.contains(&pair.as_str()) {
                bump!(2);
                out.tokens.push(Tok {
                    text: pair,
                    kind: TokKind::Punct,
                    line: start_line,
                });
                continue;
            }
        }
        bump!(1);
        out.tokens.push(Tok {
            text: c.to_string(),
            kind: TokKind::Punct,
            line: start_line,
        });
    }

    out.test_ranges = find_test_ranges(&out.tokens);
    out
}

/// Consume a string body after the opening `"`. For raw strings
/// (`raw == true`) the terminator is `"` followed by `hashes` `#`s and
/// escapes are inert; otherwise `\"` and `\\` are honoured.
fn skip_string_body(chars: &[char], i: &mut usize, line: &mut u32, hashes: usize, raw: bool) {
    while *i < chars.len() {
        let c = chars[*i];
        if c == '\n' {
            *line += 1;
            *i += 1;
            continue;
        }
        if !raw && c == '\\' {
            // Skip the escaped char; a `\<newline>` line-continuation
            // still has to count its newline.
            if chars.get(*i + 1) == Some(&'\n') {
                *line += 1;
            }
            *i += 2;
            continue;
        }
        if c == '"' {
            // Check for the required number of trailing hashes.
            let mut ok = true;
            for k in 0..hashes {
                if chars.get(*i + 1 + k) != Some(&'#') {
                    ok = false;
                    break;
                }
            }
            if ok {
                *i += 1 + hashes;
                return;
            }
        }
        *i += 1;
    }
}

/// Locate `#[cfg(test)]` attributes and brace-match the item that
/// follows each, returning inclusive line ranges. Handles both
/// `#[cfg(test)] mod tests { … }` and attribute-stacked forms.
fn find_test_ranges(tokens: &[Tok]) -> Vec<(u32, u32)> {
    let mut ranges = Vec::new();
    let texts: Vec<&str> = tokens.iter().map(|t| t.text.as_str()).collect();
    let mut idx = 0usize;
    while idx + 6 < tokens.len() {
        let is_cfg_test = texts[idx] == "#"
            && texts[idx + 1] == "["
            && texts[idx + 2] == "cfg"
            && texts[idx + 3] == "("
            && texts[idx + 4] == "test"
            && texts[idx + 5] == ")"
            && texts[idx + 6] == "]";
        if !is_cfg_test {
            idx += 1;
            continue;
        }
        let start_line = tokens[idx].line;
        // Find the opening brace of the annotated item, skipping any
        // further attributes and the item header. Parenthesised
        // stretches (fn args, where-clauses with parens) are skipped
        // so stray `{` inside them can't mislead — at token level a
        // `{` before the body only appears in const-generic or
        // struct-literal positions we don't hit in item headers.
        let mut j = idx + 7;
        let mut open = None;
        while j < tokens.len() {
            match texts[j] {
                "{" => {
                    open = Some(j);
                    break;
                }
                ";" => break, // e.g. `#[cfg(test)] use …;` — zero-length range
                _ => j += 1,
            }
        }
        let Some(open_j) = open else {
            // Attribute on a braceless item: cover just its lines.
            ranges.push((start_line, tokens[j.min(tokens.len() - 1)].line));
            idx += 7;
            continue;
        };
        let mut depth = 0i64;
        let mut end_line = tokens[open_j].line;
        let mut k = open_j;
        while k < tokens.len() {
            match texts[k] {
                "{" => depth += 1,
                "}" => {
                    depth -= 1;
                    if depth == 0 {
                        end_line = tokens[k].line;
                        break;
                    }
                }
                _ => {}
            }
            k += 1;
        }
        if depth != 0 {
            // Unbalanced (mid-edit file): cover to EOF.
            end_line = tokens.last().map(|t| t.line).unwrap_or(start_line);
        }
        ranges.push((start_line, end_line));
        idx = k.max(idx + 7);
    }
    ranges
}

/// Does a numeric token denote a float literal? (`0.0`, `1e-9`,
/// `2f32`, `3.5f64` — but not `0xff`, `10`, `1_000u64`.)
pub fn is_float_literal(tok: &Tok) -> bool {
    if tok.kind != TokKind::Num {
        return false;
    }
    let t = tok.text.as_str();
    if t.starts_with("0x") || t.starts_with("0b") || t.starts_with("0o") {
        return false;
    }
    t.contains('.') || t.ends_with("f32") || t.ends_with("f64") || t.contains('e') || t.contains('E')
}

#[cfg(test)]
mod tests {
    use super::*;

    fn texts(src: &str) -> Vec<String> {
        lex(src).tokens.into_iter().map(|t| t.text).collect()
    }

    #[test]
    fn strips_comments_and_strings() {
        let toks = texts("let x = \"Instant::now()\"; // Instant::now()\n/* Instant::now() */ y");
        assert_eq!(toks, vec!["let", "x", "=", ";", "y"]);
    }

    #[test]
    fn raw_strings_swallowed() {
        let toks = texts("let s = r#\"fn f() { x.unwrap() }\"#; done");
        assert_eq!(toks, vec!["let", "s", "=", ";", "done"]);
    }

    #[test]
    fn lifetimes_vs_char_literals() {
        let toks = texts("fn f<'a>(x: &'a str) { let c = 'x'; let n = '\\n'; }");
        assert!(toks.contains(&"'a".to_string()));
        // char literals are swallowed whole — no stray quote or 'x tokens
        assert!(!toks.contains(&"'x".to_string()));
        assert!(!toks.contains(&"'".to_string()));
    }

    #[test]
    fn multichar_punct_and_ranges() {
        let toks = texts("a == b; c != d; for i in 0..n {} x::y");
        assert!(toks.contains(&"==".to_string()));
        assert!(toks.contains(&"!=".to_string()));
        assert!(toks.contains(&"..".to_string()));
        assert!(toks.contains(&"::".to_string()));
    }

    #[test]
    fn float_literal_detection() {
        let l = lex("a = 0.0; b = 1e-9; c = 2f32; d = 10; e = 0xff; f = 1_000u64;");
        let nums: Vec<&Tok> = l.tokens.iter().filter(|t| t.kind == TokKind::Num).collect();
        let flags: Vec<bool> = nums.iter().map(|t| is_float_literal(t)).collect();
        assert_eq!(flags, vec![true, true, true, false, false, false]);
    }

    #[test]
    fn line_numbers_tracked() {
        let l = lex("a\nb\n\nc");
        let lines: Vec<u32> = l.tokens.iter().map(|t| t.line).collect();
        assert_eq!(lines, vec![1, 2, 4]);
    }

    #[test]
    fn comments_captured_with_lines() {
        let l = lex("x // gcn-lint: allow(D1, reason=\"why\")\ny");
        assert_eq!(l.comments.len(), 1);
        assert_eq!(l.comments[0].line, 1);
        assert!(l.comments[0].text.contains("gcn-lint"));
    }

    #[test]
    fn cfg_test_region_found() {
        let src = "fn prod() {}\n#[cfg(test)]\nmod tests {\n  fn t() {}\n}\nfn after() {}";
        let l = lex(src);
        assert_eq!(l.test_ranges, vec![(2, 5)]);
        assert!(l.in_test_region(3));
        assert!(!l.in_test_region(1));
        assert!(!l.in_test_region(6));
    }
}
