//! Rendering for the analyze pass: human text and the stable
//! tagged-enum JSON schema.
//!
//! The JSON shape mirrors the tagged message enums the report tooling
//! already consumes elsewhere (`{"type": …, "data": {…}}` per node),
//! so future `BENCH_*`/report pipelines can diff contract drift
//! across PRs without a schema negotiation. Schema changes bump
//! `SCHEMA_VERSION`.

use super::rules::{Finding, Suppressed, RULES};
use crate::util::json::Json;
use std::collections::BTreeMap;

/// Bumped whenever the JSON layout changes shape.
pub const SCHEMA_VERSION: i64 = 1;

/// Aggregated result of analyzing a set of roots.
#[derive(Debug, Default)]
pub struct Report {
    pub roots: Vec<String>,
    pub files_scanned: usize,
    pub findings: Vec<Finding>,
    pub suppressed: Vec<Suppressed>,
}

impl Report {
    /// Clean means zero unsuppressed findings — the exit-0 criterion.
    pub fn clean(&self) -> bool {
        self.findings.is_empty()
    }

    /// Per-rule counts over all known rules (zero-filled so the JSON
    /// keys are stable across runs).
    fn counts(items: impl Iterator<Item = String>) -> BTreeMap<String, usize> {
        let mut by_rule: BTreeMap<String, usize> =
            RULES.iter().map(|r| (r.id.to_string(), 0)).collect();
        for rule in items {
            *by_rule.entry(rule).or_insert(0) += 1;
        }
        by_rule
    }

    /// The stable tagged-enum JSON document.
    pub fn to_json(&self) -> Json {
        let by_rule = Self::counts(self.findings.iter().map(|f| f.rule.clone()));
        let suppressed_by_rule = Self::counts(self.suppressed.iter().map(|s| s.rule.clone()));
        let count_obj = |m: &BTreeMap<String, usize>| {
            Json::Obj(
                m.iter()
                    .map(|(k, v)| (k.clone(), Json::Int(*v as i64)))
                    .collect(),
            )
        };
        let findings = Json::Arr(
            self.findings
                .iter()
                .map(|f| {
                    Json::obj(vec![
                        ("type", "finding".into()),
                        (
                            "data",
                            Json::obj(vec![
                                ("rule", f.rule.as_str().into()),
                                ("path", f.path.as_str().into()),
                                ("line", Json::Int(f.line as i64)),
                                ("message", f.message.as_str().into()),
                                ("snippet", f.snippet.as_str().into()),
                            ]),
                        ),
                    ])
                })
                .collect(),
        );
        let suppressed = Json::Arr(
            self.suppressed
                .iter()
                .map(|s| {
                    Json::obj(vec![
                        ("type", "suppressed".into()),
                        (
                            "data",
                            Json::obj(vec![
                                ("rule", s.rule.as_str().into()),
                                ("path", s.path.as_str().into()),
                                ("line", Json::Int(s.line as i64)),
                                ("reason", s.reason.as_str().into()),
                            ]),
                        ),
                    ])
                })
                .collect(),
        );
        let summary = Json::obj(vec![
            ("type", "summary".into()),
            (
                "data",
                Json::obj(vec![
                    ("by_rule", count_obj(&by_rule)),
                    ("suppressed_by_rule", count_obj(&suppressed_by_rule)),
                    ("total", Json::Int(self.findings.len() as i64)),
                    ("suppressed_total", Json::Int(self.suppressed.len() as i64)),
                    ("clean", Json::Bool(self.clean())),
                ]),
            ),
        ]);
        Json::obj(vec![
            ("type", "analysis_report".into()),
            (
                "data",
                Json::obj(vec![
                    ("version", Json::Int(SCHEMA_VERSION)),
                    (
                        "roots",
                        Json::Arr(self.roots.iter().map(|r| r.as_str().into()).collect()),
                    ),
                    ("files_scanned", Json::Int(self.files_scanned as i64)),
                    ("findings", findings),
                    ("suppressed", suppressed),
                    ("summary", summary),
                ]),
            ),
        ])
    }

    /// Human-readable rendering, one finding per block.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "gcn-abft analyze: scanned {} files under [{}]\n",
            self.files_scanned,
            self.roots.join(", ")
        ));
        for f in &self.findings {
            let name = RULES
                .iter()
                .find(|r| r.id == f.rule)
                .map(|r| r.name)
                .unwrap_or("?");
            out.push_str(&format!(
                "  [{} {}] {}:{}: {}\n",
                f.rule, name, f.path, f.line, f.message
            ));
            if !f.snippet.is_empty() {
                out.push_str(&format!("      > {}\n", f.snippet));
            }
        }
        for s in &self.suppressed {
            out.push_str(&format!(
                "  suppressed [{}] {}:{} — reason: {}\n",
                s.rule, s.path, s.line, s.reason
            ));
        }
        if self.clean() {
            out.push_str(&format!(
                "PASS: 0 findings ({} suppressed with reason)\n",
                self.suppressed.len()
            ));
        } else {
            out.push_str(&format!(
                "FAIL: {} finding(s) ({} suppressed with reason)\n",
                self.findings.len(),
                self.suppressed.len()
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Report {
        Report {
            roots: vec!["src".into()],
            files_scanned: 2,
            findings: vec![Finding {
                rule: "F1".into(),
                path: "src/coordinator/server.rs".into(),
                line: 10,
                message: "unwrap".into(),
                snippet: "m.lock().unwrap()".into(),
            }],
            suppressed: vec![Suppressed {
                rule: "D1".into(),
                path: "src/util/bench.rs".into(),
                line: 5,
                reason: "wall clock is the measurement".into(),
            }],
        }
    }

    #[test]
    fn json_schema_shape() {
        let j = sample().to_json();
        assert_eq!(j.get("type").and_then(|t| t.as_str()), Some("analysis_report"));
        let data = j.get("data").expect("data");
        assert_eq!(
            data.get("version").and_then(|v| v.as_f64()),
            Some(SCHEMA_VERSION as f64)
        );
        let summary = data.get("summary").expect("summary");
        assert_eq!(summary.get("type").and_then(|t| t.as_str()), Some("summary"));
        let sd = summary.get("data").expect("summary data");
        assert_eq!(sd.get("total").and_then(|v| v.as_f64()), Some(1.0));
        assert_eq!(sd.get("suppressed_total").and_then(|v| v.as_f64()), Some(1.0));
        // Zero-filled per-rule keys are stable.
        let by_rule = sd.get("by_rule").expect("by_rule");
        for r in RULES {
            assert!(by_rule.get(r.id).is_some(), "missing rule key {}", r.id);
        }
        // Round-trips through the JSON parser.
        let text = j.to_pretty();
        let back = Json::parse(&text).expect("parse back");
        assert_eq!(
            back.get("type").and_then(|t| t.as_str()),
            Some("analysis_report")
        );
    }

    #[test]
    fn render_flags_pass_fail() {
        let r = sample();
        let text = r.render();
        assert!(text.contains("FAIL: 1 finding(s)"));
        assert!(text.contains("[F1 fail-stop-not-panic]"));
        assert!(text.contains("suppressed [D1]"));
        let clean = Report {
            findings: vec![],
            ..sample()
        };
        assert!(clean.render().contains("PASS: 0 findings"));
    }
}
