//! `gcn-abft analyze` — the std-only architectural lint pass.
//!
//! The repo's two load-bearing promises — every scaling mechanism is
//! bit-identical to the simple path, and every fault is fail-stop,
//! never silent — are invariants of the *source*, not just of any one
//! test run. This subsystem mechanizes them as lexer-level lint rules
//! (see [`rules::RULES`]) over a comment/string-stripped token stream
//! ([`lexer`]), reported as human text or a stable tagged-enum JSON
//! document ([`report`]). The scanner is deliberately dependency-free
//! (no `syn`): the offline workspace vendors nothing but `anyhow`,
//! and a token stream is enough to match the forbidden idioms.
//!
//! Entry points: [`analyze_paths`] for library/tests use and
//! [`run_cli`] behind the `analyze` subcommand. Exit status: 0 clean,
//! 1 unsuppressed findings, 2 usage/IO error.

pub mod lexer;
pub mod report;
pub mod rules;

pub use report::{Report, SCHEMA_VERSION};
pub use rules::{scan_source, Finding, Suppressed, RULES};

use crate::util::cli::Args;
use std::path::{Path, PathBuf};

/// Collect every `.rs` file under `root` (or `root` itself if it is a
/// file), sorted so scan order — and therefore report order — is
/// deterministic across filesystems.
fn collect_rs_files(root: &Path) -> Result<Vec<PathBuf>, String> {
    let mut out = Vec::new();
    if root.is_file() {
        out.push(root.to_path_buf());
        return Ok(out);
    }
    if !root.is_dir() {
        return Err(format!("no such file or directory: {}", root.display()));
    }
    let mut stack = vec![root.to_path_buf()];
    while let Some(dir) = stack.pop() {
        let entries = std::fs::read_dir(&dir)
            .map_err(|e| format!("read_dir {}: {e}", dir.display()))?;
        let mut paths: Vec<PathBuf> = entries
            .filter_map(|e| e.ok().map(|e| e.path()))
            .collect();
        paths.sort();
        for p in paths {
            if p.is_dir() {
                stack.push(p);
            } else if p.extension().is_some_and(|e| e == "rs") {
                out.push(p);
            }
        }
    }
    out.sort();
    Ok(out)
}

/// Display path for a scanned file: relative to the current directory
/// when possible, always forward-slashed.
fn display_path(p: &Path) -> String {
    let rel = std::env::current_dir()
        .ok()
        .and_then(|cwd| p.strip_prefix(&cwd).ok().map(|r| r.to_path_buf()))
        .unwrap_or_else(|| p.to_path_buf());
    rel.to_string_lossy().replace('\\', "/")
}

/// Analyze every `.rs` file under the given roots.
pub fn analyze_paths<P: AsRef<Path>>(roots: &[P]) -> Result<Report, String> {
    let mut rep = Report::default();
    for root in roots {
        let root = root.as_ref();
        rep.roots.push(display_path(root));
        for file in collect_rs_files(root)? {
            let src = std::fs::read_to_string(&file)
                .map_err(|e| format!("read {}: {e}", file.display()))?;
            let (mut f, mut s) = scan_source(&display_path(&file), &src);
            rep.files_scanned += 1;
            rep.findings.append(&mut f);
            rep.suppressed.append(&mut s);
        }
    }
    Ok(rep)
}

/// Default scan roots: the crate's `src` and `tests` trees. Resolved
/// against the current directory first (`rust/src` when invoked from
/// the repo root, `src` when invoked from `rust/`), falling back to
/// the crate's own location so `cargo run -- analyze` works from
/// anywhere inside the workspace.
pub fn default_roots() -> Vec<PathBuf> {
    let candidates: [&[&str]; 3] = [
        &["rust/src", "rust/tests"],
        &["src", "tests"],
        &[concat!(env!("CARGO_MANIFEST_DIR"), "/src"), concat!(env!("CARGO_MANIFEST_DIR"), "/tests")],
    ];
    for set in candidates {
        let paths: Vec<PathBuf> = set.iter().map(PathBuf::from).collect();
        if paths.iter().all(|p| p.is_dir()) {
            return paths;
        }
    }
    vec![PathBuf::from("src"), PathBuf::from("tests")]
}

/// CLI driver behind `gcn-abft analyze [--json] [paths…]`.
pub fn run_cli(args: &Args) -> i32 {
    let roots: Vec<PathBuf> = if args.positional.is_empty() {
        default_roots()
    } else {
        args.positional.iter().map(PathBuf::from).collect()
    };
    let rep = match analyze_paths(&roots) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("gcn-abft analyze: {e}");
            return 2;
        }
    };
    if args.has_flag("json") {
        println!("{}", rep.to_json().to_pretty());
    } else {
        print!("{}", rep.render());
    }
    if rep.clean() {
        0
    } else {
        1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collect_is_sorted_and_recursive() {
        // Scan our own module directory deterministically.
        let dir = Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/src/analysis"));
        let files = collect_rs_files(dir).expect("walk");
        assert!(files.len() >= 4);
        let mut sorted = files.clone();
        sorted.sort();
        assert_eq!(files, sorted);
    }

    #[test]
    fn missing_root_is_an_error() {
        assert!(analyze_paths(&[Path::new("/nonexistent/gcn-abft-xyz")]).is_err());
    }
}
