//! The architectural lint rules and the suppression machinery.
//!
//! Each rule mechanizes one contract the repo's tests and README have
//! so far enforced only by convention:
//!
//! | rule | contract |
//! |------|----------|
//! | `D1` | all time comes from the `Clock` trait so `VirtualClock` tests stay authoritative |
//! | `D2` | no hash-order iteration where accumulation order defines bit-identity |
//! | `D3` | checksum partial sums accumulate in f64 — additivity over row bands is only exact there |
//! | `D4` | no `==`/`!=` against float literals outside tests — use thresholds or `total_cmp` |
//! | `F1` | coordinator request paths fail stop (`Failed` responses), never panic |
//! | `C1` | only scoped threads outside the sanctioned spawn sites — no detached workers |
//! | `M1` | resident operand/check-state mutation only through `runtime/mutate.rs` — serving paths go through `GraphDelta` + the epoch fence |
//! | `N1` | raw socket construction only in `coordinator/net.rs` + `coordinator/shard.rs` — one wire path, one frame codec |
//! | `K1` | `unsafe`, arch intrinsics and per-lane kernel entry points only in the kernels modules — call sites use the dispatched entries |
//!
//! Suppression is inline and *reasoned*:
//! `// gcn-lint: allow(RULE, reason="…")` on the finding's line or the
//! line above. A directive without a reason is itself a finding
//! (`LINT`) that cannot be suppressed — the report surfaces every
//! accepted reason so drift stays reviewable.

use super::lexer::{is_float_literal, lex, Lexed, Tok, TokKind};

/// An unsuppressed rule violation.
#[derive(Debug, Clone)]
pub struct Finding {
    pub rule: String,
    pub path: String,
    pub line: u32,
    pub message: String,
    pub snippet: String,
}

/// A violation silenced by a reasoned `gcn-lint: allow` directive.
#[derive(Debug, Clone)]
pub struct Suppressed {
    pub rule: String,
    pub path: String,
    pub line: u32,
    pub reason: String,
}

/// Static description of one rule, for docs and the report header.
pub struct RuleInfo {
    pub id: &'static str,
    pub name: &'static str,
    pub contract: &'static str,
}

/// Every rule the pass knows, in report order. `LINT` is the
/// meta-rule for malformed directives.
pub const RULES: &[RuleInfo] = &[
    RuleInfo {
        id: "D1",
        name: "no-raw-clock",
        contract: "Instant::now/SystemTime::now only in coordinator/clock.rs; \
                   everything else reads time through the Clock trait",
    },
    RuleInfo {
        id: "D2",
        name: "deterministic-iteration",
        contract: "no HashMap/HashSet in abft/ or the shard wire path; \
                   hash-order iteration breaks bit-identical accumulation",
    },
    RuleInfo {
        id: "D3",
        name: "f64-accumulation",
        contract: "no `as f32` narrowing in abft checksum partial-sum paths; \
                   band additivity is only exact in f64",
    },
    RuleInfo {
        id: "D4",
        name: "no-float-eq",
        contract: "no ==/!= against float literals outside tests; \
                   use thresholds or total_cmp",
    },
    RuleInfo {
        id: "F1",
        name: "fail-stop-not-panic",
        contract: "no unwrap/expect/panic!/unreachable! in coordinator \
                   request paths; errors become Failed responses",
    },
    RuleInfo {
        id: "C1",
        name: "scoped-threads-only",
        contract: "thread::spawn only in util/parallel.rs and the shard \
                   transports; all other parallelism is scoped",
    },
    RuleInfo {
        id: "M1",
        name: "mutation-only-in-mutate",
        contract: "GcnOperands/CheckState mutation primitives (mutate::apply, \
                   .swap_weights, CheckState::build) only inside runtime/mutate.rs \
                   and runtime/operands.rs; serving paths mutate through \
                   GraphDelta + EpochFence so every patch is epoch-fenced and \
                   bit-identical to a rebuild",
    },
    RuleInfo {
        id: "N1",
        name: "sockets-only-in-net",
        contract: "TcpListener/TcpStream/UnixListener/UnixStream construction \
                   only in coordinator/net.rs and coordinator/shard.rs; every \
                   byte between coordinator and shard workers goes through the \
                   shard_proto frame codec",
    },
    RuleInfo {
        id: "K1",
        name: "kernels-confine-lane-code",
        contract: "unsafe blocks, std::arch/core::arch intrinsics, runtime \
                   feature detection and the per-lane `*_with` kernel entry \
                   points only inside tensor/kernels.rs and sparse/kernels.rs; \
                   call sites go through the dispatched entries so one module \
                   owns every lane-width decision",
    },
    RuleInfo {
        id: "LINT",
        name: "well-formed-suppression",
        contract: "every gcn-lint directive parses and carries a reason",
    },
];

/// A parsed (or rejected) `gcn-lint:` directive.
#[derive(Debug)]
enum Directive {
    Allow { rule: String, reason: String, line: u32 },
    Malformed { line: u32, detail: String },
}

/// Parse every `gcn-lint:` directive out of the file's line comments.
/// A directive must *start* the comment (after the `//`/`///`/`//!`
/// marker) so prose that merely mentions the syntax is inert.
fn parse_directives(lexed: &Lexed) -> Vec<Directive> {
    let mut out = Vec::new();
    for c in &lexed.comments {
        let head = c
            .text
            .trim_start_matches(|ch| ch == '/' || ch == '!')
            .trim_start();
        let Some(body) = head.strip_prefix("gcn-lint:") else {
            continue;
        };
        out.push(parse_allow(body.trim(), c.line));
    }
    out
}

/// Parse `allow(RULE, reason="…")`. Anything else is `Malformed`.
fn parse_allow(body: &str, line: u32) -> Directive {
    let malformed = |detail: &str| Directive::Malformed {
        line,
        detail: detail.to_string(),
    };
    let Some(rest) = body.strip_prefix("allow") else {
        return malformed("expected `allow(rule, reason=\"…\")`");
    };
    let rest = rest.trim_start();
    let Some(rest) = rest.strip_prefix('(') else {
        return malformed("expected `(` after `allow`");
    };
    let Some(inner) = rest.rfind(')').map(|p| &rest[..p]) else {
        return malformed("unclosed `allow(`");
    };
    let Some((rule_part, reason_part)) = inner.split_once(',') else {
        return malformed("missing `, reason=\"…\"` — suppressions must be justified");
    };
    let rule = rule_part.trim().to_string();
    if rule.is_empty() || !RULES.iter().any(|r| r.id == rule) {
        return malformed(&format!("unknown rule `{rule}`"));
    }
    if rule == "LINT" {
        return malformed("the LINT meta-rule cannot be suppressed");
    }
    let reason_part = reason_part.trim();
    let Some(q) = reason_part.strip_prefix("reason=") else {
        return malformed("expected `reason=\"…\"`");
    };
    let q = q.trim();
    let reason = q
        .strip_prefix('"')
        .and_then(|s| s.strip_suffix('"'))
        .map(|s| s.trim().to_string());
    match reason {
        Some(r) if !r.is_empty() => Directive::Allow { rule, reason: r, line },
        _ => malformed("reason must be a non-empty quoted string"),
    }
}

/// Normalize a path for suffix matching: forward slashes only.
fn norm(path: &str) -> String {
    path.replace('\\', "/")
}

fn ends_with_any(path: &str, suffixes: &[&str]) -> bool {
    suffixes.iter().any(|s| path.ends_with(s))
}

/// Scope predicates — which files each rule watches or exempts.
fn d1_exempt(path: &str) -> bool {
    ends_with_any(path, &["coordinator/clock.rs"])
}
fn d2_scope(path: &str) -> bool {
    path.contains("/abft/")
        || path.starts_with("abft/")
        || ends_with_any(
            path,
            &[
                "coordinator/shard.rs",
                "coordinator/shard_proto.rs",
                "coordinator/net.rs",
            ],
        )
}
fn d3_scope(path: &str) -> bool {
    ends_with_any(path, &["abft/checksum.rs", "abft/fused.rs", "abft/split.rs"])
}
fn d4_exempt_file(path: &str) -> bool {
    // Integration tests (tests/) assert bit-identity with exact float
    // equality on purpose; in-crate #[cfg(test)] regions are excluded
    // per-line instead.
    path.contains("/tests/") || path.starts_with("tests/")
}
fn f1_scope(path: &str) -> bool {
    ends_with_any(
        path,
        &[
            "coordinator/server.rs",
            "coordinator/shard.rs",
            "coordinator/shard_proto.rs",
            "coordinator/net.rs",
            "coordinator/supervisor.rs",
            "coordinator/batcher.rs",
            "coordinator/mod.rs",
        ],
    )
}
fn n1_exempt(path: &str) -> bool {
    // The two transport homes may construct sockets; integration tests
    // exercise transports through their public APIs, and in-crate test
    // regions are excluded per-line like F1/C1.
    ends_with_any(path, &["coordinator/net.rs", "coordinator/shard.rs"])
        || path.contains("/tests/")
        || path.starts_with("tests/")
}
fn c1_exempt(path: &str) -> bool {
    ends_with_any(path, &["util/parallel.rs", "coordinator/shard.rs"])
}
fn m1_exempt(path: &str) -> bool {
    // The mutation subsystem itself and the operand type that owns the
    // primitives. Integration tests exercise the primitives directly.
    ends_with_any(path, &["runtime/mutate.rs", "runtime/operands.rs"])
        || path.contains("/tests/")
        || path.starts_with("tests/")
}
fn k1_exempt(path: &str) -> bool {
    // The kernels modules own lane-width code; integration tests (the
    // bit-identity property suite) call the `*_with` entries to pin the
    // per-lane contract, and in-crate test regions are excluded
    // per-line like F1/C1.
    ends_with_any(path, &["tensor/kernels.rs", "sparse/kernels.rs"])
        || path.contains("/tests/")
        || path.starts_with("tests/")
}

/// Scan one file's source. `path` is the display path (repo-relative
/// where possible); scoping matches on its suffix.
pub fn scan_source(path: &str, src: &str) -> (Vec<Finding>, Vec<Suppressed>) {
    let path = norm(path);
    let lexed = lex(src);
    let lines: Vec<&str> = src.lines().collect();
    let snippet = |line: u32| -> String {
        lines
            .get(line.saturating_sub(1) as usize)
            .map(|l| l.trim().to_string())
            .unwrap_or_default()
    };

    let mut raw: Vec<Finding> = Vec::new();
    let mut push = |rule: &str, line: u32, message: String| {
        raw.push(Finding {
            rule: rule.to_string(),
            path: path.clone(),
            line,
            message,
            snippet: snippet(line),
        });
    };

    let toks = &lexed.tokens;
    let text = |j: usize| toks.get(j).map(|t| t.text.as_str()).unwrap_or("");
    let seq = |j: usize, pat: &[&str]| (0..pat.len()).all(|k| text(j + k) == pat[k]);

    for j in 0..toks.len() {
        let t = &toks[j];

        // D1 no-raw-clock — applies everywhere (tests included: the
        // VirtualClock harness is what keeps the batching tests
        // deterministic) except clock.rs itself.
        if !d1_exempt(&path)
            && (seq(j, &["Instant", "::", "now"]) || seq(j, &["SystemTime", "::", "now"]))
        {
            push(
                "D1",
                t.line,
                format!(
                    "raw `{}::now()` bypasses the Clock trait — inject a Clock \
                     (MonotonicClock/VirtualClock) instead",
                    t.text
                ),
            );
        }

        // D2 deterministic-iteration — hash collections anywhere in
        // the checksum/wire scope, tests included (a hash-ordered
        // test would assert order-dependent sums).
        if d2_scope(&path)
            && t.kind == TokKind::Ident
            && (t.text == "HashMap" || t.text == "HashSet")
        {
            push(
                "D2",
                t.line,
                format!(
                    "`{}` iteration order is nondeterministic — use BTreeMap/BTreeSet \
                     or a sorted Vec so accumulation order is pinned",
                    t.text
                ),
            );
        }

        // D3 f64-accumulation — `as f32` narrowing in checksum files,
        // outside #[cfg(test)] (tests narrow deliberately to build
        // f32 inputs).
        if d3_scope(&path)
            && !lexed.in_test_region(t.line)
            && (seq(j, &["as", "f32"]) || seq(j, &["sum", "::", "<", "f32", ">"]))
        {
            push(
                "D3",
                t.line,
                "f32 narrowing in a checksum partial-sum path — band additivity \
                 (eᵀSHWe summed over bands) is only exact in f64"
                    .to_string(),
            );
        }

        // D4 no-float-eq — ==/!= adjacent to a float literal, outside
        // tests.
        if !d4_exempt_file(&path)
            && !lexed.in_test_region(t.line)
            && (t.text == "==" || t.text == "!=")
            && t.kind == TokKind::Punct
        {
            let prev_float = j > 0 && is_float_literal(&toks[j - 1]);
            let next_float = toks.get(j + 1).map(is_float_literal).unwrap_or(false);
            if prev_float || next_float {
                push(
                    "D4",
                    t.line,
                    format!(
                        "`{}` against a float literal — exact float comparison; \
                         use a threshold or restructure (annotate if exactness is the point)",
                        t.text
                    ),
                );
            }
        }

        // F1 fail-stop-not-panic — coordinator request paths only,
        // outside #[cfg(test)].
        if f1_scope(&path) && !lexed.in_test_region(t.line) {
            let prev_dot = j > 0 && text(j - 1) == ".";
            if prev_dot && t.kind == TokKind::Ident && (t.text == "unwrap" || t.text == "expect") {
                push(
                    "F1",
                    t.line,
                    format!(
                        "`.{}()` in a coordinator request path can abort the server — \
                         propagate the error into a Failed response \
                         (recover lock poison explicitly)",
                        t.text
                    ),
                );
            }
            if t.kind == TokKind::Ident
                && (t.text == "panic" || t.text == "unreachable")
                && text(j + 1) == "!"
            {
                push(
                    "F1",
                    t.line,
                    format!(
                        "`{}!` in a coordinator request path — the fail-stop contract \
                         requires a Failed response, not a crash",
                        t.text
                    ),
                );
            }
        }

        // M1 mutation-only-in-mutate — the in-place operand mutation
        // primitives outside the sanctioned module, outside tests.
        // Serving paths must route mutation through GraphDelta + the
        // EpochFence so patches are fenced and bit-identical to a
        // rebuild; direct calls bypass both.
        if !m1_exempt(&path) && !lexed.in_test_region(t.line) {
            if seq(j, &["mutate", "::", "apply"]) {
                push(
                    "M1",
                    t.line,
                    "direct `mutate::apply` on resident operands bypasses the \
                     epoch fence — go through EpochFence::apply (annotate \
                     offline tooling that owns its operands)"
                        .to_string(),
                );
            }
            let prev_dot = j > 0 && text(j - 1) == ".";
            if prev_dot && t.kind == TokKind::Ident && t.text == "swap_weights" {
                push(
                    "M1",
                    t.line,
                    "`.swap_weights()` outside runtime/mutate.rs mutates resident \
                     operands unfenced — submit GraphDelta::SwapWeights instead"
                        .to_string(),
                );
            }
            if seq(j, &["CheckState", "::", "build"]) {
                push(
                    "M1",
                    t.line,
                    "`CheckState::build` outside the operand module rebuilds \
                     checksum state out of band — the cached state in \
                     GcnOperands is the single source of truth"
                        .to_string(),
                );
            }
        }

        // N1 sockets-only-in-net — raw socket construction outside the
        // transport homes forks the wire path: bytes that bypass the
        // shard_proto codec can drift from the frames the bit-identity
        // and fail-stop tests pin.
        if !n1_exempt(&path) && !lexed.in_test_region(t.line) {
            let socket_ctor = seq(j, &["TcpListener", "::", "bind"])
                || seq(j, &["TcpStream", "::", "connect"])
                || seq(j, &["UnixListener", "::", "bind"])
                || seq(j, &["UnixStream", "::", "connect"]);
            if socket_ctor {
                push(
                    "N1",
                    t.line,
                    format!(
                        "raw `{}` construction outside coordinator/net.rs and \
                         coordinator/shard.rs — route shard traffic through the \
                         transports so every frame goes through shard_proto",
                        t.text
                    ),
                );
            }
        }

        // C1 scoped-threads-only — `thread::spawn` outside the
        // sanctioned spawn sites (scope.spawn is a method call and
        // never matches this token sequence).
        if !c1_exempt(&path) && !lexed.in_test_region(t.line) && seq(j, &["thread", "::", "spawn"])
        {
            push(
                "C1",
                t.line,
                "detached `thread::spawn` — use std::thread::scope (or the \
                 util::parallel helpers) so worker lifetimes are bounded"
                    .to_string(),
            );
        }

        // K1 kernels-confine-lane-code — lane-width machinery outside
        // the kernels modules forks the bit-identity contract: a second
        // home for unsafe/intrinsics/per-lane entries is a second place
        // the per-lane-width property tests would have to pin.
        if !k1_exempt(&path) && !lexed.in_test_region(t.line) {
            if t.kind == TokKind::Ident && t.text == "unsafe" {
                push(
                    "K1",
                    t.line,
                    "`unsafe` outside the kernels modules — intrinsic or \
                     aliasing tricks belong in tensor/kernels.rs / \
                     sparse/kernels.rs where the bit-identity tests pin them"
                        .to_string(),
                );
            }
            if seq(j, &["std", "::", "arch"]) || seq(j, &["core", "::", "arch"]) {
                push(
                    "K1",
                    t.line,
                    format!(
                        "`{}::arch` intrinsics outside the kernels modules — \
                         keep arch-specific code behind the dispatched kernel \
                         entries",
                        t.text
                    ),
                );
            }
            if t.kind == TokKind::Ident && t.text == "is_x86_feature_detected" {
                push(
                    "K1",
                    t.line,
                    "runtime feature detection outside the kernels modules — \
                     lane selection is kernels::active()'s decision alone"
                        .to_string(),
                );
            }
            if t.kind == TokKind::Ident
                && matches!(
                    t.text.as_str(),
                    "axpy_f32_with" | "axpy_f32_to_f64_with" | "col_acc_f64_with"
                )
            {
                push(
                    "K1",
                    t.line,
                    format!(
                        "per-lane entry `{}` called outside the kernels \
                         modules — use the dispatched entry (axpy_f32 / \
                         axpy_f32_to_f64 / col_acc_f64) so GCN_ABFT_KERNEL \
                         and forced overrides keep governing lane width",
                        t.text
                    ),
                );
            }
        }
    }

    // Apply suppressions: a reasoned allow on the finding's line or
    // the line directly above silences it (and is surfaced in the
    // report); malformed directives become LINT findings.
    let directives = parse_directives(&lexed);
    let mut findings = Vec::new();
    let mut suppressed = Vec::new();
    for f in raw {
        let hit = directives.iter().find_map(|d| match d {
            Directive::Allow { rule, reason, line }
                if *rule == f.rule && (*line == f.line || *line + 1 == f.line) =>
            {
                Some(reason.clone())
            }
            _ => None,
        });
        match hit {
            Some(reason) => suppressed.push(Suppressed {
                rule: f.rule,
                path: f.path,
                line: f.line,
                reason,
            }),
            None => findings.push(f),
        }
    }
    for d in &directives {
        if let Directive::Malformed { line, detail } = d {
            findings.push(Finding {
                rule: "LINT".to_string(),
                path: path.clone(),
                line: *line,
                message: format!("malformed gcn-lint directive: {detail}"),
                snippet: snippet(*line),
            });
        }
    }
    findings.sort_by_key(|f| f.line);
    (findings, suppressed)
}

#[cfg(test)]
mod tests {
    use super::*;

    // Fixtures are assembled by joining lines (so this file's own
    // scan — string literals are stripped — stays clean regardless).
    fn src(lines: &[&str]) -> String {
        lines.join("\n")
    }

    fn findings_for(path: &str, lines: &[&str]) -> Vec<Finding> {
        scan_source(path, &src(lines)).0
    }

    #[test]
    fn d1_positive_and_exempt() {
        let code = ["fn f() {", "let t = Instant::now();", "}"];
        let f = findings_for("src/coordinator/server.rs", &code);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, "D1");
        assert_eq!(f[0].line, 2);
        assert!(findings_for("src/coordinator/clock.rs", &code).is_empty());
    }

    #[test]
    fn d1_suppressed_with_reason() {
        let code = [
            "// gcn-lint: allow(D1, reason=\"wall-clock is the measurement\")",
            "let t = Instant::now();",
        ];
        let (f, s) = scan_source("src/util/bench.rs", &src(&code));
        assert!(f.is_empty());
        assert_eq!(s.len(), 1);
        assert_eq!(s[0].rule, "D1");
        assert_eq!(s[0].reason, "wall-clock is the measurement");
    }

    #[test]
    fn suppression_without_reason_rejected() {
        let code = ["// gcn-lint: allow(D1)", "let t = Instant::now();"];
        let f = findings_for("src/util/bench.rs", &code);
        // Both the original D1 and a LINT finding survive.
        assert!(f.iter().any(|x| x.rule == "D1"));
        assert!(f.iter().any(|x| x.rule == "LINT"));
    }

    #[test]
    fn suppression_of_unknown_rule_rejected() {
        let code = ["// gcn-lint: allow(Z9, reason=\"nope\")"];
        let f = findings_for("src/lib.rs", &code);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, "LINT");
    }

    #[test]
    fn d2_positive_and_out_of_scope() {
        let code = ["use std::collections::HashMap;"];
        assert_eq!(findings_for("src/abft/fused.rs", &code).len(), 1);
        assert_eq!(findings_for("src/coordinator/shard.rs", &code).len(), 1);
        assert!(findings_for("src/graph/synth.rs", &code).is_empty());
    }

    #[test]
    fn d3_positive_and_test_region_exempt() {
        let code = ["fn f(x: f64) -> f32 {", "x as f32", "}"];
        let f = findings_for("src/abft/checksum.rs", &code);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, "D3");
        let test_code = [
            "#[cfg(test)]",
            "mod tests {",
            "fn f(x: f64) -> f32 { x as f32 }",
            "}",
        ];
        assert!(findings_for("src/abft/checksum.rs", &test_code).is_empty());
        // Out-of-scope file: no D3.
        assert!(findings_for("src/tensor/ops.rs", &code).is_empty());
    }

    #[test]
    fn d4_positive_negative_and_tests_exempt() {
        let pos = ["if x == 0.0 { return; }"];
        let f = findings_for("src/sparse/csr.rs", &pos);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, "D4");
        // Threshold comparison is fine.
        assert!(findings_for("src/sparse/csr.rs", &["if x <= 1e-7 { return; }"]).is_empty());
        // Integer equality is fine.
        assert!(findings_for("src/sparse/csr.rs", &["if n == 0 { return; }"]).is_empty());
        // Integration tests assert bit-identity deliberately.
        assert!(findings_for("tests/prop_pin.rs", &pos).is_empty());
    }

    #[test]
    fn f1_positive_negative_and_scope() {
        let code = ["fn f() {", "let g = m.lock().unwrap();", "}"];
        let f = findings_for("src/coordinator/batcher.rs", &code);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, "F1");
        // Poison recovery does not trip the rule.
        let ok = ["let g = m.lock().unwrap_or_else(|p| p.into_inner());"];
        assert!(findings_for("src/coordinator/batcher.rs", &ok).is_empty());
        // Out of scope: unwrap is allowed elsewhere.
        assert!(findings_for("src/gcn/train.rs", &code).is_empty());
        // panic!/unreachable! in scope.
        let p = ["fn f() {", "panic!(\"boom\");", "unreachable!()", "}"];
        assert_eq!(findings_for("src/coordinator/mod.rs", &p).len(), 2);
    }

    #[test]
    fn f1_test_region_exempt() {
        let code = [
            "#[cfg(test)]",
            "mod tests {",
            "fn t() { m.lock().unwrap(); }",
            "}",
        ];
        assert!(findings_for("src/coordinator/server.rs", &code).is_empty());
    }

    #[test]
    fn c1_positive_and_exempt() {
        let code = ["let h = std::thread::spawn(|| {});"];
        let f = findings_for("src/coordinator/mod.rs", &code);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, "C1");
        assert!(findings_for("src/util/parallel.rs", &code).is_empty());
        assert!(findings_for("src/coordinator/shard.rs", &code).is_empty());
        // scope.spawn is a method call — clean.
        assert!(findings_for(
            "src/coordinator/mod.rs",
            &["std::thread::scope(|s| { s.spawn(|| {}); });"]
        )
        .is_empty());
    }

    #[test]
    fn m1_positive_exempt_and_suppressed() {
        let patch = ["let o = mutate::apply(&mut ops, &delta)?;"];
        let f = findings_for("src/coordinator/server.rs", &patch);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, "M1");
        // The sanctioned module, the operand type and tests are exempt.
        assert!(findings_for("src/runtime/mutate.rs", &patch).is_empty());
        assert!(findings_for("src/runtime/operands.rs", &patch).is_empty());
        assert!(findings_for("tests/prop_incremental_operands.rs", &patch).is_empty());
        let test_region = [
            "#[cfg(test)]",
            "mod tests {",
            "fn t() { mutate::apply(&mut ops, &d).unwrap(); }",
            "}",
        ];
        assert!(findings_for("src/coordinator/shard.rs", &test_region).is_empty());
        // Reasoned suppression works like any other rule.
        let allowed = [
            "// gcn-lint: allow(M1, reason=\"offline verifier owns the operands\")",
            "let o = mutate::apply(&mut ops, &delta)?;",
        ];
        let (f2, s2) = scan_source("src/main.rs", &src(&allowed));
        assert!(f2.is_empty());
        assert_eq!(s2.len(), 1);
        assert_eq!(s2[0].rule, "M1");
    }

    #[test]
    fn m1_swap_weights_and_check_state_build() {
        let swap = ["ops.swap_weights(w1, w2)?;"];
        let f = findings_for("src/coordinator/server.rs", &swap);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, "M1");
        // Declaring a fn named swap_weights is not a call on operands.
        assert!(
            findings_for("src/coordinator/server.rs", &["fn swap_weights() {}"]).is_empty()
        );
        let build = ["let c = CheckState::build(&f, &s, &w1, &w2);"];
        let f2 = findings_for("src/runtime/backend/native.rs", &build);
        assert_eq!(f2.len(), 1);
        assert_eq!(f2[0].rule, "M1");
        assert!(findings_for("src/runtime/operands.rs", &build).is_empty());
    }

    #[test]
    fn n1_positive_exempt_and_suppressed() {
        let dial = ["let s = TcpStream::connect(addr)?;"];
        let f = findings_for("src/coordinator/server.rs", &dial);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, "N1");
        let bind = ["let l = std::net::TcpListener::bind(addr)?;"];
        assert_eq!(findings_for("src/report/bench.rs", &bind).len(), 1);
        let unix = ["let s = UnixStream::connect(path)?;"];
        assert_eq!(findings_for("src/runtime/mutate.rs", &unix).len(), 1);
        // The transport homes may construct sockets.
        assert!(findings_for("src/coordinator/net.rs", &dial).is_empty());
        assert!(findings_for("src/coordinator/shard.rs", &unix).is_empty());
        // Integration tests and in-crate test regions are exempt.
        assert!(findings_for("tests/supervised_recovery.rs", &dial).is_empty());
        let test_region = [
            "#[cfg(test)]",
            "mod tests {",
            "fn t() { let l = TcpListener::bind(\"127.0.0.1:0\").unwrap(); }",
            "}",
        ];
        assert!(findings_for("src/graph/synth.rs", &test_region).is_empty());
        // Reasoned suppression works like any other rule.
        let allowed = [
            "// gcn-lint: allow(N1, reason=\"delta feed client, not shard traffic\")",
            "let s = UnixStream::connect(path)?;",
        ];
        let (f2, s2) = scan_source("src/coordinator/mod.rs", &src(&allowed));
        assert!(f2.is_empty());
        assert_eq!(s2.len(), 1);
        assert_eq!(s2[0].rule, "N1");
    }

    #[test]
    fn k1_positive_exempt_and_suppressed() {
        let unsafe_block = ["unsafe { core::arch::x86_64::_mm256_setzero_ps() };"];
        let f = findings_for("src/tensor/ops.rs", &unsafe_block);
        // Both the `unsafe` keyword and the core::arch path are flagged.
        assert_eq!(f.len(), 2, "{f:?}");
        assert!(f.iter().all(|x| x.rule == "K1"));
        let detect = ["if is_x86_feature_detected(\"avx2\") {}"];
        assert_eq!(findings_for("src/runtime/backend/native.rs", &detect).len(), 1);
        let lane_entry = ["kernels::axpy_f32_with(Lanes::X8, out, a, b);"];
        let f2 = findings_for("src/sparse/csr.rs", &lane_entry);
        assert_eq!(f2.len(), 1);
        assert_eq!(f2[0].rule, "K1");
        // The dispatched entry is the sanctioned call shape.
        assert!(
            findings_for("src/sparse/csr.rs", &["kernels::axpy_f32(out, a, b);"]).is_empty()
        );
        // The kernels modules own the lane code.
        assert!(findings_for("src/tensor/kernels.rs", &unsafe_block).is_empty());
        assert!(findings_for("src/sparse/kernels.rs", &lane_entry).is_empty());
        // Integration tests and in-crate test regions are exempt.
        assert!(findings_for("tests/prop_kernels.rs", &lane_entry).is_empty());
        let test_region = [
            "#[cfg(test)]",
            "mod tests {",
            "fn t() { kernels::axpy_f32_with(Lanes::Scalar, o, a, b); }",
            "}",
        ];
        assert!(findings_for("src/tensor/ops.rs", &test_region).is_empty());
        // Reasoned suppression works like any other rule.
        let allowed = [
            "// gcn-lint: allow(K1, reason=\"pinning one lane for a repro\")",
            "kernels::axpy_f32_with(Lanes::X8, out, a, b);",
        ];
        let (f3, s3) = scan_source("src/main.rs", &src(&allowed));
        assert!(f3.is_empty());
        assert_eq!(s3.len(), 1);
        assert_eq!(s3[0].rule, "K1");
    }

    #[test]
    fn suppression_line_above_or_same_line() {
        let above = [
            "// gcn-lint: allow(C1, reason=\"driver outlives scope\")",
            "let h = std::thread::spawn(|| {});",
        ];
        let (f, s) = scan_source("src/coordinator/mod.rs", &src(&above));
        assert!(f.is_empty());
        assert_eq!(s.len(), 1);
        let same = ["let h = std::thread::spawn(|| {}); // gcn-lint: allow(C1, reason=\"x\")"];
        let (f2, s2) = scan_source("src/coordinator/mod.rs", &src(&same));
        assert!(f2.is_empty());
        assert_eq!(s2.len(), 1);
    }

    #[test]
    fn lint_rule_itself_not_suppressible() {
        let code = ["// gcn-lint: allow(LINT, reason=\"meta\")"];
        let f = findings_for("src/lib.rs", &code);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, "LINT");
    }
}
