//! Fault-injection campaigns: the engine behind Table I.
//!
//! One campaign = one forward pass of the checked 2-layer GCN with `k`
//! injected faults (k = 1 single-bit flips for the main table, k ≥ 2 for
//! the §IV-B multi-fault experiment; multi-bit and stuck-at models are
//! available through [`FaultModelKind`]). Faults land uniformly on the
//! op timeline of the *checked* execution, so longer phases and bigger
//! matrices attract proportionally more faults, and the checker's own
//! state is exposed to faults — both as in the paper.
//!
//! Campaigns run on the [`InstrumentedEngine`] — the same banded f64
//! engine behind the `instrumented` serving backend — never on a
//! concrete forward path directly. Because the engine's fault timeline
//! is split at fixed logical-band prefix offsets, a campaign's
//! detections are bit-identical whether a single forward runs serially
//! or band-parallel (`cfg.band_workers`).
//!
//! Classification at each threshold τ (see DESIGN.md §6). "Corrupted"
//! means the output differs *numerically* from the golden run at all
//! (bit-level — the paper's faults always land in a stored result):
//! * **detected** — output corrupted and some check fired;
//! * **false positive** — output correct but a check fired (flip landed in
//!   check state);
//! * **silent** — output corrupted, no check fired (the fault's checksum
//!   residual sits below τ — exactly the paper's "indistinguishable from
//!   rounding" bucket, which vanishes as τ tightens);
//! * **benign** — output bit-identical and no check fired (e.g. a sign
//!   flip on a 0.0 product; the paper folds these into its three buckets —
//!   we report them separately for transparency, see EXPERIMENTS.md).

use super::bitflip::FaultSite;
use super::model::FaultModelKind;
use crate::abft::Scheme;
use crate::runtime::backend::instrumented::EngineRun;
use crate::runtime::backend::{ChecksumScheme, InstrumentedEngine};
use crate::util::rng::{Pcg64, SplitMix64};

/// Campaign sweep configuration.
#[derive(Debug, Clone)]
pub struct CampaignConfig {
    pub scheme: ChecksumScheme,
    /// Which fault model samples each campaign's events.
    pub fault_model: FaultModelKind,
    pub thresholds: Vec<f64>,
    pub campaigns: usize,
    pub faults_per_campaign: usize,
    pub seed: u64,
    /// Workers across campaigns (outer parallelism).
    pub threads: usize,
    /// Workers inside one checked forward (logical-band parallelism;
    /// results are bit-identical at any value).
    pub band_workers: usize,
}

impl Default for CampaignConfig {
    fn default() -> Self {
        Self {
            scheme: Scheme::Fused,
            fault_model: FaultModelKind::BitFlip,
            thresholds: crate::abft::CheckPolicy::PAPER_THRESHOLDS.to_vec(),
            campaigns: 500,
            faults_per_campaign: 1,
            seed: 0xABF7,
            threads: default_threads(),
            band_workers: 1,
        }
    }
}

/// A sensible worker count for campaign parallelism.
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .clamp(1, 16)
}

/// Outcome counts at one threshold.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Tally {
    pub detected: usize,
    pub false_positive: usize,
    pub silent: usize,
    pub benign: usize,
}

impl Tally {
    pub fn total(&self) -> usize {
        self.detected + self.false_positive + self.silent + self.benign
    }
    pub fn detected_rate(&self) -> f64 {
        self.detected as f64 / self.total().max(1) as f64
    }
    pub fn false_positive_rate(&self) -> f64 {
        self.false_positive as f64 / self.total().max(1) as f64
    }
    pub fn silent_rate(&self) -> f64 {
        self.silent as f64 / self.total().max(1) as f64
    }
    pub fn benign_rate(&self) -> f64 {
        self.benign as f64 / self.total().max(1) as f64
    }
}

/// Aggregated result of a campaign sweep.
#[derive(Debug, Clone)]
pub struct CampaignReport {
    pub scheme: ChecksumScheme,
    pub fault_model: FaultModelKind,
    pub campaigns: usize,
    pub faults_per_campaign: usize,
    /// (threshold, tally), in the order of `cfg.thresholds`.
    pub per_threshold: Vec<(f64, Tally)>,
    /// Campaigns in which ≥ 1 node's output row changed numerically
    /// (the paper's "critical fault" — Table I columns 2–3).
    pub critical: usize,
    /// Mean fraction of nodes with changed outputs, over critical
    /// campaigns.
    pub avg_nodes_affected: f64,
    /// Stricter criticality: campaigns where ≥ 1 node's *argmax class*
    /// changed (not in the paper's table; reported for depth).
    pub class_critical: usize,
    /// Mean fraction of nodes whose argmax changed, over class-critical
    /// campaigns.
    pub avg_classes_changed: f64,
    /// Faults that landed on data-path (matmul) results.
    pub data_faults: usize,
    /// Faults that landed on checksum-accumulation results.
    pub checksum_faults: usize,
    /// Total ops on the checked timeline (per campaign).
    pub timeline_ops: u64,
}

impl CampaignReport {
    pub fn critical_rate(&self) -> f64 {
        self.critical as f64 / self.campaigns.max(1) as f64
    }
}

/// Raw per-campaign measurements, classified later under each τ.
struct CampaignOutcome {
    /// |predicted − actual| per check (NaN possible — handled as fired).
    residuals: Vec<f64>,
    /// max |faulty − golden| across all layer preactivations.
    max_diff: f64,
    /// Nodes whose final-layer output row changed numerically.
    nodes_affected: usize,
    /// Nodes whose argmax class changed.
    classes_changed: usize,
    sites: Vec<FaultSite>,
}

/// Run a full campaign sweep on an instrumented engine.
pub fn run_campaigns(engine: &InstrumentedEngine, cfg: &CampaignConfig) -> CampaignReport {
    assert!(!cfg.thresholds.is_empty());
    assert!(cfg.faults_per_campaign >= 1);

    // Golden reference (fault-free checked forward — the data path of a
    // hooked run with no events is bit-identical to an unhooked one).
    let golden = engine.forward(cfg.scheme, &[], cfg.band_workers);
    let golden_classes = golden.preacts.last().unwrap().argmax_rows();
    let timeline_ops = golden.timeline_ops;

    // Per-campaign RNG derivation that is independent of thread layout.
    let mut sm = SplitMix64::new(cfg.seed);
    let base = sm.next_u64();

    let outcomes: Vec<CampaignOutcome> = if cfg.threads <= 1 {
        (0..cfg.campaigns)
            .map(|i| run_one(engine, &golden, &golden_classes, cfg, base, i, timeline_ops))
            .collect()
    } else {
        let mut results: Vec<Option<CampaignOutcome>> = Vec::new();
        results.resize_with(cfg.campaigns, || None);
        let next = std::sync::atomic::AtomicUsize::new(0);
        let results_mx = std::sync::Mutex::new(&mut results);
        std::thread::scope(|scope| {
            for _ in 0..cfg.threads {
                scope.spawn(|| loop {
                    let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    if i >= cfg.campaigns {
                        break;
                    }
                    let out =
                        run_one(engine, &golden, &golden_classes, cfg, base, i, timeline_ops);
                    results_mx.lock().unwrap()[i] = Some(out);
                });
            }
        });
        results.into_iter().map(|o| o.unwrap()).collect()
    };

    // Classify under each threshold.
    let mut per_threshold = Vec::with_capacity(cfg.thresholds.len());
    for &tau in &cfg.thresholds {
        let mut tally = Tally::default();
        for o in &outcomes {
            // NaN-safe comparisons: non-finite residuals count as fired.
            let fired = o.residuals.iter().any(|&r| !(r <= tau));
            // Corruption is bit-level: any numeric deviation from golden.
            let corrupted = !(o.max_diff <= 0.0);
            match (corrupted, fired) {
                (true, true) => tally.detected += 1,
                (false, true) => tally.false_positive += 1,
                (true, false) => tally.silent += 1,
                (false, false) => tally.benign += 1,
            }
        }
        per_threshold.push((tau, tally));
    }

    let n_nodes = golden_classes.len() as f64;
    let critical = outcomes.iter().filter(|o| o.nodes_affected > 0).count();
    let avg_nodes_affected = if critical > 0 {
        outcomes
            .iter()
            .filter(|o| o.nodes_affected > 0)
            .map(|o| o.nodes_affected as f64 / n_nodes)
            .sum::<f64>()
            / critical as f64
    } else {
        0.0
    };
    let class_critical = outcomes.iter().filter(|o| o.classes_changed > 0).count();
    let avg_classes_changed = if class_critical > 0 {
        outcomes
            .iter()
            .filter(|o| o.classes_changed > 0)
            .map(|o| o.classes_changed as f64 / n_nodes)
            .sum::<f64>()
            / class_critical as f64
    } else {
        0.0
    };
    let data_faults = outcomes
        .iter()
        .flat_map(|o| &o.sites)
        .filter(|s| matches!(s, FaultSite::DataMul | FaultSite::DataAdd))
        .count();
    let checksum_faults = outcomes
        .iter()
        .flat_map(|o| &o.sites)
        .filter(|s| matches!(s, FaultSite::ChecksumAcc))
        .count();

    CampaignReport {
        scheme: cfg.scheme,
        fault_model: cfg.fault_model,
        campaigns: cfg.campaigns,
        faults_per_campaign: cfg.faults_per_campaign,
        per_threshold,
        critical,
        avg_nodes_affected,
        class_critical,
        avg_classes_changed,
        data_faults,
        checksum_faults,
        timeline_ops,
    }
}

fn run_one(
    engine: &InstrumentedEngine,
    golden: &EngineRun,
    golden_classes: &[usize],
    cfg: &CampaignConfig,
    base: u64,
    index: usize,
    timeline_ops: u64,
) -> CampaignOutcome {
    let mut rng = Pcg64::new(base, index as u64);
    let events = cfg
        .fault_model
        .sample(&mut rng, timeline_ops, cfg.faults_per_campaign);
    let run = engine.forward(cfg.scheme, &events, cfg.band_workers);
    // A fault scheduled near the tail of its timeline segment can defer
    // past the segment end without firing (zero-value deferral); such a
    // campaign is a clean run and classifies as benign.

    let residuals = run.checks.iter().map(|c| c.residual()).collect();
    let mut max_diff = 0f64;
    for (p, g) in run.preacts.iter().zip(&golden.preacts) {
        let d = p.max_abs_diff(g);
        // Propagate NaN as "definitely corrupted".
        if d.is_nan() {
            max_diff = f64::NAN;
            break;
        }
        max_diff = max_diff.max(d);
    }
    // Per-node spread of the fault at the final layer (paper's
    // "nodes critically affected"): rows that changed numerically.
    let last = run.preacts.last().unwrap();
    let last_golden = golden.preacts.last().unwrap();
    let mut nodes_affected = 0usize;
    for r in 0..last.rows() {
        let changed = last
            .row(r)
            .iter()
            .zip(last_golden.row(r))
            .any(|(a, b)| a.to_bits() != b.to_bits());
        if changed {
            nodes_affected += 1;
        }
    }
    let classes = last.argmax_rows();
    let classes_changed = classes
        .iter()
        .zip(golden_classes)
        .filter(|(a, b)| a != b)
        .count();

    CampaignOutcome {
        residuals,
        max_diff,
        nodes_affected,
        classes_changed,
        sites: run.hits.iter().map(|h| h.site).collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gcn::GcnModel;
    use crate::graph::DatasetId;

    fn setup() -> InstrumentedEngine {
        let g = DatasetId::Tiny.build(0);
        let m = GcnModel::two_layer(&g, 8, 1);
        InstrumentedEngine::from_model(&m, &g.features)
    }

    fn cfg(scheme: Scheme, campaigns: usize) -> CampaignConfig {
        CampaignConfig {
            scheme,
            campaigns,
            threads: 2,
            ..Default::default()
        }
    }

    #[test]
    fn tallies_partition_campaigns() {
        let engine = setup();
        let report = run_campaigns(&engine, &cfg(Scheme::Fused, 100));
        assert_eq!(report.per_threshold.len(), 4);
        for (_, t) in &report.per_threshold {
            assert_eq!(t.total(), 100, "tally doesn't partition: {t:?}");
        }
        let landed = report.data_faults + report.checksum_faults;
        assert!(
            landed <= 100 && landed >= 93,
            "faults should (almost) always land: {landed}/100"
        );
    }

    #[test]
    fn deterministic_across_thread_counts() {
        let engine = setup();
        let mut c1 = cfg(Scheme::Split, 60);
        c1.threads = 1;
        let mut c4 = cfg(Scheme::Split, 60);
        c4.threads = 4;
        let r1 = run_campaigns(&engine, &c1);
        let r4 = run_campaigns(&engine, &c4);
        for ((t1, a), (t4, b)) in r1.per_threshold.iter().zip(&r4.per_threshold) {
            assert_eq!(t1, t4);
            assert_eq!(a, b, "thread count changed results");
        }
        assert_eq!(r1.critical, r4.critical);
    }

    #[test]
    fn deterministic_across_band_worker_counts() {
        // The tentpole determinism claim: band-parallel checked forwards
        // report bit-identical detections to the serial run.
        let engine = setup();
        let mut serial = cfg(Scheme::Fused, 50);
        serial.band_workers = 1;
        serial.threads = 1;
        let r1 = run_campaigns(&engine, &serial);
        for workers in [2, 4] {
            let mut par = serial.clone();
            par.band_workers = workers;
            let rp = run_campaigns(&engine, &par);
            assert_eq!(r1.per_threshold, rp.per_threshold, "band_workers={workers}");
            assert_eq!(r1.critical, rp.critical);
            assert_eq!(r1.data_faults, rp.data_faults);
            assert_eq!(r1.checksum_faults, rp.checksum_faults);
        }
    }

    #[test]
    fn detection_improves_or_holds_with_tighter_threshold() {
        let engine = setup();
        let report = run_campaigns(&engine, &cfg(Scheme::Fused, 300));
        // Silent rate must be non-increasing as τ tightens.
        let silents: Vec<usize> = report.per_threshold.iter().map(|(_, t)| t.silent).collect();
        for w in silents.windows(2) {
            assert!(w[1] <= w[0], "silent rate increased with tighter τ: {silents:?}");
        }
        // At τ=1e-7 silent faults should (nearly) vanish — paper finds 0.
        let tight = report.per_threshold.last().unwrap().1;
        assert!(
            tight.silent_rate() < 0.02,
            "silent rate at 1e-7 too high: {:?}",
            tight
        );
    }

    #[test]
    fn most_faults_hit_the_data_path() {
        // Matmul dominates the timeline, so most flips land there (§IV-A).
        let engine = setup();
        let report = run_campaigns(&engine, &cfg(Scheme::Fused, 200));
        assert!(
            report.data_faults > report.checksum_faults,
            "data {} vs checksum {}",
            report.data_faults,
            report.checksum_faults
        );
    }

    #[test]
    fn multi_fault_detection_is_at_least_single_fault() {
        let engine = setup();
        let mut single = cfg(Scheme::Fused, 150);
        single.faults_per_campaign = 1;
        let mut multi = cfg(Scheme::Fused, 150);
        multi.faults_per_campaign = 3;
        let rs = run_campaigns(&engine, &single);
        let rm = run_campaigns(&engine, &multi);
        let tau_idx = 3; // 1e-7
        let ds = rs.per_threshold[tau_idx].1;
        let dm = rm.per_threshold[tau_idx].1;
        // With 3 faults, almost every campaign is flagged (paper: 100%).
        let flagged = dm.detected + dm.false_positive;
        assert!(
            flagged as f64 / dm.total() as f64 + 0.02
                >= (ds.detected + ds.false_positive) as f64 / ds.total() as f64,
            "multi-fault flag rate regressed: single {ds:?}, multi {dm:?}"
        );
    }

    #[test]
    fn split_and_fused_have_comparable_detection() {
        let engine = setup();
        let rs = run_campaigns(&engine, &cfg(Scheme::Split, 300));
        let rf = run_campaigns(&engine, &cfg(Scheme::Fused, 300));
        let ds = rs.per_threshold[3].1.detected_rate();
        let df = rf.per_threshold[3].1.detected_rate();
        assert!(
            (ds - df).abs() < 0.15,
            "schemes diverge too much: split {ds}, fused {df}"
        );
    }

    #[test]
    fn multibit_campaigns_detect_at_least_as_well_as_single_bit() {
        // A multi-bit upset perturbs the stored result at least as much
        // as one of its constituent flips; at the tight threshold its
        // detected+flagged rate must not collapse.
        let engine = setup();
        let mut mb = cfg(Scheme::Fused, 150);
        mb.fault_model = FaultModelKind::MultiBit { bits: 3 };
        let rm = run_campaigns(&engine, &mb);
        for (_, t) in &rm.per_threshold {
            assert_eq!(t.total(), 150);
        }
        let tight = rm.per_threshold.last().unwrap().1;
        assert!(
            tight.silent_rate() < 0.02,
            "multibit silent rate too high: {tight:?}"
        );
        let rb = run_campaigns(&engine, &cfg(Scheme::Fused, 150));
        let flagged_mb = tight.detected + tight.false_positive;
        let tight_b = rb.per_threshold.last().unwrap().1;
        let flagged_b = tight_b.detected + tight_b.false_positive;
        assert!(
            flagged_mb as f64 + 0.05 * 150.0 >= flagged_b as f64,
            "multibit flag rate collapsed: {flagged_mb} vs single-bit {flagged_b}"
        );
    }

    #[test]
    fn stuck_at_campaigns_are_detected_when_they_corrupt() {
        // A bit latched for thousands of ops corrupts many stored
        // results — when the output changes at all, the checks must
        // catch essentially all of it at the tight threshold.
        let engine = setup();
        let mut sa = cfg(Scheme::Fused, 150);
        sa.fault_model = FaultModelKind::StuckAt { duration: 2048 };
        let r = run_campaigns(&engine, &sa);
        for (_, t) in &r.per_threshold {
            assert_eq!(t.total(), 150);
        }
        let tight = r.per_threshold.last().unwrap().1;
        assert!(
            tight.silent_rate() < 0.02,
            "stuck-at silent rate too high: {tight:?}"
        );
        // Stuck-at windows overwhelmingly produce corruption.
        assert!(
            r.critical > 90,
            "stuck-at windows should usually corrupt: {}/150",
            r.critical
        );
        // One logical defect = at most one hit, even when its window
        // spans several timeline segments (the engine dedupes per-band
        // hits by the defect's scheduled index).
        assert!(
            r.data_faults + r.checksum_faults <= 150,
            "a stuck window must count as one fault: {} data + {} checksum",
            r.data_faults,
            r.checksum_faults
        );
    }
}
