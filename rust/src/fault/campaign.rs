//! Fault-injection campaigns: the engine behind Table I.
//!
//! One campaign = one forward pass of the checked 2-layer GCN with `k`
//! injected single-bit flips (k = 1 for the main table, k ≥ 2 for the
//! §IV-B multi-fault experiment). Faults land uniformly on the op
//! timeline of the *checked* execution, so longer phases and bigger
//! matrices attract proportionally more faults, and the checker's own
//! state is exposed to faults — both as in the paper.
//!
//! Classification at each threshold τ (see DESIGN.md §6). "Corrupted"
//! means the output differs *numerically* from the golden run at all
//! (bit-level — the paper's faults always land in a stored result):
//! * **detected** — output corrupted and some check fired;
//! * **false positive** — output correct but a check fired (flip landed in
//!   check state);
//! * **silent** — output corrupted, no check fired (the fault's checksum
//!   residual sits below τ — exactly the paper's "indistinguishable from
//!   rounding" bucket, which vanishes as τ tightens);
//! * **benign** — output bit-identical and no check fired (e.g. a sign
//!   flip on a 0.0 product; the paper folds these into its three buckets —
//!   we report them separately for transparency, see EXPERIMENTS.md).

use super::bitflip::FaultSite;
use super::plan::{FaultPlan, InjectHook};
use crate::abft::{fused_forward_checked, split_forward_checked, EngineModel, Scheme};
use crate::sparse::Csr;
use crate::tensor::instrumented::CountingHook;
use crate::tensor::Dense64;
use crate::util::rng::{Pcg64, SplitMix64};

/// Campaign sweep configuration.
#[derive(Debug, Clone)]
pub struct CampaignConfig {
    pub scheme: Scheme,
    pub thresholds: Vec<f64>,
    pub campaigns: usize,
    pub faults_per_campaign: usize,
    pub seed: u64,
    pub threads: usize,
}

impl Default for CampaignConfig {
    fn default() -> Self {
        Self {
            scheme: Scheme::Fused,
            thresholds: crate::abft::CheckPolicy::PAPER_THRESHOLDS.to_vec(),
            campaigns: 500,
            faults_per_campaign: 1,
            seed: 0xABF7,
            threads: default_threads(),
        }
    }
}

/// A sensible worker count for campaign parallelism.
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .clamp(1, 16)
}

/// Outcome counts at one threshold.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Tally {
    pub detected: usize,
    pub false_positive: usize,
    pub silent: usize,
    pub benign: usize,
}

impl Tally {
    pub fn total(&self) -> usize {
        self.detected + self.false_positive + self.silent + self.benign
    }
    pub fn detected_rate(&self) -> f64 {
        self.detected as f64 / self.total().max(1) as f64
    }
    pub fn false_positive_rate(&self) -> f64 {
        self.false_positive as f64 / self.total().max(1) as f64
    }
    pub fn silent_rate(&self) -> f64 {
        self.silent as f64 / self.total().max(1) as f64
    }
    pub fn benign_rate(&self) -> f64 {
        self.benign as f64 / self.total().max(1) as f64
    }
}

/// Aggregated result of a campaign sweep.
#[derive(Debug, Clone)]
pub struct CampaignReport {
    pub scheme: Scheme,
    pub campaigns: usize,
    pub faults_per_campaign: usize,
    /// (threshold, tally), in the order of `cfg.thresholds`.
    pub per_threshold: Vec<(f64, Tally)>,
    /// Campaigns in which ≥ 1 node's output row changed numerically
    /// (the paper's "critical fault" — Table I columns 2–3).
    pub critical: usize,
    /// Mean fraction of nodes with changed outputs, over critical
    /// campaigns.
    pub avg_nodes_affected: f64,
    /// Stricter criticality: campaigns where ≥ 1 node's *argmax class*
    /// changed (not in the paper's table; reported for depth).
    pub class_critical: usize,
    /// Mean fraction of nodes whose argmax changed, over class-critical
    /// campaigns.
    pub avg_classes_changed: f64,
    /// Faults that landed on data-path (matmul) results.
    pub data_faults: usize,
    /// Faults that landed on checksum-accumulation results.
    pub checksum_faults: usize,
    /// Total ops on the checked timeline (per campaign).
    pub timeline_ops: u64,
}

impl CampaignReport {
    pub fn critical_rate(&self) -> f64 {
        self.critical as f64 / self.campaigns.max(1) as f64
    }
}

/// Raw per-campaign measurements, classified later under each τ.
struct CampaignOutcome {
    /// |predicted − actual| per check (NaN possible — handled as fired).
    residuals: Vec<f64>,
    /// max |faulty − golden| across all layer preactivations.
    max_diff: f64,
    /// Nodes whose final-layer output row changed numerically.
    nodes_affected: usize,
    /// Nodes whose argmax class changed.
    classes_changed: usize,
    sites: Vec<FaultSite>,
}

/// Run a full campaign sweep for one dataset/model/scheme.
pub fn run_campaigns(em: &EngineModel, features: &Csr, cfg: &CampaignConfig) -> CampaignReport {
    assert!(!cfg.thresholds.is_empty());
    assert!(cfg.faults_per_campaign >= 1);

    // Golden references (computed once).
    let golden = em.golden_forward(features);
    let golden_classes = golden.last().unwrap().argmax_rows();
    let h_c = features.col_sums_f64();

    // Timeline length of the checked execution.
    let mut cnt = CountingHook::default();
    match cfg.scheme {
        Scheme::Split => {
            split_forward_checked(em, features, &h_c, &mut cnt);
        }
        Scheme::Fused => {
            fused_forward_checked(em, features, &mut cnt);
        }
    }
    let timeline_ops = cnt.total();

    // Per-campaign RNG derivation that is independent of thread layout.
    let mut sm = SplitMix64::new(cfg.seed);
    let base = sm.next_u64();

    let outcomes: Vec<CampaignOutcome> = if cfg.threads <= 1 {
        (0..cfg.campaigns)
            .map(|i| run_one(em, features, &h_c, &golden, &golden_classes, cfg, base, i, timeline_ops))
            .collect()
    } else {
        let mut results: Vec<Option<CampaignOutcome>> = Vec::new();
        results.resize_with(cfg.campaigns, || None);
        let next = std::sync::atomic::AtomicUsize::new(0);
        let results_mx = std::sync::Mutex::new(&mut results);
        std::thread::scope(|scope| {
            for _ in 0..cfg.threads {
                scope.spawn(|| loop {
                    let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    if i >= cfg.campaigns {
                        break;
                    }
                    let out = run_one(
                        em,
                        features,
                        &h_c,
                        &golden,
                        &golden_classes,
                        cfg,
                        base,
                        i,
                        timeline_ops,
                    );
                    results_mx.lock().unwrap()[i] = Some(out);
                });
            }
        });
        results.into_iter().map(|o| o.unwrap()).collect()
    };

    // Classify under each threshold.
    let mut per_threshold = Vec::with_capacity(cfg.thresholds.len());
    for &tau in &cfg.thresholds {
        let mut tally = Tally::default();
        for o in &outcomes {
            // NaN-safe comparisons: non-finite residuals count as fired.
            let fired = o.residuals.iter().any(|&r| !(r <= tau));
            // Corruption is bit-level: any numeric deviation from golden.
            let corrupted = !(o.max_diff <= 0.0);
            match (corrupted, fired) {
                (true, true) => tally.detected += 1,
                (false, true) => tally.false_positive += 1,
                (true, false) => tally.silent += 1,
                (false, false) => tally.benign += 1,
            }
        }
        per_threshold.push((tau, tally));
    }

    let n_nodes = golden_classes.len() as f64;
    let critical = outcomes.iter().filter(|o| o.nodes_affected > 0).count();
    let avg_nodes_affected = if critical > 0 {
        outcomes
            .iter()
            .filter(|o| o.nodes_affected > 0)
            .map(|o| o.nodes_affected as f64 / n_nodes)
            .sum::<f64>()
            / critical as f64
    } else {
        0.0
    };
    let class_critical = outcomes.iter().filter(|o| o.classes_changed > 0).count();
    let avg_classes_changed = if class_critical > 0 {
        outcomes
            .iter()
            .filter(|o| o.classes_changed > 0)
            .map(|o| o.classes_changed as f64 / n_nodes)
            .sum::<f64>()
            / class_critical as f64
    } else {
        0.0
    };
    let data_faults = outcomes
        .iter()
        .flat_map(|o| &o.sites)
        .filter(|s| matches!(s, FaultSite::DataMul | FaultSite::DataAdd))
        .count();
    let checksum_faults = outcomes
        .iter()
        .flat_map(|o| &o.sites)
        .filter(|s| matches!(s, FaultSite::ChecksumAcc))
        .count();

    CampaignReport {
        scheme: cfg.scheme,
        campaigns: cfg.campaigns,
        faults_per_campaign: cfg.faults_per_campaign,
        per_threshold,
        critical,
        avg_nodes_affected,
        class_critical,
        avg_classes_changed,
        data_faults,
        checksum_faults,
        timeline_ops,
    }
}

#[allow(clippy::too_many_arguments)]
fn run_one(
    em: &EngineModel,
    features: &Csr,
    h_c: &[f64],
    golden: &[Dense64],
    golden_classes: &[usize],
    cfg: &CampaignConfig,
    base: u64,
    index: usize,
    timeline_ops: u64,
) -> CampaignOutcome {
    let mut rng = Pcg64::new(base, index as u64);
    let plan = FaultPlan::sample(&mut rng, timeline_ops, cfg.faults_per_campaign);
    let mut hook = InjectHook::new(&plan);
    let (preacts, checks) = match cfg.scheme {
        Scheme::Split => split_forward_checked(em, features, h_c, &mut hook),
        Scheme::Fused => fused_forward_checked(em, features, &mut hook),
    };
    // A fault scheduled at the very tail of the timeline can defer past
    // the end without firing (zero-value deferral); such a campaign is a
    // clean run and classifies as benign.

    let residuals = checks.iter().map(|c| c.residual()).collect();
    let mut max_diff = 0f64;
    for (p, g) in preacts.iter().zip(golden) {
        let d = p.max_abs_diff(g);
        // Propagate NaN as "definitely corrupted".
        if d.is_nan() {
            max_diff = f64::NAN;
            break;
        }
        max_diff = max_diff.max(d);
    }
    // Per-node spread of the fault at the final layer (paper's
    // "nodes critically affected"): rows that changed numerically.
    let last = preacts.last().unwrap();
    let last_golden = golden.last().unwrap();
    let mut nodes_affected = 0usize;
    for r in 0..last.rows() {
        let changed = last
            .row(r)
            .iter()
            .zip(last_golden.row(r))
            .any(|(a, b)| a.to_bits() != b.to_bits());
        if changed {
            nodes_affected += 1;
        }
    }
    let classes = last.argmax_rows();
    let classes_changed = classes
        .iter()
        .zip(golden_classes)
        .filter(|(a, b)| a != b)
        .count();

    CampaignOutcome {
        residuals,
        max_diff,
        nodes_affected,
        classes_changed,
        sites: hook.hits,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gcn::GcnModel;
    use crate::graph::DatasetId;

    fn setup() -> (EngineModel, Csr) {
        let g = DatasetId::Tiny.build(0);
        let m = GcnModel::two_layer(&g, 8, 1);
        (EngineModel::from_model(&m), g.features.clone())
    }

    fn cfg(scheme: Scheme, campaigns: usize) -> CampaignConfig {
        CampaignConfig {
            scheme,
            campaigns,
            threads: 2,
            ..Default::default()
        }
    }

    #[test]
    fn tallies_partition_campaigns() {
        let (em, feats) = setup();
        let report = run_campaigns(&em, &feats, &cfg(Scheme::Fused, 100));
        assert_eq!(report.per_threshold.len(), 4);
        for (_, t) in &report.per_threshold {
            assert_eq!(t.total(), 100, "tally doesn't partition: {t:?}");
        }
        let landed = report.data_faults + report.checksum_faults;
        assert!(
            landed <= 100 && landed >= 95,
            "faults should (almost) always land: {landed}/100"
        );
    }

    #[test]
    fn deterministic_across_thread_counts() {
        let (em, feats) = setup();
        let mut c1 = cfg(Scheme::Split, 60);
        c1.threads = 1;
        let mut c4 = cfg(Scheme::Split, 60);
        c4.threads = 4;
        let r1 = run_campaigns(&em, &feats, &c1);
        let r4 = run_campaigns(&em, &feats, &c4);
        for ((t1, a), (t4, b)) in r1.per_threshold.iter().zip(&r4.per_threshold) {
            assert_eq!(t1, t4);
            assert_eq!(a, b, "thread count changed results");
        }
        assert_eq!(r1.critical, r4.critical);
    }

    #[test]
    fn detection_improves_or_holds_with_tighter_threshold() {
        let (em, feats) = setup();
        let report = run_campaigns(&em, &feats, &cfg(Scheme::Fused, 300));
        // Silent rate must be non-increasing as τ tightens.
        let silents: Vec<usize> = report.per_threshold.iter().map(|(_, t)| t.silent).collect();
        for w in silents.windows(2) {
            assert!(w[1] <= w[0], "silent rate increased with tighter τ: {silents:?}");
        }
        // At τ=1e-7 silent faults should (nearly) vanish — paper finds 0.
        let tight = report.per_threshold.last().unwrap().1;
        assert!(
            tight.silent_rate() < 0.02,
            "silent rate at 1e-7 too high: {:?}",
            tight
        );
    }

    #[test]
    fn most_faults_hit_the_data_path() {
        // Matmul dominates the timeline, so most flips land there (§IV-A).
        let (em, feats) = setup();
        let report = run_campaigns(&em, &feats, &cfg(Scheme::Fused, 200));
        assert!(
            report.data_faults > report.checksum_faults,
            "data {} vs checksum {}",
            report.data_faults,
            report.checksum_faults
        );
    }

    #[test]
    fn multi_fault_detection_is_at_least_single_fault() {
        let (em, feats) = setup();
        let mut single = cfg(Scheme::Fused, 150);
        single.faults_per_campaign = 1;
        let mut multi = cfg(Scheme::Fused, 150);
        multi.faults_per_campaign = 3;
        let rs = run_campaigns(&em, &feats, &single);
        let rm = run_campaigns(&em, &feats, &multi);
        let tau_idx = 3; // 1e-7
        let ds = rs.per_threshold[tau_idx].1;
        let dm = rm.per_threshold[tau_idx].1;
        // With 3 faults, almost every campaign is flagged (paper: 100%).
        let flagged = dm.detected + dm.false_positive;
        assert!(
            flagged as f64 / dm.total() as f64 + 0.02
                >= (ds.detected + ds.false_positive) as f64 / ds.total() as f64,
            "multi-fault flag rate regressed: single {ds:?}, multi {dm:?}"
        );
    }

    #[test]
    fn split_and_fused_have_comparable_detection() {
        let (em, feats) = setup();
        let rs = run_campaigns(&em, &feats, &cfg(Scheme::Split, 300));
        let rf = run_campaigns(&em, &feats, &cfg(Scheme::Fused, 300));
        let ds = rs.per_threshold[3].1.detected_rate();
        let df = rf.per_threshold[3].1.detected_rate();
        assert!(
            (ds - df).abs() < 0.15,
            "schemes diverge too much: split {ds}, fused {df}"
        );
    }
}
