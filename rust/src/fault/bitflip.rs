//! Single-bit flips in arithmetic results — the paper's fault model
//! (§IV-A): "we introduce random single-bit flips into the results of
//! arithmetic operations within matrix multiplication (multiply and add)
//! or checksum accumulation, at randomly selected time points. The
//! affected arithmetic operations for matrix multiplications involve
//! single-precision floats, while checksum accumulation uses
//! double-precision floats. All bits of every arithmetic operation output
//! can be flipped with equal probability."

/// Flip bit `bit` (0 = LSB) of the **f32 image** of a data-path value.
///
/// The engine's baseline arithmetic is f64 (so the fault-free residual is
/// pure f64 rounding — DESIGN.md §6); the accelerator's data path is f32.
/// The fault is therefore applied to the value as the accelerator would
/// hold it: round to f32, flip one of its 32 bits, and carry the *delta*
/// forward. Preserving only the delta (rather than the re-rounded value)
/// keeps a faulty run bit-identical to the golden run everywhere except
/// the injected corruption.
#[inline]
pub fn flip_f32_image(v: f64, bit: u32) -> f64 {
    debug_assert!(bit < 32);
    let v32 = v as f32;
    let flipped = f32::from_bits(v32.to_bits() ^ (1u32 << bit));
    v + (flipped as f64 - v32 as f64)
}

/// Flip bit `bit` (0 = LSB) of an f64 checksum-accumulator value.
#[inline]
pub fn flip_f64(v: f64, bit: u32) -> f64 {
    debug_assert!(bit < 64);
    f64::from_bits(v.to_bits() ^ (1u64 << bit))
}

/// Which datapath a fault landed in.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FaultSite {
    /// fp32 multiply result in a matmul.
    DataMul,
    /// fp32 accumulate result in a matmul.
    DataAdd,
    /// fp64 checksum-accumulation result.
    ChecksumAcc,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_image_flip_changes_value() {
        let v = 3.25f64;
        for bit in [0u32, 10, 22, 23, 30, 31] {
            let f = flip_f32_image(v, bit);
            assert_ne!(f, v, "bit {bit} produced no change");
        }
    }

    #[test]
    fn f32_image_flip_delta_matches_f32_semantics() {
        let v = 1.0f64;
        // Sign bit: 1.0 -> -1.0, delta -2.
        assert_eq!(flip_f32_image(v, 31), -1.0);
        // Mantissa LSB of 1.0f32: delta = 2^-23.
        let d = flip_f32_image(v, 0) - v;
        assert!((d - 2f64.powi(-23)).abs() < 1e-12);
    }

    #[test]
    fn f32_flip_is_involution_for_mantissa_and_sign() {
        // Applying the same flip twice to an exact-f32 value restores it,
        // for flips whose delta stays within f64's relative range of the
        // original (mantissa + sign bits). Exponent flips produce huge
        // deltas whose round trip loses the original — acceptable, since
        // the fault model never needs to "un-flip".
        let v = 7.5f64; // representable exactly in f32
        for bit in (0..23).chain([31]) {
            let once = flip_f32_image(v, bit);
            let twice = flip_f32_image(once, bit);
            assert!(
                (twice - v).abs() < 1e-6,
                "bit {bit}: {v} -> {once} -> {twice}"
            );
        }
    }

    #[test]
    fn f64_flip_exact_involution() {
        let v = -123.456f64;
        for bit in 0..64 {
            let once = flip_f64(v, bit);
            assert_ne!(once.to_bits(), v.to_bits());
            assert_eq!(flip_f64(once, bit).to_bits(), v.to_bits());
        }
    }

    #[test]
    fn exponent_flip_can_produce_nonfinite() {
        // 1.5f32 has exponent 0111_1111; setting bit 30 makes it
        // 1111_1111 → NaN (non-zero mantissa), which must propagate.
        let v = 1.5f64;
        let f = flip_f32_image(v, 30);
        assert!(!f.is_finite(), "expected non-finite, got {f}");
    }

    #[test]
    fn low_mantissa_flip_is_small() {
        let v = 100.0f64;
        let d = (flip_f32_image(v, 0) - v).abs();
        // ulp of 100f32 is 2^-23 * 2^6 ≈ 7.6e-6
        assert!(d > 0.0 && d < 1e-4, "delta {d}");
    }
}
