//! Pluggable fault models over the op-index timeline.
//!
//! The paper's experiments use uniform single-bit flips (§IV-A), but the
//! fault model is orthogonal to the checker: any corruption of a stored
//! arithmetic result is detectable iff its checksum residual clears τ.
//! This module makes the model a first-class, swappable component (the
//! PyGFI line of work argues GNN-robustness studies need exactly this):
//!
//! * [`FaultModel`] — samples the [`FaultEvent`]s of one run;
//! * [`BitFlip`] — the paper's model (one bit, uniform over the
//!   timeline; the refactored form of the old `InjectHook` plan);
//! * [`MultiBit`] — several simultaneous bit flips in one stored result
//!   (burst/MBU faults);
//! * [`StuckAt`] — a datapath bit latched at 0/1 for a window of ops
//!   (persistent defect rather than a transient);
//! * [`NoFaults`] — the golden model, used by the serving path and the
//!   backend-parity property tests.
//!
//! Execution side: a [`SegmentHook`] applies a set of events to one
//! **contiguous segment** `[start, end)` of the global op timeline. The
//! instrumented engine splits each aggregation phase into fixed logical
//! row bands with precomputed prefix offsets, and hands every band its
//! own `SegmentHook` — so a fault plan lands on the same logical op
//! whether the bands run serially or in parallel, and detection results
//! are bit-identical at any worker count.

use super::bitflip::{flip_f32_image, flip_f64, FaultSite};
use super::plan::FaultPlan;
use crate::tensor::instrumented::ExecHook;
use crate::util::rng::Pcg64;

/// What a fault does to the stored result it lands on.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultKind {
    /// Flip one bit of the stored result: bit `bit32` of the f32 image on
    /// the data path, bit `bit64` of the f64 accumulator on the checker
    /// path (the paper's model).
    BitFlip { bit32: u32, bit64: u32 },
    /// Flip several bits of the same stored result at once.
    MultiBit { mask32: u32, mask64: u64 },
    /// From `op_index` for `duration` ops, the given bit of every stored
    /// result (at any site) is forced to `stuck_one`.
    StuckAt {
        bit32: u32,
        bit64: u32,
        stuck_one: bool,
        duration: u64,
    },
}

/// One scheduled fault on the absolute op timeline.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultEvent {
    /// Absolute index on the op timeline (0-based).
    pub op_index: u64,
    pub kind: FaultKind,
}

/// Where a fault actually landed (for the paper's site statistics).
/// `op_index` identifies the *defect*: the op a point fault fired at,
/// or a stuck-at fault's scheduled index — stable across timeline
/// segments, so one logical persistent defect dedupes to one hit
/// however many segments its window spans (`persistent` distinguishes
/// the two, so a point fault firing at a stuck fault's scheduled index
/// is never merged with it).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultHit {
    pub op_index: u64,
    pub site: FaultSite,
    /// True for stuck-at (windowed) defects, false for point faults.
    pub persistent: bool,
}

/// A fault model: samples the events of one run over a timeline of
/// `total_ops` operations. Implementations must be deterministic given
/// the RNG state.
pub trait FaultModel: Send + Sync {
    fn name(&self) -> &'static str;
    /// Sample `faults` fault events for one run.
    fn sample(&self, rng: &mut Pcg64, total_ops: u64, faults: usize) -> Vec<FaultEvent>;
}

/// The paper's model: one uniformly placed single-bit flip per fault.
#[derive(Debug, Clone, Copy, Default)]
pub struct BitFlip;

impl FaultModel for BitFlip {
    fn name(&self) -> &'static str {
        "bitflip"
    }

    fn sample(&self, rng: &mut Pcg64, total_ops: u64, faults: usize) -> Vec<FaultEvent> {
        FaultPlan::sample(rng, total_ops, faults).events()
    }
}

/// `bits` simultaneous flips in one stored result (multi-bit upset).
#[derive(Debug, Clone, Copy)]
pub struct MultiBit {
    pub bits: u32,
}

impl Default for MultiBit {
    fn default() -> Self {
        Self { bits: 2 }
    }
}

impl FaultModel for MultiBit {
    fn name(&self) -> &'static str {
        "multibit"
    }

    fn sample(&self, rng: &mut Pcg64, total_ops: u64, faults: usize) -> Vec<FaultEvent> {
        let bits = self.bits.clamp(1, 32) as usize;
        let plan = FaultPlan::sample(rng, total_ops, faults);
        let mut events = Vec::with_capacity(plan.faults.len());
        for f in &plan.faults {
            let mask32 = rng
                .sample_indices(32, bits)
                .into_iter()
                .fold(0u32, |m, b| m | (1u32 << b));
            let mask64 = rng
                .sample_indices(64, bits)
                .into_iter()
                .fold(0u64, |m, b| m | (1u64 << b));
            events.push(FaultEvent {
                op_index: f.op_index,
                kind: FaultKind::MultiBit { mask32, mask64 },
            });
        }
        events
    }
}

/// A bit stuck at 0/1 for a window of `duration` ops (persistent defect;
/// `u64::MAX` models a permanently latched line).
#[derive(Debug, Clone, Copy)]
pub struct StuckAt {
    pub duration: u64,
}

impl Default for StuckAt {
    fn default() -> Self {
        Self { duration: 4096 }
    }
}

impl FaultModel for StuckAt {
    fn name(&self) -> &'static str {
        "stuckat"
    }

    fn sample(&self, rng: &mut Pcg64, total_ops: u64, faults: usize) -> Vec<FaultEvent> {
        let plan = FaultPlan::sample(rng, total_ops, faults);
        let mut events = Vec::with_capacity(plan.faults.len());
        for f in &plan.faults {
            events.push(FaultEvent {
                op_index: f.op_index,
                kind: FaultKind::StuckAt {
                    bit32: f.bit32,
                    bit64: f.bit64,
                    stuck_one: rng.gen_bool(0.5),
                    duration: self.duration.max(1),
                },
            });
        }
        events
    }
}

/// The golden model: no faults, ever. Serving and parity tests use it.
#[derive(Debug, Clone, Copy, Default)]
pub struct NoFaults;

impl FaultModel for NoFaults {
    fn name(&self) -> &'static str {
        "none"
    }

    fn sample(&self, _rng: &mut Pcg64, _total_ops: u64, _faults: usize) -> Vec<FaultEvent> {
        Vec::new()
    }
}

/// Value-level selector for configs/CLI (avoids generics in
/// `CampaignConfig`). Delegates to the trait implementations above.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultModelKind {
    BitFlip,
    MultiBit { bits: u32 },
    StuckAt { duration: u64 },
}

impl FaultModelKind {
    pub fn name(&self) -> &'static str {
        match self {
            FaultModelKind::BitFlip => "bitflip",
            FaultModelKind::MultiBit { .. } => "multibit",
            FaultModelKind::StuckAt { .. } => "stuckat",
        }
    }

    /// Parse `bitflip`, `multibit[:BITS]`, `stuckat[:DURATION]`.
    pub fn parse(s: &str) -> Option<FaultModelKind> {
        let lower = s.to_ascii_lowercase();
        let (head, arg) = match lower.split_once(':') {
            Some((h, a)) => (h, Some(a)),
            None => (lower.as_str(), None),
        };
        match head {
            "bitflip" | "single" => Some(FaultModelKind::BitFlip),
            "multibit" | "mbu" => {
                let bits = match arg {
                    Some(a) => a.parse().ok()?,
                    None => MultiBit::default().bits,
                };
                Some(FaultModelKind::MultiBit { bits })
            }
            "stuckat" | "stuck-at" => {
                let duration = match arg {
                    Some(a) => a.parse().ok()?,
                    None => StuckAt::default().duration,
                };
                Some(FaultModelKind::StuckAt { duration })
            }
            _ => None,
        }
    }

    pub fn sample(&self, rng: &mut Pcg64, total_ops: u64, faults: usize) -> Vec<FaultEvent> {
        match *self {
            FaultModelKind::BitFlip => BitFlip.sample(rng, total_ops, faults),
            FaultModelKind::MultiBit { bits } => MultiBit { bits }.sample(rng, total_ops, faults),
            FaultModelKind::StuckAt { duration } => {
                StuckAt { duration }.sample(rng, total_ops, faults)
            }
        }
    }
}

/// Execution hook applying fault events to one contiguous timeline
/// segment `[start, end)`.
///
/// Point faults (bit flips) defer past exact-zero stored values — the
/// paper flips bits of stored results, which are (near-)always nonzero;
/// a flip on a 0.0 product yields a denormal delta that rounds away and
/// models nothing physical — but **deferral never crosses a segment
/// boundary**: a fault that reaches the end of its segment still armed
/// is dropped (the run classifies as benign). Because segment boundaries
/// are a fixed property of the workload (logical bands + prefix
/// offsets), not of the worker count, injection is bit-reproducible
/// serial or parallel.
#[derive(Debug, Clone)]
pub struct SegmentHook {
    /// Point events scheduled inside this segment, sorted by op index.
    points: Vec<FaultEvent>,
    /// Stuck-at events whose active window overlaps this segment.
    stuck: Vec<FaultEvent>,
    stuck_fired: Vec<bool>,
    /// Absolute index of the next op this segment will observe.
    counter: u64,
    start: u64,
    /// Next point event to fire.
    next: usize,
    /// Faults that actually modified a stored result, in op order.
    pub hits: Vec<FaultHit>,
}

impl SegmentHook {
    /// Hook for the segment `[start, end)` of the global timeline.
    pub fn new(events: &[FaultEvent], start: u64, end: u64) -> SegmentHook {
        let mut points = Vec::new();
        let mut stuck = Vec::new();
        for ev in events {
            match ev.kind {
                FaultKind::StuckAt { duration, .. } => {
                    let window_end = ev.op_index.saturating_add(duration);
                    if ev.op_index < end && window_end > start {
                        stuck.push(*ev);
                    }
                }
                _ => {
                    if ev.op_index >= start && ev.op_index < end {
                        points.push(*ev);
                    }
                }
            }
        }
        points.sort_by_key(|e| e.op_index);
        let stuck_fired = vec![false; stuck.len()];
        SegmentHook {
            points,
            stuck,
            stuck_fired,
            counter: start,
            start,
            next: 0,
            hits: Vec::new(),
        }
    }

    /// Hook spanning the whole timeline (single-segment execution).
    pub fn spanning(events: &[FaultEvent]) -> SegmentHook {
        Self::new(events, 0, u64::MAX)
    }

    /// Ops observed by this segment so far.
    pub fn ops_seen(&self) -> u64 {
        self.counter - self.start
    }

    /// True when every point fault of this segment fired.
    pub fn exhausted(&self) -> bool {
        self.next >= self.points.len()
    }

    #[inline(always)]
    fn observe(&mut self, site: FaultSite, v: f64) -> f64 {
        let idx = self.counter;
        self.counter += 1;
        let mut out = v;

        // Persistent stuck-at conditions: pure function of the op index.
        for i in 0..self.stuck.len() {
            let ev = self.stuck[i];
            if let FaultKind::StuckAt {
                bit32,
                bit64,
                stuck_one,
                duration,
            } = ev.kind
            {
                let active = idx >= ev.op_index && idx - ev.op_index < duration;
                if active {
                    let forced = force_bit(out, site, bit32, bit64, stuck_one);
                    if forced.to_bits() != out.to_bits() {
                        if !self.stuck_fired[i] {
                            self.stuck_fired[i] = true;
                            // Keyed by the defect's scheduled index (not
                            // the firing op) so a window spanning several
                            // segments dedupes to one logical hit.
                            self.hits.push(FaultHit {
                                op_index: ev.op_index,
                                site,
                                persistent: true,
                            });
                        }
                        out = forced;
                    }
                }
            }
        }

        // Point faults: fire at the scheduled op, deferring past
        // exact-zero values (within this segment only).
        if self.next < self.points.len() && self.points[self.next].op_index <= idx {
            let zero = match site {
                // gcn-lint: allow(D4, reason="deliberate exact-zero test: a bit flip on a +-0.0 value is a no-op the fault model must defer past, so tolerance comparison would be wrong")
                FaultSite::ChecksumAcc => out == 0.0,
                // gcn-lint: allow(D4, reason="same exact-zero deferral, on the value as stored in f32")
                _ => out as f32 == 0.0,
            };
            if !zero {
                let kind = self.points[self.next].kind;
                self.next += 1;
                self.hits.push(FaultHit {
                    op_index: idx,
                    site,
                    persistent: false,
                });
                out = apply_point(out, site, kind);
            }
        }
        out
    }
}

/// Apply a point fault to a stored result at the given site.
fn apply_point(v: f64, site: FaultSite, kind: FaultKind) -> f64 {
    match (kind, site) {
        (FaultKind::BitFlip { bit64, .. }, FaultSite::ChecksumAcc) => flip_f64(v, bit64),
        (FaultKind::BitFlip { bit32, .. }, _) => flip_f32_image(v, bit32),
        (FaultKind::MultiBit { mask64, .. }, FaultSite::ChecksumAcc) => {
            f64::from_bits(v.to_bits() ^ mask64)
        }
        (FaultKind::MultiBit { mask32, .. }, _) => {
            let v32 = v as f32;
            let flipped = f32::from_bits(v32.to_bits() ^ mask32);
            v + (flipped as f64 - v32 as f64)
        }
        // Stuck-at is handled as a persistent condition, never a point.
        (FaultKind::StuckAt { .. }, _) => v,
    }
}

/// Force one bit of the stored result to `stuck_one` (f32 image on the
/// data path with delta-carry, f64 bits on the checker path).
fn force_bit(v: f64, site: FaultSite, bit32: u32, bit64: u32, stuck_one: bool) -> f64 {
    match site {
        FaultSite::ChecksumAcc => {
            let mask = 1u64 << bit64;
            let bits = if stuck_one {
                v.to_bits() | mask
            } else {
                v.to_bits() & !mask
            };
            f64::from_bits(bits)
        }
        _ => {
            let v32 = v as f32;
            let mask = 1u32 << bit32;
            let bits = if stuck_one {
                v32.to_bits() | mask
            } else {
                v32.to_bits() & !mask
            };
            let forced = f32::from_bits(bits);
            v + (forced as f64 - v32 as f64)
        }
    }
}

impl ExecHook for SegmentHook {
    #[inline(always)]
    fn mul(&mut self, v: f64) -> f64 {
        self.observe(FaultSite::DataMul, v)
    }

    #[inline(always)]
    fn add(&mut self, v: f64) -> f64 {
        self.observe(FaultSite::DataAdd, v)
    }

    #[inline(always)]
    fn csum(&mut self, v: f64) -> f64 {
        self.observe(FaultSite::ChecksumAcc, v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::instrumented::{matmul_hooked, CountingHook, NopHook};
    use crate::tensor::{Dense, Dense64};

    fn d64(rows: usize, cols: usize, f: impl FnMut(usize, usize) -> f32) -> Dense64 {
        Dense64::from_dense(&Dense::from_fn(rows, cols, f))
    }

    #[test]
    fn spanning_hook_counts_like_counting_hook() {
        let a = d64(5, 4, |r, c| (r + c) as f32);
        let b = d64(4, 3, |r, c| (r * c) as f32 + 1.0);
        let mut cnt = CountingHook::default();
        matmul_hooked(&a, &b, &mut cnt);
        let mut hook = SegmentHook::spanning(&[]);
        matmul_hooked(&a, &b, &mut hook);
        assert_eq!(hook.ops_seen(), cnt.total());
        assert!(hook.exhausted());
        assert!(hook.hits.is_empty());
    }

    #[test]
    fn bitflip_fires_once_at_scheduled_op() {
        let a = d64(6, 6, |_, _| 1.0);
        let b = a.clone();
        let mut nop = NopHook;
        let golden = matmul_hooked(&a, &b, &mut nop);
        let events = [FaultEvent {
            op_index: 37,
            kind: FaultKind::BitFlip { bit32: 31, bit64: 0 },
        }];
        let mut hook = SegmentHook::spanning(&events);
        let faulty = matmul_hooked(&a, &b, &mut hook);
        assert!(hook.exhausted());
        assert_eq!(hook.hits.len(), 1);
        assert_eq!(hook.hits[0].op_index, 37);
        assert!(!faulty.identical(&golden));
    }

    #[test]
    fn segment_split_is_equivalent_to_spanning() {
        // Two events, one per half; running the two halves with separate
        // hooks must reproduce the single spanning hook bit-for-bit.
        let events = [
            FaultEvent {
                op_index: 3,
                kind: FaultKind::BitFlip { bit32: 30, bit64: 62 },
            },
            FaultEvent {
                op_index: 11,
                kind: FaultKind::MultiBit {
                    mask32: 0b110,
                    mask64: 0b1100,
                },
            },
        ];
        let values: Vec<f64> = (0..16).map(|i| 1.0 + i as f64 * 0.25).collect();
        let mut span = SegmentHook::spanning(&events);
        let full: Vec<f64> = values.iter().map(|&v| span.mul(v)).collect();

        let mut lo = SegmentHook::new(&events, 0, 8);
        let mut hi = SegmentHook::new(&events, 8, 16);
        let mut split: Vec<f64> = values[..8].iter().map(|&v| lo.mul(v)).collect();
        split.extend(values[8..].iter().map(|&v| hi.mul(v)));
        assert_eq!(full.len(), split.len());
        for (a, b) in full.iter().zip(&split) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(span.hits.len(), lo.hits.len() + hi.hits.len());
    }

    #[test]
    fn deferral_does_not_cross_segment_boundary() {
        // A fault scheduled at op 6 sees zeros through the end of its
        // segment [0, 8) and is dropped, not carried into [8, 16).
        let events = [FaultEvent {
            op_index: 6,
            kind: FaultKind::BitFlip { bit32: 31, bit64: 63 },
        }];
        let mut lo = SegmentHook::new(&events, 0, 8);
        for _ in 0..8 {
            assert_eq!(lo.mul(0.0), 0.0);
        }
        assert!(!lo.exhausted(), "zero values must defer the fault");
        assert!(lo.hits.is_empty());
        let mut hi = SegmentHook::new(&events, 8, 16);
        for _ in 8..16 {
            assert_eq!(hi.mul(2.0), 2.0, "dropped fault must not fire later");
        }
        assert!(hi.hits.is_empty());
    }

    #[test]
    fn stuck_at_forces_bit_over_window() {
        let events = [FaultEvent {
            op_index: 2,
            kind: FaultKind::StuckAt {
                bit32: 31,
                bit64: 63,
                stuck_one: true,
                duration: 3,
            },
        }];
        let mut hook = SegmentHook::spanning(&events);
        // Ops 0,1 untouched; ops 2..5 have the f32 sign bit forced to 1;
        // op 5 onward untouched again.
        assert_eq!(hook.mul(1.0), 1.0);
        assert_eq!(hook.mul(1.0), 1.0);
        assert_eq!(hook.mul(1.0), -1.0);
        assert_eq!(hook.mul(-1.0), -1.0); // already negative: unchanged
        assert_eq!(hook.mul(2.5), -2.5);
        assert_eq!(hook.mul(1.0), 1.0);
        // One logical defect = one hit, however many ops it corrupted.
        assert_eq!(hook.hits.len(), 1);
        assert_eq!(hook.hits[0].op_index, 2);
    }

    #[test]
    fn stuck_at_zero_clears_bit_on_checksum_path() {
        let events = [FaultEvent {
            op_index: 0,
            kind: FaultKind::StuckAt {
                bit32: 0,
                bit64: 62,
                stuck_one: false,
                duration: u64::MAX,
            },
        }];
        let mut hook = SegmentHook::spanning(&events);
        let v = 3.5f64; // exponent uses bit 62
        let forced = hook.csum(v);
        assert_ne!(forced.to_bits(), v.to_bits());
        assert_eq!(
            forced.to_bits(),
            v.to_bits() & !(1u64 << 62),
            "bit 62 must be cleared"
        );
    }

    #[test]
    fn multibit_flips_mask_on_both_paths() {
        let events = [
            FaultEvent {
                op_index: 0,
                kind: FaultKind::MultiBit {
                    mask32: (1 << 31) | 1,
                    mask64: 0,
                },
            },
            FaultEvent {
                op_index: 1,
                kind: FaultKind::MultiBit {
                    mask32: 0,
                    mask64: (1 << 63) | 1,
                },
            },
        ];
        let mut hook = SegmentHook::spanning(&events);
        let a = hook.mul(1.0);
        assert!(a < 0.0, "sign bit must flip: {a}");
        let v = 2.0f64;
        let b = hook.csum(v);
        assert_eq!(b.to_bits(), v.to_bits() ^ ((1u64 << 63) | 1));
    }

    #[test]
    fn models_sample_deterministically_and_in_range() {
        for kind in [
            FaultModelKind::BitFlip,
            FaultModelKind::MultiBit { bits: 3 },
            FaultModelKind::StuckAt { duration: 100 },
        ] {
            let mut r1 = Pcg64::from_seed(5);
            let mut r2 = Pcg64::from_seed(5);
            let e1 = kind.sample(&mut r1, 1000, 4);
            let e2 = kind.sample(&mut r2, 1000, 4);
            assert_eq!(e1, e2, "{kind:?} not deterministic");
            assert_eq!(e1.len(), 4);
            for ev in &e1 {
                assert!(ev.op_index < 1000);
            }
        }
    }

    #[test]
    fn kind_parses() {
        assert_eq!(FaultModelKind::parse("bitflip"), Some(FaultModelKind::BitFlip));
        assert_eq!(
            FaultModelKind::parse("multibit:4"),
            Some(FaultModelKind::MultiBit { bits: 4 })
        );
        assert_eq!(
            FaultModelKind::parse("stuckat:512"),
            Some(FaultModelKind::StuckAt { duration: 512 })
        );
        assert_eq!(
            FaultModelKind::parse("stuck-at"),
            Some(FaultModelKind::StuckAt {
                duration: StuckAt::default().duration
            })
        );
        assert_eq!(FaultModelKind::parse("bogus"), None);
        assert_eq!(FaultModelKind::parse("multibit:x"), None);
    }
}
