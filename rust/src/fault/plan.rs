//! Fault plans and the injecting execution hook.
//!
//! A plan schedules one or more bit flips at absolute positions on the
//! layer-execution op timeline (every data-path and checker-path result,
//! in program order). Uniform sampling over the timeline reproduces the
//! paper's premise that "faults are more likely to occur during the matrix
//! multiplication step that lasts longer" (§IV-A).

use super::bitflip::{flip_f32_image, flip_f64, FaultSite};
use crate::tensor::instrumented::ExecHook;
use crate::util::rng::Pcg64;

/// One scheduled bit flip.
#[derive(Debug, Clone, Copy)]
pub struct PlannedFault {
    /// Absolute index on the op timeline (0-based).
    pub op_index: u64,
    /// Bit to flip if the op is a data-path f32 result (0..32).
    pub bit32: u32,
    /// Bit to flip if the op is a checker-path f64 result (0..64).
    pub bit64: u32,
}

/// A set of faults for one campaign, sorted by op index.
#[derive(Debug, Clone)]
pub struct FaultPlan {
    pub faults: Vec<PlannedFault>,
}

impl FaultPlan {
    /// Sample `k` distinct op indices uniformly from `[0, total_ops)`,
    /// each with an independently uniform bit choice.
    pub fn sample(rng: &mut Pcg64, total_ops: u64, k: usize) -> Self {
        assert!(total_ops >= k as u64, "timeline shorter than fault count");
        let mut idxs = std::collections::BTreeSet::new();
        while idxs.len() < k {
            idxs.insert(rng.gen_range(total_ops));
        }
        let faults = idxs
            .into_iter()
            .map(|op_index| PlannedFault {
                op_index,
                bit32: rng.gen_range(32) as u32,
                bit64: rng.gen_range(64) as u32,
            })
            .collect();
        Self { faults }
    }
}

/// Execution hook that injects the planned flips. After the run,
/// [`InjectHook::hits`] reports which site each fault actually landed on
/// (used for the paper's data-vs-checksum fault-share statistics).
#[derive(Debug, Clone)]
pub struct InjectHook {
    plan: Vec<PlannedFault>,
    /// Next fault to fire (plan is sorted by op_index).
    next: usize,
    /// Global op counter.
    counter: u64,
    /// Site actually hit per fired fault.
    pub hits: Vec<FaultSite>,
}

impl InjectHook {
    pub fn new(plan: &FaultPlan) -> Self {
        Self {
            plan: plan.faults.clone(),
            next: 0,
            counter: 0,
            hits: Vec::with_capacity(plan.faults.len()),
        }
    }

    /// Number of ops seen so far.
    pub fn ops_seen(&self) -> u64 {
        self.counter
    }

    /// True if every planned fault fired.
    pub fn exhausted(&self) -> bool {
        self.next >= self.plan.len()
    }

    /// A fault is due when its scheduled index has been reached
    /// (`<=` rather than `==` so a deferred fault stays armed).
    #[inline(always)]
    fn due(&mut self, value_is_zero: bool) -> Option<PlannedFault> {
        if self.next < self.plan.len() && self.plan[self.next].op_index <= self.counter {
            // Defer past exact-zero data values: the paper flips bits of
            // *stored results*, which are (near-)always nonzero — a flip
            // on a 0.0 product yields a denormal delta that rounds away
            // in the accumulator and models nothing physical. The fault
            // slides to the next op instead.
            if value_is_zero {
                return None;
            }
            let f = self.plan[self.next];
            self.next += 1;
            Some(f)
        } else {
            None
        }
    }
}

impl ExecHook for InjectHook {
    #[inline(always)]
    fn mul(&mut self, v: f64) -> f64 {
        let out = match self.due(v as f32 == 0.0) {
            Some(f) => {
                self.hits.push(FaultSite::DataMul);
                flip_f32_image(v, f.bit32)
            }
            None => v,
        };
        self.counter += 1;
        out
    }

    #[inline(always)]
    fn add(&mut self, v: f64) -> f64 {
        let out = match self.due(v as f32 == 0.0) {
            Some(f) => {
                self.hits.push(FaultSite::DataAdd);
                flip_f32_image(v, f.bit32)
            }
            None => v,
        };
        self.counter += 1;
        out
    }

    #[inline(always)]
    fn csum(&mut self, v: f64) -> f64 {
        let out = match self.due(v == 0.0) {
            Some(f) => {
                self.hits.push(FaultSite::ChecksumAcc);
                flip_f64(v, f.bit64)
            }
            None => v,
        };
        self.counter += 1;
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::instrumented::{matmul_hooked, CountingHook, NopHook};
    use crate::tensor::{Dense, Dense64};

    #[test]
    fn plan_sampling_is_sorted_distinct_in_range() {
        let mut rng = Pcg64::from_seed(1);
        let p = FaultPlan::sample(&mut rng, 1000, 5);
        assert_eq!(p.faults.len(), 5);
        for w in p.faults.windows(2) {
            assert!(w[0].op_index < w[1].op_index);
        }
        for f in &p.faults {
            assert!(f.op_index < 1000);
            assert!(f.bit32 < 32);
            assert!(f.bit64 < 64);
        }
    }

    #[test]
    fn hook_counts_like_counting_hook() {
        let a = Dense64::from_dense(&Dense::from_fn(5, 4, |r, c| (r + c) as f32));
        let b = Dense64::from_dense(&Dense::from_fn(4, 3, |r, c| (r * c) as f32 + 1.0));
        let mut cnt = CountingHook::default();
        matmul_hooked(&a, &b, &mut cnt);
        let plan = FaultPlan {
            faults: vec![],
        };
        let mut inj = InjectHook::new(&plan);
        matmul_hooked(&a, &b, &mut inj);
        assert_eq!(inj.ops_seen(), cnt.total());
        assert!(inj.exhausted());
        assert!(inj.hits.is_empty());
    }

    #[test]
    fn injection_fires_exactly_once_at_scheduled_op() {
        let a = Dense64::from_dense(&Dense::from_fn(6, 6, |_, _| 1.0));
        let b = a.clone();
        let mut nop = NopHook;
        let golden = matmul_hooked(&a, &b, &mut nop);
        let plan = FaultPlan {
            faults: vec![PlannedFault {
                op_index: 37,
                bit32: 31, // sign flip: guaranteed visible
                bit64: 0,
            }],
        };
        let mut inj = InjectHook::new(&plan);
        let faulty = matmul_hooked(&a, &b, &mut inj);
        assert!(inj.exhausted());
        assert_eq!(inj.hits.len(), 1);
        assert!(!faulty.identical(&golden));
    }

    #[test]
    fn site_classification_matches_callback() {
        let plan = FaultPlan {
            faults: vec![
                PlannedFault {
                    op_index: 0,
                    bit32: 1,
                    bit64: 1,
                },
                PlannedFault {
                    op_index: 1,
                    bit32: 1,
                    bit64: 1,
                },
                PlannedFault {
                    op_index: 2,
                    bit32: 1,
                    bit64: 1,
                },
            ],
        };
        let mut inj = InjectHook::new(&plan);
        inj.mul(1.0);
        inj.add(1.0);
        inj.csum(1.0);
        assert_eq!(
            inj.hits,
            vec![FaultSite::DataMul, FaultSite::DataAdd, FaultSite::ChecksumAcc]
        );
    }

    #[test]
    fn deterministic_given_same_rng_seed() {
        let mut r1 = Pcg64::from_seed(9);
        let mut r2 = Pcg64::from_seed(9);
        let p1 = FaultPlan::sample(&mut r1, 500, 3);
        let p2 = FaultPlan::sample(&mut r2, 500, 3);
        for (a, b) in p1.faults.iter().zip(&p2.faults) {
            assert_eq!(a.op_index, b.op_index);
            assert_eq!(a.bit32, b.bit32);
            assert_eq!(a.bit64, b.bit64);
        }
    }
}
