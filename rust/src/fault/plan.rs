//! Fault plans: uniformly sampled bit-flip schedules over the op
//! timeline.
//!
//! A plan schedules one or more bit flips at absolute positions on the
//! layer-execution op timeline (every data-path and checker-path result,
//! in program order). Uniform sampling over the timeline reproduces the
//! paper's premise that "faults are more likely to occur during the matrix
//! multiplication step that lasts longer" (§IV-A).
//!
//! Execution of a plan lives in [`super::model`]: `FaultPlan::events()`
//! lowers the plan to [`FaultEvent`]s and [`FaultPlan::hook`] builds a
//! whole-timeline [`SegmentHook`] (what the old `InjectHook` was — the
//! hook machinery is now shared with the richer fault models and with
//! the band-parallel instrumented backend).

use super::model::{FaultEvent, FaultKind, SegmentHook};
use crate::util::rng::Pcg64;

/// One scheduled bit flip.
#[derive(Debug, Clone, Copy)]
pub struct PlannedFault {
    /// Absolute index on the op timeline (0-based).
    pub op_index: u64,
    /// Bit to flip if the op is a data-path f32 result (0..32).
    pub bit32: u32,
    /// Bit to flip if the op is a checker-path f64 result (0..64).
    pub bit64: u32,
}

/// A set of faults for one campaign, sorted by op index.
#[derive(Debug, Clone)]
pub struct FaultPlan {
    pub faults: Vec<PlannedFault>,
}

impl FaultPlan {
    /// Sample `k` distinct op indices uniformly from `[0, total_ops)`,
    /// each with an independently uniform bit choice.
    pub fn sample(rng: &mut Pcg64, total_ops: u64, k: usize) -> Self {
        assert!(total_ops >= k as u64, "timeline shorter than fault count");
        let mut idxs = std::collections::BTreeSet::new();
        while idxs.len() < k {
            idxs.insert(rng.gen_range(total_ops));
        }
        let faults = idxs
            .into_iter()
            .map(|op_index| PlannedFault {
                op_index,
                bit32: rng.gen_range(32) as u32,
                bit64: rng.gen_range(64) as u32,
            })
            .collect();
        Self { faults }
    }

    /// Lower the plan to single-bit-flip fault events.
    pub fn events(&self) -> Vec<FaultEvent> {
        self.faults
            .iter()
            .map(|f| FaultEvent {
                op_index: f.op_index,
                kind: FaultKind::BitFlip {
                    bit32: f.bit32,
                    bit64: f.bit64,
                },
            })
            .collect()
    }

    /// An execution hook injecting this plan over the whole timeline.
    pub fn hook(&self) -> SegmentHook {
        SegmentHook::spanning(&self.events())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fault::FaultSite;
    use crate::tensor::instrumented::{matmul_hooked, CountingHook, ExecHook, NopHook};
    use crate::tensor::{Dense, Dense64};

    #[test]
    fn plan_sampling_is_sorted_distinct_in_range() {
        let mut rng = Pcg64::from_seed(1);
        let p = FaultPlan::sample(&mut rng, 1000, 5);
        assert_eq!(p.faults.len(), 5);
        for w in p.faults.windows(2) {
            assert!(w[0].op_index < w[1].op_index);
        }
        for f in &p.faults {
            assert!(f.op_index < 1000);
            assert!(f.bit32 < 32);
            assert!(f.bit64 < 64);
        }
    }

    #[test]
    fn hook_counts_like_counting_hook() {
        let a = Dense64::from_dense(&Dense::from_fn(5, 4, |r, c| (r + c) as f32));
        let b = Dense64::from_dense(&Dense::from_fn(4, 3, |r, c| (r * c) as f32 + 1.0));
        let mut cnt = CountingHook::default();
        matmul_hooked(&a, &b, &mut cnt);
        let plan = FaultPlan { faults: vec![] };
        let mut inj = plan.hook();
        matmul_hooked(&a, &b, &mut inj);
        assert_eq!(inj.ops_seen(), cnt.total());
        assert!(inj.exhausted());
        assert!(inj.hits.is_empty());
    }

    #[test]
    fn injection_fires_exactly_once_at_scheduled_op() {
        let a = Dense64::from_dense(&Dense::from_fn(6, 6, |_, _| 1.0));
        let b = a.clone();
        let mut nop = NopHook;
        let golden = matmul_hooked(&a, &b, &mut nop);
        let plan = FaultPlan {
            faults: vec![PlannedFault {
                op_index: 37,
                bit32: 31, // sign flip: guaranteed visible
                bit64: 0,
            }],
        };
        let mut inj = plan.hook();
        let faulty = matmul_hooked(&a, &b, &mut inj);
        assert!(inj.exhausted());
        assert_eq!(inj.hits.len(), 1);
        assert!(!faulty.identical(&golden));
    }

    #[test]
    fn site_classification_matches_callback() {
        let plan = FaultPlan {
            faults: vec![
                PlannedFault {
                    op_index: 0,
                    bit32: 1,
                    bit64: 1,
                },
                PlannedFault {
                    op_index: 1,
                    bit32: 1,
                    bit64: 1,
                },
                PlannedFault {
                    op_index: 2,
                    bit32: 1,
                    bit64: 1,
                },
            ],
        };
        let mut inj = plan.hook();
        inj.mul(1.0);
        inj.add(1.0);
        inj.csum(1.0);
        let sites: Vec<FaultSite> = inj.hits.iter().map(|h| h.site).collect();
        assert_eq!(
            sites,
            vec![FaultSite::DataMul, FaultSite::DataAdd, FaultSite::ChecksumAcc]
        );
    }

    #[test]
    fn deterministic_given_same_rng_seed() {
        let mut r1 = Pcg64::from_seed(9);
        let mut r2 = Pcg64::from_seed(9);
        let p1 = FaultPlan::sample(&mut r1, 500, 3);
        let p2 = FaultPlan::sample(&mut r2, 500, 3);
        for (a, b) in p1.faults.iter().zip(&p2.faults) {
            assert_eq!(a.op_index, b.op_index);
            assert_eq!(a.bit32, b.bit32);
            assert_eq!(a.bit64, b.bit64);
        }
    }
}
