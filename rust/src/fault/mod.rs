//! Fault injection: pluggable fault models (single-bit, multi-bit,
//! stuck-at) over the op timeline, segment hooks with deterministic
//! prefix offsets, and the campaign runner behind Table I.

pub mod bitflip;
pub mod campaign;
pub mod model;
pub mod plan;

pub use bitflip::{flip_f32_image, flip_f64, FaultSite};
pub use campaign::{run_campaigns, CampaignConfig, CampaignReport, Tally};
pub use model::{
    BitFlip, FaultEvent, FaultHit, FaultKind, FaultModel, FaultModelKind, MultiBit, NoFaults,
    SegmentHook, StuckAt,
};
pub use plan::{FaultPlan, PlannedFault};
