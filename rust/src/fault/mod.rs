//! Fault injection: single-bit flips in arithmetic results, fault plans
//! over the op timeline, and the campaign runner behind Table I.

pub mod bitflip;
pub mod campaign;
pub mod plan;

pub use bitflip::{flip_f32_image, flip_f64, FaultSite};
pub use campaign::{run_campaigns, CampaignConfig, CampaignReport, Tally};
pub use plan::{FaultPlan, InjectHook, PlannedFault};
