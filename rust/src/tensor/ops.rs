//! Dense matrix operations: the clean (uninstrumented, fast) reference
//! implementations used for golden runs and by the coordinator's native
//! fallback path. The fault-injectable variants live in
//! [`crate::tensor::instrumented`].

use super::dense::Dense;
use super::kernels;
use crate::util::parallel::par_row_chunks_mut;

/// Rows of B (each `n` f32 wide) kept hot per k-block. 128 rows × up to
/// ~1 K columns ≈ 512 KB worst case, sized for a typical L2; for the
/// repo's layer shapes (n ≤ 16 output columns) a block is a few KB and
/// lives in L1 across the whole row band.
const MATMUL_K_BLOCK: usize = 128;

/// `A · B`, fp32 data path with per-element f32 accumulation — matches the
/// simulated accelerator (MAC results are fp32, which is what the fault
/// model flips bits in). Serial entry point; see [`matmul_par`].
pub fn matmul(a: &Dense, b: &Dense) -> Dense {
    matmul_par(a, b, 1)
}

/// Cache-blocked, row-parallel `A · B` over `threads` scoped workers.
///
/// The output rows are partitioned into contiguous bands (one per
/// worker); within a band the k dimension is blocked so the touched rows
/// of `B` stay cache-resident while the band's output rows are swept.
/// Per-row evaluation order is identical to the serial kernel, so the
/// result is bit-identical at any thread count.
pub fn matmul_par(a: &Dense, b: &Dense, threads: usize) -> Dense {
    assert_eq!(
        a.cols(),
        b.rows(),
        "matmul shape mismatch: {:?} x {:?}",
        a.shape(),
        b.shape()
    );
    let (m, k) = a.shape();
    let n = b.cols();
    let mut out = Dense::zeros(m, n);
    if m == 0 || n == 0 || k == 0 {
        return out;
    }
    par_row_chunks_mut(out.data_mut(), n, threads, |first_row, band| {
        // k-blocked i-k-j order: the MATMUL_K_BLOCK rows of B are reused
        // by every output row of the band before the next block loads.
        for kb in (0..k).step_by(MATMUL_K_BLOCK) {
            let kb_end = (kb + MATMUL_K_BLOCK).min(k);
            for (r, out_row) in band.chunks_mut(n).enumerate() {
                let a_row = a.row(first_row + r);
                for (kk, &aik) in a_row[kb..kb_end].iter().enumerate() {
                    // gcn-lint: allow(D4, reason="skip is bit-exact: x*0.0 contributes exactly 0.0 to the f32 accumulator, so eliding the multiply cannot change output bits")
                    if aik == 0.0 {
                        continue;
                    }
                    kernels::axpy_f32(out_row, aik, b.row(kb + kk));
                }
            }
        }
    });
    out
}

/// ReLU, elementwise, in place.
pub fn relu_inplace(m: &mut Dense) {
    for v in m.data_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

/// ReLU into a new matrix.
pub fn relu(m: &Dense) -> Dense {
    let mut out = m.clone();
    relu_inplace(&mut out);
    out
}

/// Row-wise argmax (predicted class per node).
pub fn argmax_rows(m: &Dense) -> Vec<usize> {
    (0..m.rows())
        .map(|r| {
            let row = m.row(r);
            let mut best = 0;
            let mut best_v = row[0];
            for (j, &v) in row.iter().enumerate().skip(1) {
                if v > best_v {
                    best_v = v;
                    best = j;
                }
            }
            best
        })
        .collect()
}

/// Row-wise log-softmax (used by the tiny trainer; numerically stabilized).
pub fn log_softmax_rows(m: &Dense) -> Dense {
    let mut out = m.clone();
    for r in 0..out.rows() {
        let row = out.row_mut(r);
        let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0f64;
        for v in row.iter() {
            sum += ((v - max) as f64).exp();
        }
        let lse = max as f64 + sum.ln();
        for v in row.iter_mut() {
            *v = (*v as f64 - lse) as f32;
        }
    }
    out
}

/// `a + b` elementwise.
pub fn add(a: &Dense, b: &Dense) -> Dense {
    assert_eq!(a.shape(), b.shape());
    let data = a
        .data()
        .iter()
        .zip(b.data())
        .map(|(x, y)| x + y)
        .collect();
    Dense::from_vec(a.rows(), a.cols(), data)
}

/// `a - b` elementwise.
pub fn sub(a: &Dense, b: &Dense) -> Dense {
    assert_eq!(a.shape(), b.shape());
    let data = a
        .data()
        .iter()
        .zip(b.data())
        .map(|(x, y)| x - y)
        .collect();
    Dense::from_vec(a.rows(), a.cols(), data)
}

/// `s * m` scalar scale.
pub fn scale(m: &Dense, s: f32) -> Dense {
    let data = m.data().iter().map(|x| x * s).collect();
    Dense::from_vec(m.rows(), m.cols(), data)
}

/// Row-vector (`1×n` as slice) times matrix: `v · M` with f64 accumulation
/// — this is how checksum vectors propagate (`h_c·W`, `s_c·X`), and the
/// paper accumulates checksums in double precision.
pub fn vecmat_f64(v: &[f32], m: &Dense) -> Vec<f32> {
    assert_eq!(v.len(), m.rows(), "vecmat shape mismatch");
    let mut acc = vec![0f64; m.cols()];
    for (r, &vr) in v.iter().enumerate() {
        // gcn-lint: allow(D4, reason="skip is bit-exact: a 0.0 row contributes exactly 0.0 to the f64 accumulator")
        if vr == 0.0 {
            continue;
        }
        kernels::axpy_f32_to_f64(&mut acc, vr as f64, m.row(r));
    }
    acc.into_iter().map(|x| x as f32).collect()
}

/// Matrix times column vector: `M · v` with f64 accumulation.
pub fn matvec_f64(m: &Dense, v: &[f32]) -> Vec<f32> {
    assert_eq!(v.len(), m.cols(), "matvec shape mismatch");
    (0..m.rows())
        .map(|r| {
            m.row(r)
                .iter()
                .zip(v)
                .map(|(&a, &b)| a as f64 * b as f64)
                .sum::<f64>() as f32
        })
        .collect()
}

/// Dot product with f64 accumulation.
pub fn dot_f64(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(&x, &y)| x as f64 * y as f64).sum()
}

/// Dot product of an f64 checksum row (offline `s_c`) with an f32 online
/// checksum column (`H·w_r`), accumulated in f64 — the fused-check inner
/// product of the serving path.
pub fn dot_mixed(a: &[f64], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(&x, &y)| x * y as f64).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m23() -> Dense {
        Dense::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.])
    }
    fn m32() -> Dense {
        Dense::from_vec(3, 2, vec![7., 8., 9., 10., 11., 12.])
    }

    #[test]
    fn matmul_known() {
        let c = matmul(&m23(), &m32());
        assert_eq!(c.shape(), (2, 2));
        assert_eq!(c.data(), &[58., 64., 139., 154.]);
    }

    #[test]
    fn matmul_identity() {
        let m = m23();
        let i2 = Dense::eye(2);
        let i3 = Dense::eye(3);
        assert_eq!(matmul(&i2, &m), m);
        assert_eq!(matmul(&m, &i3), m);
    }

    #[test]
    #[should_panic(expected = "matmul shape mismatch")]
    fn matmul_shape_mismatch_panics() {
        matmul(&m23(), &m23());
    }

    #[test]
    fn matmul_par_bit_identical_to_serial() {
        // Shapes chosen to exercise k-blocking (k > MATMUL_K_BLOCK) and a
        // multi-band output (rows·cols above the min-work threshold).
        let a = Dense::from_fn(600, 200, |r, c| ((r * 7 + c * 3) % 13) as f32 * 0.37 - 2.0);
        let b = Dense::from_fn(200, 9, |r, c| ((r + 5 * c) % 11) as f32 * 0.21 - 1.0);
        let serial = matmul(&a, &b);
        for threads in [2, 3, 8, 64] {
            let par = matmul_par(&a, &b, threads);
            assert_eq!(serial, par, "threads={threads} diverged");
        }
    }

    #[test]
    fn matmul_par_degenerate_shapes() {
        let a = Dense::zeros(0, 5);
        let b = Dense::zeros(5, 3);
        assert_eq!(matmul_par(&a, &b, 4).shape(), (0, 3));
        let a = Dense::from_vec(1, 1, vec![2.0]);
        let b = Dense::from_vec(1, 1, vec![3.0]);
        assert_eq!(matmul_par(&a, &b, 8).data(), &[6.0]);
    }

    #[test]
    fn relu_clamps_negatives() {
        let m = Dense::from_vec(1, 4, vec![-1., 0., 2., -0.5]);
        assert_eq!(relu(&m).data(), &[0., 0., 2., 0.]);
    }

    #[test]
    fn argmax_picks_first_max() {
        let m = Dense::from_vec(2, 3, vec![1., 3., 3., -1., -2., -3.]);
        assert_eq!(argmax_rows(&m), vec![1, 0]);
    }

    #[test]
    fn log_softmax_rows_sum_to_one() {
        let m = Dense::from_vec(2, 4, vec![1., 2., 3., 4., -10., 0., 10., 20.]);
        let ls = log_softmax_rows(&m);
        for r in 0..2 {
            let s: f64 = ls.row(r).iter().map(|&x| (x as f64).exp()).sum();
            assert!((s - 1.0).abs() < 1e-5, "row {r} sums to {s}");
        }
    }

    #[test]
    fn elementwise_ops() {
        let a = Dense::from_vec(1, 2, vec![1., 2.]);
        let b = Dense::from_vec(1, 2, vec![10., 20.]);
        assert_eq!(add(&a, &b).data(), &[11., 22.]);
        assert_eq!(sub(&b, &a).data(), &[9., 18.]);
        assert_eq!(scale(&a, 3.0).data(), &[3., 6.]);
    }

    #[test]
    fn vecmat_matvec_agree_with_matmul() {
        let m = m32();
        let v = vec![1., 2., 3.];
        let vm = vecmat_f64(&v, &m);
        // (1,2,3) · m32 = [7+18+33, 8+20+36] = [58, 64]
        assert_eq!(vm, vec![58., 64.]);
        let mv = matvec_f64(&m, &[1., 1.]);
        assert_eq!(mv, vec![15., 19., 23.]);
    }

    #[test]
    fn dot_accumulates() {
        assert_eq!(dot_f64(&[1., 2.], &[3., 4.]), 11.0);
        assert_eq!(dot_mixed(&[1.5, -2.0], &[2., 4.]), -5.0);
    }

    #[test]
    fn checksum_identity_through_matmul() {
        // eᵀ(AB)e == (eᵀA)(Be): the core ABFT identity on dense data.
        let a = Dense::from_fn(5, 4, |r, c| ((r + 2 * c) as f32) * 0.5 - 1.0);
        let b = Dense::from_fn(4, 6, |r, c| ((3 * r + c) as f32) * 0.25 - 2.0);
        let ab = matmul(&a, &b);
        let lhs = ab.checksum_f64();
        let ac = a.col_sums();
        let br = b.row_sums();
        let rhs = dot_f64(&ac, &br);
        assert!((lhs - rhs).abs() < 1e-3, "lhs {lhs} rhs {rhs}");
    }
}
