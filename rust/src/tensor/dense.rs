//! Dense row-major matrices.
//!
//! The data path of the simulated accelerator is fp32 (matching the paper's
//! "single-precision floats for matrix multiplication"); checksum
//! accumulation is fp64 (`abft::checksum`). `Dense` is deliberately simple —
//! a shape + contiguous `Vec<f32>` — because the fault-injection engine
//! needs full control over every multiply-accumulate.

use std::fmt;

/// Row-major dense matrix of f32.
#[derive(Clone, PartialEq)]
pub struct Dense {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl fmt::Debug for Dense {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Dense({}x{})", self.rows, self.cols)?;
        if self.rows * self.cols <= 64 {
            write!(f, " {:?}", self.data)?;
        }
        Ok(())
    }
}

impl Dense {
    /// Zero-filled matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    /// Build from a row-major data vector.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(
            data.len(),
            rows * cols,
            "data length {} != {rows}x{cols}",
            data.len()
        );
        Self { rows, cols, data }
    }

    /// Build by evaluating `f(r, c)`.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                data.push(f(r, c));
            }
        }
        Self { rows, cols, data }
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Self {
        Self::from_fn(n, n, |r, c| if r == c { 1.0 } else { 0.0 })
    }

    pub fn rows(&self) -> usize {
        self.rows
    }
    pub fn cols(&self) -> usize {
        self.cols
    }
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }
    pub fn len(&self) -> usize {
        self.data.len()
    }
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
    pub fn data(&self) -> &[f32] {
        &self.data
    }
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }
    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c] = v;
    }

    /// Borrow row `r` as a slice.
    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Mutable row slice.
    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Transpose into a new matrix.
    pub fn transpose(&self) -> Dense {
        let mut out = Dense::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                out.set(c, r, self.get(r, c));
            }
        }
        out
    }

    /// Append a column (used to enhance `W` with `w_r`). Returns a new
    /// `(rows, cols+1)` matrix.
    pub fn with_appended_col(&self, col: &[f32]) -> Dense {
        assert_eq!(col.len(), self.rows, "appended column length mismatch");
        let mut out = Dense::zeros(self.rows, self.cols + 1);
        for r in 0..self.rows {
            out.row_mut(r)[..self.cols].copy_from_slice(self.row(r));
            out.set(r, self.cols, col[r]);
        }
        out
    }

    /// Append a row (used to enhance `H` with `h_c` in the baseline split
    /// checker). Returns a new `(rows+1, cols)` matrix.
    pub fn with_appended_row(&self, row: &[f32]) -> Dense {
        assert_eq!(row.len(), self.cols, "appended row length mismatch");
        let mut data = self.data.clone();
        data.extend_from_slice(row);
        Dense::from_vec(self.rows + 1, self.cols, data)
    }

    /// Slice out the top-left `(rows, cols)` block.
    pub fn block(&self, rows: usize, cols: usize) -> Dense {
        assert!(rows <= self.rows && cols <= self.cols);
        Dense::from_fn(rows, cols, |r, c| self.get(r, c))
    }

    /// Column `c` as a vector.
    pub fn col(&self, c: usize) -> Vec<f32> {
        (0..self.rows).map(|r| self.get(r, c)).collect()
    }

    /// Sum of all elements, accumulated in f64 (the "actual checksum" of
    /// ABFT — accumulation precision matches the paper's fp64 checksums).
    pub fn checksum_f64(&self) -> f64 {
        self.data.iter().map(|&x| x as f64).sum()
    }

    /// Per-column sums (`eᵀM`), f64 accumulation, returned as f32 check row.
    pub fn col_sums(&self) -> Vec<f32> {
        self.col_sums_f64().into_iter().map(|x| x as f32).collect()
    }

    /// Per-column sums at full f64 precision — the serving path keeps
    /// `s_c` in f64 so the cached offline state adds no rounding floor of
    /// its own to the checksum residuals. The per-row accumulate is the
    /// vectorized [`crate::tensor::kernels::col_acc_f64`]: lanes span
    /// columns, each column still sums its rows in order, so the result
    /// is bit-identical at every kernel width.
    pub fn col_sums_f64(&self) -> Vec<f64> {
        let mut acc = vec![0f64; self.cols];
        for r in 0..self.rows {
            super::kernels::col_acc_f64(&mut acc, self.row(r));
        }
        acc
    }

    /// Per-row sums (`M·e`), f64 accumulation, returned as f32 check column.
    pub fn row_sums(&self) -> Vec<f32> {
        (0..self.rows)
            .map(|r| self.row(r).iter().map(|&x| x as f64).sum::<f64>() as f32)
            .collect()
    }

    /// Max |a - b| over all elements (matrices must be the same shape).
    pub fn max_abs_diff(&self, other: &Dense) -> f32 {
        assert_eq!(self.shape(), other.shape());
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_indexing() {
        let m = Dense::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(m.shape(), (2, 3));
        assert_eq!(m.get(0, 0), 1.0);
        assert_eq!(m.get(1, 2), 6.0);
        assert_eq!(m.row(1), &[4., 5., 6.]);
        assert_eq!(m.col(1), vec![2., 5.]);
    }

    #[test]
    #[should_panic(expected = "data length")]
    fn bad_shape_panics() {
        Dense::from_vec(2, 2, vec![1.0]);
    }

    #[test]
    fn eye_and_transpose() {
        let i = Dense::eye(3);
        assert_eq!(i.get(1, 1), 1.0);
        assert_eq!(i.get(0, 1), 0.0);
        let m = Dense::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let t = m.transpose();
        assert_eq!(t.shape(), (3, 2));
        assert_eq!(t.get(2, 1), 6.0);
        assert_eq!(t.transpose(), m);
    }

    #[test]
    fn append_col_row() {
        let m = Dense::from_vec(2, 2, vec![1., 2., 3., 4.]);
        let mc = m.with_appended_col(&[9., 8.]);
        assert_eq!(mc.shape(), (2, 3));
        assert_eq!(mc.get(0, 2), 9.0);
        assert_eq!(mc.get(1, 0), 3.0);
        let mr = m.with_appended_row(&[7., 6.]);
        assert_eq!(mr.shape(), (3, 2));
        assert_eq!(mr.get(2, 0), 7.0);
    }

    #[test]
    fn sums_and_checksum() {
        let m = Dense::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(m.col_sums(), vec![5., 7., 9.]);
        assert_eq!(m.row_sums(), vec![6., 15.]);
        assert_eq!(m.checksum_f64(), 21.0);
    }

    #[test]
    fn checksum_identity_col_then_total() {
        // Σ col_sums == Σ row_sums == checksum
        let m = Dense::from_fn(7, 5, |r, c| (r * 5 + c) as f32 * 0.25 - 3.0);
        let by_cols: f64 = m.col_sums().iter().map(|&x| x as f64).sum();
        let by_rows: f64 = m.row_sums().iter().map(|&x| x as f64).sum();
        assert!((by_cols - m.checksum_f64()).abs() < 1e-4);
        assert!((by_rows - m.checksum_f64()).abs() < 1e-4);
    }

    #[test]
    fn block_extraction() {
        let m = Dense::from_fn(4, 4, |r, c| (r * 4 + c) as f32);
        let b = m.block(2, 3);
        assert_eq!(b.shape(), (2, 3));
        assert_eq!(b.get(1, 2), 6.0);
    }

    #[test]
    fn max_abs_diff_works() {
        let a = Dense::from_vec(1, 3, vec![1., 2., 3.]);
        let b = Dense::from_vec(1, 3, vec![1., 2.5, 3.]);
        assert_eq!(a.max_abs_diff(&b), 0.5);
    }
}
