//! Vectorized inner kernels with a per-lane-width **bit-identity
//! contract**, selected at runtime by a process-global dispatch.
//!
//! # The bit-identity contract
//!
//! Every kernel in this module is bit-identical to its scalar reference
//! **at every lane width, by construction**: the vector lanes always
//! span *independent output elements* (adjacent output columns of an
//! axpy broadcast, adjacent columns of an f64 column accumulator) and
//! never a reduction axis. Each output element therefore sees exactly
//! the same sequence of fused-nothing `a + b * c`-shaped f32/f64
//! operations, in exactly the same order, regardless of how many
//! elements are processed per iteration — widening the tile reorders
//! *nothing within any element*, so IEEE-754 evaluation is unchanged
//! bit for bit. Reduction-shaped loops (dot products, `checksum_f64`,
//! the CSR column-sum scatter) stay scalar-sequential in their home
//! modules: vectorizing a reduction would re-associate the sum and
//! break the contract.
//!
//! This is what lets every existing equivalence property in the tree
//! (batching, shards, mutate, scheme parity, incremental operands)
//! hold unchanged under any dispatch: swapping `Lanes::Scalar` for
//! `Lanes::X8` can change *throughput only*, never a single output
//! bit. `tests/prop_kernels.rs` pins this per lane width.
//!
//! # Dispatch
//!
//! [`active`] picks the lane width once per process: a test/bench
//! override ([`force`]) wins, else the `GCN_ABFT_KERNEL` environment
//! variable (`scalar` | `x8`, cached on first read), else [`Lanes::X8`]
//! — the unrolled eight-lane tile, which the backend autovectorizer
//! turns into 256-bit SIMD on every mainstream target. The override is
//! a process-global atomic rather than thread-local state on purpose:
//! the row-band workers (`util::parallel::par_row_chunks_mut`) and the
//! banded aggregation fan-out spawn scoped worker threads, and a forced
//! width must bind *all* of them, not just the forcing thread.
//!
//! Only this module (and `sparse::kernels`) may branch on a lane width
//! or call the `*_with` per-lane entry points — lint rule K1 confines
//! kernel internals here, so the rest of the tree stays
//! width-oblivious and the contract has one enforcement point.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

/// A runtime-selectable lane width. `Scalar` is the reference
/// implementation every other width must match bit for bit.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Lanes {
    /// Plain element-at-a-time loops — the reference kernels.
    Scalar,
    /// Eight-lane unrolled tiles over `chunks_exact(8)` with a scalar
    /// tail: fixed in-chunk indices elide every bounds check and give
    /// the autovectorizer a branch-free 8×f32 (or 8×f64-accumulate)
    /// body.
    X8,
}

impl Lanes {
    /// Every runtime-selectable width, scalar reference first — the
    /// iteration order of the bit-identity property tests.
    pub const ALL: [Lanes; 2] = [Lanes::Scalar, Lanes::X8];

    pub fn name(self) -> &'static str {
        match self {
            Lanes::Scalar => "scalar",
            Lanes::X8 => "x8",
        }
    }

    /// Parse a dispatch name (`GCN_ABFT_KERNEL`, bench flags).
    pub fn parse(s: &str) -> Option<Lanes> {
        match s.to_ascii_lowercase().as_str() {
            "scalar" => Some(Lanes::Scalar),
            "x8" | "vector" => Some(Lanes::X8),
            _ => None,
        }
    }
}

/// Test/bench override: 0 = none, 1 = scalar, 2 = x8. Process-global
/// so scoped worker threads inherit the forced width (see module docs).
static FORCED: AtomicU8 = AtomicU8::new(0);
/// The environment selection, read once per process.
static ENV_CHOICE: OnceLock<Lanes> = OnceLock::new();

fn env_choice() -> Lanes {
    *ENV_CHOICE.get_or_init(|| match std::env::var("GCN_ABFT_KERNEL") {
        Ok(v) => Lanes::parse(&v).unwrap_or_else(|| {
            eprintln!("GCN_ABFT_KERNEL={v:?} is not a kernel (scalar, x8); using x8");
            Lanes::X8
        }),
        Err(_) => Lanes::X8,
    })
}

/// The lane width every dispatched kernel call uses right now:
/// [`force`] override first, else the cached `GCN_ABFT_KERNEL`
/// environment selection, else [`Lanes::X8`].
#[inline]
pub fn active() -> Lanes {
    match FORCED.load(Ordering::Relaxed) {
        1 => Lanes::Scalar,
        2 => Lanes::X8,
        _ => env_choice(),
    }
}

/// Force the dispatch for property tests and scalar-vs-vector bench
/// A/Bs (`None` restores the environment selection). Global: binds
/// every thread, including scoped band workers. Callers that share a
/// process (test binaries run tests concurrently) must serialize
/// around it.
pub fn force(sel: Option<Lanes>) {
    FORCED.store(
        match sel {
            None => 0,
            Some(Lanes::Scalar) => 1,
            Some(Lanes::X8) => 2,
        },
        Ordering::Relaxed,
    );
}

/// `out[j] += coeff * src[j]` — the axpy broadcast at the heart of
/// dense matmul, CSR spmm and banded aggregation. Lanes span output
/// columns, so every width is bit-identical (module docs).
#[inline]
pub fn axpy_f32(out: &mut [f32], coeff: f32, src: &[f32]) {
    axpy_f32_with(active(), out, coeff, src);
}

/// Per-lane-width body of [`axpy_f32`]. Kernel-module internal (lint
/// rule K1): everything else dispatches through [`axpy_f32`].
#[inline]
pub fn axpy_f32_with(lanes: Lanes, out: &mut [f32], coeff: f32, src: &[f32]) {
    debug_assert_eq!(out.len(), src.len());
    match lanes {
        Lanes::Scalar => {
            for (o, &s) in out.iter_mut().zip(src) {
                *o += coeff * s;
            }
        }
        Lanes::X8 => {
            let mut o8 = out.chunks_exact_mut(8);
            let mut s8 = src.chunks_exact(8);
            for (o, s) in (&mut o8).zip(&mut s8) {
                o[0] += coeff * s[0];
                o[1] += coeff * s[1];
                o[2] += coeff * s[2];
                o[3] += coeff * s[3];
                o[4] += coeff * s[4];
                o[5] += coeff * s[5];
                o[6] += coeff * s[6];
                o[7] += coeff * s[7];
            }
            for (o, &s) in o8.into_remainder().iter_mut().zip(s8.remainder()) {
                *o += coeff * s;
            }
        }
    }
}

/// `acc[j] += coeff * src[j] as f64` — the widening axpy the f64
/// checksum row (`vecmat_f64`) is built from. Same column-lane layout,
/// same bit-identity argument; the f32→f64 widening is exact, so the
/// only rounding is the final f64 fused-nothing multiply-add per
/// element, identical at every width.
#[inline]
pub fn axpy_f32_to_f64(acc: &mut [f64], coeff: f64, src: &[f32]) {
    axpy_f32_to_f64_with(active(), acc, coeff, src);
}

/// Per-lane-width body of [`axpy_f32_to_f64`] (kernel-module internal,
/// lint rule K1).
#[inline]
pub fn axpy_f32_to_f64_with(lanes: Lanes, acc: &mut [f64], coeff: f64, src: &[f32]) {
    debug_assert_eq!(acc.len(), src.len());
    match lanes {
        Lanes::Scalar => {
            for (a, &s) in acc.iter_mut().zip(src) {
                *a += coeff * s as f64;
            }
        }
        Lanes::X8 => {
            let mut a8 = acc.chunks_exact_mut(8);
            let mut s8 = src.chunks_exact(8);
            for (a, s) in (&mut a8).zip(&mut s8) {
                a[0] += coeff * s[0] as f64;
                a[1] += coeff * s[1] as f64;
                a[2] += coeff * s[2] as f64;
                a[3] += coeff * s[3] as f64;
                a[4] += coeff * s[4] as f64;
                a[5] += coeff * s[5] as f64;
                a[6] += coeff * s[6] as f64;
                a[7] += coeff * s[7] as f64;
            }
            for (a, &s) in a8.into_remainder().iter_mut().zip(s8.remainder()) {
                *a += coeff * s as f64;
            }
        }
    }
}

/// `acc[j] += src[j] as f64` — one row's contribution to the f64
/// column-sum reduction behind `Dense::col_sums_f64`. Lanes span
/// columns; each column's row-major accumulation order is untouched,
/// so every width is bit-identical (and the f32→f64 widening is
/// exact — no multiply, no extra rounding at all).
#[inline]
pub fn col_acc_f64(acc: &mut [f64], src: &[f32]) {
    col_acc_f64_with(active(), acc, src);
}

/// Per-lane-width body of [`col_acc_f64`] (kernel-module internal,
/// lint rule K1).
#[inline]
pub fn col_acc_f64_with(lanes: Lanes, acc: &mut [f64], src: &[f32]) {
    debug_assert_eq!(acc.len(), src.len());
    match lanes {
        Lanes::Scalar => {
            for (a, &s) in acc.iter_mut().zip(src) {
                *a += s as f64;
            }
        }
        Lanes::X8 => {
            let mut a8 = acc.chunks_exact_mut(8);
            let mut s8 = src.chunks_exact(8);
            for (a, s) in (&mut a8).zip(&mut s8) {
                a[0] += s[0] as f64;
                a[1] += s[1] as f64;
                a[2] += s[2] as f64;
                a[3] += s[3] as f64;
                a[4] += s[4] as f64;
                a[5] += s[5] as f64;
                a[6] += s[6] as f64;
                a[7] += s[7] as f64;
            }
            for (a, &s) in a8.into_remainder().iter_mut().zip(s8.remainder()) {
                *a += s as f64;
            }
        }
    }
}

/// Achieved arithmetic intensity (flops per byte of data moved) of an
/// `m×k · k×n` dense matmul under the kernel's traffic model: every
/// operand matrix streamed once, the f32 output read and written once
/// per k-block pass (the axpy accumulates in place). Feeds the
/// `report bench` kernels area and the `Auto` scheme's decision log —
/// a *model*, not a hardware counter.
pub fn matmul_intensity(m: usize, k: usize, n: usize) -> f64 {
    let flops = 2.0 * m as f64 * k as f64 * n as f64;
    let bytes = 4.0 * (m * k + k * n + 2 * m * n) as f64;
    flops / bytes.max(1.0)
}

/// Achieved arithmetic intensity of a CSR spmm (`nnz` stored edges
/// against an `·×cols` dense right-hand side): 2 flops per stored
/// element per column, against the per-nonzero axpy traffic (4-byte
/// value + 8-byte column index, then a 4-byte load and 4+4-byte
/// read-modify-write per output column). Same modelling caveat as
/// [`matmul_intensity`].
pub fn spmm_intensity(nnz: usize, cols: usize) -> f64 {
    let flops = 2.0 * nnz as f64 * cols as f64;
    let bytes = nnz as f64 * (12.0 + 12.0 * cols as f64);
    flops / bytes.max(1.0)
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used)]

    use super::*;

    #[test]
    fn lanes_parse_and_names_round_trip() {
        for l in Lanes::ALL {
            assert_eq!(Lanes::parse(l.name()), Some(l));
        }
        assert_eq!(Lanes::parse("X8"), Some(Lanes::X8));
        assert_eq!(Lanes::parse("vector"), Some(Lanes::X8));
        assert_eq!(Lanes::parse("avx-512"), None);
    }

    // Bit-identity across widths on ragged lengths, including the
    // all-tail (< 8) and exact-multiple cases. The full-op properties
    // (matmul, spmm, checksums, random shapes) live in
    // tests/prop_kernels.rs; this pins the primitives in isolation.
    #[test]
    fn primitives_bit_identical_across_widths_on_ragged_tails() {
        for len in [0usize, 1, 3, 7, 8, 9, 15, 16, 37] {
            let src: Vec<f32> = (0..len).map(|i| (i as f32 * 0.37 - 3.1).sin()).collect();
            let base_f32: Vec<f32> = (0..len).map(|i| (i as f32 * 1.13).cos()).collect();
            let base_f64: Vec<f64> = base_f32.iter().map(|&v| v as f64 * 1.000001).collect();
            let mut ref_f32 = base_f32.clone();
            axpy_f32_with(Lanes::Scalar, &mut ref_f32, 0.123_456_7, &src);
            let mut ref_axpy64 = base_f64.clone();
            axpy_f32_to_f64_with(Lanes::Scalar, &mut ref_axpy64, 0.987_654_3, &src);
            let mut ref_col64 = base_f64.clone();
            col_acc_f64_with(Lanes::Scalar, &mut ref_col64, &src);
            for lanes in Lanes::ALL {
                let mut out = base_f32.clone();
                axpy_f32_with(lanes, &mut out, 0.123_456_7, &src);
                let same = out
                    .iter()
                    .zip(&ref_f32)
                    .all(|(a, b)| a.to_bits() == b.to_bits());
                assert!(same, "axpy_f32 {lanes:?} diverged at len {len}");
                let mut acc = base_f64.clone();
                axpy_f32_to_f64_with(lanes, &mut acc, 0.987_654_3, &src);
                let same = acc
                    .iter()
                    .zip(&ref_axpy64)
                    .all(|(a, b)| a.to_bits() == b.to_bits());
                assert!(same, "axpy_f32_to_f64 {lanes:?} diverged at len {len}");
                let mut acc = base_f64.clone();
                col_acc_f64_with(lanes, &mut acc, &src);
                let same = acc
                    .iter()
                    .zip(&ref_col64)
                    .all(|(a, b)| a.to_bits() == b.to_bits());
                assert!(same, "col_acc_f64 {lanes:?} diverged at len {len}");
            }
        }
    }

    #[test]
    fn intensity_models_are_finite_and_ordered() {
        // Dense matmul reuses operands k-fold; spmm streams — the model
        // must reflect that (matmul well above the spmm ~1/6 ceiling).
        let mm = matmul_intensity(512, 512, 512);
        let sp = spmm_intensity(10_000, 64);
        assert!(mm.is_finite() && sp.is_finite());
        assert!(mm > sp, "matmul intensity {mm} ≤ spmm {sp}");
        assert!(sp < 0.2, "spmm streams: intensity should be < 0.2, got {sp}");
    }
}
