//! Dense tensor substrate: f32 row-major matrices for the serving path,
//! f64 matrices + the MAC-level instrumented engine for fault injection
//! and op counting.

pub mod dense;
pub mod dense64;
pub mod instrumented;
pub mod kernels;
pub mod ops;

pub use dense::Dense;
pub use dense64::Dense64;
pub use instrumented::{CountingHook, ExecHook, NopHook};
