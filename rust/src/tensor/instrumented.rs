//! MAC-level instrumented execution engine.
//!
//! This is the "accelerator datapath simulator" substrate: every arithmetic
//! result produced while executing a GCN layer flows through an
//! [`ExecHook`], so single-bit faults can be injected at an arbitrary
//! operation index (the paper injects flips into "the results of arithmetic
//! operations … within matrix multiplication (multiply and add) or checksum
//! accumulation, at randomly selected time points", §IV-A).
//!
//! Numerics (see DESIGN.md §6): the simulation's baseline arithmetic is
//! f64 so the fault-free predicted-vs-actual residual is ~1e-13 relative —
//! negligible against the paper's tightest threshold (1e-7). The fault
//! model distinguishes the two physical datapaths:
//!
//! * data path (matmul multiply & add results) — **single-precision** in
//!   the accelerator; a fault flips one of the 32 bits of the value's f32
//!   image ([`ExecHook::mul`] / [`ExecHook::add`]);
//! * checker path (checksum accumulation) — **double-precision**; a fault
//!   flips one of the 64 bits of the f64 accumulator ([`ExecHook::csum`]).
//!
//! Hooks are statically dispatched (generics) so the counting pass and the
//! fault pass both run at full speed.

use super::dense64::Dense64;

/// Observer/transformer of every arithmetic result.
///
/// Implementations: [`CountingHook`] (op accounting),
/// `fault::SegmentHook` (fault-model injection over one timeline
/// segment), [`NopHook`] (golden runs).
pub trait ExecHook {
    /// A multiply result on the data path. May return a modified value.
    fn mul(&mut self, v: f64) -> f64;
    /// An accumulate (add) result on the data path.
    fn add(&mut self, v: f64) -> f64;
    /// A checksum-accumulation (add) result on the checker path.
    fn csum(&mut self, v: f64) -> f64;
}

/// Pass-through hook for golden runs.
#[derive(Debug, Default, Clone)]
pub struct NopHook;

impl ExecHook for NopHook {
    #[inline(always)]
    fn mul(&mut self, v: f64) -> f64 {
        v
    }
    #[inline(always)]
    fn add(&mut self, v: f64) -> f64 {
        v
    }
    #[inline(always)]
    fn csum(&mut self, v: f64) -> f64 {
        v
    }
}

/// Counts data-path and checker-path operations without modifying values.
/// Used to size the fault-injection timeline (faults land uniformly over
/// all counted ops, so longer phases attract proportionally more faults —
/// §IV-A) and to cross-check the analytic op model of `opcount`.
#[derive(Debug, Default, Clone)]
pub struct CountingHook {
    pub data_ops: u64,
    pub checksum_ops: u64,
}

impl ExecHook for CountingHook {
    #[inline(always)]
    fn mul(&mut self, v: f64) -> f64 {
        self.data_ops += 1;
        v
    }
    #[inline(always)]
    fn add(&mut self, v: f64) -> f64 {
        self.data_ops += 1;
        v
    }
    #[inline(always)]
    fn csum(&mut self, v: f64) -> f64 {
        self.checksum_ops += 1;
        v
    }
}

impl CountingHook {
    pub fn total(&self) -> u64 {
        self.data_ops + self.checksum_ops
    }
}

/// Instrumented dense·dense matmul. Every product and every accumulator
/// update is an individually observable operation.
pub fn matmul_hooked<H: ExecHook>(a: &Dense64, b: &Dense64, hook: &mut H) -> Dense64 {
    matmul_rows_hooked(a, b, 0, a.rows(), hook)
}

/// Instrumented matmul over the output-row range `[lo, hi)` of
/// `a · b` — the unit the banded combination phase hands each logical
/// band. Per-row op order is identical to the full [`matmul_hooked`]
/// (rows are independent), so band outputs stitch bit-exactly.
pub fn matmul_rows_hooked<H: ExecHook>(
    a: &Dense64,
    b: &Dense64,
    lo: usize,
    hi: usize,
    hook: &mut H,
) -> Dense64 {
    assert_eq!(
        a.cols(),
        b.rows(),
        "matmul shape mismatch: {:?} x {:?}",
        a.shape(),
        b.shape()
    );
    assert!(lo <= hi && hi <= a.rows(), "row range out of bounds");
    let k = a.cols();
    let n = b.cols();
    let mut out = Dense64::zeros(hi - lo, n);
    for i in lo..hi {
        let a_row = a.row(i);
        for kk in 0..k {
            let aik = a_row[kk];
            let b_row = b.row(kk);
            let out_row = out.row_mut(i - lo);
            for j in 0..n {
                let p = hook.mul(aik * b_row[j]);
                out_row[j] = hook.add(out_row[j] + p);
            }
        }
    }
    out
}

/// Instrumented dense `M · v` (data path): the `H·w_r` / `S·x_r` check
/// columns ride the same MAC array as the rest of the multiplication.
pub fn matvec_hooked<H: ExecHook>(m: &Dense64, v: &[f64], hook: &mut H) -> Vec<f64> {
    matvec_rows_hooked(m, v, 0, m.rows(), hook)
}

/// Instrumented dense matvec over the row range `[lo, hi)`.
pub fn matvec_rows_hooked<H: ExecHook>(
    m: &Dense64,
    v: &[f64],
    lo: usize,
    hi: usize,
    hook: &mut H,
) -> Vec<f64> {
    assert_eq!(v.len(), m.cols(), "matvec shape mismatch");
    assert!(lo <= hi && hi <= m.rows(), "row range out of bounds");
    (lo..hi)
        .map(|r| {
            let mut acc = 0f64;
            for (&x, &y) in m.row(r).iter().zip(v) {
                let p = hook.mul(x * y);
                acc = hook.add(acc + p);
            }
            acc
        })
        .collect()
}

/// Instrumented per-column sums `eᵀM` (checker path).
/// This is the online `h_c` computation the baseline split checker needs.
pub fn col_sums_hooked<H: ExecHook>(m: &Dense64, hook: &mut H) -> Vec<f64> {
    let mut acc = vec![0f64; m.cols()];
    for r in 0..m.rows() {
        let row = m.row(r);
        for (a, &x) in acc.iter_mut().zip(row) {
            *a = hook.csum(*a + x);
        }
    }
    acc
}

/// Instrumented total checksum `eᵀMe` over the first `cols` columns of a
/// matrix (checker path) — restricting lets the check column of an
/// enhanced output be excluded from the "actual" checksum.
///
/// Accumulation is a hooked **pairwise (adder-tree) reduction**: the same
/// op count as a serial accumulator (M−1 adds, every partial result
/// observable/flippable), but with an O(eps·log M) rounding floor instead
/// of O(eps·M) — necessary so the fault-free residual stays far below the
/// paper's tightest threshold (1e-7) even at Nell scale, and faithful to
/// how wide accumulations are reduced in hardware.
pub fn block_checksum_hooked<H: ExecHook>(m: &Dense64, cols: usize, hook: &mut H) -> f64 {
    assert!(cols <= m.cols());
    if m.rows() == 0 || cols == 0 {
        return 0.0;
    }
    // Serial sum within rows is fine (rows are short); combine row sums
    // pairwise. Total hooked adds = rows·cols − 1 (same as one serial
    // accumulator over all elements).
    let row_sums: Vec<f64> = (0..m.rows())
        .map(|r| {
            let row = &m.row(r)[..cols];
            let mut acc = row[0];
            for &x in &row[1..] {
                acc = hook.csum(acc + x);
            }
            acc
        })
        .collect();
    pairwise_sum_hooked(&row_sums, hook)
}

/// Hooked pairwise reduction of pre-computed partials. The first partial
/// seeds the accumulator (no op), every combine is one hooked add —
/// total adds = len−1, matching a serial reduction's op count.
pub fn pairwise_sum_hooked<H: ExecHook>(xs: &[f64], hook: &mut H) -> f64 {
    match xs.len() {
        0 => 0.0,
        1 => xs[0],
        2 => hook.csum(xs[0] + xs[1]),
        n => {
            let (lo, hi) = xs.split_at(n / 2);
            let a = pairwise_sum_hooked(lo, hook);
            let b = pairwise_sum_hooked(hi, hook);
            hook.csum(a + b)
        }
    }
}

/// Instrumented row-vector · matrix (checker path): `v·M`.
/// Used for `h_c·[W|w_r]` and `s_c·[X|x_r]`; each product and each
/// accumulate is an individually observable checker op.
pub fn vecmat_hooked<H: ExecHook>(v: &[f64], m: &Dense64, hook: &mut H) -> Vec<f64> {
    assert_eq!(v.len(), m.rows(), "vecmat shape mismatch");
    let mut acc = vec![0f64; m.cols()];
    for (r, &vr) in v.iter().enumerate() {
        let row = m.row(r);
        for (a, &x) in acc.iter_mut().zip(row) {
            let p = hook.csum(vr * x);
            *a = hook.csum(*a + p);
        }
    }
    acc
}

/// Instrumented dot product (checker path; multiply and accumulate are
/// separately observable results, so both count as checker ops — the
/// paper counts multiplications and additions equally).
pub fn dot_hooked<H: ExecHook>(a: &[f64], b: &[f64], hook: &mut H) -> f64 {
    assert_eq!(a.len(), b.len());
    let mut acc = 0f64;
    for (&x, &y) in a.iter().zip(b) {
        let p = hook.csum(x * y);
        acc = hook.csum(acc + p);
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Dense;

    fn d64(rows: usize, cols: usize, f: impl FnMut(usize, usize) -> f32) -> Dense64 {
        Dense64::from_dense(&Dense::from_fn(rows, cols, f))
    }

    #[test]
    fn nop_hook_matches_reference_matmul() {
        let a = d64(4, 3, |r, c| (r as f32) - (c as f32) * 0.5);
        let b = d64(3, 5, |r, c| (r * 5 + c) as f32 * 0.1);
        let mut nop = NopHook;
        let hooked = matmul_hooked(&a, &b, &mut nop);
        let plain = crate::tensor::ops::matmul(&a.to_dense(), &b.to_dense());
        assert!(hooked.to_dense().max_abs_diff(&plain) < 1e-5);
    }

    #[test]
    fn counting_hook_counts_2mkn_data_ops() {
        let a = Dense64::zeros(4, 3);
        let b = Dense64::zeros(3, 5);
        let mut c = CountingHook::default();
        matmul_hooked(&a, &b, &mut c);
        assert_eq!(c.data_ops, 2 * 4 * 3 * 5);
        assert_eq!(c.checksum_ops, 0);
        assert_eq!(c.total(), 120);
    }

    #[test]
    fn col_sums_hooked_matches_and_counts() {
        let m = d64(6, 4, |r, c| (r + c) as f32);
        let mut c = CountingHook::default();
        let s = col_sums_hooked(&m, &mut c);
        assert_eq!(s, vec![15.0, 21.0, 27.0, 33.0]);
        assert_eq!(c.checksum_ops, 6 * 4);
        assert_eq!(c.data_ops, 0);
    }

    #[test]
    fn block_checksum_excludes_check_column() {
        let m = Dense64::from_vec(2, 3, vec![1., 2., 100., 3., 4., 100.]);
        let mut nop = NopHook;
        assert_eq!(block_checksum_hooked(&m, 2, &mut nop), 10.0);
        let mut c = CountingHook::default();
        block_checksum_hooked(&m, 2, &mut c);
        // rows*cols - 1 adds (serial-within-row + pairwise combine)
        assert_eq!(c.checksum_ops, 3);
    }

    #[test]
    fn vecmat_dot_matvec_agree_with_reference() {
        let m = d64(3, 4, |r, c| (r * 4 + c) as f32 * 0.5 - 2.0);
        let v = vec![1.0f64, -1.0, 2.0];
        let mut nop = NopHook;
        let vm = vecmat_hooked(&v, &m, &mut nop);
        // reference via dense transpose
        for (j, &got) in vm.iter().enumerate() {
            let want: f64 = (0..3).map(|r| v[r] * m.get(r, j)).sum();
            assert!((got - want).abs() < 1e-12);
        }
        let x = vec![1.0f64, 2.0, 3.0, 4.0];
        let mv = matvec_hooked(&m, &x, &mut nop);
        for (r, &got) in mv.iter().enumerate() {
            let want: f64 = (0..4).map(|c| m.get(r, c) * x[c]).sum();
            assert!((got - want).abs() < 1e-12);
        }
        assert_eq!(dot_hooked(&[1., 2.], &[3., 4.], &mut nop), 11.0);
    }

    #[test]
    fn matvec_counts_data_ops() {
        let m = Dense64::zeros(5, 7);
        let v = vec![0.0; 7];
        let mut c = CountingHook::default();
        matvec_hooked(&m, &v, &mut c);
        assert_eq!(c.data_ops, 2 * 5 * 7);
        assert_eq!(c.checksum_ops, 0);
    }

    #[test]
    fn flip_hook_perturbs_one_result() {
        // A hook that negates exactly the 5th data-path op result.
        struct FlipOnce {
            countdown: i64,
        }
        impl ExecHook for FlipOnce {
            fn mul(&mut self, v: f64) -> f64 {
                self.countdown -= 1;
                if self.countdown == 0 {
                    -v
                } else {
                    v
                }
            }
            fn add(&mut self, v: f64) -> f64 {
                self.countdown -= 1;
                if self.countdown == 0 {
                    -v
                } else {
                    v
                }
            }
            fn csum(&mut self, v: f64) -> f64 {
                v
            }
        }
        let a = d64(3, 3, |r, c| (r + c) as f32 + 1.0);
        let b = d64(3, 3, |_, _| 1.0); // all-ones: every product is nonzero
        let mut nop = NopHook;
        let golden = matmul_hooked(&a, &b, &mut nop);
        let mut hook = FlipOnce { countdown: 5 };
        let faulty = matmul_hooked(&a, &b, &mut hook);
        assert!(!faulty.identical(&golden), "fault had no effect");
    }
}
