//! f64 dense matrices for the instrumented (fault-injection) engine.
//!
//! The fault-injection simulation runs its *baseline* arithmetic in f64 so
//! that the predicted-vs-actual checksum residual of a fault-free run is
//! pure rounding noise at the 1e-13 relative level — far below the
//! paper's tightest threshold (1e-7). Injected faults then flip one bit of
//! the **f32 image** of a matmul result (the accelerator's single-precision
//! data path) or of the f64 checksum accumulator, so the residual measures
//! the fault effect alone, matching the paper's methodology (§IV-A, and
//! see DESIGN.md §6).

use super::dense::Dense;

/// Row-major dense matrix of f64.
#[derive(Debug, Clone, PartialEq)]
pub struct Dense64 {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Dense64 {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols);
        Self { rows, cols, data }
    }

    /// Widen an f32 matrix.
    pub fn from_dense(d: &Dense) -> Self {
        Self {
            rows: d.rows(),
            cols: d.cols(),
            data: d.data().iter().map(|&x| x as f64).collect(),
        }
    }

    /// Narrow to f32 (for handing results back to the serving-path types).
    pub fn to_dense(&self) -> Dense {
        Dense::from_vec(
            self.rows,
            self.cols,
            self.data.iter().map(|&x| x as f32).collect(),
        )
    }

    pub fn rows(&self) -> usize {
        self.rows
    }
    pub fn cols(&self) -> usize {
        self.cols
    }
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }
    pub fn data(&self) -> &[f64] {
        &self.data
    }
    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> f64 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f64) {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c] = v;
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f64] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f64] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// ReLU in place.
    pub fn relu_inplace(&mut self) {
        for v in &mut self.data {
            if *v < 0.0 {
                *v = 0.0;
            }
        }
    }

    /// Row-wise argmax.
    pub fn argmax_rows(&self) -> Vec<usize> {
        (0..self.rows)
            .map(|r| {
                let row = self.row(r);
                let mut best = 0;
                let mut best_v = row[0];
                for (j, &v) in row.iter().enumerate().skip(1) {
                    if v > best_v {
                        best_v = v;
                        best = j;
                    }
                }
                best
            })
            .collect()
    }

    /// Sum of all elements.
    pub fn checksum(&self) -> f64 {
        self.data.iter().sum()
    }

    /// Sum of |elements| — the magnitude scale used by relative thresholds.
    pub fn abs_sum(&self) -> f64 {
        self.data.iter().map(|x| x.abs()).sum()
    }

    /// Max |a - b|.
    pub fn max_abs_diff(&self, other: &Dense64) -> f64 {
        assert_eq!(self.shape(), other.shape());
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    /// Exact elementwise equality (golden-vs-faulty corruption test).
    pub fn identical(&self, other: &Dense64) -> bool {
        self.shape() == other.shape()
            && self
                .data
                .iter()
                .zip(&other.data)
                .all(|(a, b)| a.to_bits() == b.to_bits())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn widen_narrow_roundtrip() {
        let d = Dense::from_vec(2, 2, vec![1.5, -2.0, 0.0, 4.25]);
        let w = Dense64::from_dense(&d);
        assert_eq!(w.get(1, 1), 4.25);
        assert_eq!(w.to_dense(), d);
    }

    #[test]
    fn checksum_and_abs_sum() {
        let m = Dense64::from_vec(1, 3, vec![1.0, -2.0, 3.0]);
        assert_eq!(m.checksum(), 2.0);
        assert_eq!(m.abs_sum(), 6.0);
    }

    #[test]
    fn relu_and_argmax() {
        let mut m = Dense64::from_vec(2, 2, vec![-1.0, 2.0, 3.0, -4.0]);
        assert_eq!(m.argmax_rows(), vec![1, 0]);
        m.relu_inplace();
        assert_eq!(m.data(), &[0.0, 2.0, 3.0, 0.0]);
    }

    #[test]
    fn identical_detects_bit_level_change() {
        let a = Dense64::from_vec(1, 2, vec![1.0, 2.0]);
        let mut b = a.clone();
        assert!(a.identical(&b));
        b.set(0, 1, 2.0 + 1e-15);
        assert!(!a.identical(&b));
        assert!(a.max_abs_diff(&b) > 0.0);
    }
}
