//! Hand-rolled CLI argument parsing (`clap` is not available offline).
//!
//! Supports the forms the `gcn-abft` binary needs:
//! `--flag`, `--key value`, `--key=value`, plus positional arguments.
//! Unknown flags are an error so typos fail loudly.

use std::collections::BTreeMap;

/// Parsed command line: positional args + `--key value` options.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

/// Specification of what a subcommand accepts.
#[derive(Debug, Clone, Default)]
pub struct Spec {
    /// Option names (expect a value).
    pub options: Vec<&'static str>,
    /// Boolean flag names (no value).
    pub flags: Vec<&'static str>,
}

/// Argument-parsing errors. `thiserror` is not available in the offline
/// registry, so `Display`/`Error` are implemented by hand.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CliError {
    UnknownOption(String),
    MissingValue(String),
    InvalidValue {
        key: String,
        value: String,
        reason: String,
    },
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::UnknownOption(name) => write!(f, "unknown option --{name}"),
            CliError::MissingValue(name) => write!(f, "option --{name} requires a value"),
            CliError::InvalidValue { key, value, reason } => {
                write!(f, "invalid value for --{key}: {value} ({reason})")
            }
        }
    }
}

impl std::error::Error for CliError {}

impl Args {
    /// Parse raw argv (not including the program/subcommand names) against
    /// a spec.
    pub fn parse<I: IntoIterator<Item = String>>(raw: I, spec: &Spec) -> Result<Args, CliError> {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(stripped) = arg.strip_prefix("--") {
                // --key=value form.
                if let Some((k, v)) = stripped.split_once('=') {
                    if spec.options.contains(&k) {
                        out.options.insert(k.to_string(), v.to_string());
                    } else {
                        return Err(CliError::UnknownOption(k.to_string()));
                    }
                    continue;
                }
                if spec.flags.contains(&stripped) {
                    out.flags.push(stripped.to_string());
                } else if spec.options.contains(&stripped) {
                    let v = it
                        .next()
                        .ok_or_else(|| CliError::MissingValue(stripped.to_string()))?;
                    out.options.insert(stripped.to_string(), v);
                } else {
                    return Err(CliError::UnknownOption(stripped.to_string()));
                }
            } else {
                out.positional.push(arg);
            }
        }
        Ok(out)
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize, CliError> {
        match self.options.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| CliError::InvalidValue {
                key: name.to_string(),
                value: v.clone(),
                reason: format!("{e}"),
            }),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64, CliError> {
        match self.options.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| CliError::InvalidValue {
                key: name.to_string(),
                value: v.clone(),
                reason: format!("{e}"),
            }),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64, CliError> {
        match self.options.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| CliError::InvalidValue {
                key: name.to_string(),
                value: v.clone(),
                reason: format!("{e}"),
            }),
        }
    }

    pub fn get_str(&self, name: &str, default: &str) -> String {
        self.options
            .get(name)
            .cloned()
            .unwrap_or_else(|| default.to_string())
    }

    /// Comma-separated list option.
    pub fn get_list(&self, name: &str, default: &[&str]) -> Vec<String> {
        match self.options.get(name) {
            Some(v) => v.split(',').map(|s| s.trim().to_string()).collect(),
            None => default.iter().map(|s| s.to_string()).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> Spec {
        Spec {
            options: vec!["campaigns", "seed", "datasets", "threshold"],
            flags: vec!["json", "verbose"],
        }
    }

    fn parse(args: &[&str]) -> Result<Args, CliError> {
        Args::parse(args.iter().map(|s| s.to_string()), &spec())
    }

    #[test]
    fn parses_options_and_flags() {
        let a = parse(&["--campaigns", "500", "--json", "pos1"]).unwrap();
        assert_eq!(a.get_usize("campaigns", 0).unwrap(), 500);
        assert!(a.has_flag("json"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn parses_equals_form() {
        let a = parse(&["--seed=42"]).unwrap();
        assert_eq!(a.get_u64("seed", 0).unwrap(), 42);
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(matches!(
            parse(&["--bogus", "1"]),
            Err(CliError::UnknownOption(_))
        ));
    }

    #[test]
    fn missing_value_rejected() {
        assert!(matches!(
            parse(&["--campaigns"]),
            Err(CliError::MissingValue(_))
        ));
    }

    #[test]
    fn invalid_number_rejected() {
        let a = parse(&["--campaigns", "many"]).unwrap();
        assert!(matches!(
            a.get_usize("campaigns", 0),
            Err(CliError::InvalidValue { .. })
        ));
    }

    #[test]
    fn list_option() {
        let a = parse(&["--datasets", "cora, nell"]).unwrap();
        assert_eq!(a.get_list("datasets", &[]), vec!["cora", "nell"]);
        let b = parse(&[]).unwrap();
        assert_eq!(b.get_list("datasets", &["all"]), vec!["all"]);
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&[]).unwrap();
        assert_eq!(a.get_usize("campaigns", 123).unwrap(), 123);
        assert_eq!(a.get_f64("threshold", 1e-7).unwrap(), 1e-7);
        assert_eq!(a.get_str("datasets", "all"), "all");
        assert!(!a.has_flag("json"));
    }
}
