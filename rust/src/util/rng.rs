//! Deterministic, seedable pseudo-random number generation.
//!
//! The offline crate registry for this build environment does not carry the
//! `rand` crate, so the repo ships its own small PRNG substrate:
//!
//! * [`SplitMix64`] — the classic 64-bit mixer, used for seeding.
//! * [`Pcg64`] — PCG-XSH-RR 64/32 folded into a 64-bit output helper; the
//!   workhorse generator used everywhere (dataset synthesis, weight init,
//!   fault-plan sampling).
//!
//! Every consumer takes an explicit seed so that *all* experiments in the
//! repo are bit-reproducible: `gcn-abft table1 --seed 7` prints the same
//! table on every machine.

/// SplitMix64: tiny, high-quality 64-bit mixer (Steele et al., "Fast
/// splittable pseudorandom number generators", OOPSLA 2014).
///
/// Used to expand a single user seed into independent stream seeds.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create a new mixer from a seed.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// PCG-XSH-RR 64/32 (O'Neill 2014) with two 32-bit draws fused into a
/// 64-bit output. Small state, good statistical quality, very fast — and
/// deterministic across platforms (pure integer arithmetic).
#[derive(Debug, Clone)]
pub struct Pcg64 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6_364_136_223_846_793_005;

impl Pcg64 {
    /// Seed the generator. `seed` selects the starting point, `stream`
    /// selects one of 2^63 distinct sequences.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Self {
            state: 0,
            inc: (stream << 1) | 1,
        };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Derive a generator from a single seed via SplitMix64 (seed and
    /// stream drawn independently).
    pub fn from_seed(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let s = sm.next_u64();
        let inc = sm.next_u64();
        Self::new(s, inc)
    }

    /// Split off an independent child generator (used to give each
    /// fault-injection campaign its own stream).
    pub fn split(&mut self) -> Pcg64 {
        let s = self.next_u64();
        let inc = self.next_u64();
        Pcg64::new(s, inc)
    }

    /// Next 32 uniformly random bits.
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next 64 uniformly random bits.
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, bound)` without modulo bias (Lemire's method).
    pub fn gen_range(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "gen_range bound must be positive");
        // 128-bit multiply rejection sampling.
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in `[0, bound)`.
    pub fn gen_index(&mut self, bound: usize) -> usize {
        self.gen_range(bound as u64) as usize
    }

    /// Uniform f64 in `[0, 1)` (53 random mantissa bits).
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    pub fn gen_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn gen_f64_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.gen_f64()
    }

    /// Uniform f32 in `[lo, hi)`.
    pub fn gen_f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.gen_f32()
    }

    /// Bernoulli draw with probability `p`.
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.gen_f64() < p
    }

    /// Standard normal via Box–Muller (cached second draw omitted for
    /// simplicity; weight init is not on the hot path).
    pub fn gen_normal(&mut self) -> f64 {
        // Avoid log(0).
        let u1 = (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
        let u1 = if u1 <= f64::MIN_POSITIVE { f64::MIN_POSITIVE } else { u1 };
        let u2 = self.gen_f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (Floyd's algorithm for
    /// small k, partial shuffle otherwise). Result order is unspecified.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} from {n}");
        if k * 4 > n {
            let mut all: Vec<usize> = (0..n).collect();
            // Partial Fisher–Yates: first k slots become the sample.
            for i in 0..k {
                let j = i + self.gen_index(n - i);
                all.swap(i, j);
            }
            all.truncate(k);
            return all;
        }
        // Floyd's: O(k) expected, dedup via sorted insert.
        let mut chosen: Vec<usize> = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.gen_index(j + 1);
            let pick = if chosen.contains(&t) { j } else { t };
            chosen.push(pick);
        }
        chosen
    }

    /// Weighted index sampling: returns i with probability w[i]/Σw.
    pub fn gen_weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "weights must sum to a positive value");
        let mut x = self.gen_f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            if x < w {
                return i;
            }
            x -= w;
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_is_deterministic() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn splitmix_known_vector() {
        // Reference values for seed 1234567 (from the published algorithm).
        let mut sm = SplitMix64::new(0);
        let first = sm.next_u64();
        // seed 0 first output of splitmix64 is 0xE220A8397B1DCDAF
        assert_eq!(first, 0xE220_A839_7B1D_CDAF);
    }

    #[test]
    fn pcg_deterministic_and_stream_distinct() {
        let mut a = Pcg64::from_seed(7);
        let mut b = Pcg64::from_seed(7);
        let mut c = Pcg64::from_seed(8);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        let vc: Vec<u64> = (0..8).map(|_| c.next_u64()).collect();
        assert_eq!(va, vb);
        assert_ne!(va, vc);
    }

    #[test]
    fn gen_range_bounds() {
        let mut r = Pcg64::from_seed(1);
        for _ in 0..10_000 {
            let x = r.gen_range(17);
            assert!(x < 17);
        }
        // All residues reachable.
        let mut seen = [false; 17];
        for _ in 0..10_000 {
            seen[r.gen_range(17) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn gen_f64_unit_interval() {
        let mut r = Pcg64::from_seed(2);
        let mut sum = 0.0;
        for _ in 0..100_000 {
            let x = r.gen_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / 100_000.0;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean} too far from 0.5");
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::from_seed(3);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gen_normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn sample_indices_distinct_and_in_range() {
        let mut r = Pcg64::from_seed(4);
        for &(n, k) in &[(10usize, 3usize), (100, 90), (5, 5), (1000, 10)] {
            let s = r.sample_indices(n, k);
            assert_eq!(s.len(), k);
            let mut sorted = s.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), k, "duplicates for n={n} k={k}");
            assert!(sorted.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn shuffle_permutes() {
        let mut r = Pcg64::from_seed(5);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_sampling_respects_weights() {
        let mut r = Pcg64::from_seed(6);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[r.gen_weighted(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.25, "ratio {ratio}");
    }

    #[test]
    fn split_streams_independent() {
        let mut parent = Pcg64::from_seed(9);
        let mut c1 = parent.split();
        let mut c2 = parent.split();
        let v1: Vec<u64> = (0..4).map(|_| c1.next_u64()).collect();
        let v2: Vec<u64> = (0..4).map(|_| c2.next_u64()).collect();
        assert_ne!(v1, v2);
    }
}
