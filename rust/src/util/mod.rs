//! Infrastructure substrates built in-repo because the offline registry
//! lacks the usual crates: PRNG (`rand`), JSON (`serde_json`), bench
//! harness (`criterion`), property testing (`proptest`), CLI (`clap`).

pub mod bench;
pub mod cli;
pub mod json;
pub mod parallel;
pub mod proptest;
pub mod rng;

/// Format a large count with thousands separators for table output.
pub fn fmt_count(n: u64) -> String {
    let s = n.to_string();
    let bytes = s.as_bytes();
    let mut out = String::with_capacity(s.len() + s.len() / 3);
    for (i, b) in bytes.iter().enumerate() {
        if i > 0 && (bytes.len() - i) % 3 == 0 {
            out.push(',');
        }
        out.push(*b as char);
    }
    out
}

/// Format an op count in millions with 2 decimals (Table II style).
pub fn fmt_millions(n: u64) -> String {
    format!("{:.2}", n as f64 / 1e6)
}

/// Format a ratio as a percentage with one decimal.
pub fn fmt_pct(x: f64) -> String {
    format!("{:.1}%", x * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn count_formatting() {
        assert_eq!(fmt_count(0), "0");
        assert_eq!(fmt_count(999), "999");
        assert_eq!(fmt_count(1000), "1,000");
        assert_eq!(fmt_count(1234567), "1,234,567");
    }

    #[test]
    fn millions_formatting() {
        assert_eq!(fmt_millions(2_800_000), "2.80");
        assert_eq!(fmt_millions(84_300_000), "84.30");
    }

    #[test]
    fn pct_formatting() {
        assert_eq!(fmt_pct(0.2), "20.0%");
        assert_eq!(fmt_pct(0.0334), "3.3%");
    }
}
