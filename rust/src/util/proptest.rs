//! Lightweight property-based testing helper (the `proptest` crate is not
//! available in the offline registry).
//!
//! Provides the two things the repo's invariant tests need:
//!
//! 1. seeded random *generators* for the domain types (shapes, dense
//!    matrices, sparse patterns), and
//! 2. a [`check`] runner that executes a property over many random cases
//!    and, on failure, retries with a *shrunken* case (halved dimensions)
//!    to report the smallest failing input it can find, along with the
//!    seed needed to replay it.

use super::rng::Pcg64;

/// Configuration for a property run.
#[derive(Debug, Clone)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
    /// Max shrink rounds after the first failure.
    pub max_shrink: usize,
}

impl Default for Config {
    fn default() -> Self {
        Self {
            cases: 64,
            seed: 0xC0FFEE,
            max_shrink: 16,
        }
    }
}

/// One failing case, with the RNG seed to reproduce it.
#[derive(Debug)]
pub struct Failure {
    pub case_index: usize,
    pub seed: u64,
    pub message: String,
    pub shrunk: bool,
}

/// Run `prop` over `cfg.cases` random inputs produced by `gen`.
///
/// `gen` receives a per-case RNG; `prop` returns `Err(msg)` on violation.
/// `shrink` maps a failing input to a list of smaller candidates; pass
/// [`no_shrink`] when shrinking is not meaningful.
///
/// Panics with a replayable report on failure — intended to be called from
/// `#[test]` functions.
pub fn check<T, G, P, S>(cfg: &Config, mut gen: G, mut prop: P, mut shrink: S)
where
    T: Clone + std::fmt::Debug,
    G: FnMut(&mut Pcg64) -> T,
    P: FnMut(&T) -> Result<(), String>,
    S: FnMut(&T) -> Vec<T>,
{
    let mut root = Pcg64::from_seed(cfg.seed);
    for case_index in 0..cfg.cases {
        let mut case_rng = root.split();
        let input = gen(&mut case_rng);
        if let Err(msg) = prop(&input) {
            // Shrink: breadth-first over the candidates, keep the last
            // failing one.
            let mut best = input.clone();
            let mut best_msg = msg;
            let mut frontier = shrink(&best);
            let mut rounds = 0;
            while rounds < cfg.max_shrink {
                let mut advanced = false;
                for cand in frontier.drain(..) {
                    if let Err(m) = prop(&cand) {
                        best = cand;
                        best_msg = m;
                        advanced = true;
                        break;
                    }
                }
                if !advanced {
                    break;
                }
                frontier = shrink(&best);
                rounds += 1;
            }
            panic!(
                "property failed (case {case_index}, seed {:#x}, shrunk {} rounds)\n\
                 input: {best:?}\nviolation: {best_msg}",
                cfg.seed, rounds
            );
        }
    }
}

/// A shrinker that never shrinks.
pub fn no_shrink<T>(_: &T) -> Vec<T> {
    Vec::new()
}

/// Generate a random matrix dimension in `[1, max]`, biased toward small
/// and "awkward" values (1, odd sizes, powers of two ± 1).
pub fn gen_dim(rng: &mut Pcg64, max: usize) -> usize {
    match rng.gen_index(5) {
        0 => 1,
        1 => rng.gen_index(4.min(max)) + 1,
        2 => {
            let p = 1usize << rng.gen_index(5);
            (p + rng.gen_index(3)).clamp(1, max)
        }
        _ => rng.gen_index(max) + 1,
    }
}

/// Generate a dense row-major matrix of values in [-range, range).
pub fn gen_matrix(rng: &mut Pcg64, rows: usize, cols: usize, range: f32) -> Vec<f32> {
    (0..rows * cols)
        .map(|_| rng.gen_f32_range(-range, range))
        .collect()
}

/// Shrink a (rows, cols) shape by halving each dimension.
pub fn shrink_shape(rows: usize, cols: usize) -> Vec<(usize, usize)> {
    let mut out = Vec::new();
    if rows > 1 {
        out.push((rows / 2, cols));
    }
    if cols > 1 {
        out.push((rows, cols / 2));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_completes() {
        check(
            &Config { cases: 32, ..Default::default() },
            |rng| gen_dim(rng, 64),
            |&d| {
                if d >= 1 && d <= 64 {
                    Ok(())
                } else {
                    Err(format!("dim {d} out of range"))
                }
            },
            no_shrink,
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_report() {
        check(
            &Config { cases: 64, ..Default::default() },
            |rng| rng.gen_index(100),
            |&x| if x < 90 { Ok(()) } else { Err(format!("{x} >= 90")) },
            no_shrink,
        );
    }

    #[test]
    fn shrinking_reduces_input() {
        // Property fails for any n >= 4; shrinker halves. The reported
        // failing input should be the boundary-ish small case, which we
        // verify indirectly by catching the panic message.
        let result = std::panic::catch_unwind(|| {
            check(
                &Config { cases: 16, ..Default::default() },
                |rng| 4 + rng.gen_index(100),
                |&x| if x < 4 { Ok(()) } else { Err("too big".into()) },
                |&x| if x / 2 >= 1 { vec![x / 2] } else { vec![] },
            );
        });
        let err = result.unwrap_err();
        let msg = err.downcast_ref::<String>().unwrap();
        // Shrinking halves until prop passes; smallest failing is 4..7.
        assert!(
            msg.contains("input: 4")
                || msg.contains("input: 5")
                || msg.contains("input: 6")
                || msg.contains("input: 7"),
            "unexpected shrink result: {msg}"
        );
    }

    #[test]
    fn gen_dim_in_bounds() {
        let mut rng = Pcg64::from_seed(11);
        for _ in 0..1000 {
            let d = gen_dim(&mut rng, 33);
            assert!((1..=33).contains(&d));
        }
    }

    #[test]
    fn gen_matrix_shape_and_range() {
        let mut rng = Pcg64::from_seed(12);
        let m = gen_matrix(&mut rng, 3, 5, 2.0);
        assert_eq!(m.len(), 15);
        assert!(m.iter().all(|v| (-2.0..2.0).contains(v)));
    }
}
