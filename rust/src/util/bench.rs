//! A tiny benchmark harness (criterion is not available offline).
//!
//! Provides warmup + repeated timed runs, robust statistics (median, mean,
//! stddev, min), and a stable one-line report format that the repo's
//! `cargo bench` targets (all `harness = false`) use. Measurements are
//! wall-clock; each sample runs the closure enough times to exceed a
//! minimum sample duration so short closures are still measurable.

use std::time::{Duration, Instant};

/// Result of a benchmark run.
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    /// Per-iteration time for every sample, seconds.
    pub samples: Vec<f64>,
    /// Iterations folded into each sample.
    pub iters_per_sample: u64,
}

impl BenchStats {
    pub fn median(&self) -> f64 {
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.total_cmp(b));
        let n = s.len();
        if n == 0 {
            return f64::NAN;
        }
        if n % 2 == 1 {
            s[n / 2]
        } else {
            0.5 * (s[n / 2 - 1] + s[n / 2])
        }
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn stddev(&self) -> f64 {
        let m = self.mean();
        if self.samples.len() < 2 {
            return 0.0;
        }
        let var = self
            .samples
            .iter()
            .map(|x| (x - m) * (x - m))
            .sum::<f64>()
            / (self.samples.len() - 1) as f64;
        var.sqrt()
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    /// Render one stable report line:
    /// `bench_name                     median 12.345 µs  mean 12.5 µs ±0.4  min 12.1 µs  (20 samples x 64 iters)`
    pub fn report_line(&self) -> String {
        format!(
            "{:<44} median {:>12}  mean {:>12} ±{:<10}  min {:>12}  ({} samples x {} iters)",
            self.name,
            fmt_time(self.median()),
            fmt_time(self.mean()),
            fmt_time(self.stddev()),
            fmt_time(self.min()),
            self.samples.len(),
            self.iters_per_sample
        )
    }
}

/// Human-friendly time formatting.
pub fn fmt_time(secs: f64) -> String {
    if !secs.is_finite() {
        return "n/a".to_string();
    }
    if secs >= 1.0 {
        format!("{secs:.3} s")
    } else if secs >= 1e-3 {
        format!("{:.3} ms", secs * 1e3)
    } else if secs >= 1e-6 {
        format!("{:.3} µs", secs * 1e6)
    } else {
        format!("{:.1} ns", secs * 1e9)
    }
}

/// Benchmark runner configuration.
#[derive(Debug, Clone)]
pub struct Bencher {
    pub warmup: Duration,
    pub samples: usize,
    pub min_sample_time: Duration,
}

impl Default for Bencher {
    fn default() -> Self {
        Self {
            warmup: Duration::from_millis(200),
            samples: 15,
            min_sample_time: Duration::from_millis(20),
        }
    }
}

impl Bencher {
    /// Quick profile for expensive end-to-end benches.
    pub fn quick() -> Self {
        Self {
            warmup: Duration::from_millis(50),
            samples: 5,
            min_sample_time: Duration::from_millis(5),
        }
    }

    /// Run `f` repeatedly and collect per-iteration timings.
    /// `f` must perform one unit of work per call; its result is
    /// black-boxed to prevent the optimizer from deleting the work.
    pub fn run<T, F: FnMut() -> T>(&self, name: &str, mut f: F) -> BenchStats {
        // Warmup + calibration: figure out how many iterations fit in
        // min_sample_time.
        // gcn-lint: allow(D1, reason="wall-clock IS the measurement here: the bench harness reports real elapsed seconds, nothing schedules off them")
        let warm_start = Instant::now();
        let mut warm_iters: u64 = 0;
        while warm_start.elapsed() < self.warmup {
            black_box(f());
            warm_iters += 1;
        }
        let per_iter = warm_start.elapsed().as_secs_f64() / warm_iters.max(1) as f64;
        let iters = ((self.min_sample_time.as_secs_f64() / per_iter.max(1e-9)).ceil() as u64)
            .max(1);

        let mut samples = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            // gcn-lint: allow(D1, reason="per-sample wall time is the benchmark's output")
            let t0 = Instant::now();
            for _ in 0..iters {
                black_box(f());
            }
            samples.push(t0.elapsed().as_secs_f64() / iters as f64);
        }
        BenchStats {
            name: name.to_string(),
            samples,
            iters_per_sample: iters,
        }
    }

    /// Run and print the report line; returns the stats for further use.
    pub fn bench<T, F: FnMut() -> T>(&self, name: &str, f: F) -> BenchStats {
        let stats = self.run(name, f);
        println!("{}", stats.report_line());
        stats
    }
}

/// Opaque value sink — prevents dead-code elimination of benchmark work.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Standard header printed at the top of each bench binary.
pub fn bench_header(title: &str) {
    println!("=== {title} ===");
    println!(
        "(custom harness: criterion unavailable in the offline registry; \
         median/mean/min over repeated samples)"
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_math() {
        let s = BenchStats {
            name: "t".into(),
            samples: vec![1.0, 2.0, 3.0, 4.0],
            iters_per_sample: 1,
        };
        assert!((s.median() - 2.5).abs() < 1e-12);
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert!((s.min() - 1.0).abs() < 1e-12);
        assert!(s.stddev() > 0.0);
    }

    #[test]
    fn median_odd() {
        let s = BenchStats {
            name: "t".into(),
            samples: vec![3.0, 1.0, 2.0],
            iters_per_sample: 1,
        };
        assert!((s.median() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn runs_and_reports() {
        let b = Bencher {
            warmup: Duration::from_millis(5),
            samples: 3,
            min_sample_time: Duration::from_millis(1),
        };
        let mut acc = 0u64;
        let stats = b.run("noop", || {
            acc = acc.wrapping_add(1);
            acc
        });
        assert_eq!(stats.samples.len(), 3);
        assert!(stats.median() >= 0.0);
        let line = stats.report_line();
        assert!(line.contains("noop"));
    }

    #[test]
    fn time_formatting() {
        assert_eq!(fmt_time(2.0), "2.000 s");
        assert_eq!(fmt_time(2e-3), "2.000 ms");
        assert_eq!(fmt_time(2e-6), "2.000 µs");
        assert_eq!(fmt_time(2e-9), "2.0 ns");
    }
}
