//! Minimal JSON emitter (and a tiny value model) used for machine-readable
//! experiment outputs (`--json` flags, metrics dumps).
//!
//! `serde`/`serde_json` are not available in the offline registry for this
//! build, so the repo carries its own small, allocation-light writer. Only
//! what the experiment reports need: objects, arrays, strings, numbers,
//! booleans and null — always emitted with stable key order (insertion
//! order) so outputs diff cleanly between runs.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Objects preserve insertion order via a Vec of pairs
/// (experiment reports want stable, meaningful ordering, not alphabetical).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Int(i64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Convenience: build an object from pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Convenience: array from an iterator of values.
    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Serialize with 2-space indentation.
    pub fn to_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, level: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Int(i) => {
                let _ = write!(out, "{i}");
            }
            Json::Num(x) => {
                if x.is_finite() {
                    // Shortest roundtrip representation.
                    let _ = write!(out, "{x}");
                } else {
                    // JSON has no Inf/NaN; emit null like serde_json does.
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, level + 1);
                    item.write(out, indent, level + 1);
                }
                if !items.is_empty() {
                    newline_indent(out, indent, level);
                }
                out.push(']');
            }
            Json::Obj(pairs) => {
                out.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, level + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, level + 1);
                }
                if !pairs.is_empty() {
                    newline_indent(out, indent, level);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, level: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..(w * level) {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Self {
        Json::Str(s)
    }
}
impl From<f64> for Json {
    fn from(x: f64) -> Self {
        Json::Num(x)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Self {
        Json::Int(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Self {
        Json::Int(x as i64)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Self {
        Json::Int(x as i64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}

impl Json {
    /// Parse a JSON document (full recursive grammar; used for the
    /// artifact manifest written by `python/compile/aot.py`).
    pub fn parse(text: &str) -> Result<Json, String> {
        let bytes: Vec<char> = text.chars().collect();
        let mut p = Parser { c: &bytes, i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.c.len() {
            return Err(format!("trailing input at {}", p.i));
        }
        Ok(v)
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            Json::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Int(i) if *i >= 0 => Some(*i as usize),
            // gcn-lint: allow(D4, reason="exact integrality test: fract()==0.0 is the definition of a whole number, no tolerance belongs here")
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 => Some(*x as usize),
            _ => None,
        }
    }

    pub fn entries(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(pairs) => Some(pairs),
            _ => None,
        }
    }

    /// Array element access.
    pub fn items(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
}

struct Parser<'a> {
    c: &'a [char],
    i: usize,
}

impl Parser<'_> {
    fn ws(&mut self) {
        while self.i < self.c.len() && self.c[self.i].is_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<char> {
        self.c.get(self.i).copied()
    }

    fn expect(&mut self, ch: char) -> Result<(), String> {
        if self.peek() == Some(ch) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{ch}' at {}", self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.ws();
        match self.peek() {
            Some('{') => self.object(),
            Some('[') => self.array(),
            Some('"') => Ok(Json::Str(self.string()?)),
            Some('t') => self.lit("true", Json::Bool(true)),
            Some('f') => self.lit("false", Json::Bool(false)),
            Some('n') => self.lit("null", Json::Null),
            Some(c) if c == '-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {other:?} at {}", self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        for ch in word.chars() {
            self.expect(ch)?;
        }
        Ok(v)
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect('{')?;
        let mut pairs = Vec::new();
        self.ws();
        if self.peek() == Some('}') {
            self.i += 1;
            return Ok(Json::Obj(pairs));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(':')?;
            let v = self.value()?;
            pairs.push((k, v));
            self.ws();
            match self.peek() {
                Some(',') => {
                    self.i += 1;
                }
                Some('}') => {
                    self.i += 1;
                    return Ok(Json::Obj(pairs));
                }
                other => return Err(format!("expected ',' or '}}', got {other:?}")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect('[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(',') => {
                    self.i += 1;
                }
                Some(']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                other => return Err(format!("expected ',' or ']', got {other:?}")),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect('"')?;
        let mut out = String::new();
        while let Some(c) = self.peek() {
            self.i += 1;
            match c {
                '"' => return Ok(out),
                '\\' => {
                    let esc = self.peek().ok_or("eof in escape")?;
                    self.i += 1;
                    match esc {
                        '"' => out.push('"'),
                        '\\' => out.push('\\'),
                        '/' => out.push('/'),
                        'n' => out.push('\n'),
                        't' => out.push('\t'),
                        'r' => out.push('\r'),
                        'b' => out.push('\u{8}'),
                        'f' => out.push('\u{c}'),
                        'u' => {
                            let mut code = 0u32;
                            for _ in 0..4 {
                                let h = self.peek().ok_or("eof in \\u")?;
                                self.i += 1;
                                code = code * 16
                                    + h.to_digit(16).ok_or("bad hex in \\u")?;
                            }
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        o => return Err(format!("bad escape \\{o}")),
                    }
                }
                c => out.push(c),
            }
        }
        Err("unterminated string".into())
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some('-') {
            self.i += 1;
        }
        let mut is_float = false;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() {
                self.i += 1;
            } else if c == '.' || c == 'e' || c == 'E' || c == '+' || c == '-' {
                is_float = true;
                self.i += 1;
            } else {
                break;
            }
        }
        let s: String = self.c[start..self.i].iter().collect();
        if is_float {
            s.parse::<f64>()
                .map(Json::Num)
                .map_err(|e| format!("bad number {s}: {e}"))
        } else {
            s.parse::<i64>()
                .map(Json::Int)
                .map_err(|e| format!("bad number {s}: {e}"))
        }
    }
}

/// Parse a small subset of JSON back (flat objects of numbers/strings —
/// enough to read experiment configs). Returns key → value maps.
pub fn parse_flat_object(text: &str) -> Option<BTreeMap<String, String>> {
    let t = text.trim();
    let inner = t.strip_prefix('{')?.strip_suffix('}')?;
    let mut map = BTreeMap::new();
    if inner.trim().is_empty() {
        return Some(map);
    }
    // Split on commas not inside strings — configs are flat, so this is safe.
    let mut depth_str = false;
    let mut cur = String::new();
    let mut parts = Vec::new();
    let mut prev = '\0';
    for c in inner.chars() {
        if c == '"' && prev != '\\' {
            depth_str = !depth_str;
        }
        if c == ',' && !depth_str {
            parts.push(cur.clone());
            cur.clear();
        } else {
            cur.push(c);
        }
        prev = c;
    }
    if !cur.trim().is_empty() {
        parts.push(cur);
    }
    for p in parts {
        let mut kv = p.splitn(2, ':');
        let k = kv.next()?.trim().trim_matches('"').to_string();
        let v = kv.next()?.trim().trim_matches('"').to_string();
        map.insert(k, v);
    }
    Some(map)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(Json::Null.to_string(), "null");
        assert_eq!(Json::Bool(true).to_string(), "true");
        assert_eq!(Json::Int(-3).to_string(), "-3");
        assert_eq!(Json::Num(1.5).to_string(), "1.5");
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
        assert_eq!(Json::from("hi").to_string(), "\"hi\"");
    }

    #[test]
    fn escaping() {
        assert_eq!(
            Json::from("a\"b\\c\nd").to_string(),
            "\"a\\\"b\\\\c\\nd\""
        );
        assert_eq!(Json::from("\u{1}").to_string(), "\"\\u0001\"");
    }

    #[test]
    fn nested_structures() {
        let v = Json::obj(vec![
            ("name", Json::from("cora")),
            ("nodes", Json::from(2708usize)),
            ("rates", Json::arr(vec![Json::Num(0.95), Json::Num(0.03)])),
        ]);
        assert_eq!(
            v.to_string(),
            r#"{"name":"cora","nodes":2708,"rates":[0.95,0.03]}"#
        );
    }

    #[test]
    fn pretty_has_newlines() {
        let v = Json::obj(vec![("a", Json::Int(1))]);
        let p = v.to_pretty();
        assert!(p.contains('\n'));
        assert!(p.contains("\"a\": 1"));
    }

    #[test]
    fn key_order_is_insertion_order() {
        let v = Json::obj(vec![("z", Json::Int(1)), ("a", Json::Int(2))]);
        let s = v.to_string();
        assert!(s.find("\"z\"").unwrap() < s.find("\"a\"").unwrap());
    }

    #[test]
    fn full_parser_roundtrips() {
        let src = Json::obj(vec![
            ("version", Json::Int(1)),
            ("flavour", Json::from("pallas")),
            (
                "models",
                Json::obj(vec![(
                    "tiny",
                    Json::obj(vec![
                        ("classes", Json::Int(4)),
                        ("f", Json::Int(32)),
                        ("file", Json::from("gcn_tiny.hlo.txt")),
                        ("hidden", Json::Int(8)),
                        ("n", Json::Int(64)),
                    ]),
                )]),
            ),
            ("rates", Json::arr(vec![Json::Num(0.5), Json::Null, Json::Bool(true)])),
        ]);
        let parsed = Json::parse(&src.to_pretty()).unwrap();
        assert_eq!(parsed, src);
        let tiny = parsed.get("models").unwrap().get("tiny").unwrap();
        assert_eq!(tiny.get("n").unwrap().as_usize(), Some(64));
        assert_eq!(tiny.get("file").unwrap().as_str(), Some("gcn_tiny.hlo.txt"));
    }

    #[test]
    fn parser_rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("hello").is_err());
        assert!(Json::parse("{}extra").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn parser_handles_escapes_and_numbers() {
        let v = Json::parse(r#"{"s": "a\nb\u0041", "x": -1.5e2, "i": -7}"#).unwrap();
        assert_eq!(v.get("s").unwrap().as_str(), Some("a\nbA"));
        assert_eq!(v.get("x").unwrap().as_f64(), Some(-150.0));
        assert_eq!(v.get("i").unwrap().as_f64(), Some(-7.0));
    }

    #[test]
    fn parse_flat_roundtrip() {
        let m = parse_flat_object(r#"{"a": "x", "b": 3}"#).unwrap();
        assert_eq!(m["a"], "x");
        assert_eq!(m["b"], "3");
        assert!(parse_flat_object("{}").unwrap().is_empty());
        assert!(parse_flat_object("nope").is_none());
    }
}
