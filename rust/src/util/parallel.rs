//! Scoped-thread helpers for row-parallel kernels.
//!
//! The dense/sparse hot paths (`tensor::ops::matmul_par`,
//! `sparse::Csr::spmm_par`) partition their *output* rows into contiguous
//! bands and process each band on its own `std::thread::scope` worker.
//! Because every band owns a disjoint `&mut` slice of the output and the
//! per-row floating-point evaluation order is unchanged, the parallel
//! kernels are **bit-identical** to their serial counterparts at any
//! thread count — determinism the ABFT checkers and the reproducibility
//! tests rely on.

/// A sensible worker count for data-parallel kernels on this host.
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .clamp(1, 16)
}

/// Below this many output elements per band, thread-spawn overhead
/// (~10–20 µs each) rivals the band's compute, so the worker count is
/// capped to keep every band at least this large. Small kernels (e.g.
/// the 64×8 tiny-dataset layers) therefore run inline regardless of the
/// requested thread count.
const MIN_BAND_ELEMS: usize = 2048;

/// Split `data` (a row-major buffer of rows of width `row_width`) into at
/// most `threads` contiguous whole-row bands and run `f(first_row, band)`
/// on each band from a scoped thread. Runs inline when `threads <= 1` or
/// when the buffer is too small for multiple bands of [`MIN_BAND_ELEMS`]
/// to be worth a spawn.
pub fn par_row_chunks_mut<T, F>(data: &mut [T], row_width: usize, threads: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(row_width > 0, "row_width must be positive");
    debug_assert_eq!(data.len() % row_width, 0, "buffer is not whole rows");
    let rows = data.len() / row_width;
    let threads = threads
        .min(data.len() / MIN_BAND_ELEMS)
        .clamp(1, rows.max(1));
    if threads <= 1 {
        f(0, data);
        return;
    }
    let band_rows = (rows + threads - 1) / threads;
    std::thread::scope(|scope| {
        let f = &f;
        for (band, chunk) in data.chunks_mut(band_rows * row_width).enumerate() {
            scope.spawn(move || f(band * band_rows, chunk));
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_threads_positive_and_bounded() {
        let t = default_threads();
        assert!((1..=16).contains(&t));
    }

    #[test]
    fn covers_every_row_exactly_once() {
        // Small cases run inline (below MIN_BAND_ELEMS); the 2048-row
        // cases genuinely split into multiple spawned bands.
        for &(rows, width, threads) in &[
            (1usize, 3usize, 4usize),
            (7, 2, 3),
            (16, 5, 4),
            (5, 1, 8),
            (9, 4, 1),
            (2048, 4, 4),
            (2050, 3, 3),
        ] {
            let mut data = vec![0u32; rows * width];
            par_row_chunks_mut(&mut data, width, threads, |first_row, band| {
                for (r, row) in band.chunks_mut(width).enumerate() {
                    for v in row {
                        *v += (first_row + r + 1) as u32;
                    }
                }
            });
            for r in 0..rows {
                for c in 0..width {
                    assert_eq!(
                        data[r * width + c],
                        (r + 1) as u32,
                        "rows={rows} width={width} threads={threads} r={r} c={c}"
                    );
                }
            }
        }
    }

    #[test]
    fn serial_and_parallel_agree() {
        // Big enough that the threads=6 run really spawns several bands.
        let rows = 1200;
        let width = 8;
        let work = |first_row: usize, band: &mut [f64]| {
            for (r, row) in band.chunks_mut(width).enumerate() {
                let i = first_row + r;
                for (c, v) in row.iter_mut().enumerate() {
                    *v = (i * width + c) as f64 * 0.5 - 3.0;
                }
            }
        };
        let mut serial = vec![0f64; rows * width];
        par_row_chunks_mut(&mut serial, width, 1, work);
        let mut parallel = vec![0f64; rows * width];
        par_row_chunks_mut(&mut parallel, width, 6, work);
        assert_eq!(serial, parallel);
    }
}
