//! Analytic operation-count model (multiplications and additions counted
//! equally, as in the paper's Table II).
//!
//! Combination-first 2-phase dataflow for layer ℓ with input `H (N×F,
//! nnz_H)`, weights `W (F×h)`, adjacency `S (N×N, nnz_S)`:
//!
//! * **true output**: `2·nnz_H·h` (combination SpMM) + `2·nnz_S·h`
//!   (aggregation SpMM);
//! * **split check** (Eqs. 2–3, full enhanced products as in Fig. 1):
//!   online `h_c` (nnz_H adds — zero for layer 1, whose input is static),
//!   `h_c·[W|w_r]` (2F(h+1)), `H·w_r` (2·nnz_H), actual checksum of `X`
//!   (N·h − 1 adds), `S·x_r` (2·nnz_S), `s_c·[X|x_r]` (2N(h+1)), actual
//!   checksum of `H_out` (N·h − 1);
//! * **fused check** (Eqs. 5–6): `H·w_r` (2·nnz_H), `S·x_r` (2·nnz_S),
//!   `s_c·[X|x_r]` (2N(h+1)), actual checksum of `H_out` (N·h − 1).
//!
//! The per-layer saving of GCN-ABFT is therefore exactly
//! `nnz_H + 2F(h+1) + (N·h − 1)` — the `h_c` state, its propagation
//! through the weights, and the intermediate actual checksum.
//!
//! These formulas are cross-checked op-for-op against the instrumented
//! engine (`CountingHook`) in the test suite, so Table II is generated
//! from a model that provably matches what the executors do.

use crate::graph::Graph;

/// Shape summary of one GCN layer for op counting.
#[derive(Debug, Clone, Copy)]
pub struct LayerShape {
    /// Node count (rows of H and S).
    pub n: usize,
    /// Input feature dimension (cols of H, rows of W).
    pub f: usize,
    /// Output dimension (cols of W).
    pub h: usize,
    /// Nonzeros of the layer input H (dense inputs: N·F).
    pub nnz_h: usize,
    /// Nonzeros of the adjacency S.
    pub nnz_s: usize,
    /// Whether the input's column checksum h_c is known offline
    /// (true for layer 1: features are static).
    pub static_input: bool,
}

/// Op counts for one layer under one scheme.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct LayerOps {
    pub true_out: u64,
    pub check: u64,
}

impl LayerShape {
    /// Operations for the true (unchecked) layer output.
    pub fn true_ops(&self) -> u64 {
        2 * self.nnz_h as u64 * self.h as u64 + 2 * self.nnz_s as u64 * self.h as u64
    }

    /// Checking overhead of baseline split ABFT for this layer.
    pub fn split_check_ops(&self) -> u64 {
        let (n, f, h) = (self.n as u64, self.f as u64, self.h as u64);
        let nnz_h = self.nnz_h as u64;
        let nnz_s = self.nnz_s as u64;
        let h_c = if self.static_input { 0 } else { nnz_h };
        let hc_w = 2 * f * (h + 1);
        let x_r = 2 * nnz_h;
        let actual_x = n * h - 1;
        let s_xr = 2 * nnz_s;
        let sc_x = 2 * n * (h + 1);
        let actual_out = n * h - 1;
        h_c + hc_w + x_r + actual_x + s_xr + sc_x + actual_out
    }

    /// Checking overhead of fused GCN-ABFT for this layer.
    pub fn fused_check_ops(&self) -> u64 {
        let (n, h) = (self.n as u64, self.h as u64);
        let nnz_h = self.nnz_h as u64;
        let nnz_s = self.nnz_s as u64;
        let x_r = 2 * nnz_h;
        let s_xr = 2 * nnz_s;
        let sc_x = 2 * n * (h + 1);
        let actual_out = n * h - 1;
        x_r + s_xr + sc_x + actual_out
    }

    /// The closed-form saving (split − fused); must equal the difference
    /// of the two functions above.
    pub fn saving_ops(&self) -> u64 {
        let (n, f, h) = (self.n as u64, self.f as u64, self.h as u64);
        let h_c = if self.static_input {
            0
        } else {
            self.nnz_h as u64
        };
        h_c + 2 * f * (h + 1) + (n * h - 1)
    }
}

/// Op accounting for a whole model on a dataset.
#[derive(Debug, Clone)]
pub struct ModelOps {
    pub layers: Vec<LayerShape>,
}

/// Aggregate counts for Table II.
#[derive(Debug, Clone, Copy, Default)]
pub struct TableRow {
    pub true_out: u64,
    pub split_check: u64,
    pub fused_check: u64,
}

impl TableRow {
    pub fn split_total(&self) -> u64 {
        self.true_out + self.split_check
    }
    pub fn fused_total(&self) -> u64 {
        self.true_out + self.fused_check
    }
    /// Fractional saving in checking ops.
    pub fn check_saving(&self) -> f64 {
        1.0 - self.fused_check as f64 / self.split_check as f64
    }
    /// Fractional saving in total ops.
    pub fn total_saving(&self) -> f64 {
        1.0 - self.fused_total() as f64 / self.split_total() as f64
    }
}

impl ModelOps {
    /// Shape out a 2-layer GCN on a dataset graph (hidden width `hidden`).
    pub fn two_layer(graph: &Graph, hidden: usize) -> Self {
        let n = graph.num_nodes;
        let nnz_s = graph.adjacency_nnz();
        let layer1 = LayerShape {
            n,
            f: graph.feat_dim(),
            h: hidden,
            nnz_h: graph.features.nnz(),
            nnz_s,
            static_input: true,
        };
        let layer2 = LayerShape {
            n,
            f: hidden,
            h: graph.num_classes,
            nnz_h: n * hidden, // dense activations
            nnz_s,
            static_input: false,
        };
        Self {
            layers: vec![layer1, layer2],
        }
    }

    pub fn table_row(&self) -> TableRow {
        let mut row = TableRow::default();
        for l in &self.layers {
            row.true_out += l.true_ops();
            row.split_check += l.split_check_ops();
            row.fused_check += l.fused_check_ops();
        }
        row
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::abft::{fused_forward_checked, split_forward_checked, EngineModel};
    use crate::gcn::GcnModel;
    use crate::graph::DatasetId;
    use crate::tensor::CountingHook;

    #[test]
    fn closed_form_saving_matches_difference() {
        let g = DatasetId::Tiny.build(0);
        let m = ModelOps::two_layer(&g, 8);
        for l in &m.layers {
            assert_eq!(l.split_check_ops() - l.fused_check_ops(), l.saving_ops());
        }
    }

    #[test]
    fn analytic_model_matches_instrumented_engine_exactly() {
        // The strongest validation of Table II: the closed-form counts
        // equal the op-for-op measured counts of the checked executors.
        let g = DatasetId::Tiny.build(3);
        let gm = GcnModel::two_layer(&g, 8, 1);
        let em = EngineModel::from_model(&gm);
        let ops = ModelOps::two_layer(&g, 8);
        let row = ops.table_row();

        let mut cs = CountingHook::default();
        let h_c = g.features.col_sums_f64();
        split_forward_checked(&em, &g.features, &h_c, &mut cs);
        assert_eq!(cs.data_ops + cs.checksum_ops, row.split_total());

        let mut cf = CountingHook::default();
        fused_forward_checked(&em, &g.features, &mut cf);
        assert_eq!(cf.data_ops + cf.checksum_ops, row.fused_total());
    }

    #[test]
    fn savings_are_positive_for_all_paper_datasets() {
        for id in DatasetId::ALL {
            // Use scaled-down builds for speed; ratios are scale-free
            // enough for a sanity bound.
            let g = if matches!(id, DatasetId::Nell | DatasetId::Pubmed) {
                id.build_scaled(0, 0.05)
            } else {
                id.build(0)
            };
            let row = ModelOps::two_layer(&g, id.hidden_dim()).table_row();
            assert!(row.check_saving() > 0.05, "{}: {}", id.name(), row.check_saving());
            assert!(row.check_saving() < 0.6, "{}: {}", id.name(), row.check_saving());
            assert!(row.total_saving() > 0.0);
            assert!(row.fused_total() < row.split_total());
        }
    }

    #[test]
    fn cora_true_ops_land_near_paper() {
        // Paper Table II: Cora true output ≈ 2.8 M ops.
        let g = DatasetId::Cora.build(0);
        let row = ModelOps::two_layer(&g, 16).table_row();
        let m = row.true_out as f64 / 1e6;
        assert!(
            (2.0..4.0).contains(&m),
            "Cora true ops {m:.2}M out of expected band"
        );
    }
}
