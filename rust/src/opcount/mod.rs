//! Analytic operation-count accounting for execution + checking
//! (regenerates the paper's Table II), including the per-(backend,
//! scheme) checksum-overhead matrix behind `gcn-abft opcount`.

pub mod backend;
pub mod model;

pub use backend::{backend_matrix, check_ops_for, BackendOpsRow, BackendProfile};
pub use model::{LayerShape, ModelOps, TableRow};
