//! Analytic operation-count accounting for execution + checking
//! (regenerates the paper's Table II).

pub mod model;

pub use model::{LayerShape, ModelOps, TableRow};
