//! Checksum-overhead accounting **per (backend, scheme)** pair.
//!
//! The paper's Table II counts the checking ops of the accelerator-style
//! enhanced products (check rows/columns computed alongside the true
//! output) — that is what the instrumented f64 engine executes, op for
//! op. The native serving backends compute leaner checks: the fused
//! predicted checksum is a single `s_c·x_r` dot (no `s_c·X`
//! localization row) and the layer-1 check column `x_r` is cached
//! offline. This module gives both profiles a closed form over
//! [`LayerShape`]s so `gcn-abft opcount` can print the full
//! dataset × backend × scheme matrix — including the paper's >21%
//! fused-vs-split saving — from one command.

use super::model::LayerShape;
use crate::abft::Scheme;
use crate::graph::DatasetId;

/// Which backend's checking structure to count.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendProfile {
    /// Native f32 serving backends (`native-dense`/`native-banded`):
    /// offline layer-1 `x_r`, scalar predicted checksum, f64 re-sum of
    /// the true output.
    Native,
    /// MAC-instrumented f64 engine (and, structurally, the paper's
    /// accelerator): full enhanced products with localization rows.
    Instrumented,
}

impl BackendProfile {
    pub fn name(&self) -> &'static str {
        match self {
            BackendProfile::Native => "native",
            BackendProfile::Instrumented => "instrumented",
        }
    }
}

/// Checking-overhead ops of one layer under a backend profile + scheme.
/// `Scheme::Auto` counts as whichever concrete scheme is cheaper on
/// this layer — the quantity [`resolve_scheme`] minimizes.
pub fn check_ops_for(profile: BackendProfile, scheme: Scheme, l: &LayerShape) -> u64 {
    if scheme == Scheme::Auto {
        return check_ops_for(profile, Scheme::Fused, l)
            .min(check_ops_for(profile, Scheme::Split, l));
    }
    match profile {
        BackendProfile::Instrumented => match scheme {
            Scheme::Split => l.split_check_ops(),
            _ => l.fused_check_ops(),
        },
        BackendProfile::Native => {
            let (n, f, h) = (l.n as u64, l.f as u64, l.h as u64);
            let nnz_h = l.nnz_h as u64;
            // Fused: online x_r ride-along (layer 1's is cached offline),
            // predicted = s_c·x_r (2N), actual = f64 re-sum (N·h − 1).
            let x_r = if l.static_input { 0 } else { 2 * nnz_h };
            let fused = x_r + 2 * n + (n * h - 1);
            match scheme {
                // Split adds the phase-1 check: online h_c (layer 1's is
                // offline), predicted = h_c·w_r (2F), actual = re-sum of
                // X (N·h − 1).
                Scheme::Split => {
                    let h_c = if l.static_input { 0 } else { nnz_h };
                    fused + h_c + 2 * f + (n * h - 1)
                }
                _ => fused,
            }
        }
    }
}

/// Resolve [`Scheme::Auto`] to the concrete scheme with the lowest total
/// measured check-op cost over the layer shapes actually being served —
/// the arithmetic-intensity-guided placement decision (Kosaian & Rashmi:
/// pick the cheapest adequate check from measured profiles, not a flag).
/// Concrete schemes pass through unchanged, so every backend can call
/// this unconditionally at its `plan`/`run` entry. Both schemes preserve
/// the detection contract (they differ only in *where* checks sit), so
/// the argmin is over cost alone; ties break to `Fused`, the paper's
/// scheme.
pub fn resolve_scheme(profile: BackendProfile, scheme: Scheme, shapes: &[LayerShape]) -> Scheme {
    if scheme != Scheme::Auto {
        return scheme;
    }
    let total =
        |s: Scheme| -> u64 { shapes.iter().map(|l| check_ops_for(profile, s, l)).sum() };
    if total(Scheme::Split) < total(Scheme::Fused) {
        Scheme::Split
    } else {
        Scheme::Fused
    }
}

/// One row of the (dataset × backend × scheme) matrix.
#[derive(Debug, Clone)]
pub struct BackendOpsRow {
    pub dataset: String,
    pub profile: BackendProfile,
    pub scheme: Scheme,
    pub true_ops: u64,
    pub check_ops: u64,
}

impl BackendOpsRow {
    /// Checking overhead as a fraction of the true-output work.
    pub fn overhead(&self) -> f64 {
        self.check_ops as f64 / self.true_ops.max(1) as f64
    }
}

/// Layer shapes of a dataset's 2-layer GCN at paper scale, from the
/// published statistics alone (no graph build — Nell's matrix stays on
/// paper). `S` nnz is `2E + N` (every edge twice plus self-loops).
pub fn spec_layer_shapes(id: DatasetId) -> [LayerShape; 2] {
    let spec = id.spec();
    let n = spec.num_nodes;
    let hidden = id.hidden_dim();
    let nnz_s = 2 * spec.num_edges + n;
    [
        LayerShape {
            n,
            f: spec.feat_dim,
            h: hidden,
            nnz_h: spec.feat_nnz,
            nnz_s,
            static_input: true,
        },
        LayerShape {
            n,
            f: hidden,
            h: spec.num_classes,
            nnz_h: n * hidden,
            nnz_s,
            static_input: false,
        },
    ]
}

/// The full matrix for a set of datasets: every (backend, scheme) pair
/// per dataset, fused rows directly comparable to split rows.
pub fn backend_matrix(datasets: &[DatasetId]) -> Vec<BackendOpsRow> {
    let mut rows = Vec::new();
    for &id in datasets {
        let shapes = spec_layer_shapes(id);
        let true_ops: u64 = shapes.iter().map(|l| l.true_ops()).sum();
        for profile in [BackendProfile::Instrumented, BackendProfile::Native] {
            for scheme in [Scheme::Split, Scheme::Fused] {
                let check_ops = shapes.iter().map(|l| check_ops_for(profile, scheme, l)).sum();
                rows.push(BackendOpsRow {
                    dataset: id.name().to_string(),
                    profile,
                    scheme,
                    true_ops,
                    check_ops,
                });
            }
        }
    }
    rows
}

/// Fused-vs-split checking saving for one (dataset, profile) pair in a
/// matrix produced by [`backend_matrix`].
pub fn check_saving(rows: &[BackendOpsRow], dataset: &str, profile: BackendProfile) -> f64 {
    let find = |scheme: Scheme| {
        rows.iter()
            .find(|r| r.dataset == dataset && r.profile == profile && r.scheme == scheme)
            .map(|r| r.check_ops)
            .unwrap_or(0)
    };
    let split = find(Scheme::Split);
    let fused = find(Scheme::Fused);
    if split == 0 {
        return 0.0;
    }
    1.0 - fused as f64 / split as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn instrumented_profile_is_the_paper_accounting() {
        let shapes = spec_layer_shapes(DatasetId::Cora);
        for l in &shapes {
            assert_eq!(
                check_ops_for(BackendProfile::Instrumented, Scheme::Split, l),
                l.split_check_ops()
            );
            assert_eq!(
                check_ops_for(BackendProfile::Instrumented, Scheme::Fused, l),
                l.fused_check_ops()
            );
        }
    }

    #[test]
    fn native_checks_are_leaner_than_instrumented() {
        for id in DatasetId::ALL {
            for l in &spec_layer_shapes(id) {
                for scheme in [Scheme::Split, Scheme::Fused] {
                    let native = check_ops_for(BackendProfile::Native, scheme, l);
                    let inst = check_ops_for(BackendProfile::Instrumented, scheme, l);
                    assert!(
                        native < inst,
                        "{}: native {native} >= instrumented {inst} ({scheme:?})",
                        id.name()
                    );
                }
            }
        }
    }

    #[test]
    fn fused_saves_over_split_on_every_backend_and_dataset() {
        let rows = backend_matrix(&DatasetId::ALL.to_vec());
        for id in DatasetId::ALL {
            for profile in [BackendProfile::Native, BackendProfile::Instrumented] {
                let saving = check_saving(&rows, id.name(), profile);
                assert!(
                    saving > 0.0 && saving < 1.0,
                    "{} / {:?}: saving {saving}",
                    id.name(),
                    profile
                );
            }
            // The paper's headline: >21% checking saving on the
            // accelerator accounting for the feature-heavy graphs
            // (the saving scales with 2F(h+1), the h_c·[W|w_r] state
            // GCN-ABFT eliminates).
            let inst = check_saving(&rows, id.name(), BackendProfile::Instrumented);
            if matches!(id, DatasetId::Cora | DatasetId::Citeseer) {
                assert!(inst > 0.21, "{}: instrumented saving {inst}", id.name());
            }
        }
    }

    #[test]
    fn auto_resolves_to_the_measured_argmin_on_every_dataset() {
        for id in DatasetId::ALL {
            let shapes = spec_layer_shapes(id);
            for profile in [BackendProfile::Native, BackendProfile::Instrumented] {
                let total = |s: Scheme| -> u64 {
                    shapes.iter().map(|l| check_ops_for(profile, s, l)).sum()
                };
                let resolved = resolve_scheme(profile, Scheme::Auto, &shapes);
                assert_ne!(resolved, Scheme::Auto, "Auto must resolve to a concrete scheme");
                // The resolved scheme is the argmin over the explicit
                // schemes — the acceptance property. (On both current
                // profiles split strictly exceeds fused, so the argmin
                // is constantly Fused; the assertion stays valid if a
                // future profile flips the ordering.)
                for s in [Scheme::Split, Scheme::Fused] {
                    assert!(
                        total(resolved) <= total(s),
                        "{} / {:?}: Auto picked {:?} ({}) but {:?} costs {}",
                        id.name(),
                        profile,
                        resolved,
                        total(resolved),
                        s,
                        total(s),
                    );
                }
                // Per-layer Auto accounting = min of the concrete pair.
                for l in &shapes {
                    assert_eq!(
                        check_ops_for(profile, Scheme::Auto, l),
                        check_ops_for(profile, Scheme::Fused, l)
                            .min(check_ops_for(profile, Scheme::Split, l)),
                    );
                }
                // Concrete schemes pass through untouched.
                for s in [Scheme::Split, Scheme::Fused] {
                    assert_eq!(resolve_scheme(profile, s, &shapes), s);
                }
            }
        }
    }

    #[test]
    fn matrix_rows_cover_all_pairs() {
        let rows = backend_matrix(&[DatasetId::Cora]);
        assert_eq!(rows.len(), 4, "2 backends × 2 schemes");
        for r in &rows {
            assert!(r.check_ops > 0 && r.true_ops > 0);
            assert!(r.overhead() > 0.0 && r.overhead() < 1.0, "{r:?}");
        }
    }
}
