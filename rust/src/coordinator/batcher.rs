//! Dynamic batcher: coalesce concurrent inference requests into one
//! accelerator pass, bounded by batch size and a latency deadline —
//! the standard continuous-batching control loop of serving systems.

use super::request::InferenceRequest;
use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

/// Batching policy.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    /// Close a batch at this many requests.
    pub max_batch: usize,
    /// ... or when the oldest member has waited this long.
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        Self {
            max_batch: 8,
            max_wait: Duration::from_millis(5),
        }
    }
}

/// A closed batch.
#[derive(Debug)]
pub struct Batch {
    pub requests: Vec<InferenceRequest>,
}

impl Batch {
    pub fn len(&self) -> usize {
        self.requests.len()
    }
    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }
}

/// Pull one batch from `rx` under `policy`. Returns `None` when the
/// channel is closed and drained. Blocks for the first request, then
/// fills greedily until size or deadline.
pub fn next_batch(rx: &Receiver<InferenceRequest>, policy: &BatchPolicy) -> Option<Batch> {
    let first = rx.recv().ok()?;
    let deadline = Instant::now() + policy.max_wait;
    let mut requests = vec![first];
    while requests.len() < policy.max_batch {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(r) => requests.push(r),
            Err(RecvTimeoutError::Timeout) => break,
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    Some(Batch { requests })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;
    use std::time::Instant;

    fn req(id: u64) -> InferenceRequest {
        InferenceRequest {
            id,
            query_nodes: vec![0],
            perturbations: vec![],
            submitted: Instant::now(),
        }
    }

    #[test]
    fn fills_to_max_batch() {
        let (tx, rx) = mpsc::channel();
        for i in 0..10 {
            tx.send(req(i)).unwrap();
        }
        let policy = BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_millis(50),
        };
        let b = next_batch(&rx, &policy).unwrap();
        assert_eq!(b.len(), 4);
        let ids: Vec<u64> = b.requests.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3]);
    }

    #[test]
    fn deadline_closes_partial_batch() {
        let (tx, rx) = mpsc::channel();
        tx.send(req(0)).unwrap();
        let policy = BatchPolicy {
            max_batch: 100,
            max_wait: Duration::from_millis(10),
        };
        let t0 = Instant::now();
        let b = next_batch(&rx, &policy).unwrap();
        assert_eq!(b.len(), 1);
        assert!(t0.elapsed() < Duration::from_millis(500));
    }

    #[test]
    fn closed_channel_yields_none() {
        let (tx, rx) = mpsc::channel::<InferenceRequest>();
        drop(tx);
        assert!(next_batch(&rx, &BatchPolicy::default()).is_none());
    }

    #[test]
    fn drains_remaining_after_close() {
        let (tx, rx) = mpsc::channel();
        tx.send(req(0)).unwrap();
        tx.send(req(1)).unwrap();
        drop(tx);
        let policy = BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(5),
        };
        let b = next_batch(&rx, &policy).unwrap();
        assert_eq!(b.len(), 2);
        assert!(next_batch(&rx, &policy).is_none());
    }
}
