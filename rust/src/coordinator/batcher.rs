//! Continuous-batching scheduler: a priority-aware admission queue that
//! coalesces newly arrived requests into the *next* batch while the
//! current one is still executing on a worker.
//!
//! The old control loop (one batcher thread blocking on an mpsc, going
//! idle while the backend ran) closed a batch on size/deadline and then
//! stopped admitting — exactly when load is highest the next
//! accelerator pass started under-filled. Here admission never blocks
//! on a forward: producers [`Scheduler::submit`] into the queue at any
//! time, and each executor pulls its next batch directly with
//! [`Scheduler::next_batch`] the moment it finishes the previous one
//! (double-buffered by construction — while worker A executes, the
//! queue keeps filling for whoever pulls next).
//!
//! Close policy (mixing size, oldest-waiter deadline, and a starvation
//! bound):
//!
//! * **size** — ≥ `max_batch` requests are queued;
//! * **deadline** — some queued request has waited out its hold budget,
//!   `min(effective_wait, request.deadline)`, measured from *arrival* (a
//!   request admitted with an already-expired budget closes the batch
//!   immediately — the old loop's idle-spin edge, where the first
//!   member's expired deadline still waited out a full `recv_timeout`,
//!   is gone). `effective_wait` is the configured `max_wait`, or — under
//!   [`AdaptiveWait`] (`--adaptive-wait`) — an auto-tuned budget derived
//!   from an EWMA of the observed inter-arrival times, clamped to
//!   `[min_wait, max_wait]`;
//! * **drain** — the scheduler was shut down; whatever is queued is
//!   released without waiting.
//!
//! Members are picked in priority order (rank, then arrival) — except
//! that a request older than `starvation_factor × max_wait`, or past
//! its **explicit per-request deadline**, is **force included** ahead
//! of any priority, so background traffic is never starved by an
//! interactive flood: no request waits in the admission queue past the
//! starvation bound while batches are closing, and a caller-declared
//! deadline is honored in member selection, not just in close timing.
//!
//! **Overload survival** ([`AdmissionControl`], `--queue-cap`): with a
//! bounded queue configured, [`Scheduler::submit`] becomes fallible.
//! Shedding is strictly from the bottom — Background sheds first,
//! Interactive last: a full *class* cap tail-drops the arrival, a full
//! *total* cap evicts the youngest member of the worst strictly-lower
//! class (or sheds the arrival when nothing below it is queued). With
//! `early_reject`, a request whose declared deadline provably cannot be
//! met — estimated from an EWMA of observed batch service times fed in
//! via [`Scheduler::record_service`] — is refused at admission, and
//! queued members whose deadline has expired (or become unmeetable) are
//! moved to [`Batch::shed`] at close time instead of executing late.
//! Every shed outcome carries a [`ShedReason`] and is answered by the
//! caller as a `Shed` response — a distinct class from `Failed`, so
//! degraded availability is never conflated with fault detection.
//!
//! Every decision is a pure function of the queue and a [`Tick`] from
//! the [`Clock`], so the whole policy is tested deterministically on a
//! [`super::clock::VirtualClock`] with zero real sleeps
//! (`tests/scheduler_virtual_clock.rs`).

use super::clock::{Clock, MonotonicClock, Tick};
use super::lock_recover;
use super::request::InferenceRequest;
use std::sync::{Condvar, Mutex, RwLock, RwLockReadGuard, RwLockWriteGuard};
use std::time::Duration;

/// Batching policy.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    /// Close a batch at this many requests.
    pub max_batch: usize,
    /// ... or when the oldest member has waited this long.
    pub max_wait: Duration,
    /// A request older than `starvation_factor × max_wait` is force
    /// included in the next batch regardless of priority pressure.
    pub starvation_factor: u32,
    /// Auto-tune the hold budget from the observed arrival rate
    /// (`--adaptive-wait`); `None` = the fixed `max_wait` governs.
    pub adaptive: Option<AdaptiveWait>,
    /// Bounded admission with per-priority shedding (`--queue-cap`);
    /// `None` = the legacy unbounded queue, `submit` never sheds.
    pub admission: Option<AdmissionControl>,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        Self {
            max_batch: 8,
            max_wait: Duration::from_millis(5),
            starvation_factor: 4,
            adaptive: None,
            admission: None,
        }
    }
}

impl BatchPolicy {
    /// The absolute age past which a queued request is starved:
    /// `starvation_factor × max_wait` (factor clamped to ≥ 1; always
    /// anchored at the *configured* `max_wait`, so the fairness bound
    /// stays stable while the adaptive hold budget moves).
    pub fn starvation_bound(&self) -> Duration {
        self.max_wait * self.starvation_factor.max(1)
    }
}

/// Adaptive hold-budget policy: the scheduler keeps an EWMA of the
/// inter-arrival time and holds a non-full batch for
/// `ewma × (max_batch − 1)` — the time a full batch takes to assemble
/// at the observed rate — clamped to `[min_wait, max_wait]`. Under an
/// arrival flood the budget collapses toward `min_wait` (arrivals fill
/// batches by size anyway); under a trickle it rises toward `max_wait`
/// but never past the configured ceiling, so worst-case latency is
/// unchanged. The EWMA update is pinned by a `VirtualClock` test.
#[derive(Debug, Clone, Copy)]
pub struct AdaptiveWait {
    /// EWMA smoothing factor in (0, 1]: `ewma ← α·dt + (1−α)·ewma`.
    pub alpha: f64,
    /// Lower clamp for the effective hold budget.
    pub min_wait: Duration,
}

impl Default for AdaptiveWait {
    fn default() -> Self {
        Self {
            alpha: 0.2,
            min_wait: Duration::from_micros(200),
        }
    }
}

/// Bounded admission policy (`--queue-cap`). All shedding decisions are
/// pure functions of the queue and the arrival's [`Tick`], so they are
/// pinned on a `VirtualClock` with zero sleeps.
///
/// Shed-from-the-bottom ordering: Background sheds first, Interactive
/// last. A full class cap tail-drops the arrival itself; a full total
/// cap evicts the *youngest* queued member of the *worst* class that is
/// strictly lower-priority than the arrival — never a peer or better —
/// and sheds the arrival when no such victim exists.
#[derive(Debug, Clone, Copy)]
pub struct AdmissionControl {
    /// Hard bound on the total queue depth across all classes.
    pub total_cap: usize,
    /// Per-class bounds, indexed by [`Priority::rank`]
    /// (`[interactive, batch, background]`); `usize::MAX` leaves a
    /// class governed by `total_cap` alone.
    ///
    /// [`Priority::rank`]: super::request::Priority::rank
    pub class_caps: [usize; 3],
    /// Deadline-aware early rejection: refuse a request whose declared
    /// deadline provably cannot be met (queue-ahead estimate × the
    /// [`Scheduler::record_service`] EWMA), and shed already-expired /
    /// unmeetable members into [`Batch::shed`] at close time instead of
    /// executing them late. Off by default: without it, expired
    /// deadlines keep their legacy promote-and-serve semantics.
    pub early_reject: bool,
}

impl Default for AdmissionControl {
    fn default() -> Self {
        Self {
            total_cap: 1024,
            class_caps: [usize::MAX; 3],
            early_reject: false,
        }
    }
}

/// Why a request was shed (attached to the handed-back request so the
/// caller can answer it with a machine-readable `Shed` response).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShedReason {
    /// The bounded queue (class or total cap) was full and no
    /// strictly-lower-priority victim existed.
    QueueFull,
    /// Evicted from the queue to admit a higher-priority arrival.
    Evicted,
    /// The declared deadline provably cannot (or can no longer) be met.
    DeadlineUnmeetable,
}

impl ShedReason {
    pub fn name(&self) -> &'static str {
        match self {
            ShedReason::QueueFull => "queue-full",
            ShedReason::Evicted => "evicted",
            ShedReason::DeadlineUnmeetable => "deadline-unmeetable",
        }
    }
}

/// A request shed by admission control, handed back to the caller —
/// the scheduler never answers clients itself, so whoever submitted it
/// owns turning this into a `Shed` response.
#[derive(Debug)]
pub struct ShedRequest {
    pub req: InferenceRequest,
    pub reason: ShedReason,
}

/// Verdict for the arriving request in a [`SubmitOutcome`].
#[derive(Debug)]
pub enum Admission {
    /// The request was queued.
    Admitted,
    /// The request was refused and is handed back with the reason.
    Shed(ShedRequest),
}

/// Everything [`Scheduler::submit`] decided: the arrival's own verdict
/// plus any queued requests evicted to make room for it. With
/// `admission: None` the verdict is always `Admitted` and `evicted` is
/// always empty — legacy call sites may ignore the return value.
#[derive(Debug)]
pub struct SubmitOutcome {
    pub admission: Admission,
    /// Lower-priority members evicted to admit this arrival
    /// (youngest-first within the worst queued class).
    pub evicted: Vec<ShedRequest>,
}

impl SubmitOutcome {
    pub fn is_admitted(&self) -> bool {
        matches!(self.admission, Admission::Admitted)
    }

    /// Drain every shed request (the refused arrival and/or evicted
    /// members) for answering.
    pub fn into_shed(self) -> Vec<ShedRequest> {
        let mut out = self.evicted;
        if let Admission::Shed(s) = self.admission {
            out.push(s);
        }
        out
    }
}

/// Smoothing factor for the batch service-time EWMA feeding
/// deadline-aware early rejection (`ewma ← α·dt + (1−α)·ewma`).
const SERVICE_EWMA_ALPHA: f64 = 0.3;

/// Why a batch was closed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CloseReason {
    /// `max_batch` requests were ready.
    Size,
    /// A queued request waited out its hold budget.
    Deadline,
    /// A starved request was force-included over priority order.
    Starvation,
    /// Shutdown drain: remaining requests released without waiting.
    Drain,
}

/// A closed batch. `requests` are in scheduling order: force-included
/// members (past the starvation bound or an explicit deadline) first,
/// then the rest — both groups sorted by (priority, arrival). `shed`
/// holds members rejected at close time by deadline-aware early
/// rejection (`AdmissionControl::early_reject`) — the executor must
/// answer them with `Shed` responses *before* the forward, and they
/// never appear in `requests`, so a shed request never executes.
#[derive(Debug)]
pub struct Batch {
    pub requests: Vec<InferenceRequest>,
    pub closed_by: CloseReason,
    pub shed: Vec<ShedRequest>,
}

impl Batch {
    pub fn len(&self) -> usize {
        self.requests.len()
    }
    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }
}

/// Scheduler counters (snapshot via [`Scheduler::stats`]).
#[derive(Debug, Clone, Default)]
pub struct SchedStats {
    pub submitted: u64,
    pub batches: u64,
    /// Requests force-included into a batch over priority order —
    /// because they crossed the starvation bound or an explicit
    /// per-request deadline.
    pub starvation_promotions: u64,
    /// Requests shed by admission control, per priority rank
    /// (`[interactive, batch, background]`): refused arrivals, evicted
    /// members, and close-time deadline rejections all count here.
    pub shed: [u64; 3],
}

impl SchedStats {
    /// Total shed across all classes.
    pub fn shed_total(&self) -> u64 {
        self.shed.iter().sum()
    }
}

/// One queued request with its admission bookkeeping.
#[derive(Debug)]
struct Queued {
    req: InferenceRequest,
    arrived: Tick,
    seq: u64,
}

impl Queued {
    /// How long the scheduler may hold this request before a close is
    /// forced: the effective hold budget (`max_wait`, or the adaptive
    /// tuning of it), tightened by the request's own deadline when one
    /// is set.
    fn hold_deadline(&self, eff_wait: Duration) -> Tick {
        let budget = match self.req.deadline {
            Some(d) => d.min(eff_wait),
            None => eff_wait,
        };
        self.arrived.after(budget)
    }

    /// Whether the request's **declared** deadline (not the
    /// max_wait-capped hold budget) has expired — the condition that
    /// promotes it over priority order in member selection. A deadline
    /// looser than `max_wait` must not jump priority any earlier than
    /// the caller asked for.
    fn deadline_expired(&self, now: Tick) -> bool {
        match self.req.deadline {
            Some(d) => now >= self.arrived.after(d),
            None => false,
        }
    }
}

#[derive(Debug, Default)]
struct State {
    queue: Vec<Queued>,
    shutdown: bool,
    next_seq: u64,
    stats: SchedStats,
    /// EWMA of inter-arrival time in ns (adaptive policy only; `None`
    /// until two arrivals have been observed).
    ewma_arrival_ns: Option<f64>,
    /// Tick of the most recent arrival.
    last_arrival: Option<Tick>,
    /// EWMA of batch service time in ns, fed by executors through
    /// [`Scheduler::record_service`]; `None` until the first batch
    /// completes. Drives deadline-aware early rejection.
    ewma_service_ns: Option<f64>,
}

/// The continuous-batching scheduler. Shared by reference between the
/// admission side ([`submit`](Scheduler::submit)) and any number of
/// executor threads ([`next_batch`](Scheduler::next_batch)).
#[derive(Debug)]
pub struct Scheduler<C: Clock = MonotonicClock> {
    clock: C,
    policy: BatchPolicy,
    state: Mutex<State>,
    cv: Condvar,
    /// The epoch gate (dynamic graphs): executors hold a **read** lock
    /// for the duration of each batch execution; a graph-delta applier
    /// takes the **write** lock, which waits for every in-flight batch
    /// to drain before resident state (the published operand snapshot
    /// *and* any shard-worker-held bands) may move to the next epoch.
    /// Admission is untouched — requests keep queueing while the fence
    /// is held, they just execute against the next graph version.
    epoch_gate: RwLock<()>,
}

impl Scheduler<MonotonicClock> {
    /// A real-time scheduler (production path).
    pub fn with_policy(policy: BatchPolicy) -> Scheduler<MonotonicClock> {
        Scheduler::new(MonotonicClock::new(), policy)
    }
}

impl<C: Clock> Scheduler<C> {
    pub fn new(clock: C, policy: BatchPolicy) -> Scheduler<C> {
        Scheduler {
            clock,
            policy,
            state: Mutex::new(State::default()),
            cv: Condvar::new(),
            epoch_gate: RwLock::new(()),
        }
    }

    /// Enter batch execution: hold the returned guard for exactly the
    /// span in which a batch touches a graph-version snapshot (or the
    /// shard transport's resident bands). Many batches may execute
    /// concurrently; an epoch boundary ([`Self::epoch_guard`]) waits
    /// for all of them. Lock poison is recovered — the gate carries no
    /// data, so a panicked holder leaves nothing inconsistent.
    pub fn batch_guard(&self) -> RwLockReadGuard<'_, ()> {
        self.epoch_gate.read().unwrap_or_else(|p| p.into_inner())
    }

    /// Enter an epoch boundary: blocks until every in-flight batch
    /// drops its [`Self::batch_guard`], then holds executors out until
    /// the guard is dropped. The delta applier holds this across
    /// operand publication *and* shard delta routing, so a batch never
    /// observes a half-applied graph version.
    pub fn epoch_guard(&self) -> RwLockWriteGuard<'_, ()> {
        self.epoch_gate.write().unwrap_or_else(|p| p.into_inner())
    }

    /// The scheduler's clock — tests advance a
    /// [`super::clock::VirtualClock`] through this.
    pub fn clock(&self) -> &C {
        &self.clock
    }

    pub fn policy(&self) -> &BatchPolicy {
        &self.policy
    }

    /// Admit one request. Never blocks on an executing forward; stamps
    /// the arrival tick used by every close decision and (adaptive
    /// policy) folds the inter-arrival gap into the EWMA.
    ///
    /// With [`BatchPolicy::admission`] set this is fallible: the
    /// outcome says whether the arrival was `Admitted` or `Shed` (the
    /// request is handed back), and carries any lower-priority members
    /// evicted to make room. With `admission: None` the legacy
    /// unbounded behavior is unchanged and the outcome may be ignored.
    pub fn submit(&self, req: InferenceRequest) -> SubmitOutcome {
        let arrived = self.clock.now();
        let mut st = lock_recover(&self.state);
        if let Some(aw) = self.policy.adaptive {
            if let Some(prev) = st.last_arrival {
                let dt = arrived.since(prev).as_nanos() as f64;
                st.ewma_arrival_ns = Some(match st.ewma_arrival_ns {
                    Some(e) => aw.alpha * dt + (1.0 - aw.alpha) * e,
                    None => dt,
                });
            }
            st.last_arrival = Some(arrived);
        }
        st.stats.submitted += 1;
        let mut evicted = Vec::new();
        if let Some(ac) = self.policy.admission {
            let rank = req.priority.rank();

            // Deadline-aware early rejection: with `ahead` peers-or-
            // better queued, this arrival rides no earlier than batch
            // `ahead / max_batch + 1`; if that many service times
            // already exceed the declared budget, answering late helps
            // nobody — refuse now so the client can back off.
            if ac.early_reject {
                if let (Some(d), Some(ewma)) = (req.deadline, st.ewma_service_ns) {
                    let ahead = st
                        .queue
                        .iter()
                        .filter(|q| q.req.priority.rank() <= rank)
                        .count();
                    let batches_before = (ahead / self.policy.max_batch.max(1) + 1) as f64;
                    if batches_before * ewma > d.as_nanos() as f64 {
                        st.stats.shed[rank] += 1;
                        return SubmitOutcome {
                            admission: Admission::Shed(ShedRequest {
                                req,
                                reason: ShedReason::DeadlineUnmeetable,
                            }),
                            evicted,
                        };
                    }
                }
            }

            // Class cap: tail-drop the arrival — its own class is full,
            // so no lower class pays for it.
            let in_class = st
                .queue
                .iter()
                .filter(|q| q.req.priority.rank() == rank)
                .count();
            if in_class >= ac.class_caps[rank].max(1) {
                st.stats.shed[rank] += 1;
                return SubmitOutcome {
                    admission: Admission::Shed(ShedRequest {
                        req,
                        reason: ShedReason::QueueFull,
                    }),
                    evicted,
                };
            }

            // Total cap: shed from the bottom. Evict the youngest
            // member of the worst class strictly below the arrival
            // (Background sheds first, Interactive last); if nothing
            // below it is queued, the arrival itself is shed — a full
            // queue of equal-or-better work is never preempted.
            if st.queue.len() >= ac.total_cap.max(1) {
                let victim = st
                    .queue
                    .iter()
                    .enumerate()
                    .filter(|(_, q)| q.req.priority.rank() > rank)
                    .max_by_key(|(_, q)| (q.req.priority.rank(), q.seq))
                    .map(|(i, _)| i);
                match victim {
                    Some(i) => {
                        let v = st.queue.remove(i);
                        st.stats.shed[v.req.priority.rank()] += 1;
                        evicted.push(ShedRequest {
                            req: v.req,
                            reason: ShedReason::Evicted,
                        });
                    }
                    None => {
                        st.stats.shed[rank] += 1;
                        return SubmitOutcome {
                            admission: Admission::Shed(ShedRequest {
                                req,
                                reason: ShedReason::QueueFull,
                            }),
                            evicted,
                        };
                    }
                }
            }
        }
        let seq = st.next_seq;
        st.next_seq += 1;
        st.queue.push(Queued { req, arrived, seq });
        self.cv.notify_all();
        SubmitOutcome {
            admission: Admission::Admitted,
            evicted,
        }
    }

    /// Fold one completed batch's service time into the EWMA that
    /// drives deadline-aware early rejection. Executors call this after
    /// every forward; tests feed known durations directly, so the
    /// estimate stays a pure function of its inputs.
    pub fn record_service(&self, took: Duration) {
        let mut st = lock_recover(&self.state);
        let ns = took.as_nanos() as f64;
        st.ewma_service_ns = Some(match st.ewma_service_ns {
            Some(e) => SERVICE_EWMA_ALPHA * ns + (1.0 - SERVICE_EWMA_ALPHA) * e,
            None => ns,
        });
    }

    /// The current batch service-time estimate (`None` until the first
    /// [`record_service`](Self::record_service)).
    pub fn ewma_service(&self) -> Option<Duration> {
        lock_recover(&self.state)
            .ewma_service_ns
            .map(|ns| Duration::from_nanos(ns as u64))
    }

    /// Back-off hint for a shed response: the service-time EWMA times
    /// the batches the backlog represents, i.e. roughly when the queue
    /// ahead of a retry will have drained. `None` until the first
    /// completed batch seeds the EWMA — clients then fall back to their
    /// own retry policy.
    pub fn retry_after_hint(&self) -> Option<Duration> {
        let st = lock_recover(&self.state);
        let ewma = st.ewma_service_ns?;
        let mb = self.policy.max_batch.max(1);
        // Queued batches ahead, plus the one the retry itself rides.
        let batches = ((st.queue.len() + mb - 1) / mb + 1) as f64;
        Some(Duration::from_nanos((ewma * batches) as u64))
    }

    /// Close admission: queued requests drain (immediately, without
    /// waiting out deadlines) and then [`next_batch`](Self::next_batch)
    /// returns `None`.
    pub fn shutdown(&self) {
        let mut st = lock_recover(&self.state);
        st.shutdown = true;
        self.cv.notify_all();
    }

    /// Requests currently queued.
    pub fn pending(&self) -> usize {
        lock_recover(&self.state).queue.len()
    }

    pub fn stats(&self) -> SchedStats {
        lock_recover(&self.state).stats.clone()
    }

    /// The hold budget currently in force: the configured `max_wait`,
    /// or — under the adaptive policy — `ewma_interarrival ×
    /// (max_batch − 1)` clamped to `[min_wait, max_wait]`.
    pub fn effective_wait(&self) -> Duration {
        let st = lock_recover(&self.state);
        Self::effective_wait_inner(&self.policy, &st)
    }

    fn effective_wait_inner(p: &BatchPolicy, st: &State) -> Duration {
        match (p.adaptive, st.ewma_arrival_ns) {
            (Some(aw), Some(ewma)) => {
                let target = ewma * p.max_batch.saturating_sub(1).max(1) as f64;
                // f64→u64 casts saturate, so an absurd EWMA clamps to
                // max_wait instead of wrapping.
                let target = Duration::from_nanos(target as u64);
                let lo = aw.min_wait.min(p.max_wait);
                target.clamp(lo, p.max_wait)
            }
            // No two arrivals observed yet (or fixed policy): the
            // configured ceiling governs.
            _ => p.max_wait,
        }
    }

    /// Non-blocking pull: close and return a batch if the policy says
    /// so at `clock.now()`, else `None`. This is the whole scheduler
    /// surface a virtual-clock test needs.
    pub fn poll(&self) -> Option<Batch> {
        let now = self.clock.now();
        let mut st = lock_recover(&self.state);
        Self::close_ready(&mut st, &self.policy, now)
    }

    /// Blocking pull for executors: waits (on real time — pair with a
    /// [`MonotonicClock`]) until a batch closes, and returns `None`
    /// once the scheduler is shut down and drained.
    pub fn next_batch(&self) -> Option<Batch> {
        let mut st = lock_recover(&self.state);
        loop {
            let now = self.clock.now();
            if let Some(b) = Self::close_ready(&mut st, &self.policy, now) {
                return Some(b);
            }
            if st.shutdown && st.queue.is_empty() {
                return None;
            }
            st = match Self::next_wakeup(&st, &self.policy, now) {
                // A poisoned condvar wait means some other holder panicked;
                // the queue itself is still consistent — recover and keep
                // serving (fail-stop lives at the response layer, not here).
                Some(wait) => self
                    .cv
                    .wait_timeout(st, wait)
                    .unwrap_or_else(|p| p.into_inner())
                    .0,
                None => self.cv.wait(st).unwrap_or_else(|p| p.into_inner()),
            };
        }
    }

    /// The close decision: size, oldest-waiter deadline, or drain.
    fn close_ready(st: &mut State, p: &BatchPolicy, now: Tick) -> Option<Batch> {
        if st.queue.is_empty() {
            return None;
        }
        let eff = Self::effective_wait_inner(p, st);
        let reason = if st.queue.len() >= p.max_batch.max(1) {
            CloseReason::Size
        } else if st.queue.iter().any(|q| now >= q.hold_deadline(eff)) {
            CloseReason::Deadline
        } else if st.shutdown {
            CloseReason::Drain
        } else {
            return None;
        };
        Some(Self::take_batch(st, p, now, reason))
    }

    /// Sleep budget until the next time-driven close (None: queue empty,
    /// only a submit or shutdown can make progress).
    fn next_wakeup(st: &State, p: &BatchPolicy, now: Tick) -> Option<Duration> {
        let eff = Self::effective_wait_inner(p, st);
        st.queue
            .iter()
            .map(|q| q.hold_deadline(eff))
            .min()
            .map(|dl| dl.since(now).max(Duration::from_micros(10)))
    }

    /// Select and remove up to `max_batch` members: urgent requests
    /// first, then by (priority rank, arrival, seq). Urgent = past the
    /// starvation bound, or past an **explicit** per-request deadline —
    /// a caller-declared latency budget must be honored in selection
    /// too, not only in close timing, or size pressure could hold the
    /// request all the way to the starvation bound. (Plain `max_wait`
    /// aging deliberately does *not* jump priority: under overload that
    /// would collapse priority scheduling into FIFO.)
    fn take_batch(st: &mut State, p: &BatchPolicy, now: Tick, reason: CloseReason) -> Batch {
        // Deadline-aware early rejection at close time (opt-in via
        // `AdmissionControl::early_reject`): a member whose declared
        // deadline has already expired — or provably cannot be met even
        // if it rode the very next batch (`now + ewma_service` past the
        // deadline) — is shed instead of executed late. Without the
        // opt-in, expired deadlines keep their promote-and-serve
        // semantics below.
        let mut shed: Vec<ShedRequest> = Vec::new();
        if matches!(p.admission, Some(ac) if ac.early_reject) {
            let ewma = st.ewma_service_ns;
            let queue = std::mem::take(&mut st.queue);
            for q in queue {
                let unmeetable = match q.req.deadline {
                    Some(d) => {
                        let dl = q.arrived.after(d);
                        now >= dl
                            || ewma.is_some_and(|e| now.after(Duration::from_nanos(e as u64)) > dl)
                    }
                    None => false,
                };
                if unmeetable {
                    st.stats.shed[q.req.priority.rank()] += 1;
                    shed.push(ShedRequest {
                        req: q.req,
                        reason: ShedReason::DeadlineUnmeetable,
                    });
                } else {
                    st.queue.push(q);
                }
            }
            if st.queue.is_empty() {
                // Everything queued was unmeetable; the "batch" is pure
                // rejection work — no forward, no batch counted.
                return Batch {
                    requests: Vec::new(),
                    closed_by: reason,
                    shed,
                };
            }
        }

        let n = st.queue.len();
        let take = p.max_batch.max(1).min(n);
        let bound = p.starvation_bound();
        let starved: Vec<bool> = st
            .queue
            .iter()
            .map(|q| now >= q.arrived.after(bound))
            .collect();
        let urgent: Vec<bool> = st
            .queue
            .iter()
            .zip(&starved)
            .map(|(q, &s)| s || q.deadline_expired(now))
            .collect();

        let mut order: Vec<usize> = (0..n).collect();
        order.sort_by_key(|&i| {
            let q = &st.queue[i];
            (!urgent[i], q.req.priority.rank(), q.arrived, q.seq)
        });
        order.truncate(take);

        // Promotions: selected urgent members that a pure (priority,
        // arrival) cut of the same size would have left out. Membership
        // in that cut is tested through a bitvec — a linear scan per
        // member (`by_prio.contains`) is quadratic per close exactly
        // when the queue is deep under overload.
        let mut promotions = 0u64;
        let mut starved_promoted = false;
        if n > take {
            let mut by_prio: Vec<usize> = (0..n).collect();
            by_prio.sort_by_key(|&i| {
                let q = &st.queue[i];
                (q.req.priority.rank(), q.arrived, q.seq)
            });
            by_prio.truncate(take);
            let mut in_prio_cut = vec![false; n];
            for &i in &by_prio {
                in_prio_cut[i] = true;
            }
            for &i in &order {
                if urgent[i] && !in_prio_cut[i] {
                    promotions += 1;
                    if starved[i] {
                        starved_promoted = true;
                    }
                }
            }
        }

        let mut rank_of = vec![usize::MAX; n];
        for (rank, &i) in order.iter().enumerate() {
            rank_of[i] = rank;
        }
        let queue = std::mem::take(&mut st.queue);
        let mut picked: Vec<(usize, InferenceRequest)> = Vec::with_capacity(take);
        for (i, q) in queue.into_iter().enumerate() {
            if rank_of[i] != usize::MAX {
                picked.push((rank_of[i], q.req));
            } else {
                st.queue.push(q);
            }
        }
        picked.sort_by_key(|(rank, _)| *rank);

        st.stats.batches += 1;
        st.stats.starvation_promotions += promotions;
        let closed_by = if starved_promoted {
            CloseReason::Starvation
        } else {
            reason
        };
        Batch {
            requests: picked.into_iter().map(|(_, r)| r).collect(),
            closed_by,
            shed,
        }
    }
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used, clippy::panic)]

    use super::super::clock::VirtualClock;
    use super::super::request::Priority;
    use super::*;

    fn ms(x: u64) -> Duration {
        Duration::from_millis(x)
    }

    fn req(id: u64) -> InferenceRequest {
        InferenceRequest::new(id, vec![0], vec![])
    }

    fn sched(max_batch: usize, max_wait_ms: u64, k: u32) -> Scheduler<VirtualClock> {
        Scheduler::new(
            VirtualClock::new(),
            BatchPolicy {
                max_batch,
                max_wait: ms(max_wait_ms),
                starvation_factor: k,
                adaptive: None,
                admission: None,
            },
        )
    }

    fn adaptive_sched(
        max_batch: usize,
        max_wait_ms: u64,
        aw: AdaptiveWait,
    ) -> Scheduler<VirtualClock> {
        Scheduler::new(
            VirtualClock::new(),
            BatchPolicy {
                max_batch,
                max_wait: ms(max_wait_ms),
                starvation_factor: 4,
                adaptive: Some(aw),
                admission: None,
            },
        )
    }

    fn capped_sched(max_batch: usize, ac: AdmissionControl) -> Scheduler<VirtualClock> {
        Scheduler::new(
            VirtualClock::new(),
            BatchPolicy {
                max_batch,
                max_wait: ms(5),
                starvation_factor: 4,
                adaptive: None,
                admission: Some(ac),
            },
        )
    }

    #[test]
    fn fills_to_max_batch_in_fifo_order_at_equal_priority() {
        let s = sched(4, 50, 4);
        for i in 0..10 {
            s.submit(req(i));
        }
        let b = s.poll().unwrap();
        assert_eq!(b.len(), 4);
        assert_eq!(b.closed_by, CloseReason::Size);
        let ids: Vec<u64> = b.requests.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3]);
        assert_eq!(s.pending(), 6);
    }

    #[test]
    fn deadline_closes_partial_batch_without_real_time() {
        let s = sched(100, 10, 4);
        s.submit(req(0));
        assert!(s.poll().is_none(), "no close before the hold deadline");
        s.clock().advance(ms(9));
        assert!(s.poll().is_none());
        s.clock().advance(ms(1));
        let b = s.poll().unwrap();
        assert_eq!(b.len(), 1);
        assert_eq!(b.closed_by, CloseReason::Deadline);
    }

    #[test]
    fn priority_orders_members_within_a_window() {
        let s = sched(8, 5, 4);
        s.submit(req(0).with_priority(Priority::Background));
        s.submit(req(1).with_priority(Priority::Batch));
        s.submit(req(2).with_priority(Priority::Interactive));
        s.submit(req(3).with_priority(Priority::Interactive));
        s.clock().advance(ms(5));
        let b = s.poll().unwrap();
        let ids: Vec<u64> = b.requests.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![2, 3, 1, 0], "priority rank, FIFO within rank");
    }

    #[test]
    fn shutdown_drains_immediately_then_yields_none() {
        let s = sched(8, 1_000_000, 1);
        s.submit(req(0));
        s.submit(req(1));
        s.shutdown();
        // next_batch must not wait out the huge max_wait: drain closes
        // immediately (and this blocking call returns at once).
        let b = s.next_batch().unwrap();
        assert_eq!(b.len(), 2);
        assert_eq!(b.closed_by, CloseReason::Drain);
        assert!(s.next_batch().is_none());
        assert!(s.poll().is_none());
    }

    #[test]
    fn expired_request_closes_immediately() {
        // The old next_batch idle-spin edge: a first member whose
        // deadline is already spent still waited out recv_timeout. A
        // zero hold budget must close at the very tick of admission.
        let s = sched(8, 5, 4);
        s.submit(req(0).with_deadline(Duration::ZERO));
        let b = s.poll().expect("already-expired request must close now");
        assert_eq!(b.closed_by, CloseReason::Deadline);
        assert_eq!(b.len(), 1);
    }

    #[test]
    fn starved_background_is_promoted_over_priority_order() {
        let s = sched(2, 5, 3); // starvation bound = 15 ms
        s.submit(req(0).with_priority(Priority::Background));
        // Flood: two fresh interactive requests per window.
        s.submit(req(1));
        s.submit(req(2));
        let b = s.poll().unwrap();
        assert_eq!(b.closed_by, CloseReason::Size);
        assert!(b.requests.iter().all(|r| r.priority == Priority::Interactive));
        s.clock().advance(ms(15));
        s.submit(req(3));
        s.submit(req(4));
        let b = s.poll().unwrap();
        assert_eq!(b.closed_by, CloseReason::Starvation);
        assert_eq!(b.requests[0].id, 0, "starved member leads the batch");
        assert_eq!(s.stats().starvation_promotions, 1);
    }

    #[test]
    fn stats_count_submissions_and_batches() {
        let s = sched(2, 5, 4);
        for i in 0..5 {
            s.submit(req(i));
        }
        let mut batches = 0;
        while s.poll().is_some() {
            batches += 1;
        }
        assert_eq!(batches, 2, "fifth request still inside its window");
        s.shutdown();
        assert!(s.next_batch().is_some());
        let st = s.stats();
        assert_eq!(st.submitted, 5);
        assert_eq!(st.batches, 3);
    }

    #[test]
    fn adaptive_wait_pins_the_ewma_update() {
        let aw = AdaptiveWait {
            alpha: 0.5,
            min_wait: ms(1),
        };
        let s = adaptive_sched(5, 100, aw);
        // Before two arrivals there is no interval to average: the
        // configured ceiling governs.
        assert_eq!(s.effective_wait(), ms(100));
        s.submit(req(0));
        assert_eq!(s.effective_wait(), ms(100));
        // dt = 4 ms → ewma = 4 ms → hold = 4 ms × (max_batch−1) = 16 ms.
        s.clock().advance(ms(4));
        s.submit(req(1));
        assert_eq!(s.effective_wait(), ms(16));
        // dt = 2 ms → ewma = 0.5·2 + 0.5·4 = 3 ms → hold = 12 ms.
        s.clock().advance(ms(2));
        s.submit(req(2));
        assert_eq!(s.effective_wait(), ms(12));
    }

    #[test]
    fn adaptive_wait_clamps_to_min_and_max() {
        let aw = AdaptiveWait {
            alpha: 1.0,
            min_wait: ms(2),
        };
        // Fast arrivals: 100 µs gaps → raw hold = 0.7 ms → clamps to
        // min_wait, and the deadline close fires at the clamped budget,
        // far before the 50 ms ceiling.
        let s = adaptive_sched(8, 50, aw);
        s.submit(req(0));
        s.clock().advance(Duration::from_micros(100));
        s.submit(req(1));
        assert_eq!(s.effective_wait(), ms(2));
        assert!(s.poll().is_none(), "inside the adaptive budget");
        s.clock().advance(ms(2));
        let b = s.poll().expect("adaptive budget expired for request 0");
        assert_eq!(b.closed_by, CloseReason::Deadline);
        assert_eq!(b.len(), 2);

        // Slow arrivals: 10 s gap → clamps to the configured ceiling.
        let s = adaptive_sched(8, 50, aw);
        s.submit(req(0));
        s.clock().advance(Duration::from_secs(10));
        s.submit(req(1));
        assert_eq!(s.effective_wait(), ms(50));
    }

    #[test]
    fn policy_defaults_are_sane() {
        let p = BatchPolicy::default();
        assert_eq!(p.max_batch, 8);
        assert_eq!(p.starvation_bound(), p.max_wait * 4);
        let p = BatchPolicy {
            starvation_factor: 0,
            ..Default::default()
        };
        assert_eq!(p.starvation_bound(), p.max_wait, "factor clamps to 1");
    }

    #[test]
    fn epoch_gate_waits_for_inflight_batches() {
        let s = std::sync::Arc::new(sched(4, 50, 4));
        // Concurrent batch guards coexist.
        let g1 = s.batch_guard();
        let g2 = s.batch_guard();
        // An epoch boundary cannot be entered while batches execute.
        assert!(s.epoch_gate.try_write().is_err());
        drop(g1);
        assert!(s.epoch_gate.try_write().is_err());
        drop(g2);
        {
            let _fence = s.epoch_guard();
            // While the fence is held, executors are held out...
            assert!(s.epoch_gate.try_read().is_err());
            // ...but admission keeps flowing.
            s.submit(req(0));
            assert_eq!(s.pending(), 1);
        }
        let _g = s.batch_guard();
    }

    /// Promotion accounting must stay correct (and linear) on a deep
    /// queue — the overload regime where the old `by_prio.contains`
    /// scan went quadratic per close. 100 starved background members
    /// against a fresh interactive flood: the priority cut holds only
    /// interactive, so every selected starved member is a promotion.
    #[test]
    fn deep_queue_promotion_accounting_is_exact() {
        let s = sched(4, 5, 2); // starvation bound = 10 ms
        for i in 0..100 {
            s.submit(req(i).with_priority(Priority::Background));
        }
        s.clock().advance(ms(10));
        for i in 100..200 {
            s.submit(req(i));
        }
        let b = s.poll().unwrap();
        assert_eq!(b.closed_by, CloseReason::Starvation);
        assert_eq!(b.len(), 4);
        assert!(b.requests.iter().all(|r| r.priority == Priority::Background && r.id < 4));
        assert_eq!(s.stats().starvation_promotions, 4);
    }

    #[test]
    fn class_cap_tail_drops_the_arrival() {
        let s = capped_sched(
            8,
            AdmissionControl {
                total_cap: 100,
                class_caps: [usize::MAX, usize::MAX, 2],
                early_reject: false,
            },
        );
        for i in 0..2 {
            let out = s.submit(req(i).with_priority(Priority::Background));
            assert!(out.is_admitted());
        }
        let out = s.submit(req(2).with_priority(Priority::Background));
        assert!(!out.is_admitted());
        match out.admission {
            Admission::Shed(sh) => {
                assert_eq!(sh.req.id, 2, "the arrival itself is handed back");
                assert_eq!(sh.reason, ShedReason::QueueFull);
            }
            Admission::Admitted => panic!("class cap must shed"),
        }
        // Other classes are untouched by a full background cap.
        assert!(s.submit(req(3)).is_admitted());
        assert_eq!(s.stats().shed, [0, 0, 1]);
        assert_eq!(s.pending(), 3);
    }

    #[test]
    fn total_cap_evicts_youngest_of_the_worst_class_first() {
        let s = capped_sched(
            8,
            AdmissionControl {
                total_cap: 3,
                class_caps: [usize::MAX; 3],
                early_reject: false,
            },
        );
        s.submit(req(0).with_priority(Priority::Background));
        s.submit(req(1).with_priority(Priority::Background));
        s.submit(req(2).with_priority(Priority::Batch));
        // Interactive arrival: the *youngest background* (id 1) is
        // evicted — not the batch member, not the older background.
        let out = s.submit(req(3));
        assert!(out.is_admitted());
        assert_eq!(out.evicted.len(), 1);
        assert_eq!(out.evicted[0].req.id, 1);
        assert_eq!(out.evicted[0].reason, ShedReason::Evicted);
        // A background arrival at the bound finds no strictly-worse
        // victim: the arrival itself sheds, the queue is untouched.
        let out = s.submit(req(4).with_priority(Priority::Background));
        assert!(!out.is_admitted());
        assert!(out.evicted.is_empty());
        assert_eq!(s.pending(), 3);
        assert_eq!(s.stats().shed_total(), 2);
    }

    #[test]
    fn service_time_ewma_is_pinned() {
        let s = sched(4, 5, 4);
        assert_eq!(s.ewma_service(), None);
        s.record_service(ms(10));
        assert_eq!(s.ewma_service(), Some(ms(10)));
        // ewma ← 0.3·20 + 0.7·10 = 13 ms.
        s.record_service(ms(20));
        assert_eq!(s.ewma_service(), Some(ms(13)));
    }

    #[test]
    fn retry_after_hint_scales_with_backlog() {
        let s = sched(4, 5, 4);
        // No EWMA yet: no hint, clients use their own policy.
        assert_eq!(s.retry_after_hint(), None);
        s.record_service(ms(10));
        // Empty queue: just the batch the retry itself rides.
        assert_eq!(s.retry_after_hint(), Some(ms(10)));
        // 5 queued at max_batch 4 → 2 batches ahead + 1 = 3 × EWMA.
        for id in 0..5 {
            s.submit(req(id));
        }
        assert_eq!(s.retry_after_hint(), Some(ms(30)));
    }

    /// Regression: a thread panicking while it holds the scheduler's
    /// state lock used to poison every later submit/poll into a
    /// coordinator-wide abort. The scheduler now recovers the lock and
    /// keeps serving.
    #[test]
    fn poisoned_state_lock_no_longer_aborts_the_scheduler() {
        let s = std::sync::Arc::new(sched(4, 50, 4));
        s.submit(req(0));
        let s2 = std::sync::Arc::clone(&s);
        let joined = std::thread::spawn(move || {
            let _guard = s2.state.lock().unwrap();
            panic!("poison the scheduler state");
        })
        .join();
        assert!(joined.is_err());
        assert!(s.state.lock().is_err(), "lock must actually be poisoned");
        for i in 1..4 {
            s.submit(req(i));
        }
        let b = s.poll().expect("scheduler still drains after poisoning");
        assert_eq!(b.len(), 4);
        let ids: Vec<u64> = b.requests.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3]);
    }

}
