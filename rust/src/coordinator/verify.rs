//! Online GCN-ABFT verification of runtime outputs.
//!
//! Every accelerator pass returns, alongside the logits, the per-layer
//! fused predicted checksums (`s_c·H·w_r`, computed in-graph) and the
//! in-graph actual checksums. The coordinator checks:
//!
//! 1. per layer: `|pred[ℓ] − actual[ℓ]| ≤ τ·scale` — the GCN-ABFT check
//!    proper, covering the accelerator's matmul datapath;
//! 2. end-to-end: `|pred[1] − Σ logits(host)| ≤ τ·scale` — re-summing the
//!    logits *after* they crossed the runtime boundary extends coverage
//!    to transfer/memory corruption of the response payload.
//!
//! The XLA data path is f32, so τ here is a relative tolerance sized to
//! f32 accumulation noise (default 1e-3 relative) — unlike the f64
//! fault-injection engine where the paper's absolute thresholds apply
//! (DESIGN.md §6).
//!
//! Verification is strictly a *fault* verdict: a fired check yields
//! `VerifyStatus::Failed` (or `RecoveredAfterRetry`). Requests refused
//! by admission control never reach this module — they are answered
//! `VerifyStatus::Shed` before any forward runs, keeping the
//! availability taxonomy (shed) disjoint from the correctness taxonomy
//! (failed) end to end.

use crate::runtime::GcnOutputs;

/// Verification policy for the f32 serving path.
#[derive(Debug, Clone, Copy)]
pub struct ServePolicy {
    /// Relative tolerance: a check fires when
    /// `|pred − actual| > rel_tol · max(1, |actual|)`.
    pub rel_tol: f64,
}

impl Default for ServePolicy {
    fn default() -> Self {
        Self { rel_tol: 1e-3 }
    }
}

/// Result of verifying one accelerator pass.
#[derive(Debug, Clone, PartialEq)]
pub struct VerifyReport {
    /// Per-layer in-graph check residuals (relative).
    pub layer_residuals: Vec<f64>,
    /// Host-side logits checksum residual (relative).
    pub host_residual: f64,
    /// Overall verdict.
    pub ok: bool,
}

impl ServePolicy {
    fn fires(&self, predicted: f64, actual: f64) -> bool {
        let scale = actual.abs().max(1.0);
        !((predicted - actual).abs() <= self.rel_tol * scale)
    }

    fn residual(&self, predicted: f64, actual: f64) -> f64 {
        let scale = actual.abs().max(1.0);
        (predicted - actual).abs() / scale
    }

    /// Verify one pass.
    pub fn verify(&self, out: &GcnOutputs) -> VerifyReport {
        let mut ok = true;
        let mut layer_residuals = Vec::with_capacity(out.predicted.len());
        for (p, a) in out.predicted.iter().zip(&out.actual) {
            layer_residuals.push(self.residual(*p as f64, *a as f64));
            if self.fires(*p as f64, *a as f64) {
                ok = false;
            }
        }
        // Host-side re-sum of the logits against the final layer's
        // prediction (f64 accumulation host-side).
        let host_sum: f64 = out.logits.data().iter().map(|&x| x as f64).sum();
        let pred_last = *out.predicted.last().unwrap_or(&0.0) as f64;
        let host_residual = self.residual(pred_last, host_sum);
        if self.fires(pred_last, host_sum) {
            ok = false;
        }
        VerifyReport {
            layer_residuals,
            host_residual,
            ok,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Dense;

    fn clean_outputs() -> GcnOutputs {
        let logits = Dense::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        GcnOutputs {
            predicted: vec![5.0, 10.0],
            actual: vec![5.0, 10.0],
            logits,
        }
    }

    #[test]
    fn clean_pass_verifies() {
        let r = ServePolicy::default().verify(&clean_outputs());
        assert!(r.ok, "{r:?}");
        assert!(r.layer_residuals.iter().all(|&x| x < 1e-6));
        assert!(r.host_residual < 1e-6);
    }

    #[test]
    fn layer_mismatch_fails() {
        let mut o = clean_outputs();
        o.actual[0] = 5.2;
        let r = ServePolicy::default().verify(&o);
        assert!(!r.ok);
    }

    #[test]
    fn host_corruption_detected() {
        let mut o = clean_outputs();
        // Corrupt a logit after the in-graph checksums were computed:
        // in-graph pred/actual still agree, but the host re-sum breaks.
        o.logits.set(0, 0, 100.0);
        let r = ServePolicy::default().verify(&o);
        assert!(!r.ok);
        assert!(r.host_residual > 0.5);
    }

    #[test]
    fn tolerance_scales_with_magnitude() {
        let p = ServePolicy { rel_tol: 1e-3 };
        let logits = Dense::from_vec(1, 1, vec![10_000.0]);
        let o = GcnOutputs {
            predicted: vec![0.0, 10_000.0],
            actual: vec![0.0, 10_003.0], // 3e-4 relative — inside tol
            logits,
        };
        let r = p.verify(&o);
        assert!(r.ok, "{r:?}");
    }

    #[test]
    fn nan_outputs_fail() {
        let mut o = clean_outputs();
        o.actual[1] = f32::NAN;
        assert!(!ServePolicy::default().verify(&o).ok);
    }
}
