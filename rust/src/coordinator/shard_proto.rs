//! The shard wire protocol, shared by every stream transport.
//!
//! One frame = `u32` little-endian header length, UTF-8 JSON header,
//! raw payload of `header.payload` bytes; floats cross the wire as raw
//! little-endian bit patterns (never decimal text), which is what keeps
//! remote shards bit-identical to in-process sharding. The frame set
//! (`init`/`ready`/`agg`/`band`/`delta`/`ack`/`shutdown`/`error`) has
//! no unix-specific content, so [`ProcTransport`] (Unix domain sockets)
//! and [`TcpTransport`] (TCP) speak byte-identical protocols by
//! construction: both drive the generic engine in this module over
//! their own `Read + Write` stream type, and the worker side of both
//! is [`serve_shard_connection`]. A change to the codec or the lockstep
//! discipline changes every transport at once — proc and tcp cannot
//! drift.
//!
//! Decoding is **fail-stop, never panic**: every malformed input —
//! truncated frame, oversized length, bit-flipped header, short
//! payload, trailing bytes — surfaces as a typed [`FrameError`], and a
//! shard dying under a frame write surfaces as a typed [`ShardDead`]
//! naming the culprit shard (closing the race where the all-alive
//! pre-check passed but the shard died before the write landed). The
//! supervisor ([`super::supervisor`]) consumes that death through the
//! transport's poisoned per-shard state.
//!
//! [`ProcTransport`]: super::shard::ProcTransport
//! [`TcpTransport`]: super::net::TcpTransport

use crate::runtime::operands::RowBand;
use crate::sparse::Csr;
use crate::tensor::Dense;
use crate::util::json::Json;
use super::clock::{Clock, MonotonicClock};
use anyhow::{anyhow, bail, Result};
use std::io::{Read, Write};

/// Sanity ceiling on frame payloads (covers Nell-scale phases with
/// slack; a corrupt length must not trigger a huge allocation).
pub const MAX_PAYLOAD_BYTES: usize = 1 << 31;
/// Sanity ceiling on frame headers.
pub const MAX_HEADER_BYTES: usize = 1 << 16;

// ---------------------------------------------------------------------
// Typed errors.
// ---------------------------------------------------------------------

/// A malformed or undeliverable frame. Every decode failure is one of
/// these variants — never a panic (lint rule F1 covers this module) and
/// never a silent partial decode, so a corrupt frame can only produce a
/// fail-stop `Failed` response upstream.
#[derive(Debug)]
pub enum FrameError {
    /// The peer closed the stream inside a frame (clean EOF at a frame
    /// boundary is `Ok(None)` from [`read_frame`], not an error).
    ClosedMidFrame,
    /// Payload shorter than the fields it must carry.
    Truncated { have: usize, want: usize },
    /// Payload longer than the fields it must carry.
    TrailingBytes(usize),
    /// Header length field of zero or beyond [`MAX_HEADER_BYTES`].
    BadHeaderLen(usize),
    /// Header bytes that are not UTF-8 JSON.
    BadHeader(String),
    /// Payload length field beyond [`MAX_PAYLOAD_BYTES`].
    BadPayloadLen(usize),
    /// A required header field is absent or not an integer.
    MissingField(&'static str),
    /// A wire index does not fit in `usize`.
    IndexOverflow,
    /// A shipped band whose CSR structure is inconsistent.
    BadBand(String),
    /// The underlying stream failed mid-read.
    Io(std::io::Error),
}

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FrameError::ClosedMidFrame => write!(f, "peer closed mid-frame"),
            FrameError::Truncated { have, want } => {
                write!(f, "frame payload truncated ({have} < {want} bytes)")
            }
            FrameError::TrailingBytes(n) => write!(f, "{n} trailing bytes in frame payload"),
            FrameError::BadHeaderLen(n) => write!(f, "implausible frame header length {n}"),
            FrameError::BadHeader(e) => write!(f, "bad frame header: {e}"),
            FrameError::BadPayloadLen(n) => write!(f, "implausible frame payload length {n}"),
            FrameError::MissingField(key) => write!(f, "frame header missing {key:?}"),
            FrameError::IndexOverflow => write!(f, "index overflows usize"),
            FrameError::BadBand(e) => write!(f, "bad band CSR: {e}"),
            FrameError::Io(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for FrameError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            FrameError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for FrameError {
    fn from(e: std::io::Error) -> FrameError {
        FrameError::Io(e)
    }
}

/// A shard died under the transport: the frame write (or the lockstep
/// reply read) itself failed, naming the culprit shard. This is the
/// typed signal the shard supervisor consumes — the transport poisons
/// the shard's stream when it constructs one of these, so
/// `ShardTransport::probe` reports the death on the next tick even if
/// the error string never leaves the executor.
#[derive(Debug, Clone)]
pub struct ShardDead {
    pub shard: usize,
    pub detail: String,
}

impl std::fmt::Display for ShardDead {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "shard {} died mid-request ({})", self.shard, self.detail)
    }
}

impl std::error::Error for ShardDead {}

// ---------------------------------------------------------------------
// Payload codec.
// ---------------------------------------------------------------------

/// Append `f32`s to a payload as raw little-endian bit patterns.
pub fn push_f32s(buf: &mut Vec<u8>, xs: &[f32]) {
    for &x in xs {
        buf.extend_from_slice(&x.to_le_bytes());
    }
}

/// Append `f64`s to a payload as raw little-endian bit patterns.
pub fn push_f64s(buf: &mut Vec<u8>, xs: &[f64]) {
    for &x in xs {
        buf.extend_from_slice(&x.to_le_bytes());
    }
}

/// Append indices to a payload as little-endian `u64`s.
pub fn push_u64s(buf: &mut Vec<u8>, xs: &[usize]) {
    for &x in xs {
        buf.extend_from_slice(&(x as u64).to_le_bytes());
    }
}

/// Sequential reader over a frame payload. Every accessor is length-
/// checked: short payloads yield [`FrameError::Truncated`], and
/// [`Wire::done`] rejects trailing bytes, so a decoded frame is exactly
/// its declared fields or a typed error.
pub struct Wire<'a>(pub &'a [u8]);

impl<'a> Wire<'a> {
    fn chunk(&mut self, bytes: usize) -> Result<&'a [u8], FrameError> {
        if self.0.len() < bytes {
            return Err(FrameError::Truncated {
                have: self.0.len(),
                want: bytes,
            });
        }
        let (head, tail) = self.0.split_at(bytes);
        self.0 = tail;
        Ok(head)
    }

    pub fn f32s(&mut self, n: usize) -> Result<Vec<f32>, FrameError> {
        let raw = self.chunk(n * 4)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    pub fn f64s(&mut self, n: usize) -> Result<Vec<f64>, FrameError> {
        let raw = self.chunk(n * 8)?;
        Ok(raw
            .chunks_exact(8)
            .map(|c| f64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]]))
            .collect())
    }

    pub fn f64(&mut self) -> Result<f64, FrameError> {
        Ok(self.f64s(1)?[0])
    }

    pub fn usizes(&mut self, n: usize) -> Result<Vec<usize>, FrameError> {
        let raw = self.chunk(n * 8)?;
        raw.chunks_exact(8)
            .map(|c| {
                let raw = u64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]]);
                usize::try_from(raw).map_err(|_| FrameError::IndexOverflow)
            })
            .collect()
    }

    pub fn done(&self) -> Result<(), FrameError> {
        if !self.0.is_empty() {
            return Err(FrameError::TrailingBytes(self.0.len()));
        }
        Ok(())
    }
}

/// Encode one frame: header length, JSON header, raw payload. The
/// header's `payload` field must equal `payload.len()`.
pub fn encode_frame(header: &Json, payload: &[u8]) -> Vec<u8> {
    let h = header.to_string();
    let mut buf = Vec::with_capacity(4 + h.len() + payload.len());
    buf.extend_from_slice(&(h.len() as u32).to_le_bytes());
    buf.extend_from_slice(h.as_bytes());
    buf.extend_from_slice(payload);
    buf
}

/// Read one frame. `Ok(None)` on clean EOF at a frame boundary (the
/// peer hung up between requests); every other failure mode is a typed
/// [`FrameError`].
pub fn read_frame(r: &mut impl Read) -> Result<Option<(Json, Vec<u8>)>, FrameError> {
    let mut len4 = [0u8; 4];
    // Distinguish "no next frame" from "died mid-frame".
    let mut filled = 0;
    while filled < 4 {
        match r.read(&mut len4[filled..]) {
            Ok(0) if filled == 0 => return Ok(None),
            Ok(0) => return Err(FrameError::ClosedMidFrame),
            Ok(n) => filled += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(FrameError::Io(e)),
        }
    }
    let hlen = u32::from_le_bytes(len4) as usize;
    if hlen == 0 || hlen > MAX_HEADER_BYTES {
        return Err(FrameError::BadHeaderLen(hlen));
    }
    let mut hbuf = vec![0u8; hlen];
    r.read_exact(&mut hbuf)?;
    let text = std::str::from_utf8(&hbuf)
        .map_err(|e| FrameError::BadHeader(e.to_string()))?;
    let header = Json::parse(text).map_err(|e| FrameError::BadHeader(e.to_string()))?;
    let plen = header.get("payload").and_then(Json::as_usize).unwrap_or(0);
    if plen > MAX_PAYLOAD_BYTES {
        return Err(FrameError::BadPayloadLen(plen));
    }
    let mut payload = vec![0u8; plen];
    r.read_exact(&mut payload)?;
    Ok(Some((header, payload)))
}

/// A required integer header field.
pub fn header_field(h: &Json, key: &'static str) -> Result<usize, FrameError> {
    h.get(key)
        .and_then(Json::as_usize)
        .ok_or(FrameError::MissingField(key))
}

// ---------------------------------------------------------------------
// Band frames (init / delta / band replies).
// ---------------------------------------------------------------------

/// Encode an `init` or `delta` frame carrying one band of `S` plus its
/// cached `s_c` — the two frame types share the payload layout, so a
/// worker's resident band is replaced by exactly the bytes the
/// coordinator would have shipped at spawn.
pub fn encode_band_frame(kind: &str, shard: usize, band: &RowBand) -> Vec<u8> {
    let mut payload =
        Vec::with_capacity((band.s.rows() + 1) * 8 + band.s.nnz() * 12 + band.s_c.len() * 8);
    push_u64s(&mut payload, band.s.row_ptr());
    push_u64s(&mut payload, band.s.col_idx());
    push_f32s(&mut payload, band.s.values());
    push_f64s(&mut payload, &band.s_c);
    let header = Json::obj(vec![
        ("type", Json::from(kind)),
        ("shard", Json::from(shard)),
        ("row0", Json::from(band.row0)),
        ("rows", Json::from(band.s.rows())),
        ("cols", Json::from(band.s.cols())),
        ("nnz", Json::from(band.s.nnz())),
        ("payload", Json::from(payload.len())),
    ]);
    encode_frame(&header, &payload)
}

/// Parse the band carried by an `init` or `delta` frame into the
/// worker's resident form: `(rows, cols, band-with-local-row0)`.
pub fn parse_band_frame(hdr: &Json, body: &[u8]) -> Result<(usize, usize, RowBand), FrameError> {
    let rows = header_field(hdr, "rows")?;
    let cols = header_field(hdr, "cols")?;
    let nnz = header_field(hdr, "nnz")?;
    let mut wire = Wire(body);
    let row_ptr = wire.usizes(rows + 1)?;
    let col_idx = wire.usizes(nnz)?;
    let values = wire.f32s(nnz)?;
    let s_c = wire.f64s(cols)?;
    wire.done()?;
    let band = RowBand {
        // Local band coordinates; the coordinator owns the global row
        // offset for stitching.
        row0: 0,
        s: Csr::from_raw_parts(rows, cols, row_ptr, col_idx, values)
            .map_err(|e| FrameError::BadBand(e.to_string()))?,
        s_c,
    };
    Ok((rows, cols, band))
}

/// Ship one mutated band to its worker and wait for the ack — the same
/// lockstep discipline as `agg`/`band`, so any failure names the
/// culprit shard.
pub(crate) fn ship_band_delta<S: Read + Write>(
    stream: &mut S,
    shard: usize,
    band: &RowBand,
) -> Result<()> {
    stream.write_all(&encode_band_frame("delta", shard, band))?;
    let (ack, _) = read_frame(stream)?.ok_or_else(|| anyhow!("hung up"))?;
    match ack.get("type").and_then(Json::as_str) {
        Some("ack") => Ok(()),
        Some("error") => bail!(
            "worker reported: {}",
            ack.get("msg").and_then(Json::as_str).unwrap_or("?")
        ),
        other => bail!("unexpected frame type {other:?}"),
    }
}

/// Read and fully validate one `band` reply: `(z rows, pred, actual)`.
/// Every failure mode — EOF, wire error, worker-reported error, wrong
/// frame type, mismatched shape, short payload — is an `Err`, so the
/// caller poisons the shard on any of them.
pub(crate) fn read_band_reply<S: Read>(
    stream: &mut S,
    rows: usize,
    width: usize,
) -> Result<(Vec<f32>, f64, f64)> {
    let (hdr, body) = read_frame(stream)?.ok_or_else(|| anyhow!("hung up"))?;
    match hdr.get("type").and_then(Json::as_str) {
        Some("band") => {}
        Some("error") => {
            bail!(
                "worker reported: {}",
                hdr.get("msg").and_then(Json::as_str).unwrap_or("?")
            );
        }
        other => bail!("unexpected frame type {other:?}"),
    }
    if header_field(&hdr, "rows")? != rows || header_field(&hdr, "cols")? != width {
        bail!("mismatched band shape");
    }
    let mut wire = Wire(&body);
    let z = wire.f32s(rows * width)?;
    let p = wire.f64()?;
    let a = wire.f64()?;
    wire.done()?;
    Ok((z, p, a))
}

/// Write `init` for `band` and collect the `ready` handshake, returning
/// the pid the worker echoed (accept/connect order is arbitrary on some
/// transports, so the pid pairs connections with spawned children).
pub(crate) fn init_handshake<S: Read + Write>(
    stream: &mut S,
    shard: usize,
    band: &RowBand,
) -> Result<usize> {
    stream.write_all(&encode_band_frame("init", shard, band))?;
    let (ready, _) =
        read_frame(stream)?.ok_or_else(|| anyhow!("shard {shard} hung up during init"))?;
    if ready.get("type").and_then(Json::as_str) != Some("ready") {
        bail!("shard {shard} sent {:?} instead of ready", ready.to_string());
    }
    Ok(header_field(&ready, "pid")?)
}

// ---------------------------------------------------------------------
// The generic lockstep engine (coordinator side).
// ---------------------------------------------------------------------

/// Coordinator-side view of one remote shard over any stream type: the
/// connection (poisoned to `None` the instant any frame I/O on it
/// fails) plus the global row window its resident band covers.
#[derive(Debug)]
pub(crate) struct RemoteShard<S> {
    /// `None` once the shard is known dead.
    pub stream: Option<S>,
    pub row0: usize,
    pub rows: usize,
}

/// One stitched aggregation phase.
pub(crate) struct AggregateStitch {
    pub out: Dense,
    pub pred: f64,
    pub actual: f64,
    /// Per-shard seconds the stitcher spent blocked on the reply.
    pub waits: Vec<f64>,
    pub stitch_secs: f64,
}

/// One `z = S·x` phase over remote shards, request/reply lockstep:
/// stream the shared `agg` frame to every shard concurrently, then
/// collect band replies in band order and stitch (row concat + partial
/// checksum sums). ANY failure — a send landing on a just-died shard,
/// a wire error, a malformed reply — poisons that shard's stream and
/// returns a typed [`ShardDead`], so the all-alive pre-check can never
/// race a death into a half-streamed request whose stale replies desync
/// a later stitch. Both the proc and tcp transports are this function
/// over their own stream type.
pub(crate) fn aggregate_remote<S: Read + Write + Send>(
    links: &mut [&mut RemoteShard<S>],
    n: usize,
    x: &Dense,
    x_r: &[f32],
    clock: &MonotonicClock,
) -> Result<AggregateStitch> {
    let width = x.cols();
    let mut payload = Vec::with_capacity(x.data().len() * 4 + x_r.len() * 4);
    push_f32s(&mut payload, x.data());
    push_f32s(&mut payload, x_r);
    let header = Json::obj(vec![
        ("type", Json::from("agg")),
        ("rows", Json::from(x.rows())),
        ("cols", Json::from(width)),
        ("payload", Json::from(payload.len())),
    ]);
    let frame = encode_frame(&header, &payload);

    // Nothing is sent unless every shard is believed alive: a request
    // half-streamed before discovering a known-dead shard would leave
    // orphan replies queued in the healthy workers' sockets. The check
    // is advisory (a shard can still die under the writes below — that
    // race is closed by the typed per-write errors), but it keeps the
    // common known-dead case from touching the wire at all.
    for (k, sh) in links.iter().enumerate() {
        if sh.stream.is_none() {
            bail!("shard {k} is down");
        }
    }
    // Phase 1: stream the request to every shard, concurrently —
    // sequential sends would add (shards−1) × transfer-time of pure
    // latency on wide phases (Nell's X₂ is ~60 MB). One shared frame
    // buffer; a worker only writes after reading a full request, so
    // sends cannot deadlock against replies.
    let send_errs: Vec<Option<String>> = std::thread::scope(|scope| {
        let handles: Vec<_> = links
            .iter_mut()
            .map(|sh| {
                let frame = &frame;
                // Alive per the pre-check above; a None here is
                // recorded as a dead send rather than a panic.
                sh.stream.as_mut().map(|stream| {
                    scope.spawn(move || stream.write_all(frame).err().map(|e| e.to_string()))
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| match h {
                None => Some("shard stream missing".to_string()),
                Some(h) => h
                    .join()
                    .unwrap_or_else(|_| Some("send thread panicked".to_string())),
            })
            .collect()
    });
    let mut first_dead: Option<ShardDead> = None;
    for (k, err) in send_errs.into_iter().enumerate() {
        if let Some(detail) = err {
            links[k].stream = None;
            if first_dead.is_none() {
                first_dead = Some(ShardDead { shard: k, detail });
            }
        }
    }
    if let Some(dead) = first_dead {
        return Err(dead.into());
    }
    // Phase 2: collect band results in band order and stitch. ANY
    // reply-side failure — wire error, malformed frame, short payload —
    // permanently poisons the shard: with it marked down, the pre-check
    // blocks every later aggregate, so a stale queued reply can never
    // be stitched into a subsequent forward (the lockstep/desync
    // guarantee).
    let mut out = Dense::zeros(n, width);
    let mut pred = 0f64;
    let mut actual = 0f64;
    let mut waits = vec![0f64; links.len()];
    let mut stitch = 0f64;
    for (k, sh) in links.iter_mut().enumerate() {
        let t0 = clock.now();
        let Some(stream) = sh.stream.as_mut() else {
            bail!("shard {k} is down");
        };
        let reply = read_band_reply(stream, sh.rows, width);
        waits[k] = clock.now().since(t0).as_secs_f64();
        let (z, p, a) = match reply {
            Ok(v) => v,
            Err(e) => {
                sh.stream = None;
                return Err(ShardDead {
                    shard: k,
                    detail: format!("{e:#}"),
                }
                .into());
            }
        };
        let t1 = clock.now();
        out.data_mut()[sh.row0 * width..(sh.row0 + sh.rows) * width].copy_from_slice(&z);
        pred += p;
        actual += a;
        stitch += clock.now().since(t1).as_secs_f64();
    }
    Ok(AggregateStitch {
        out,
        pred,
        actual,
        waits,
        stitch_secs: stitch,
    })
}

/// Re-ship the mutated bands named by `targets` to their shards, in
/// lockstep (ship, ack, next). A failed re-ship poisons that shard and
/// surfaces a typed [`ShardDead`]; the caller leaves the epoch fence
/// unpublished, so survivors never serve a graph version the fence
/// never published.
pub(crate) fn apply_delta_remote<S: Read + Write>(
    links: &mut [&mut RemoteShard<S>],
    bands: &[RowBand],
    targets: &[usize],
) -> Result<()> {
    // All-alive precheck, like aggregate: re-shipping to a subset while
    // a shard is down would leave the survivors on a newer graph
    // version than the epoch fence ever publishes.
    for (k, sh) in links.iter().enumerate() {
        if sh.stream.is_none() {
            bail!("shard {k} is down");
        }
    }
    for &k in targets {
        let Some(band) = bands.get(k) else {
            bail!("delta outcome names band {k} of {}", bands.len());
        };
        let Some(sh) = links.get_mut(k) else {
            bail!("delta outcome names band {k} of {}", links.len());
        };
        let Some(stream) = sh.stream.as_mut() else {
            bail!("shard {k} is down");
        };
        if let Err(e) = ship_band_delta(stream, k, band) {
            sh.stream = None;
            return Err(ShardDead {
                shard: k,
                detail: format!("delta re-ship failed: {e:#}"),
            }
            .into());
        }
        sh.row0 = band.row0;
        sh.rows = band.s.rows();
    }
    Ok(())
}

// ---------------------------------------------------------------------
// The shared worker loop (worker side).
// ---------------------------------------------------------------------

/// How a worker session over one connection ended.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SessionEnd {
    /// The coordinator sent an explicit `shutdown` frame: the worker
    /// process should exit.
    Shutdown,
    /// The coordinator hung up (EOF at a frame boundary). A
    /// listen-mode worker re-accepts and awaits a fresh `init` — this
    /// is the reconnect half of supervised recovery.
    Hangup,
}

/// Serve one coordinator connection end to end: receive this worker's
/// band of `S` (plus its `s_c`) in the `init` frame, echo `ready` with
/// this process's pid, then answer `agg` and `delta` frames until
/// shutdown or EOF. The band compute is [`RowBand::aggregate_into`] —
/// the identical serial kernel one in-proc band runs — which is what
/// makes every stream transport bit-identical to in-proc sharding.
///
/// Both worker modes are thin wrappers over this: `shard-worker
/// --socket` connects a Unix socket and serves it once; `shard-worker
/// --listen` accepts TCP connections and serves each in turn.
pub fn serve_shard_connection<S: Read + Write>(stream: &mut S) -> Result<SessionEnd> {
    let Some((init, body)) = read_frame(stream)? else {
        // Connected, then hung up before init (e.g. a port probe).
        return Ok(SessionEnd::Hangup);
    };
    if init.get("type").and_then(Json::as_str) != Some("init") {
        bail!("expected init frame, got {}", init.to_string());
    }
    let shard = header_field(&init, "shard")?;
    let (mut rows, mut cols, mut band) =
        parse_band_frame(&init, &body).map_err(|e| anyhow!("bad init frame: {e}"))?;
    let ready = Json::obj(vec![
        ("type", Json::from("ready")),
        ("shard", Json::from(shard)),
        ("pid", Json::from(std::process::id() as usize)),
        ("payload", Json::from(0usize)),
    ]);
    stream.write_all(&encode_frame(&ready, &[]))?;

    loop {
        let Some((hdr, body)) = read_frame(stream)? else {
            return Ok(SessionEnd::Hangup);
        };
        match hdr.get("type").and_then(Json::as_str) {
            Some("shutdown") => return Ok(SessionEnd::Shutdown),
            Some("agg") => {
                if let Err(e) = handle_agg(stream, &band, cols, rows, &hdr, &body) {
                    // Best-effort error frame so the coordinator logs
                    // the cause instead of a bare hang-up.
                    send_error_frame(stream, &e);
                    return Err(e);
                }
            }
            Some("delta") => match parse_band_frame(&hdr, &body) {
                Ok((new_rows, new_cols, new_band)) => {
                    // The new band fully replaces the resident one —
                    // identical bytes to what an `init` at the new
                    // graph version would have shipped, which is what
                    // keeps post-delta serving bit-identical to a
                    // freshly spawned shard tier.
                    rows = new_rows;
                    cols = new_cols;
                    band = new_band;
                    let ack = Json::obj(vec![
                        ("type", Json::from("ack")),
                        ("shard", Json::from(shard)),
                        ("payload", Json::from(0usize)),
                    ]);
                    stream.write_all(&encode_frame(&ack, &[]))?;
                }
                Err(e) => {
                    // A malformed delta must not leave this worker
                    // serving a half-replaced band: report and end the
                    // session (the coordinator poisons the shard on the
                    // failed ack — fail-stop).
                    let e = anyhow::Error::from(e);
                    send_error_frame(stream, &e);
                    return Err(e);
                }
            },
            other => bail!("unexpected frame type {other:?}"),
        }
    }
}

fn send_error_frame<S: Write>(stream: &mut S, e: &anyhow::Error) {
    let msg = format!("{e:#}");
    let err = Json::obj(vec![
        ("type", Json::from("error")),
        ("msg", Json::from(msg.as_str())),
        ("payload", Json::from(0usize)),
    ]);
    let _ = stream.write_all(&encode_frame(&err, &[]));
}

/// One `agg` request: validate, aggregate the band, reply.
fn handle_agg<S: Write>(
    stream: &mut S,
    band: &RowBand,
    cols: usize,
    rows: usize,
    hdr: &Json,
    body: &[u8],
) -> Result<()> {
    let n = header_field(hdr, "rows")?;
    let width = header_field(hdr, "cols")?;
    if n != cols {
        bail!("agg frame rows {n} != band cols {cols}");
    }
    let mut wire = Wire(body);
    let x = Dense::from_vec(n, width, wire.f32s(n * width)?);
    let x_r = wire.f32s(n)?;
    wire.done()?;
    let mut z = vec![0f32; rows * width];
    let (pred, actual) = band.aggregate_into(&x, &x_r, &mut z);
    let mut payload = Vec::with_capacity(z.len() * 4 + 16);
    push_f32s(&mut payload, &z);
    push_f64s(&mut payload, &[pred, actual]);
    let reply = Json::obj(vec![
        ("type", Json::from("band")),
        ("rows", Json::from(rows)),
        ("cols", Json::from(width)),
        ("payload", Json::from(payload.len())),
    ]);
    stream.write_all(&encode_frame(&reply, &payload))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]

    use super::*;

    #[test]
    fn frames_round_trip_bit_exactly() {
        let header = Json::obj(vec![
            ("type", Json::from("agg")),
            ("rows", Json::from(3usize)),
            ("cols", Json::from(2usize)),
            ("payload", Json::from(32usize)),
        ]);
        let xs = [1.5f32, -0.0, f32::MIN_POSITIVE, 3.25e-20];
        let ys = [std::f64::consts::PI, -1e-300];
        let mut payload = Vec::new();
        push_f32s(&mut payload, &xs);
        push_f64s(&mut payload, &ys);
        let frame = encode_frame(&header, &payload);
        let mut cursor = std::io::Cursor::new(frame);
        let (h, body) = read_frame(&mut cursor).unwrap().unwrap();
        assert_eq!(h.get("type").and_then(Json::as_str), Some("agg"));
        assert_eq!(header_field(&h, "rows").unwrap(), 3);
        let mut wire = Wire(&body);
        let got32 = wire.f32s(4).unwrap();
        let got64 = wire.f64s(2).unwrap();
        wire.done().unwrap();
        for (a, b) in xs.iter().zip(&got32) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        for (a, b) in ys.iter().zip(&got64) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // Clean EOF at a frame boundary is None, not an error.
        let mut empty = std::io::Cursor::new(Vec::<u8>::new());
        assert!(read_frame(&mut empty).unwrap().is_none());
        // A truncated frame is a typed error.
        let mut trunc = std::io::Cursor::new(vec![9u8, 0, 0]);
        assert!(matches!(
            read_frame(&mut trunc),
            Err(FrameError::ClosedMidFrame)
        ));
    }

    #[test]
    fn decode_failures_are_typed() {
        // Oversized header length.
        let mut buf = Vec::new();
        buf.extend_from_slice(&(u32::MAX).to_le_bytes());
        let mut cur = std::io::Cursor::new(buf);
        assert!(matches!(
            read_frame(&mut cur),
            Err(FrameError::BadHeaderLen(_))
        ));
        // Header bytes that are not JSON.
        let mut buf = Vec::new();
        buf.extend_from_slice(&4u32.to_le_bytes());
        buf.extend_from_slice(b"{{{{");
        let mut cur = std::io::Cursor::new(buf);
        assert!(matches!(read_frame(&mut cur), Err(FrameError::BadHeader(_))));
        // Declared payload longer than the stream.
        let hdr = Json::obj(vec![("type", Json::from("agg")), ("payload", Json::from(64usize))]);
        let frame = encode_frame(&hdr, &[0u8; 8]);
        let mut cur = std::io::Cursor::new(frame);
        assert!(matches!(read_frame(&mut cur), Err(FrameError::Io(_))));
        // Short payloads and trailing bytes in the wire reader.
        let mut wire = Wire(&[0u8; 5]);
        assert!(matches!(
            wire.f32s(2),
            Err(FrameError::Truncated { have: 5, want: 8 })
        ));
        let wire = Wire(&[0u8; 3]);
        assert!(matches!(wire.done(), Err(FrameError::TrailingBytes(3))));
        // Missing header fields.
        let hdr = Json::obj(vec![("type", Json::from("band"))]);
        assert!(matches!(
            header_field(&hdr, "rows"),
            Err(FrameError::MissingField("rows"))
        ));
    }

    #[test]
    fn shard_dead_names_the_shard() {
        let dead = ShardDead {
            shard: 3,
            detail: "broken pipe".into(),
        };
        let msg = dead.to_string();
        assert!(msg.contains("shard 3"), "{msg}");
        assert!(msg.contains("broken pipe"), "{msg}");
        let as_anyhow: anyhow::Error = dead.into();
        assert!(as_anyhow.to_string().contains("shard 3"));
    }
}
