//! Serving metrics: latency histogram + counters.

/// Log-bucketed latency histogram (microsecond resolution, powers of √2).
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    /// bucket i covers [√2^i, √2^(i+1)) microseconds.
    buckets: Vec<u64>,
    samples: Vec<f64>,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        Self {
            buckets: vec![0; 64],
            samples: Vec::new(),
        }
    }

    pub fn record(&mut self, secs: f64) {
        let us = (secs * 1e6).max(1.0);
        let idx = (us.log2() * 2.0).floor().clamp(0.0, 63.0) as usize;
        self.buckets[idx] += 1;
        self.samples.push(secs);
    }

    pub fn count(&self) -> usize {
        self.samples.len()
    }

    /// Exact percentile from retained samples.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.total_cmp(b));
        let idx = ((p / 100.0) * (s.len() - 1) as f64).round() as usize;
        s[idx.min(s.len() - 1)]
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    /// Fold another histogram into this one — used to aggregate the
    /// per-worker (or per-row-band) histograms into the serve-wide one
    /// without a shared lock on the request path.
    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.samples.extend_from_slice(&other.samples);
    }
}

/// Latency percentiles for one [`crate::coordinator::Priority`] class
/// (indexed by `Priority::rank()` in [`ServeMetrics::by_priority`]).
#[derive(Debug, Clone, Copy, Default)]
pub struct PriorityLatency {
    pub requests: u64,
    /// Percentiles in seconds; NaN when the class saw no requests.
    pub p50_secs: f64,
    pub p95_secs: f64,
    pub p99_secs: f64,
}

/// Aggregate serving metrics. The latency percentiles live here
/// directly (filled from the merged per-worker histograms when a serve
/// run finishes), not in a side channel.
#[derive(Debug, Clone, Default)]
pub struct ServeMetrics {
    pub requests: u64,
    pub batches: u64,
    /// Forwards per batch after overlay-equivalence grouping (requests
    /// with identical perturbation sets share one forward, so batching
    /// never changes an answer).
    pub overlay_groups: u64,
    pub executions: u64,
    pub checks_fired: u64,
    pub retries: u64,
    /// Forwards answered `Failed`: verification never passed within the
    /// retry budget, or — fail-stop — the forward could not execute at
    /// all (`shard_failures` separates out the latter when sharded).
    pub failures: u64,
    pub injected_faults: u64,
    /// Requests the scheduler force-included over priority order
    /// (starvation bound or expired per-request deadline).
    pub starvation_promotions: u64,
    /// Shard-tier fail-stop events: forward passes the sharded backend
    /// could not execute — in practice a shard dying mid-request — each
    /// answered with `Failed` responses for the whole batch (never a
    /// silent partial stitch). Always 0 when serving unsharded —
    /// backend errors there count in `failures` only.
    pub shard_failures: u64,
    /// Seconds the shard tier spent blocked on each shard (proc: socket
    /// round-trip; inproc: the band's compute), indexed by shard.
    /// Empty when serving unsharded.
    pub shard_wait_secs: Vec<f64>,
    /// Seconds the shard tier spent stitching band results.
    pub shard_stitch_secs: f64,
    /// Aggregation phases the shard tier executed (2 per forward) —
    /// the divisor that turns the cumulative wait/stitch seconds into
    /// per-phase costs.
    pub shard_aggregates: u64,
    /// Supervised recoveries that re-spawned a worker (or un-poisoned
    /// an in-proc band — the in-process analogue). 0 without
    /// `--supervise`.
    pub shard_respawns: u64,
    /// Supervised recoveries that re-connected to a remote tcp worker
    /// at its known address.
    pub shard_reconnects: u64,
    /// Supervised recoveries served by adopting a pre-shipped
    /// `--warm-standby` worker (zero re-ship bytes).
    pub standby_adoptions: u64,
    /// Requests replayed after their batch died on a shard and the
    /// supervisor healed the tier — each was answered exactly once,
    /// from the post-recovery forward.
    pub replayed_requests: u64,
    /// Wall-clock seconds spent inside recovery (spawn/reconnect +
    /// handshake + band re-ship), summed over all recoveries.
    pub respawn_secs: f64,
    /// The scheduler's effective hold budget at drain, in ms — equals
    /// `--max-wait-ms` unless `--adaptive-wait` tuned it from the
    /// observed arrival rate.
    pub effective_wait_ms: f64,
    /// Final graph epoch at drain (dynamic graphs): the number of
    /// deltas successfully published through the epoch fence.
    pub epoch: u64,
    /// Graph deltas applied during the run (== `epoch` today; kept
    /// separate so a future snapshot-restore can start above 0).
    pub deltas_applied: u64,
    /// Deltas that failed validation or shard routing — each one is
    /// fail-stop (epoch unchanged, serving continues on the old
    /// version), never a partial application.
    pub delta_failures: u64,
    /// Seconds spent inside the epoch fence applying deltas (drain
    /// wait + patch + shard re-ship).
    pub delta_apply_secs: f64,
    pub exec_secs: f64,
    pub verify_secs: f64,
    pub wall_secs: f64,
    /// Request-latency percentiles in seconds (NaN when the finalized
    /// run had no responses; 0 on a default-constructed value).
    pub p50_secs: f64,
    pub p95_secs: f64,
    pub p99_secs: f64,
    /// Per-priority request latencies, indexed by `Priority::rank()`.
    pub by_priority: [PriorityLatency; 3],
}

impl ServeMetrics {
    /// Fill the percentile fields from an aggregated histogram.
    pub fn set_latency_percentiles(&mut self, lat: &LatencyHistogram) {
        self.p50_secs = lat.percentile(50.0);
        self.p95_secs = lat.percentile(95.0);
        self.p99_secs = lat.percentile(99.0);
    }

    /// Fill one priority class's percentiles from its histogram.
    pub fn set_priority_percentiles(&mut self, rank: usize, lat: &LatencyHistogram) {
        self.by_priority[rank] = PriorityLatency {
            requests: lat.count() as u64,
            p50_secs: lat.percentile(50.0),
            p95_secs: lat.percentile(95.0),
            p99_secs: lat.percentile(99.0),
        };
    }
    pub fn throughput_rps(&self) -> f64 {
        self.requests as f64 / self.wall_secs.max(1e-9)
    }

    pub fn mean_batch(&self) -> f64 {
        self.requests as f64 / self.batches.max(1) as f64
    }

    /// Verification overhead as a fraction of execution time — the
    /// serving-path analogue of the paper's "checking cost".
    pub fn verify_overhead(&self) -> f64 {
        self.verify_secs / self.exec_secs.max(1e-12)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_percentiles() {
        let mut h = LatencyHistogram::new();
        for i in 1..=100 {
            h.record(i as f64 * 1e-3);
        }
        assert_eq!(h.count(), 100);
        assert!((h.percentile(50.0) - 0.05).abs() < 0.002);
        assert!((h.percentile(99.0) - 0.099).abs() < 0.002);
        assert!((h.mean() - 0.0505).abs() < 0.001);
    }

    #[test]
    fn empty_histogram_is_nan() {
        let h = LatencyHistogram::new();
        assert!(h.percentile(50.0).is_nan());
        assert!(h.mean().is_nan());
    }

    #[test]
    fn merge_combines_histograms() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        for i in 1..=50 {
            a.record(i as f64 * 1e-3);
        }
        for i in 51..=100 {
            b.record(i as f64 * 1e-3);
        }
        a.merge(&b);
        assert_eq!(a.count(), 100);
        assert!((a.percentile(50.0) - 0.05).abs() < 0.002);
        // Merging into an empty histogram is a copy.
        let mut c = LatencyHistogram::new();
        c.merge(&a);
        assert_eq!(c.count(), 100);
    }

    #[test]
    fn percentiles_surface_in_metrics() {
        let mut h = LatencyHistogram::new();
        for i in 1..=100 {
            h.record(i as f64 * 1e-3);
        }
        let mut m = ServeMetrics::default();
        m.set_latency_percentiles(&h);
        assert!((m.p50_secs - 0.05).abs() < 0.002);
        assert!((m.p99_secs - 0.099).abs() < 0.002);
        assert!(m.p95_secs <= m.p99_secs);
        // No samples -> NaN, matching LatencyHistogram::percentile.
        let mut empty = ServeMetrics::default();
        empty.set_latency_percentiles(&LatencyHistogram::new());
        assert!(empty.p50_secs.is_nan());
    }

    #[test]
    fn per_priority_percentiles_fill_their_slot() {
        let mut h = LatencyHistogram::new();
        for i in 1..=100 {
            h.record(i as f64 * 1e-3);
        }
        let mut m = ServeMetrics::default();
        m.set_priority_percentiles(2, &h);
        assert_eq!(m.by_priority[2].requests, 100);
        assert!((m.by_priority[2].p50_secs - 0.05).abs() < 0.002);
        assert!(m.by_priority[2].p95_secs <= m.by_priority[2].p99_secs);
        // Untouched classes stay at their default.
        assert_eq!(m.by_priority[0].requests, 0);
        // An empty class reports NaN percentiles, matching the
        // serve-wide convention.
        m.set_priority_percentiles(0, &LatencyHistogram::new());
        assert!(m.by_priority[0].p50_secs.is_nan());
    }

    #[test]
    fn metrics_derived_quantities() {
        let m = ServeMetrics {
            requests: 100,
            batches: 25,
            executions: 26,
            exec_secs: 2.0,
            verify_secs: 0.1,
            wall_secs: 4.0,
            ..Default::default()
        };
        assert!((m.throughput_rps() - 25.0).abs() < 1e-9);
        assert!((m.mean_batch() - 4.0).abs() < 1e-9);
        assert!((m.verify_overhead() - 0.05).abs() < 1e-9);
    }
}
