//! Serving metrics: latency histogram + counters.

/// Log-bucketed latency histogram (microsecond resolution, powers of √2).
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    /// bucket i covers [√2^i, √2^(i+1)) microseconds.
    buckets: Vec<u64>,
    samples: Vec<f64>,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        Self {
            buckets: vec![0; 64],
            samples: Vec::new(),
        }
    }

    pub fn record(&mut self, secs: f64) {
        let us = (secs * 1e6).max(1.0);
        let idx = (us.log2() * 2.0).floor().clamp(0.0, 63.0) as usize;
        self.buckets[idx] += 1;
        self.samples.push(secs);
    }

    pub fn count(&self) -> usize {
        self.samples.len()
    }

    /// Exact percentile from retained samples.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((p / 100.0) * (s.len() - 1) as f64).round() as usize;
        s[idx.min(s.len() - 1)]
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }
}

/// Aggregate serving metrics.
#[derive(Debug, Clone, Default)]
pub struct ServeMetrics {
    pub requests: u64,
    pub batches: u64,
    pub executions: u64,
    pub checks_fired: u64,
    pub retries: u64,
    pub failures: u64,
    pub injected_faults: u64,
    pub exec_secs: f64,
    pub verify_secs: f64,
    pub wall_secs: f64,
}

impl ServeMetrics {
    pub fn throughput_rps(&self) -> f64 {
        self.requests as f64 / self.wall_secs.max(1e-9)
    }

    pub fn mean_batch(&self) -> f64 {
        self.requests as f64 / self.batches.max(1) as f64
    }

    /// Verification overhead as a fraction of execution time — the
    /// serving-path analogue of the paper's "checking cost".
    pub fn verify_overhead(&self) -> f64 {
        self.verify_secs / self.exec_secs.max(1e-12)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_percentiles() {
        let mut h = LatencyHistogram::new();
        for i in 1..=100 {
            h.record(i as f64 * 1e-3);
        }
        assert_eq!(h.count(), 100);
        assert!((h.percentile(50.0) - 0.05).abs() < 0.002);
        assert!((h.percentile(99.0) - 0.099).abs() < 0.002);
        assert!((h.mean() - 0.0505).abs() < 0.001);
    }

    #[test]
    fn empty_histogram_is_nan() {
        let h = LatencyHistogram::new();
        assert!(h.percentile(50.0).is_nan());
        assert!(h.mean().is_nan());
    }

    #[test]
    fn metrics_derived_quantities() {
        let m = ServeMetrics {
            requests: 100,
            batches: 25,
            executions: 26,
            exec_secs: 2.0,
            verify_secs: 0.1,
            wall_secs: 4.0,
            ..Default::default()
        };
        assert!((m.throughput_rps() - 25.0).abs() < 1e-9);
        assert!((m.mean_batch() - 4.0).abs() < 1e-9);
        assert!((m.verify_overhead() - 0.05).abs() < 1e-9);
    }
}
