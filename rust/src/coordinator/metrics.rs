//! Serving metrics: latency histogram + counters.

/// Log-bucketed latency histogram (microsecond resolution, powers of √2).
///
/// Retained memory is **fixed** — 64 bucket counters plus four scalars
/// (~0.5 KiB) regardless of how many samples are recorded. (It
/// previously also kept every raw sample in a growing `Vec` and
/// re-sorted it per `percentile()` call: an unbounded-memory bug on the
/// same hot path admission control bounds, and O(n log n) per read.)
///
/// **Quantile error bound:** `percentile()` locates the √2-wide bucket
/// the requested rank falls in and interpolates linearly inside it, so
/// the true quantile and the estimate always share a bucket: the
/// relative error is at most `√2 − 1 ≈ 41%` in the worst case, far less
/// in practice, and the estimate is additionally clamped to the exact
/// observed `[min, max]`. `mean()` is exact (running sum), and
/// `merge()` is exact over buckets — merging then reading equals
/// reading a histogram that saw all samples directly.
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    /// bucket i covers [√2^i, √2^(i+1)) microseconds.
    buckets: [u64; 64],
    count: u64,
    /// Running sum of recorded latencies in seconds (exact mean).
    sum_secs: f64,
    /// Exact observed extremes, clamping interpolated percentiles.
    min_secs: f64,
    max_secs: f64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        Self {
            buckets: [0; 64],
            count: 0,
            sum_secs: 0.0,
            min_secs: f64::INFINITY,
            max_secs: f64::NEG_INFINITY,
        }
    }

    pub fn record(&mut self, secs: f64) {
        let us = (secs * 1e6).max(1.0);
        let idx = (us.log2() * 2.0).floor().clamp(0.0, 63.0) as usize;
        self.buckets[idx] += 1;
        self.count += 1;
        self.sum_secs += secs;
        self.min_secs = self.min_secs.min(secs);
        self.max_secs = self.max_secs.max(secs);
    }

    pub fn count(&self) -> usize {
        self.count as usize
    }

    /// Percentile estimated from the √2 log buckets: find the bucket
    /// holding the requested rank, interpolate linearly within it,
    /// clamp to the exact observed `[min, max]`. See the type-level
    /// docs for the error bound.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        let rank = ((p / 100.0) * (self.count - 1) as f64).round() as u64;
        let mut cum = 0u64;
        for (i, &n) in self.buckets.iter().enumerate() {
            if n > 0 && cum + n > rank {
                // Bucket i covers [2^(i/2), 2^((i+1)/2)) µs; place the
                // rank at the midpoint of its in-bucket slot.
                let lower = 2f64.powf(i as f64 / 2.0);
                let upper = 2f64.powf((i as f64 + 1.0) / 2.0);
                let frac = (rank - cum) as f64 + 0.5;
                let us = lower + (frac / n as f64) * (upper - lower);
                return (us * 1e-6).clamp(self.min_secs, self.max_secs);
            }
            cum += n;
        }
        // Unreachable with count > 0 (every sample sits in a bucket),
        // but fail soft with the observed maximum rather than panic.
        self.max_secs
    }

    /// Exact mean (running sum / count).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        self.sum_secs / self.count as f64
    }

    /// Fold another histogram into this one — used to aggregate the
    /// per-worker (or per-row-band) histograms into the serve-wide one
    /// without a shared lock on the request path. Exact over buckets:
    /// counters add, extremes combine, the sum stays exact.
    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum_secs += other.sum_secs;
        self.min_secs = self.min_secs.min(other.min_secs);
        self.max_secs = self.max_secs.max(other.max_secs);
    }
}

/// Latency percentiles for one [`crate::coordinator::Priority`] class
/// (indexed by `Priority::rank()` in [`ServeMetrics::by_priority`]).
#[derive(Debug, Clone, Copy, Default)]
pub struct PriorityLatency {
    pub requests: u64,
    /// Percentiles in seconds; NaN when the class saw no requests.
    pub p50_secs: f64,
    pub p95_secs: f64,
    pub p99_secs: f64,
}

/// Aggregate serving metrics. The latency percentiles live here
/// directly (filled from the merged per-worker histograms when a serve
/// run finishes), not in a side channel.
#[derive(Debug, Clone, Default)]
pub struct ServeMetrics {
    pub requests: u64,
    pub batches: u64,
    /// Forwards per batch after overlay-equivalence grouping (requests
    /// with identical perturbation sets share one forward, so batching
    /// never changes an answer).
    pub overlay_groups: u64,
    pub executions: u64,
    pub checks_fired: u64,
    pub retries: u64,
    /// Forwards answered `Failed`: verification never passed within the
    /// retry budget, or — fail-stop — the forward could not execute at
    /// all (`shard_failures` separates out the latter when sharded).
    pub failures: u64,
    pub injected_faults: u64,
    /// Requests the scheduler force-included over priority order
    /// (starvation bound or expired per-request deadline).
    pub starvation_promotions: u64,
    /// Requests shed by admission control, per priority rank
    /// (`[interactive, batch, background]`): refused at the bounded
    /// queue, evicted for a higher-priority arrival, or rejected early
    /// because their deadline was unmeetable. A `Shed` response is an
    /// availability outcome, counted apart from `failures` (fault
    /// detection) and excluded from the served-latency histograms —
    /// `requests`/`throughput_rps` keep measuring *goodput*.
    pub shed: [u64; 3],
    /// Shard-tier fail-stop events: forward passes the sharded backend
    /// could not execute — in practice a shard dying mid-request — each
    /// answered with `Failed` responses for the whole batch (never a
    /// silent partial stitch). Always 0 when serving unsharded —
    /// backend errors there count in `failures` only.
    pub shard_failures: u64,
    /// Seconds the shard tier spent blocked on each shard (proc: socket
    /// round-trip; inproc: the band's compute), indexed by shard.
    /// Empty when serving unsharded.
    pub shard_wait_secs: Vec<f64>,
    /// Seconds the shard tier spent stitching band results.
    pub shard_stitch_secs: f64,
    /// Aggregation phases the shard tier executed (2 per forward) —
    /// the divisor that turns the cumulative wait/stitch seconds into
    /// per-phase costs.
    pub shard_aggregates: u64,
    /// Supervised recoveries that re-spawned a worker (or un-poisoned
    /// an in-proc band — the in-process analogue). 0 without
    /// `--supervise`.
    pub shard_respawns: u64,
    /// Supervised recoveries that re-connected to a remote tcp worker
    /// at its known address.
    pub shard_reconnects: u64,
    /// Supervised recoveries served by adopting a pre-shipped
    /// `--warm-standby` worker (zero re-ship bytes).
    pub standby_adoptions: u64,
    /// Requests replayed after their batch died on a shard and the
    /// supervisor healed the tier — each was answered exactly once,
    /// from the post-recovery forward.
    pub replayed_requests: u64,
    /// Wall-clock seconds spent inside recovery (spawn/reconnect +
    /// handshake + band re-ship), summed over all recoveries.
    pub respawn_secs: f64,
    /// The scheduler's effective hold budget at drain, in ms — equals
    /// `--max-wait-ms` unless `--adaptive-wait` tuned it from the
    /// observed arrival rate.
    pub effective_wait_ms: f64,
    /// Final graph epoch at drain (dynamic graphs): the number of
    /// deltas successfully published through the epoch fence.
    pub epoch: u64,
    /// Graph deltas applied during the run (== `epoch` today; kept
    /// separate so a future snapshot-restore can start above 0).
    pub deltas_applied: u64,
    /// Deltas that failed validation or shard routing — each one is
    /// fail-stop (epoch unchanged, serving continues on the old
    /// version), never a partial application.
    pub delta_failures: u64,
    /// Seconds spent inside the epoch fence applying deltas (drain
    /// wait + patch + shard re-ship).
    pub delta_apply_secs: f64,
    /// The concrete checksum scheme the run executed. A configured
    /// `auto` is resolved against the (backend, operand shapes) before
    /// serving starts — this records the decision the run actually
    /// used. Empty on a default-constructed value.
    pub scheme: &'static str,
    /// The kernel dispatch the forwards ran under
    /// ([`crate::tensor::kernels::active`] at drain): `"scalar"` or
    /// `"x8"`. Empty on a default-constructed value.
    pub kernel: &'static str,
    pub exec_secs: f64,
    pub verify_secs: f64,
    pub wall_secs: f64,
    /// Request-latency percentiles in seconds (NaN when the finalized
    /// run had no responses; 0 on a default-constructed value).
    pub p50_secs: f64,
    pub p95_secs: f64,
    pub p99_secs: f64,
    /// Per-priority request latencies, indexed by `Priority::rank()`.
    pub by_priority: [PriorityLatency; 3],
}

impl ServeMetrics {
    /// Fill the percentile fields from an aggregated histogram.
    pub fn set_latency_percentiles(&mut self, lat: &LatencyHistogram) {
        self.p50_secs = lat.percentile(50.0);
        self.p95_secs = lat.percentile(95.0);
        self.p99_secs = lat.percentile(99.0);
    }

    /// Fill one priority class's percentiles from its histogram.
    pub fn set_priority_percentiles(&mut self, rank: usize, lat: &LatencyHistogram) {
        self.by_priority[rank] = PriorityLatency {
            requests: lat.count() as u64,
            p50_secs: lat.percentile(50.0),
            p95_secs: lat.percentile(95.0),
            p99_secs: lat.percentile(99.0),
        };
    }
    pub fn throughput_rps(&self) -> f64 {
        self.requests as f64 / self.wall_secs.max(1e-9)
    }

    /// Total requests shed across all priority classes.
    pub fn shed_total(&self) -> u64 {
        self.shed.iter().sum()
    }

    pub fn mean_batch(&self) -> f64 {
        self.requests as f64 / self.batches.max(1) as f64
    }

    /// Verification overhead as a fraction of execution time — the
    /// serving-path analogue of the paper's "checking cost".
    pub fn verify_overhead(&self) -> f64 {
        self.verify_secs / self.exec_secs.max(1e-12)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_percentiles() {
        let mut h = LatencyHistogram::new();
        for i in 1..=100 {
            h.record(i as f64 * 1e-3);
        }
        assert_eq!(h.count(), 100);
        assert!((h.percentile(50.0) - 0.05).abs() < 0.002);
        assert!((h.percentile(99.0) - 0.099).abs() < 0.002);
        assert!((h.mean() - 0.0505).abs() < 0.001);
    }

    #[test]
    fn empty_histogram_is_nan() {
        let h = LatencyHistogram::new();
        assert!(h.percentile(50.0).is_nan());
        assert!(h.mean().is_nan());
    }

    #[test]
    fn merge_combines_histograms() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        for i in 1..=50 {
            a.record(i as f64 * 1e-3);
        }
        for i in 51..=100 {
            b.record(i as f64 * 1e-3);
        }
        a.merge(&b);
        assert_eq!(a.count(), 100);
        assert!((a.percentile(50.0) - 0.05).abs() < 0.002);
        // Merging into an empty histogram is a copy.
        let mut c = LatencyHistogram::new();
        c.merge(&a);
        assert_eq!(c.count(), 100);
    }

    #[test]
    fn percentiles_surface_in_metrics() {
        let mut h = LatencyHistogram::new();
        for i in 1..=100 {
            h.record(i as f64 * 1e-3);
        }
        let mut m = ServeMetrics::default();
        m.set_latency_percentiles(&h);
        assert!((m.p50_secs - 0.05).abs() < 0.002);
        assert!((m.p99_secs - 0.099).abs() < 0.002);
        assert!(m.p95_secs <= m.p99_secs);
        // No samples -> NaN, matching LatencyHistogram::percentile.
        let mut empty = ServeMetrics::default();
        empty.set_latency_percentiles(&LatencyHistogram::new());
        assert!(empty.p50_secs.is_nan());
    }

    #[test]
    fn per_priority_percentiles_fill_their_slot() {
        let mut h = LatencyHistogram::new();
        for i in 1..=100 {
            h.record(i as f64 * 1e-3);
        }
        let mut m = ServeMetrics::default();
        m.set_priority_percentiles(2, &h);
        assert_eq!(m.by_priority[2].requests, 100);
        assert!((m.by_priority[2].p50_secs - 0.05).abs() < 0.002);
        assert!(m.by_priority[2].p95_secs <= m.by_priority[2].p99_secs);
        // Untouched classes stay at their default.
        assert_eq!(m.by_priority[0].requests, 0);
        // An empty class reports NaN percentiles, matching the
        // serve-wide convention.
        m.set_priority_percentiles(0, &LatencyHistogram::new());
        assert!(m.by_priority[0].p50_secs.is_nan());
    }

    /// The histogram's footprint is fixed — recording a million samples
    /// allocates nothing (it is a plain array type: no heap at all).
    #[test]
    fn histogram_memory_is_capped() {
        let mut h = LatencyHistogram::new();
        for i in 0..1_000_000u64 {
            h.record((i % 1000) as f64 * 1e-4);
        }
        assert_eq!(h.count(), 1_000_000);
        assert!(h.percentile(50.0).is_finite());
        // No Vec/Box fields: the whole state is inline, ~0.5 KiB.
        assert!(std::mem::size_of::<LatencyHistogram>() <= 64 * 8 + 64);
    }

    /// Documented quantile error bound: the estimate and the true
    /// quantile share a √2-wide bucket, so the relative error is below
    /// √2 − 1, and the estimate never leaves the observed [min, max].
    #[test]
    fn percentile_error_stays_within_the_bucket_bound() {
        let mut h = LatencyHistogram::new();
        let mut samples: Vec<f64> = (0..500).map(|i| 1e-4 * 1.017f64.powi(i)).collect();
        for &s in &samples {
            h.record(s);
        }
        samples.sort_by(|a, b| a.total_cmp(b));
        for p in [1.0, 25.0, 50.0, 90.0, 95.0, 99.0, 100.0] {
            let exact = samples[((p / 100.0) * 499.0).round() as usize];
            let est = h.percentile(p);
            let rel = (est - exact).abs() / exact;
            assert!(rel < std::f64::consts::SQRT_2 - 1.0, "p{p}: rel err {rel}");
            assert!(est >= samples[0] && est <= samples[499]);
        }
    }

    /// merge() is exact over buckets: a merged histogram reads
    /// identically to one that recorded every sample directly.
    #[test]
    fn merge_is_exact_over_buckets() {
        let mut direct = LatencyHistogram::new();
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        for i in 1..=100 {
            let s = i as f64 * 1.3e-3;
            direct.record(s);
            if i % 2 == 0 {
                a.record(s);
            } else {
                b.record(s);
            }
        }
        a.merge(&b);
        assert_eq!(a.count(), direct.count());
        for p in [10.0, 50.0, 99.0] {
            assert_eq!(a.percentile(p).to_bits(), direct.percentile(p).to_bits());
        }
        // The sums are accumulated in different orders, so the means
        // agree to rounding, not bit-for-bit.
        assert!((a.mean() - direct.mean()).abs() < 1e-12);
    }

    #[test]
    fn shed_counters_are_per_priority() {
        let m = ServeMetrics {
            shed: [1, 2, 40],
            ..Default::default()
        };
        assert_eq!(m.shed_total(), 43);
        assert_eq!(ServeMetrics::default().shed_total(), 0);
    }

    #[test]
    fn metrics_derived_quantities() {
        let m = ServeMetrics {
            requests: 100,
            batches: 25,
            executions: 26,
            exec_secs: 2.0,
            verify_secs: 0.1,
            wall_secs: 4.0,
            ..Default::default()
        };
        assert!((m.throughput_rps() - 25.0).abs() < 1e-9);
        assert!((m.mean_batch() - 4.0).abs() < 1e-9);
        assert!((m.verify_overhead() - 0.05).abs() < 1e-9);
    }
}
