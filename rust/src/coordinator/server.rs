//! The serving coordinator: batcher + executor workers + online
//! verification + metrics.
//!
//! Topology (all std threads; each worker owns its own runtime handle and
//! executable — the realistic analogue of one accelerator per worker, and
//! a hard requirement on the PJRT backend whose handles are not `Send`):
//!
//! ```text
//!   client driver ──► request ch ──► batcher ──► batch ch ─┬─► worker 0 ─┐
//!                                                          ├─► worker 1 ─┼─► response ch
//!                                                          └─► worker W ─┘
//! ```
//!
//! Every worker pass is verified with GCN-ABFT before its responses are
//! released; a fired check triggers a bounded re-execution (transient
//! fault recovery), and a persistently failing batch is answered with
//! `VerifyStatus::Failed` rather than silently wrong logits.

use super::batcher::{next_batch, Batch, BatchPolicy};
use super::metrics::{LatencyHistogram, ServeMetrics};
use super::request::{InferenceRequest, InferenceResponse, VerifyStatus};
use super::verify::ServePolicy;
use crate::graph::DatasetId;
use crate::runtime::{GcnOutputs, Manifest, ModelEntry, Runtime};
use crate::tensor::Dense;
use anyhow::Result;
use std::path::PathBuf;
use std::sync::mpsc::{Receiver, Sender};
use std::sync::Mutex;
use std::time::Instant;

/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub dataset: DatasetId,
    pub artifacts_dir: PathBuf,
    pub batch: BatchPolicy,
    pub workers: usize,
    pub policy: ServePolicy,
    /// Inject a bit flip into the logits of every K-th batch (testing the
    /// online checker's end-to-end coverage). `None` = no injection.
    pub inject_every: Option<u64>,
    pub seed: u64,
    pub max_retries: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            dataset: DatasetId::Tiny,
            artifacts_dir: PathBuf::from("artifacts"),
            batch: BatchPolicy::default(),
            workers: 2,
            policy: ServePolicy::default(),
            inject_every: None,
            seed: 7,
            max_retries: 1,
        }
    }
}

/// Resident model state shared (read-only) by all workers.
#[derive(Debug, Clone)]
pub struct ModelState {
    pub features: Dense,
    pub s: Dense,
    pub w1: Dense,
    pub w2: Dense,
}

impl ModelState {
    /// Build the state from the synthetic dataset + trained weights —
    /// the same workload the native engine uses, densified for XLA.
    pub fn build(cfg: &ServerConfig) -> ModelState {
        let opts = crate::report::ExperimentOpts {
            datasets: vec![cfg.dataset],
            seed: cfg.seed,
            scale: 1.0,
            train_epochs: 10,
        };
        let (graph, model) = crate::report::build_workload(cfg.dataset, &opts);
        ModelState {
            features: graph.features.to_dense(),
            s: model.adjacency.to_dense(),
            w1: model.layers[0].weights.clone(),
            w2: model.layers[1].weights.clone(),
        }
    }

    /// Apply a batch's perturbation overlay to a copy of the features.
    pub fn overlay(&self, batch: &Batch) -> Dense {
        let mut f = self.features.clone();
        for req in &batch.requests {
            for p in &req.perturbations {
                assert_eq!(
                    p.features.len(),
                    f.cols(),
                    "perturbation width mismatch for node {}",
                    p.node
                );
                f.row_mut(p.node).copy_from_slice(&p.features);
            }
        }
        f
    }
}

/// Run the serving pipeline until the request channel closes; returns
/// aggregated metrics. Spawns `workers` executor threads plus a batcher.
pub fn run_server(
    cfg: &ServerConfig,
    state: &ModelState,
    requests: Receiver<InferenceRequest>,
    responses: Sender<InferenceResponse>,
) -> Result<ServeMetrics> {
    run_server_with_ready(cfg, state, requests, responses, None)
}

/// As [`run_server`], additionally signalling on `ready` once every worker
/// has compiled its executable — callers use it to hold the client driver
/// back so measured latencies reflect steady-state serving rather than
/// one-time PJRT compilation (§Perf in EXPERIMENTS.md).
pub fn run_server_with_ready(
    cfg: &ServerConfig,
    state: &ModelState,
    requests: Receiver<InferenceRequest>,
    responses: Sender<InferenceResponse>,
    ready: Option<Sender<()>>,
) -> Result<ServeMetrics> {
    let wall_start = Instant::now();
    let (batch_tx, batch_rx) = std::sync::mpsc::channel::<Batch>();
    let batch_rx = Mutex::new(batch_rx);
    let metrics = Mutex::new(ServeMetrics::default());
    let latency = Mutex::new(LatencyHistogram::new());
    let batch_counter = std::sync::atomic::AtomicU64::new(0);
    let n_workers = cfg.workers.max(1);
    // Split the host's cores between inter-batch parallelism (the worker
    // pool) and intra-op parallelism (row-parallel kernels inside each
    // worker's executable), so total thread pressure stays ≈ core count
    // while `--workers` keeps scaling throughput on both axes.
    let intra_threads = (crate::util::parallel::default_threads() / n_workers).max(1);
    let compiled = std::sync::atomic::AtomicUsize::new(0);
    let ready = Mutex::new(ready);

    std::thread::scope(|scope| -> Result<()> {
        // Batcher.
        let bp = cfg.batch;
        scope.spawn(move || {
            while let Some(b) = next_batch(&requests, &bp) {
                if batch_tx.send(b).is_err() {
                    break;
                }
            }
            // dropping batch_tx closes the workers' queue
        });

        // Workers.
        let compiled = &compiled;
        let ready = &ready;
        let mut handles = Vec::new();
        for _worker_id in 0..n_workers {
            let batch_rx = &batch_rx;
            let metrics = &metrics;
            let latency = &latency;
            let responses = responses.clone();
            let batch_counter = &batch_counter;
            let cfg = cfg.clone();
            let state = state;
            handles.push(scope.spawn(move || -> Result<()> {
                // Each worker owns its own runtime + executable (one
                // accelerator per worker; required on the PJRT backend).
                let rt = Runtime::native(intra_threads);
                // Validate against the AOT manifest when one exists; fall
                // back to the dataset's canonical shape entry only when no
                // manifest file is present (fresh checkout before
                // `python -m compile.aot`). A manifest that exists but is
                // corrupt or version-skewed must still fail loudly — that
                // is the Python↔Rust contract check.
                let exe = if cfg.artifacts_dir.join("manifest.json").exists() {
                    let manifest = Manifest::load(&cfg.artifacts_dir)?;
                    rt.load_model(&manifest, cfg.dataset.name())?
                } else {
                    rt.load_entry(ModelEntry::for_dataset(cfg.dataset))
                };
                if compiled.fetch_add(1, std::sync::atomic::Ordering::SeqCst) + 1 == n_workers
                {
                    if let Some(tx) = ready.lock().unwrap().take() {
                        let _ = tx.send(());
                    }
                }
                loop {
                    let batch = {
                        let rx = batch_rx.lock().unwrap();
                        match rx.recv() {
                            Ok(b) => b,
                            Err(_) => break,
                        }
                    };
                    let bidx =
                        batch_counter.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    let features = state.overlay(&batch);

                    // Execute + verify with bounded retry.
                    let mut status = VerifyStatus::Failed;
                    let mut outputs: Option<GcnOutputs> = None;
                    let mut attempts = 0usize;
                    while attempts <= cfg.max_retries {
                        let t0 = Instant::now();
                        let mut out =
                            exe.run(&features, &state.s, &state.w1, &state.w2)?;
                        let exec_dt = t0.elapsed().as_secs_f64();

                        // Optional fault injection into the response
                        // payload (first attempt only — models a
                        // transient corruption the retry clears).
                        let inject = attempts == 0
                            && cfg
                                .inject_every
                                .map(|k| k > 0 && bidx % k == 0)
                                .unwrap_or(false);
                        if inject {
                            // Flip the top exponent bit of the logit where
                            // that flip perturbs the checksum the most
                            // (|v| < 2 explodes by 2^128, |v| ≥ 2 collapses
                            // to ~0), so detection does not depend on one
                            // element's magnitude versus the batch-wide
                            // checksum scale. Non-finite results rank
                            // highest — the verifier always flags those.
                            let delta = |v: f32| -> f64 {
                                let flipped = f32::from_bits(v.to_bits() ^ (1 << 30));
                                if flipped.is_finite() {
                                    (flipped as f64 - v as f64).abs()
                                } else {
                                    f64::INFINITY
                                }
                            };
                            let idx = out
                                .logits
                                .data()
                                .iter()
                                .enumerate()
                                .max_by(|a, b| {
                                    delta(*a.1).partial_cmp(&delta(*b.1)).unwrap()
                                })
                                .map(|(i, _)| i)
                                .unwrap_or(0);
                            let (r, c) = (idx / out.logits.cols(), idx % out.logits.cols());
                            let v = out.logits.get(r, c);
                            out.logits
                                .set(r, c, f32::from_bits(v.to_bits() ^ (1 << 30)));
                            metrics.lock().unwrap().injected_faults += 1;
                        }

                        let t1 = Instant::now();
                        let report = cfg.policy.verify(&out);
                        let verify_dt = t1.elapsed().as_secs_f64();
                        {
                            let mut m = metrics.lock().unwrap();
                            m.executions += 1;
                            m.exec_secs += exec_dt;
                            m.verify_secs += verify_dt;
                            if !report.ok {
                                m.checks_fired += 1;
                            }
                        }
                        if report.ok {
                            status = if attempts == 0 {
                                VerifyStatus::Clean
                            } else {
                                VerifyStatus::RecoveredAfterRetry
                            };
                            outputs = Some(out);
                            break;
                        }
                        attempts += 1;
                        if attempts <= cfg.max_retries {
                            metrics.lock().unwrap().retries += 1;
                        }
                    }
                    if status == VerifyStatus::Failed {
                        metrics.lock().unwrap().failures += 1;
                    }

                    // Respond per request.
                    let classes: Vec<usize> = outputs
                        .as_ref()
                        .map(|o| crate::tensor::ops::argmax_rows(&o.logits))
                        .unwrap_or_default();
                    let bsize = batch.len();
                    {
                        let mut m = metrics.lock().unwrap();
                        m.batches += 1;
                        m.requests += bsize as u64;
                    }
                    for req in batch.requests {
                        let lat = req.submitted.elapsed().as_secs_f64();
                        latency.lock().unwrap().record(lat);
                        let resp = InferenceResponse {
                            id: req.id,
                            classes: req
                                .query_nodes
                                .iter()
                                .map(|&n| (n, classes.get(n).copied().unwrap_or(usize::MAX)))
                                .collect(),
                            status,
                            latency_secs: lat,
                            batch_size: bsize,
                        };
                        let _ = responses.send(resp);
                    }
                }
                Ok(())
            }));
        }
        drop(responses);
        for h in handles {
            h.join().expect("worker panicked")?;
        }
        Ok(())
    })?;

    let mut m = metrics.into_inner().unwrap();
    m.wall_secs = wall_start.elapsed().as_secs_f64();
    let lat = latency.into_inner().unwrap();
    // Stash percentiles into the summary string via ServeSummary below.
    Ok(finalize(m, lat))
}

/// Attach latency percentiles to metrics (kept in one struct for JSON).
fn finalize(m: ServeMetrics, lat: LatencyHistogram) -> ServeMetrics {
    // percentiles are reported by the caller via summary(); retaining
    // the histogram would make ServeMetrics non-Clone-friendly for the
    // channel-free API, so we fold the three headline numbers into the
    // struct by extension below.
    LAT_P50.with(|c| c.set(lat.percentile(50.0)));
    LAT_P95.with(|c| c.set(lat.percentile(95.0)));
    LAT_P99.with(|c| c.set(lat.percentile(99.0)));
    m
}

thread_local! {
    static LAT_P50: std::cell::Cell<f64> = const { std::cell::Cell::new(f64::NAN) };
    static LAT_P95: std::cell::Cell<f64> = const { std::cell::Cell::new(f64::NAN) };
    static LAT_P99: std::cell::Cell<f64> = const { std::cell::Cell::new(f64::NAN) };
}

/// Latency percentiles of the last `run_server` call on this thread.
pub fn last_latency_percentiles() -> (f64, f64, f64) {
    (
        LAT_P50.with(|c| c.get()),
        LAT_P95.with(|c| c.get()),
        LAT_P99.with(|c| c.get()),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::Perturbation;

    #[test]
    fn overlay_applies_perturbations() {
        let state = ModelState {
            features: Dense::zeros(4, 3),
            s: Dense::eye(4),
            w1: Dense::zeros(3, 2),
            w2: Dense::zeros(2, 2),
        };
        let batch = Batch {
            requests: vec![InferenceRequest {
                id: 0,
                query_nodes: vec![1],
                perturbations: vec![Perturbation {
                    node: 2,
                    features: vec![1.0, 2.0, 3.0],
                }],
                submitted: Instant::now(),
            }],
        };
        let f = state.overlay(&batch);
        assert_eq!(f.row(2), &[1.0, 2.0, 3.0]);
        assert_eq!(f.row(1), &[0.0, 0.0, 0.0]);
        // base untouched
        assert_eq!(state.features.row(2), &[0.0, 0.0, 0.0]);
    }

    #[test]
    #[should_panic(expected = "perturbation width mismatch")]
    fn overlay_rejects_bad_width() {
        let state = ModelState {
            features: Dense::zeros(2, 3),
            s: Dense::eye(2),
            w1: Dense::zeros(3, 1),
            w2: Dense::zeros(1, 1),
        };
        let batch = Batch {
            requests: vec![InferenceRequest {
                id: 0,
                query_nodes: vec![],
                perturbations: vec![Perturbation {
                    node: 0,
                    features: vec![1.0],
                }],
                submitted: Instant::now(),
            }],
        };
        state.overlay(&batch);
    }
}
