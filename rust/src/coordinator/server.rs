//! The serving coordinator: continuous-batching scheduler + executor
//! workers + online verification + metrics.
//!
//! Topology (all std threads; each worker owns its own runtime handle and
//! executable — the realistic analogue of one accelerator per worker, and
//! a hard requirement on the PJRT backend whose handles are not `Send`):
//!
//! ```text
//!   client driver ──► request ch ──► admission ──► Scheduler ─┬─► worker 0 ─┐
//!                                    (submit)    (priority    ├─► worker 1 ─┼─► response ch
//!                                                 queue)      └─► worker W ─┘
//! ```
//!
//! Workers pull batches **directly from the scheduler** the moment they
//! finish the previous forward; admission never blocks on an executing
//! batch, so newly arrived requests coalesce into the *next* batch while
//! the current one runs (see [`super::batcher`]).
//!
//! **Coalescing is a scheduling artifact only.** Each batch is
//! partitioned into *overlay-equivalence groups* ([`overlay_groups`]):
//! requests whose perturbation sets are identical (in particular, all
//! unperturbed requests) share one forward, and requests with different
//! what-if overlays get their own forward. A request's logits and alarm
//! decisions are therefore bit-identical to serving it alone — pinned by
//! `tests/prop_batching_equivalence.rs`.
//!
//! With **dense** operands the workers replicate the model and groups
//! run batch-parallel. With **sparse** operands the propagation matrix
//! is sharded into `--workers` row bands instead: one executor loop
//! pulls batches, each band aggregates on its own worker, and the
//! logits + fused-checksum partials are stitched back together
//! (`runtime::operands`) — the paper's check is exact under that
//! stitching because both `eᵀ·Z·e` and `s_c` are additive over a row
//! partition.
//!
//! Every pass is verified with GCN-ABFT before its responses are
//! released; a fired check triggers a bounded re-execution (transient
//! fault recovery), and a persistently failing forward is answered with
//! `VerifyStatus::Failed` rather than silently wrong logits.

use super::batcher::{Batch, BatchPolicy, Scheduler};
use super::clock::{Clock, MonotonicClock};
use super::lock_recover;
use super::metrics::{LatencyHistogram, ServeMetrics};
use super::request::{InferenceRequest, InferenceResponse, VerifyStatus};
use super::shard::{self, ShardTransport, ShardTransportKind};
use super::supervisor::{Supervisor, SupervisorConfig};
use super::verify::ServePolicy;
use crate::graph::DatasetId;
use crate::runtime::backend;
use crate::runtime::{
    BackendKind, ChecksumScheme, EpochFence, ExecMode, GcnOperands, GraphDelta, Manifest,
    ModelEntry, OperandPlan, Overlay,
};
use anyhow::{bail, Result};
use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::mpsc::{Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Replays of one batch after a shard death before the executor gives
/// up and answers fail-stop anyway (guards against a flapping shard
/// pinning the executor on one batch forever).
const MAX_BATCH_REPLAYS: u32 = 2;
/// How long the executor waits for supervised recovery before
/// answering a stranded batch fail-stop after all.
const RECOVERY_WAIT: Duration = Duration::from_secs(10);

/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub dataset: DatasetId,
    pub artifacts_dir: PathBuf,
    pub batch: BatchPolicy,
    pub workers: usize,
    pub policy: ServePolicy,
    /// Inject a bit flip into the logits of every K-th batch (testing the
    /// online checker's end-to-end coverage). `None` = no injection.
    pub inject_every: Option<u64>,
    pub seed: u64,
    pub max_retries: usize,
    /// Proportional dataset shrink (1.0 = paper scale) — lets the big
    /// datasets serve quickly in smokes and tests.
    pub scale: f64,
    /// Operand representation: dense, CSR, or auto (memory-planned).
    pub mode: ExecMode,
    /// Budget for the graph operands (S + features) in MiB; the planner
    /// refuses representations that exceed it instead of OOMing.
    pub mem_budget_mb: usize,
    /// Brief training at model build so logits have realistic margins.
    pub train_epochs: usize,
    /// Which [`backend::GcnBackend`] executes the forwards
    /// (`--backend native|instrumented|pjrt`).
    pub backend: BackendKind,
    /// Checksum scheme the backend computes (`--scheme fused|split`).
    pub scheme: ChecksumScheme,
    /// Priority mix of the synthetic client driver
    /// (interactive/batch/background weights, `--priority-mix`).
    pub priority_mix: [f64; 3],
    /// Row-band shards of `S` served through the shard tier
    /// ([`super::shard`]); 0 = the classic in-process path. Sharding
    /// runs on CSR operands (`--mode dense` is refused) and the native
    /// backend.
    pub shards: usize,
    /// Where the shards run (`--shard-transport inproc|proc`).
    pub shard_transport: ShardTransportKind,
    /// Worker binary the proc transport spawns. `None` = the running
    /// executable (right for the `gcn-abft` binary; tests and benches
    /// pass `env!("CARGO_BIN_EXE_gcn-abft")`, since *their* executable
    /// has no `shard-worker` subcommand).
    pub shard_worker_bin: Option<PathBuf>,
    /// Fault injection for fail-stop tests: tear down shard 0 just
    /// before the batch with this 0-based index executes. Requests
    /// already answered stay answered; everything after gets
    /// `VerifyStatus::Failed` while the coordinator keeps serving —
    /// unless `supervise` is on, in which case the supervisor heals the
    /// shard and the stranded requests replay.
    pub kill_shard_after: Option<u64>,
    /// Run the shard supervisor (`--supervise`): probe shard liveness
    /// every `heartbeat_ms`, re-spawn/re-connect dead workers, re-ship
    /// their bands, and replay the requests that were in flight on a
    /// dead shard. Off by default — unsupervised tiers keep PR 5's
    /// fail-stop-forever semantics.
    pub supervise: bool,
    /// Supervisor tick period in milliseconds (`--heartbeat-ms`).
    pub heartbeat_ms: u64,
    /// Extra pre-shipped standby workers (`--warm-standby`) for
    /// zero-reship failover; proc/tcp spawn modes only.
    pub warm_standby: usize,
    /// Remote worker addresses for `--shard-transport tcp`
    /// (`--shard-addrs host:port,...`, one per band in band order);
    /// empty = spawn workers locally.
    pub shard_addrs: Vec<String>,
    /// Deadline the synthetic driver declares on every request
    /// (`--deadline-ms`). `None` = no declared deadlines, so
    /// deadline-aware early rejection never engages for driver traffic.
    pub driver_deadline: Option<Duration>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            dataset: DatasetId::Tiny,
            artifacts_dir: PathBuf::from("artifacts"),
            batch: BatchPolicy::default(),
            workers: 2,
            policy: ServePolicy::default(),
            inject_every: None,
            seed: 7,
            max_retries: 1,
            scale: 1.0,
            mode: ExecMode::Auto,
            mem_budget_mb: 512,
            train_epochs: 10,
            backend: BackendKind::Native,
            scheme: ChecksumScheme::Fused,
            priority_mix: [1.0, 0.0, 0.0],
            shards: 0,
            shard_transport: ShardTransportKind::InProc,
            shard_worker_bin: None,
            kill_shard_after: None,
            supervise: false,
            heartbeat_ms: 200,
            warm_standby: 0,
            shard_addrs: Vec::new(),
            driver_deadline: None,
        }
    }
}

/// The overlay-equivalence key of one request: its perturbation list,
/// node ids plus exact feature bit patterns.
type OverlayKey = Vec<(usize, Vec<u32>)>;

/// Partition a batch into overlay-equivalence groups (indices into
/// `batch.requests`, in first-seen order): requests whose perturbation
/// lists are bit-identical share one forward, so a member's answer is
/// exactly what serving it alone would produce. Unperturbed requests —
/// the common case — all land in one group and batch perfectly.
pub fn overlay_groups(batch: &Batch) -> Vec<Vec<usize>> {
    let mut index: BTreeMap<OverlayKey, usize> = BTreeMap::new();
    let mut groups: Vec<Vec<usize>> = Vec::new();
    for (i, req) in batch.requests.iter().enumerate() {
        let key: OverlayKey = req
            .perturbations
            .iter()
            .map(|p| (p.node, p.features.iter().map(|v| v.to_bits()).collect()))
            .collect();
        match index.entry(key) {
            std::collections::btree_map::Entry::Occupied(e) => groups[*e.get()].push(i),
            std::collections::btree_map::Entry::Vacant(v) => {
                v.insert(groups.len());
                groups.push(vec![i]);
            }
        }
    }
    groups
}

/// Resident model state shared (read-only) by all workers: the operand
/// set in its memory-planned representation plus the shape entry the
/// executables validate against.
#[derive(Debug, Clone)]
pub struct ModelState {
    pub ops: GcnOperands,
    pub entry: ModelEntry,
}

impl ModelState {
    /// Build the state from the synthetic dataset + trained weights.
    /// The operand representation (dense vs CSR, [`OperandPlan`]) is
    /// chosen from the memory budget; a sparse `S` is sharded into
    /// `cfg.workers` row bands. Errors when even the CSR footprint
    /// exceeds the budget — never OOMs mid-serve.
    pub fn build(cfg: &ServerConfig) -> Result<ModelState> {
        // Plan from the dataset's published statistics (the same numbers
        // the synthesizer targets) BEFORE building anything, so a refusal
        // costs nothing — the old hard-coded PubMed/Nell refusal must not
        // come back as "build and train the whole graph, then refuse".
        let spec = cfg.dataset.spec();
        let sc = |x: usize| ((x as f64 * cfg.scale).round() as usize).max(1);
        let (n_est, edges_est, feat_nnz_est) = if cfg.scale < 1.0 {
            (
                sc(spec.num_nodes).max(spec.num_classes),
                sc(spec.num_edges),
                sc(spec.feat_nnz),
            )
        } else {
            (spec.num_nodes, spec.num_edges, spec.feat_nnz)
        };
        // Sharded serving distributes the row bands of a CSR `S`
        // (that is the whole point — the bands and their checksum
        // partials are the unit of distribution), so `--shards` forces
        // the sparse representation.
        let mode = if cfg.shards > 0 {
            if cfg.mode == ExecMode::Dense {
                bail!(
                    "sharded serving (--shards) runs on CSR operands; \
                     use --mode auto or sparse"
                );
            }
            ExecMode::Sparse
        } else {
            cfg.mode
        };
        let plan = OperandPlan::choose(
            n_est,
            spec.feat_dim,
            2 * edges_est + n_est, // S nnz: every edge twice + self-loops
            feat_nnz_est,
            mode,
            cfg.mem_budget_mb.saturating_mul(1 << 20),
        )?;

        let opts = crate::report::ExperimentOpts {
            datasets: vec![cfg.dataset],
            seed: cfg.seed,
            scale: cfg.scale,
            train_epochs: cfg.train_epochs,
        };
        let (graph, model) = crate::report::build_workload(cfg.dataset, &opts);
        let w1 = model.layers[0].weights.clone();
        let w2 = model.layers[1].weights.clone();
        let entry = ModelEntry {
            name: cfg.dataset.name().to_string(),
            file: format!("gcn_{}.hlo.txt", cfg.dataset.name()),
            n: graph.num_nodes,
            f: graph.feat_dim(),
            hidden: w1.cols(),
            classes: w2.cols(),
        };
        let ops = if plan.sparse {
            // One row band per shard when the shard tier is on (the
            // bands ARE the shards); otherwise one per worker as before.
            let bands = if cfg.shards > 0 {
                cfg.shards
            } else {
                cfg.workers.max(1)
            };
            GcnOperands::sparse(graph.features, &model.adjacency, w1, w2, bands)?
        } else {
            GcnOperands::dense(
                graph.features.to_dense(),
                model.adjacency.to_dense(),
                w1,
                w2,
            )?
        };
        Ok(ModelState { ops, entry })
    }

    /// Collect one request's perturbations as feature-row overlays
    /// against this state's resident operands — see [`request_overlays`]
    /// (the serving path validates against its epoch *snapshot* instead,
    /// so a node added mid-stream is addressable from the next epoch on).
    pub fn request_overlays<'a>(&self, req: &'a InferenceRequest) -> Result<Vec<Overlay<'a>>> {
        request_overlays(&self.ops, req)
    }
}

/// Collect one request's perturbations as feature-row overlays, in
/// list order (later overlays of the same node win, matching the
/// historical copy-and-patch semantics). The base feature matrix is
/// never cloned per forward — backends apply these algebraically.
///
/// A malformed perturbation (wrong feature width, node out of
/// range) is an error, not a panic: the executor answers the
/// request `Failed` and keeps serving the rest of the batch.
pub fn request_overlays<'a>(
    ops: &GcnOperands,
    req: &'a InferenceRequest,
) -> Result<Vec<Overlay<'a>>> {
    let f = ops.feat_dim();
    let n = ops.n_nodes();
    let mut overlays = Vec::with_capacity(req.perturbations.len());
    for p in &req.perturbations {
        if p.features.len() != f {
            bail!(
                "perturbation width mismatch for node {}: got {}, feature dim is {f}",
                p.node,
                p.features.len()
            );
        }
        if p.node >= n {
            bail!("perturbation node {} out of range (n = {n})", p.node);
        }
        overlays.push(Overlay {
            node: p.node,
            row: p.features.as_slice(),
        });
    }
    Ok(overlays)
}

/// A `Failed` fail-stop response for `req`: the client sees the fault
/// (classes withheld) instead of silence or a coordinator crash.
fn failed_response(
    req: &InferenceRequest,
    lat: f64,
    bsize: usize,
    epoch: u64,
) -> InferenceResponse {
    InferenceResponse {
        id: req.id,
        priority: req.priority,
        classes: req.query_nodes.iter().map(|&n| (n, usize::MAX)).collect(),
        status: VerifyStatus::Failed,
        latency_secs: lat,
        batch_size: bsize,
        epoch,
        retry_after_ms: None,
    }
}

/// A `Shed` admission-control response for `req`: refused or evicted
/// under overload *before any forward ran*. Classes are withheld as in
/// `Failed`, but the status is a distinct availability outcome — the
/// client's cue to back off, never a fault-detection event.
/// `batch_size` and `epoch` are 0: the request never rode a batch or
/// touched a graph version. `retry_after_ms` carries the scheduler's
/// backlog-scaled service-time estimate so clients back off for roughly
/// one queue-drain instead of guessing.
fn shed_response(
    req: &InferenceRequest,
    lat: f64,
    retry_after_ms: Option<f64>,
) -> InferenceResponse {
    InferenceResponse {
        id: req.id,
        priority: req.priority,
        classes: req.query_nodes.iter().map(|&n| (n, usize::MAX)).collect(),
        status: VerifyStatus::Shed,
        latency_secs: lat,
        batch_size: 0,
        epoch: 0,
        retry_after_ms,
    }
}

/// Build one executor's backend: validate against the AOT manifest when
/// one exists and the graph is at manifest scale (a manifest that is
/// corrupt or version-skewed must fail loudly — that is the
/// Python↔Rust contract check), then instantiate the configured
/// [`backend::GcnBackend`] over the resident operands.
fn build_worker_backend(
    cfg: &ServerConfig,
    state: &ModelState,
    intra_threads: usize,
) -> Result<Box<dyn backend::GcnBackend>> {
    let full_scale = cfg.scale >= 1.0;
    if full_scale && cfg.artifacts_dir.join("manifest.json").exists() {
        let manifest = Manifest::load(&cfg.artifacts_dir)?;
        let Some(entry) = manifest.model(cfg.dataset.name()) else {
            bail!("model {:?} not in manifest", cfg.dataset.name());
        };
        let e = &state.entry;
        if (entry.n, entry.f, entry.hidden, entry.classes) != (e.n, e.f, e.hidden, e.classes) {
            bail!(
                "manifest shapes for {} diverge from the operand set",
                cfg.dataset.name()
            );
        }
    }
    backend::for_operands(
        cfg.backend,
        cfg.scheme,
        &state.ops,
        intra_threads,
        Some((cfg.artifacts_dir.as_path(), cfg.dataset.name())),
    )
}

/// Run the serving pipeline until the request channel closes; returns
/// aggregated metrics. Spawns the executor thread(s) plus an admission
/// thread feeding the continuous-batching scheduler.
pub fn run_server(
    cfg: &ServerConfig,
    state: &ModelState,
    requests: Receiver<InferenceRequest>,
    responses: Sender<InferenceResponse>,
) -> Result<ServeMetrics> {
    run_server_with_updates(cfg, state, requests, responses, None, None)
}

/// As [`run_server`], additionally signalling on `ready` once every
/// executor has built its executable — callers use it to hold the client
/// driver back so measured latencies reflect steady-state serving rather
/// than one-time setup/compilation (§Perf in EXPERIMENTS.md).
pub fn run_server_with_ready(
    cfg: &ServerConfig,
    state: &ModelState,
    requests: Receiver<InferenceRequest>,
    responses: Sender<InferenceResponse>,
    ready: Option<Sender<()>>,
) -> Result<ServeMetrics> {
    run_server_with_updates(cfg, state, requests, responses, ready, None)
}

/// As [`run_server_with_ready`], additionally accepting graph deltas on
/// `updates` (dynamic graphs). Each delta is applied behind the epoch
/// fence: the applier waits out in-flight batches (admission keeps
/// coalescing), patches a copy-on-write clone of the operands
/// ([`crate::runtime::mutate::apply`] — bit-identical to a rebuild),
/// re-ships mutated bands through the shard tier when one is running,
/// and publishes the next epoch. Every response records the epoch its
/// batch executed against; a rejected delta is fail-stop (epoch
/// unchanged, serving continues on the old graph version).
pub fn run_server_with_updates(
    cfg: &ServerConfig,
    state: &ModelState,
    requests: Receiver<InferenceRequest>,
    responses: Sender<InferenceResponse>,
    ready: Option<Sender<()>>,
    updates: Option<Receiver<GraphDelta>>,
) -> Result<ServeMetrics> {
    // One time base for the whole serve: the scheduler's decisions and
    // the wall/exec/verify timings all read the same Clock (contract
    // D1 — tests substitute a VirtualClock at the scheduler layer).
    let clock = MonotonicClock::new();
    let wall_start = clock.now();
    // The shard tier is built once, up front (the proc transport spawns
    // its worker subprocesses here), and shared with the executor. A
    // transport that cannot come up is a server-build error; a shard
    // that dies *later* is a per-request fail-stop, not a crash.
    let shard_tier: Option<Arc<dyn ShardTransport>> = if cfg.shards > 0 {
        if cfg.backend != BackendKind::Native {
            bail!(
                "sharded serving runs on the native backend \
                 (got --backend {})",
                cfg.backend.name()
            );
        }
        Some(shard::build_transport(cfg, &state.ops)?)
    } else {
        None
    };
    // The shard supervisor (`--supervise`): a daemon thread probes the
    // tier every heartbeat and heals dead shards; the executor kicks it
    // the moment a request dies on one.
    let supervisor: Option<Arc<Supervisor>> = match (&shard_tier, cfg.supervise) {
        (Some(t), true) => Some(Arc::new(Supervisor::new(
            t.clone(),
            SupervisorConfig {
                heartbeat: Duration::from_millis(cfg.heartbeat_ms.max(1)),
                ..Default::default()
            },
        ))),
        _ => None,
    };
    let sched = Scheduler::new(clock.clone(), cfg.batch);
    // The graph-version fence (dynamic graphs): executors snapshot
    // `(epoch, ops)` per batch; the delta applier publishes new
    // versions copy-on-write, so a snapshot is immutable for as long as
    // any batch holds it.
    let fence = EpochFence::new(state.ops.clone());
    // Set once the executors have drained: lets the delta applier exit
    // even when the caller keeps its updates sender open.
    let serving_done = std::sync::atomic::AtomicBool::new(false);
    let metrics = Mutex::new(ServeMetrics::default());
    let latency = Mutex::new(LatencyHistogram::new());
    let prio_latency = Mutex::new([
        LatencyHistogram::new(),
        LatencyHistogram::new(),
        LatencyHistogram::new(),
    ]);
    let batch_counter = std::sync::atomic::AtomicU64::new(0);
    let n_workers = cfg.workers.max(1);
    // Dense (replicated) operands: split the host's cores between
    // inter-batch parallelism (the worker pool) and intra-op parallelism
    // (row-parallel kernels inside each worker's executable). Sparse
    // (sharded) operands: the `--workers` axis became the row bands of
    // `S`, so a single executor loop pulls batches and each batch's
    // aggregation fans out across the band workers inside the
    // executable; combination kernels get the full intra-op width.
    let sharded = state.ops.is_sparse();
    let (pool, intra_threads) = if sharded {
        (1usize, crate::util::parallel::default_threads())
    } else {
        (
            n_workers,
            (crate::util::parallel::default_threads() / n_workers).max(1),
        )
    };
    let compiled = std::sync::atomic::AtomicUsize::new(0);
    let ready = Mutex::new(ready);

    std::thread::scope(|scope| -> Result<()> {
        // Admission: feed the scheduler from the public request channel.
        // submit() never blocks on an executing forward, so arrivals
        // keep coalescing into the next batch while workers run. With a
        // bounded queue (`--queue-cap`) submit is fallible: a refused
        // arrival — and any lower-priority member evicted to admit it —
        // is answered `Shed` right here, so overload costs the client a
        // prompt machine-readable rejection, not an unbounded wait.
        {
            let sched = &sched;
            let responses = responses.clone();
            scope.spawn(move || {
                while let Ok(r) = requests.recv() {
                    for s in sched.submit(r).into_shed() {
                        let lat = s.req.submitted.elapsed().as_secs_f64();
                        let hint = sched.retry_after_hint().map(|d| d.as_secs_f64() * 1e3);
                        let _ = responses.send(shed_response(&s.req, lat, hint));
                    }
                }
                sched.shutdown();
            });
        }

        // Delta applier (dynamic graphs): serializes graph updates
        // behind the scheduler's epoch gate. Taking the write side
        // waits out every in-flight batch — admission keeps coalescing
        // the whole time — so each batch executes against exactly one
        // graph version, and the next batch to close sees the new one.
        if let Some(updates) = updates {
            let sched = &sched;
            let clock = &clock;
            let metrics = &metrics;
            let fence = &fence;
            let serving_done = &serving_done;
            let shard_tier = shard_tier.clone();
            scope.spawn(move || {
                use std::sync::mpsc::RecvTimeoutError;
                loop {
                    let delta =
                        match updates.recv_timeout(std::time::Duration::from_millis(20)) {
                            Ok(d) => d,
                            Err(RecvTimeoutError::Disconnected) => break,
                            Err(RecvTimeoutError::Timeout) => {
                                if serving_done.load(std::sync::atomic::Ordering::SeqCst) {
                                    break;
                                }
                                continue;
                            }
                        };
                    let t0 = clock.now();
                    let gate = sched.epoch_guard();
                    // Shard re-ship runs pre-publish: a delta the shard
                    // tier cannot take is rejected whole — fail-stop,
                    // epoch unchanged, serving continues on the old
                    // graph version.
                    let applied = fence.apply_with(&delta, |ops, outcome| match &shard_tier {
                        Some(t) => t.apply_delta(ops, outcome),
                        None => Ok(()),
                    });
                    drop(gate);
                    let dt = clock.now().since(t0).as_secs_f64();
                    let mut m = lock_recover(metrics);
                    m.delta_apply_secs += dt;
                    match applied {
                        Ok((epoch, _, _)) => {
                            m.deltas_applied += 1;
                            m.epoch = epoch;
                        }
                        Err(err) => {
                            eprintln!(
                                "serve: delta rejected ({err:#}); serving continues \
                                 on the current graph version"
                            );
                            m.delta_failures += 1;
                        }
                    }
                }
            });
        }

        // Supervisor daemon: tick every heartbeat (or immediately on an
        // executor kick). Each tick runs under the scheduler's epoch
        // gate *and* the epoch fence's write lock, so a recovery
        // re-ship can never interleave with an in-flight batch or with
        // a delta's patch/re-ship/publish sequence — the same isolation
        // discipline the delta applier uses.
        if let Some(sup) = &supervisor {
            let sup = sup.clone();
            let sched = &sched;
            let fence = &fence;
            let hb = Duration::from_millis(cfg.heartbeat_ms.max(1));
            scope.spawn(move || loop {
                sup.wait_tick(hb);
                if sup.is_shutdown() {
                    break;
                }
                let gate = sched.epoch_guard();
                let _ = fence.with_current(|ops| {
                    sup.tick_with_ops(ops);
                    Ok(())
                });
                drop(gate);
            });
        }

        // Executors.
        let compiled = &compiled;
        let ready = &ready;
        let mut handles = Vec::new();
        for _worker_id in 0..pool {
            let sched = &sched;
            let clock = &clock;
            let fence = &fence;
            let metrics = &metrics;
            let latency = &latency;
            let prio_latency = &prio_latency;
            let responses = responses.clone();
            let batch_counter = &batch_counter;
            let cfg = cfg.clone();
            let state = state;
            let shard_tier = shard_tier.clone();
            let supervisor = supervisor.clone();
            handles.push(scope.spawn(move || -> Result<()> {
                // Each executor owns its own backend (one accelerator per
                // worker; a hard requirement on the PJRT backend whose
                // client handle is not Send). With the shard tier on,
                // the (single) executor runs the sharded backend over
                // the shared transport instead.
                let build = match &shard_tier {
                    Some(t) => Ok(Box::new(shard::ShardedBackend::new(
                        t.clone(),
                        cfg.scheme,
                        intra_threads,
                    )) as Box<dyn backend::GcnBackend>),
                    None => build_worker_backend(&cfg, state, intra_threads),
                };
                let exe = match build {
                    Ok(exe) => exe,
                    Err(err) => {
                        // A worker that cannot build its backend must not
                        // leave the ready channel dangling — dropping the
                        // sender unblocks the client driver immediately,
                        // so the build error surfaces instead of a
                        // recv_timeout stall.
                        lock_recover(ready).take();
                        return Err(err);
                    }
                };
                if compiled.fetch_add(1, std::sync::atomic::Ordering::SeqCst) + 1 == pool {
                    if let Some(tx) = lock_recover(ready).take() {
                        let _ = tx.send(());
                    }
                }
                // Request latencies are recorded locally and merged into
                // the serve-wide histograms at executor exit (no shared
                // lock on the response path).
                let mut local_lat = LatencyHistogram::new();
                let mut local_prio = [
                    LatencyHistogram::new(),
                    LatencyHistogram::new(),
                    LatencyHistogram::new(),
                ];
                // Pull straight from the scheduler: the next batch closes
                // (size / deadline / starvation / drain) the moment this
                // worker is free for it. `pending` holds a batch whose
                // forward died on a shard and whose requests replay once
                // the supervisor heals the tier — each request is still
                // answered exactly once.
                let mut pending: Option<Batch> = None;
                let mut replays_left = MAX_BATCH_REPLAYS;
                loop {
                    let (mut batch, is_replay) = match pending.take() {
                        Some(b) => (b, true),
                        None => {
                            replays_left = MAX_BATCH_REPLAYS;
                            match sched.next_batch() {
                                Some(b) => (b, false),
                                None => break,
                            }
                        }
                    };
                    // Close-time rejections (deadline-aware early
                    // rejection): answered `Shed` before anything else —
                    // a shed request never executes a forward. Drained
                    // here so a supervised replay of this batch cannot
                    // answer them twice; they are excluded from the
                    // served-latency histograms (goodput percentiles).
                    for s in std::mem::take(&mut batch.shed) {
                        let lat = s.req.submitted.elapsed().as_secs_f64();
                        let hint = sched.retry_after_hint().map(|d| d.as_secs_f64() * 1e3);
                        let _ = responses.send(shed_response(&s.req, lat, hint));
                    }
                    if batch.is_empty() {
                        // Pure rejection work — nothing left to execute.
                        continue;
                    }
                    // Hold the read side of the epoch gate for the whole
                    // batch and pin one graph version: everything below —
                    // overlay validation, forwards, verification, retries —
                    // reads this snapshot, so a delta landing mid-batch
                    // cannot change what any admitted request answers.
                    let _inflight = sched.batch_guard();
                    let (epoch, ops) = fence.snapshot();
                    let ops = &*ops;
                    let bidx =
                        batch_counter.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    // Scheduled shard teardown (`--kill-shard-after`):
                    // fail-stop fault injection for the shard tier.
                    if let (Some(t), Some(kill_at)) = (&shard_tier, cfg.kill_shard_after) {
                        if bidx == kill_at {
                            t.kill_shard(0);
                        }
                    }
                    let bsize = batch.len();
                    // Overlay-equivalence groups: one forward per distinct
                    // perturbation set, so coalescing never changes what
                    // any member would have answered alone.
                    let groups = overlay_groups(&batch);
                    // A replayed batch was already counted on its first
                    // pass — the request totals count *requests*, not
                    // attempts (replays surface in replayed_requests).
                    if !is_replay {
                        let mut m = lock_recover(metrics);
                        m.batches += 1;
                        m.requests += bsize as u64;
                        m.overlay_groups += groups.len() as u64;
                    }
                    // A group with malformed perturbations is answered
                    // Failed up front (per-request fail-stop); the rest
                    // of the batch still serves.
                    let mut group_overlays: Vec<Vec<Overlay<'_>>> =
                        Vec::with_capacity(groups.len());
                    let mut live_groups: Vec<Vec<usize>> = Vec::with_capacity(groups.len());
                    for members in &groups {
                        match request_overlays(ops, &batch.requests[members[0]]) {
                            Ok(o) => {
                                group_overlays.push(o);
                                live_groups.push(members.clone());
                            }
                            Err(err) => {
                                eprintln!(
                                    "serve: malformed request ({err:#}); \
                                     answering fail-stop Failed"
                                );
                                lock_recover(metrics).failures += 1;
                                for &mi in members {
                                    let req = &batch.requests[mi];
                                    let lat = req.submitted.elapsed().as_secs_f64();
                                    local_lat.record(lat);
                                    local_prio[req.priority.rank()].record(lat);
                                    let _ = responses
                                        .send(failed_response(req, lat, bsize, epoch));
                                }
                            }
                        }
                    }
                    let groups = live_groups;
                    if groups.is_empty() {
                        continue;
                    }
                    // Initial pass: the whole batch through the batched
                    // call boundary — one forward per overlay group
                    // (`result[i] == run(groups[i])` by the
                    // [`backend::GcnBackend::run_groups`] contract).
                    let group_refs: Vec<&[Overlay<'_>]> =
                        group_overlays.iter().map(|g| g.as_slice()).collect();
                    let t0 = clock.now();
                    // Fail-stop: a forward that cannot execute at all —
                    // above all a shard dying mid-request — must never
                    // become a silently stitched partial answer. Every
                    // member of the batch is answered `Failed` and the
                    // coordinator keeps serving subsequent batches.
                    let mut outs = match exe.run_groups(ops, &group_refs) {
                        Ok(outs) => outs,
                        Err(err) => {
                            {
                                let mut m = lock_recover(metrics);
                                m.exec_secs += clock.now().since(t0).as_secs_f64();
                                // shard_failures tracks shard-tier
                                // health specifically; an unsharded
                                // backend error is failures-only.
                                if shard_tier.is_some() {
                                    m.shard_failures += 1;
                                }
                            }
                            // Supervised recovery: kick the supervisor,
                            // release the batch guard (its tick needs
                            // the epoch gate's write side), and wait for
                            // the tier to come back whole. The stranded
                            // requests replay against a fresh snapshot —
                            // answered exactly once, from the
                            // post-recovery forward, never from a
                            // partial stitch.
                            let mut replay = false;
                            if let Some(sup) = supervisor.as_deref() {
                                if replays_left > 0 {
                                    replays_left -= 1;
                                    eprintln!(
                                        "serve: forward failed ({err:#}); holding \
                                         {bsize} in-flight request(s) for supervised \
                                         recovery"
                                    );
                                    sup.kick();
                                    drop(_inflight);
                                    replay = sup.wait_all_alive(RECOVERY_WAIT);
                                    if !replay {
                                        eprintln!(
                                            "serve: shard tier did not recover; \
                                             answering fail-stop Failed"
                                        );
                                    }
                                } else {
                                    eprintln!(
                                        "serve: forward failed ({err:#}); replay \
                                         budget exhausted, answering fail-stop Failed"
                                    );
                                }
                            } else {
                                eprintln!(
                                    "serve: forward failed ({err:#}); \
                                     answering fail-stop Failed"
                                );
                            }
                            if replay {
                                lock_recover(metrics).replayed_requests += bsize as u64;
                                pending = Some(batch);
                                continue;
                            }
                            {
                                let mut m = lock_recover(metrics);
                                m.failures += groups.len() as u64;
                            }
                            for members in &groups {
                                for &mi in members {
                                    let req = &batch.requests[mi];
                                    let lat = req.submitted.elapsed().as_secs_f64();
                                    local_lat.record(lat);
                                    local_prio[req.priority.rank()].record(lat);
                                    let _ = responses
                                        .send(failed_response(req, lat, bsize, epoch));
                                }
                            }
                            continue;
                        }
                    };
                    let exec_dt = clock.now().since(t0).as_secs_f64();
                    // Feed the batch service time back into the
                    // scheduler's EWMA — the signal deadline-aware early
                    // rejection estimates against.
                    sched.record_service(Duration::from_secs_f64(exec_dt.max(0.0)));
                    // A backend override returning the wrong arity would
                    // otherwise silently drop requests in the zip below:
                    // answer every member Failed and keep serving.
                    if outs.len() != groups.len() {
                        eprintln!(
                            "serve: {} returned {} outputs for {} groups; \
                             answering fail-stop Failed",
                            exe.name(),
                            outs.len(),
                            groups.len()
                        );
                        {
                            let mut m = lock_recover(metrics);
                            m.exec_secs += exec_dt;
                            m.failures += groups.len() as u64;
                        }
                        for members in &groups {
                            for &mi in members {
                                let req = &batch.requests[mi];
                                let lat = req.submitted.elapsed().as_secs_f64();
                                local_lat.record(lat);
                                local_prio[req.priority.rank()].record(lat);
                                let _ =
                                    responses.send(failed_response(req, lat, bsize, epoch));
                            }
                        }
                        continue;
                    }
                    {
                        let mut m = lock_recover(metrics);
                        m.executions += outs.len() as u64;
                        m.exec_secs += exec_dt;
                    }

                    // Optional fault injection into the response payload
                    // (first group only — models a transient corruption
                    // the per-group retry clears).
                    let inject = cfg
                        .inject_every
                        .map(|k| k > 0 && bidx % k == 0)
                        .unwrap_or(false);
                    if inject {
                        if let Some(out) = outs.first_mut() {
                            // Flip the top exponent bit of the logit where
                            // that flip perturbs the checksum the most
                            // (|v| < 2 explodes by 2^128, |v| ≥ 2 collapses
                            // to ~0), so detection does not depend on one
                            // element's magnitude versus the batch-wide
                            // checksum scale. Non-finite results rank
                            // highest — the verifier always flags those.
                            let delta = |v: f32| -> f64 {
                                let flipped = f32::from_bits(v.to_bits() ^ (1 << 30));
                                if flipped.is_finite() {
                                    (flipped as f64 - v as f64).abs()
                                } else {
                                    f64::INFINITY
                                }
                            };
                            let idx = out
                                .logits
                                .data()
                                .iter()
                                .enumerate()
                                .max_by(|a, b| delta(*a.1).total_cmp(&delta(*b.1)))
                                .map(|(i, _)| i)
                                .unwrap_or(0);
                            let (r, c) =
                                (idx / out.logits.cols(), idx % out.logits.cols());
                            let v = out.logits.get(r, c);
                            out.logits
                                .set(r, c, f32::from_bits(v.to_bits() ^ (1 << 30)));
                            lock_recover(metrics).injected_faults += 1;
                        }
                    }

                    for ((members, overlays), first_out) in
                        groups.iter().zip(&group_overlays).zip(outs)
                    {
                        // Verify with bounded re-execution: attempt 0 is
                        // the batched result; a retry re-runs this group
                        // alone (identical outputs by the run_groups
                        // contract, so recovery semantics are unchanged).
                        let mut attempts = 0usize;
                        let mut current = first_out;
                        let (status, outputs) = loop {
                            let t1 = clock.now();
                            let report = cfg.policy.verify(&current);
                            let verify_dt = clock.now().since(t1).as_secs_f64();
                            {
                                let mut m = lock_recover(metrics);
                                m.verify_secs += verify_dt;
                                if !report.ok {
                                    m.checks_fired += 1;
                                }
                            }
                            if report.ok {
                                let status = if attempts == 0 {
                                    VerifyStatus::Clean
                                } else {
                                    VerifyStatus::RecoveredAfterRetry
                                };
                                break (status, Some(current));
                            }
                            attempts += 1;
                            if attempts > cfg.max_retries {
                                break (VerifyStatus::Failed, None);
                            }
                            lock_recover(metrics).retries += 1;
                            let t0 = clock.now();
                            current = match exe.run(ops, overlays) {
                                Ok(out) => out,
                                Err(err) => {
                                    // A shard died between the batched
                                    // pass and this retry: fail-stop.
                                    eprintln!(
                                        "serve: retry forward failed ({err:#}); \
                                         answering fail-stop Failed"
                                    );
                                    if shard_tier.is_some() {
                                        lock_recover(metrics).shard_failures += 1;
                                    }
                                    break (VerifyStatus::Failed, None);
                                }
                            };
                            let dt = clock.now().since(t0).as_secs_f64();
                            {
                                let mut m = lock_recover(metrics);
                                m.executions += 1;
                                m.exec_secs += dt;
                            }
                        };
                        if status == VerifyStatus::Failed {
                            lock_recover(metrics).failures += 1;
                        }

                        // Respond per member of this overlay group.
                        let classes: Vec<usize> = outputs
                            .as_ref()
                            .map(|o| crate::tensor::ops::argmax_rows(&o.logits))
                            .unwrap_or_default();
                        for &mi in members {
                            let req = &batch.requests[mi];
                            let lat = req.submitted.elapsed().as_secs_f64();
                            local_lat.record(lat);
                            local_prio[req.priority.rank()].record(lat);
                            let resp = InferenceResponse {
                                id: req.id,
                                priority: req.priority,
                                classes: req
                                    .query_nodes
                                    .iter()
                                    .map(|&n| {
                                        (n, classes.get(n).copied().unwrap_or(usize::MAX))
                                    })
                                    .collect(),
                                status,
                                latency_secs: lat,
                                batch_size: bsize,
                                epoch,
                                retry_after_ms: None,
                            };
                            let _ = responses.send(resp);
                        }
                    }
                }
                lock_recover(latency).merge(&local_lat);
                {
                    let mut g = lock_recover(prio_latency);
                    for (a, b) in g.iter_mut().zip(&local_prio) {
                        a.merge(b);
                    }
                }
                Ok(())
            }));
        }
        drop(responses);
        let mut result = Ok(());
        for h in handles {
            // A panicking executor is a coordinator bug, but fail-stop
            // still applies: surface it as an error result, never a
            // process abort out of a poisoned join.
            let joined = match h.join() {
                Ok(r) => r,
                Err(_) => Err(anyhow::anyhow!("executor thread panicked")),
            };
            if let (Err(e), true) = (joined, result.is_ok()) {
                result = Err(e);
            }
        }
        // Executors are done (cleanly or not) — release the delta
        // applier and the supervisor daemon even if the caller still
        // holds its updates sender, so the scope can close and any
        // error above can surface.
        serving_done.store(true, std::sync::atomic::Ordering::SeqCst);
        if let Some(sup) = &supervisor {
            sup.shutdown();
        }
        result
    })?;

    let mut m = metrics.into_inner().unwrap_or_else(|p| p.into_inner());
    m.wall_secs = clock.now().since(wall_start).as_secs_f64();
    m.set_latency_percentiles(&latency.into_inner().unwrap_or_else(|p| p.into_inner()));
    for (rank, h) in prio_latency
        .into_inner()
        .unwrap_or_else(|p| p.into_inner())
        .iter()
        .enumerate()
    {
        m.set_priority_percentiles(rank, h);
    }
    let sstats = sched.stats();
    m.starvation_promotions = sstats.starvation_promotions;
    m.shed = sstats.shed;
    m.effective_wait_ms = sched.effective_wait().as_secs_f64() * 1e3;
    // Record what the run actually executed: a configured `auto`
    // resolves to its concrete scheme, and the kernel dispatch is
    // whatever `GCN_ABFT_KERNEL` (or a forced override) selected.
    m.scheme =
        backend::resolve_auto(backend::profile_for(cfg.backend), cfg.scheme, &state.ops).name();
    m.kernel = crate::tensor::kernels::active().name();
    if let Some(t) = &shard_tier {
        let tm = t.timings();
        m.shard_wait_secs = tm.wait_secs;
        m.shard_stitch_secs = tm.stitch_secs;
        m.shard_aggregates = tm.aggregates;
    }
    if let Some(sup) = &supervisor {
        let c = sup.counters();
        m.shard_respawns = c.respawns;
        m.shard_reconnects = c.reconnects;
        m.standby_adoptions = c.standby_adoptions;
        m.respawn_secs = c.respawn_secs;
    }
    Ok(m)
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]

    use super::super::batcher::CloseReason;
    use super::*;
    use crate::coordinator::request::Perturbation;
    use crate::tensor::Dense;

    fn tiny_state() -> ModelState {
        let ops = GcnOperands::dense(
            Dense::zeros(4, 3),
            Dense::eye(4),
            Dense::zeros(3, 2),
            Dense::zeros(2, 2),
        )
        .unwrap();
        let entry = ModelEntry {
            name: "test".into(),
            file: "none".into(),
            n: 4,
            f: 3,
            hidden: 2,
            classes: 2,
        };
        ModelState { ops, entry }
    }

    fn req_with(id: u64, perturbations: Vec<Perturbation>) -> InferenceRequest {
        InferenceRequest::new(id, vec![1], perturbations)
    }

    fn batch_of(requests: Vec<InferenceRequest>) -> Batch {
        Batch {
            requests,
            closed_by: CloseReason::Size,
            shed: Vec::new(),
        }
    }

    #[test]
    fn request_overlays_collect_in_list_order() {
        let state = tiny_state();
        let req = req_with(
            0,
            vec![
                Perturbation {
                    node: 2,
                    features: vec![1.0, 2.0, 3.0],
                },
                Perturbation {
                    node: 2,
                    features: vec![4.0, 5.0, 6.0],
                },
            ],
        );
        let overlays = state.request_overlays(&req).unwrap();
        assert_eq!(overlays.len(), 2);
        assert_eq!(
            overlays[0],
            Overlay {
                node: 2,
                row: &[1.0f32, 2.0, 3.0][..],
            }
        );
        // Later overlays of the same node come later — the backends
        // apply them in order, so the last one wins.
        assert_eq!(
            overlays[1],
            Overlay {
                node: 2,
                row: &[4.0f32, 5.0, 6.0][..],
            }
        );
    }

    #[test]
    fn request_overlays_reject_bad_width() {
        let state = tiny_state();
        let req = req_with(
            0,
            vec![Perturbation {
                node: 0,
                features: vec![1.0],
            }],
        );
        let err = state.request_overlays(&req).unwrap_err();
        assert!(err.to_string().contains("width mismatch"), "{err}");
    }

    #[test]
    fn request_overlays_reject_bad_node() {
        let state = tiny_state();
        let req = req_with(
            0,
            vec![Perturbation {
                node: 9,
                features: vec![1.0, 2.0, 3.0],
            }],
        );
        let err = state.request_overlays(&req).unwrap_err();
        assert!(err.to_string().contains("out of range"), "{err}");
    }

    #[test]
    fn overlay_groups_share_identical_perturbation_sets() {
        let p = |node: usize, v: f32| Perturbation {
            node,
            features: vec![v, 0.0, 0.0],
        };
        let batch = batch_of(vec![
            req_with(0, vec![]),
            req_with(1, vec![p(2, 1.0)]),
            req_with(2, vec![]),
            req_with(3, vec![p(2, 1.0)]),
            req_with(4, vec![p(2, 1.5)]),
        ]);
        let groups = overlay_groups(&batch);
        assert_eq!(groups, vec![vec![0, 2], vec![1, 3], vec![4]]);
        // The same perturbations in a different order are a different
        // forward (overlay application is order-sensitive).
        let batch = batch_of(vec![
            req_with(0, vec![p(1, 1.0), p(2, 2.0)]),
            req_with(1, vec![p(2, 2.0), p(1, 1.0)]),
        ]);
        assert_eq!(overlay_groups(&batch).len(), 2);
        // An empty batch has no groups.
        assert!(overlay_groups(&batch_of(vec![])).is_empty());
    }

    #[test]
    fn build_plans_dense_for_tiny_and_bands_when_forced_sparse() {
        let st = ModelState::build(&ServerConfig::default()).unwrap();
        assert!(!st.ops.is_sparse(), "tiny fits dense under the default budget");
        assert_eq!(st.entry.n, 64);

        let cfg = ServerConfig {
            mode: ExecMode::Sparse,
            workers: 3,
            ..Default::default()
        };
        let st = ModelState::build(&cfg).unwrap();
        assert!(st.ops.is_sparse());
        assert_eq!(st.ops.band_count(), 3);

        // Forcing dense under an impossible budget refuses up front.
        let cfg = ServerConfig {
            mode: ExecMode::Dense,
            mem_budget_mb: 0,
            ..Default::default()
        };
        assert!(ModelState::build(&cfg).is_err());
    }
}
