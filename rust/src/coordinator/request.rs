//! Request/response types for the serving coordinator.
//!
//! The serving model (DESIGN.md §3): the graph and weights are resident;
//! a request carries an optional *feature perturbation overlay* (a
//! what-if query: "reclassify with these nodes' features changed") plus
//! the node ids whose classes the caller wants. The scheduler coalesces
//! concurrent requests into accelerator passes; requests with identical
//! overlay sets share one forward, so coalescing never changes a
//! request's answer (pinned by `tests/prop_batching_equivalence.rs`).

use std::time::{Duration, Instant};

/// A feature overwrite for one node (length must equal feat_dim).
#[derive(Debug, Clone)]
pub struct Perturbation {
    pub node: usize,
    pub features: Vec<f32>,
}

/// Scheduling priority of a request. Declaration order is rank order:
/// `Interactive` is served first within a batch window.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Priority {
    /// Latency-sensitive foreground traffic (default).
    #[default]
    Interactive,
    /// Throughput-oriented bulk traffic.
    Batch,
    /// Best-effort traffic, protected only by the starvation bound.
    Background,
}

impl Priority {
    /// All priorities in rank order (index = [`Priority::rank`]).
    pub const ALL: [Priority; 3] = [Priority::Interactive, Priority::Batch, Priority::Background];

    /// 0 = most urgent. Used as the scheduler's sort key and as the
    /// index into per-priority metrics.
    pub fn rank(&self) -> usize {
        *self as usize
    }

    pub fn name(&self) -> &'static str {
        match self {
            Priority::Interactive => "interactive",
            Priority::Batch => "batch",
            Priority::Background => "background",
        }
    }

    pub fn parse(s: &str) -> Option<Priority> {
        match s.to_ascii_lowercase().as_str() {
            "interactive" | "i" => Some(Priority::Interactive),
            "batch" | "b" => Some(Priority::Batch),
            "background" | "bg" => Some(Priority::Background),
            _ => None,
        }
    }
}

/// One inference request.
#[derive(Debug, Clone)]
pub struct InferenceRequest {
    pub id: u64,
    /// Scheduling class (see [`Priority`]).
    pub priority: Priority,
    /// Optional per-request latency budget for the admission queue. It
    /// acts twice: a batch closes no later than
    /// `min(deadline, policy.max_wait)` after arrival, and once the
    /// **declared** deadline itself expires the request is
    /// force-included in the next batch ahead of priority order (a
    /// deadline looser than `max_wait` jumps priority no earlier than
    /// the caller asked for). `None` means the policy-wide `max_wait`
    /// governs close timing and only the starvation bound overrides
    /// priority.
    pub deadline: Option<Duration>,
    /// Nodes whose predicted class the caller wants.
    pub query_nodes: Vec<usize>,
    /// Feature overlay applied for this request's forward.
    pub perturbations: Vec<Perturbation>,
    pub submitted: Instant,
}

impl InferenceRequest {
    /// A default-priority request with no admission deadline, submitted
    /// now.
    pub fn new(id: u64, query_nodes: Vec<usize>, perturbations: Vec<Perturbation>) -> Self {
        InferenceRequest {
            id,
            priority: Priority::Interactive,
            deadline: None,
            query_nodes,
            perturbations,
            // gcn-lint: allow(D1, reason="client-side submit stamp: the latency epoch reported back to callers, read only via elapsed(); scheduler decisions use the Clock-trait arrival tick instead")
            submitted: Instant::now(),
        }
    }

    pub fn with_priority(mut self, priority: Priority) -> Self {
        self.priority = priority;
        self
    }

    pub fn with_deadline(mut self, deadline: Duration) -> Self {
        self.deadline = Some(deadline);
        self
    }
}

/// Verification status attached to every response.
///
/// `Shed` is deliberately a *separate* outcome class from `Failed`
/// (PyGFI-style fault-taxonomy discipline): `Failed` means the ABFT
/// checks detected a fault and the answer was withheld — a correctness
/// event — while `Shed` means admission control refused or evicted the
/// request under overload before any forward ran — an availability
/// event clients should answer with backoff, not fault triage. The two
/// are never conflated in metrics, JSON summaries, or the shard /
/// supervised recovery paths.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum VerifyStatus {
    /// All checks passed on the first execution.
    Clean,
    /// A check fired; the forward was re-executed and then passed.
    RecoveredAfterRetry,
    /// A check fired on every attempt; response withheld as faulty.
    Failed,
    /// Refused by admission control (bounded queue, priority eviction,
    /// or a provably unmeetable deadline) — no forward was executed.
    Shed,
}

/// One inference response.
#[derive(Debug, Clone)]
pub struct InferenceResponse {
    pub id: u64,
    /// The request's scheduling class (rides along for per-priority
    /// accounting at the client).
    pub priority: Priority,
    /// (node, predicted class) for each query node.
    pub classes: Vec<(usize, usize)>,
    pub status: VerifyStatus,
    /// End-to-end latency in seconds (submit → respond).
    pub latency_secs: f64,
    /// Size of the scheduling batch this request rode in.
    pub batch_size: usize,
    /// Graph version this request executed against (dynamic graphs):
    /// the epoch fence guarantees the whole batch — logits, checks,
    /// retries — ran on exactly this version. 0 until the first delta.
    pub epoch: u64,
    /// Back-off hint on `Shed` responses: the scheduler's service-time
    /// EWMA times the queued batches a retry would wait behind
    /// ([`Scheduler::retry_after_hint`](super::Scheduler::retry_after_hint)).
    /// `None` on served responses, and on sheds before the first
    /// completed batch seeds the estimate.
    pub retry_after_ms: Option<f64>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_construction() {
        let r = InferenceRequest::new(
            1,
            vec![0, 5],
            vec![Perturbation {
                node: 3,
                features: vec![0.0; 8],
            }],
        );
        assert_eq!(r.query_nodes.len(), 2);
        assert_eq!(r.perturbations[0].node, 3);
        assert_eq!(r.priority, Priority::Interactive);
        assert_eq!(r.deadline, None);

        let r = r
            .with_priority(Priority::Background)
            .with_deadline(Duration::from_millis(2));
        assert_eq!(r.priority, Priority::Background);
        assert_eq!(r.deadline, Some(Duration::from_millis(2)));
    }

    #[test]
    fn priority_rank_and_parse() {
        assert!(Priority::Interactive < Priority::Batch);
        assert!(Priority::Batch < Priority::Background);
        for (i, p) in Priority::ALL.iter().enumerate() {
            assert_eq!(p.rank(), i);
            assert_eq!(Priority::parse(p.name()), Some(*p));
        }
        assert_eq!(Priority::parse("BG"), Some(Priority::Background));
        assert_eq!(Priority::parse("urgent"), None);
        assert_eq!(Priority::default(), Priority::Interactive);
    }

    #[test]
    fn verify_status_equality() {
        assert_eq!(VerifyStatus::Clean, VerifyStatus::Clean);
        assert_ne!(VerifyStatus::Clean, VerifyStatus::Failed);
        assert_ne!(
            VerifyStatus::Shed,
            VerifyStatus::Failed,
            "availability (shed) must never be conflated with fault detection (failed)"
        );
    }
}
