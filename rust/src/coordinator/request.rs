//! Request/response types for the serving coordinator.
//!
//! The serving model (DESIGN.md §3): the graph and weights are resident;
//! a request carries an optional *feature perturbation overlay* (a
//! what-if query: "reclassify with these nodes' features changed") plus
//! the node ids whose classes the caller wants. The batcher coalesces
//! concurrent requests into one accelerator pass.

use std::time::Instant;

/// A feature overwrite for one node (length must equal feat_dim).
#[derive(Debug, Clone)]
pub struct Perturbation {
    pub node: usize,
    pub features: Vec<f32>,
}

/// One inference request.
#[derive(Debug, Clone)]
pub struct InferenceRequest {
    pub id: u64,
    /// Nodes whose predicted class the caller wants.
    pub query_nodes: Vec<usize>,
    /// Feature overlay applied for this request's batch.
    pub perturbations: Vec<Perturbation>,
    pub submitted: Instant,
}

/// Verification status attached to every response.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum VerifyStatus {
    /// All checks passed on the first execution.
    Clean,
    /// A check fired; the batch was re-executed and then passed.
    RecoveredAfterRetry,
    /// A check fired on every attempt; response withheld as faulty.
    Failed,
}

/// One inference response.
#[derive(Debug, Clone)]
pub struct InferenceResponse {
    pub id: u64,
    /// (node, predicted class) for each query node.
    pub classes: Vec<(usize, usize)>,
    pub status: VerifyStatus,
    /// End-to-end latency in seconds (submit → respond).
    pub latency_secs: f64,
    /// Size of the batch this request rode in.
    pub batch_size: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_construction() {
        let r = InferenceRequest {
            id: 1,
            query_nodes: vec![0, 5],
            perturbations: vec![Perturbation {
                node: 3,
                features: vec![0.0; 8],
            }],
            submitted: Instant::now(),
        };
        assert_eq!(r.query_nodes.len(), 2);
        assert_eq!(r.perturbations[0].node, 3);
    }

    #[test]
    fn verify_status_equality() {
        assert_eq!(VerifyStatus::Clean, VerifyStatus::Clean);
        assert_ne!(VerifyStatus::Clean, VerifyStatus::Failed);
    }
}
