//! The shard supervisor: tick-driven death detection and recovery.
//!
//! Fail-stop alone (PR 5) means a dead shard is dead forever: every
//! request touching its band answers `Failed` until an operator
//! restarts the tier. The supervisor closes the loop — "fail-stop,
//! then heal, never silent": a daemon thread ticks every
//! `--heartbeat-ms`, probes shard liveness through
//! [`ShardTransport::probe`] (poisoned stream, missed reply, pid-gone
//! for local workers) and walks each shard through a small state
//! machine:
//!
//! ```text
//!          probe ok                probe failed          tick
//! Serving ─────────▶ Serving      Serving ─────▶ Suspect ────▶ Dead
//!                                                  │ (kick skips the
//!                                                  ▼  dwell tick)
//!                             Respawning ──▶ Reshipping ──▶ Serving
//!                                  │ recover() failed
//!                                  ▼
//!                                Dead ──(strikes ≥ budget)──▶ Failed
//! ```
//!
//! One failed probe makes a shard *Suspect* (a dwell tick absorbs
//! transient hiccups); a second consecutive failure — or an executor
//! [`Supervisor::kick`] after a request actually died on the shard —
//! makes it *Dead* and triggers [`ShardTransport::recover`]:
//! respawn/reconnect the worker and re-ship its resident band + `s_c`
//! through the same `init` path that spawned it, or adopt a pre-shipped
//! `--warm-standby` worker with zero re-ship bytes. *Respawning* and
//! *Reshipping* are the transient phases of that one call (both logged,
//! so the recovery timeline is visible in stderr). A shard whose
//! recovery keeps failing goes *Failed* — terminal, so a hard fault
//! cannot spin the supervisor forever; everything else keeps serving.
//!
//! **Never a wrong answer.** The supervisor only ever runs `recover`
//! under the coordinator's epoch fence
//! ([`EpochFence::with_current`](crate::runtime::mutate::EpochFence::with_current)
//! via [`Supervisor::tick_with_ops`]'s caller), so a re-ship can never
//! race a graph delta and a half-recovered shard is never visible to an
//! aggregate. During the recovery window requests touching the dead
//! band fail-stop exactly as without supervision; the executor replays
//! them once [`Supervisor::wait_all_alive`] reports the tier whole.
//!
//! Shaped after the workgraph-style coordinator daemon pattern:
//! stale-peer detection on a tick, respawn/reconnect, a state snapshot
//! for observability ([`Supervisor::snapshot`]) and a transition log.

use super::clock::{Clock, MonotonicClock};
use super::lock_recover;
use super::shard::{RecoveryKind, ShardTransport};
use crate::runtime::GcnOperands;
use crate::util::json::Json;
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::Duration;

/// Per-shard supervision phase. `Respawning`/`Reshipping` are transient
/// within one tick (they bracket the `recover` call) but appear in the
/// transition log and in a snapshot taken mid-recovery.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardPhase {
    /// Probe says alive; requests route normally.
    Serving,
    /// One failed probe; a transient hiccup gets one dwell tick.
    Suspect,
    /// Confirmed dead; recovery will be attempted this tick.
    Dead,
    /// A replacement worker is being spawned or re-connected.
    Respawning,
    /// The resident band + `s_c` are being re-shipped (`init` path).
    Reshipping,
    /// Recovery budget exhausted; terminal. The shard fail-stops
    /// forever, exactly as an unsupervised tier would.
    Failed,
}

impl ShardPhase {
    pub fn name(&self) -> &'static str {
        match self {
            ShardPhase::Serving => "serving",
            ShardPhase::Suspect => "suspect",
            ShardPhase::Dead => "dead",
            ShardPhase::Respawning => "respawning",
            ShardPhase::Reshipping => "reshipping",
            ShardPhase::Failed => "failed",
        }
    }
}

/// Supervision knobs (`--heartbeat-ms`, plus a recovery budget so a
/// hard fault cannot respawn-loop forever).
#[derive(Debug, Clone)]
pub struct SupervisorConfig {
    /// Tick period: how often the tier is probed when nothing kicks.
    pub heartbeat: Duration,
    /// Consecutive failed recoveries before a shard goes `Failed`.
    pub max_recoveries_per_shard: u64,
}

impl Default for SupervisorConfig {
    fn default() -> SupervisorConfig {
        SupervisorConfig {
            heartbeat: Duration::from_millis(200),
            max_recoveries_per_shard: 8,
        }
    }
}

/// Cumulative recovery counters, surfaced into
/// [`ServeMetrics`](super::metrics::ServeMetrics) and the bench report.
#[derive(Debug, Clone, Default)]
pub struct SupCounters {
    /// Workers re-spawned (includes inproc heals — the in-process
    /// analogue of a respawn).
    pub respawns: u64,
    /// Remote workers re-connected at their known address.
    pub reconnects: u64,
    /// Warm standbys adopted (zero re-ship bytes).
    pub standby_adoptions: u64,
    /// Wall-clock seconds spent inside `recover` calls (spawn +
    /// handshake + band re-ship), summed over all recoveries.
    pub respawn_secs: f64,
}

struct SupState {
    phases: Vec<ShardPhase>,
    /// Consecutive failed recoveries per shard.
    strikes: Vec<u64>,
    counters: SupCounters,
    ticks: u64,
    /// Executor hint that a shard just died mid-request: the next tick
    /// skips the Suspect dwell and recovers immediately.
    kicked: bool,
    shutdown: bool,
}

/// See the module doc. Shared between the supervisor daemon thread
/// (ticking) and the executor (kick + wait_all_alive), so all state
/// sits behind one mutex + condvar.
pub struct Supervisor {
    transport: Arc<dyn ShardTransport>,
    cfg: SupervisorConfig,
    clock: MonotonicClock,
    state: Mutex<SupState>,
    cv: Condvar,
}

impl Supervisor {
    pub fn new(transport: Arc<dyn ShardTransport>, cfg: SupervisorConfig) -> Supervisor {
        let shards = transport.shards();
        Supervisor {
            transport,
            cfg,
            clock: MonotonicClock::new(),
            state: Mutex::new(SupState {
                phases: vec![ShardPhase::Serving; shards],
                strikes: vec![0; shards],
                counters: SupCounters::default(),
                ticks: 0,
                kicked: false,
                shutdown: false,
            }),
            cv: Condvar::new(),
        }
    }

    fn log_transition(&self, shard: usize, from: ShardPhase, to: ShardPhase, detail: &str) {
        if detail.is_empty() {
            eprintln!("supervisor: shard {shard} {} -> {}", from.name(), to.name());
        } else {
            eprintln!(
                "supervisor: shard {shard} {} -> {} ({detail})",
                from.name(),
                to.name()
            );
        }
    }

    /// One supervision tick over the current resident operands. The
    /// caller MUST hold the epoch fence (see
    /// [`EpochFence::with_current`](crate::runtime::mutate::EpochFence::with_current))
    /// so recovery re-ships exactly the published graph version and can
    /// never race a delta.
    pub fn tick_with_ops(&self, ops: &GcnOperands) {
        let alive = self.transport.probe();
        let mut st = lock_recover(&self.state);
        st.ticks += 1;
        let kicked = std::mem::take(&mut st.kicked);
        for (k, &ok) in alive.iter().enumerate() {
            let Some(&phase) = st.phases.get(k) else {
                continue;
            };
            let next = match (ok, phase) {
                // Failed is terminal: the budget was spent, and a shard
                // that "looks alive" after that is not trusted back.
                (_, ShardPhase::Failed) => ShardPhase::Failed,
                (true, ShardPhase::Serving) => ShardPhase::Serving,
                (true, old) => {
                    // Healed behind our back (e.g. a remote worker's own
                    // supervisor restarted it and a probe reconnected).
                    self.log_transition(k, old, ShardPhase::Serving, "probe recovered");
                    if let Some(s) = st.strikes.get_mut(k) {
                        *s = 0;
                    }
                    ShardPhase::Serving
                }
                (false, ShardPhase::Serving) if !kicked => {
                    self.log_transition(k, phase, ShardPhase::Suspect, "probe failed");
                    ShardPhase::Suspect
                }
                (false, _) => self.recover_shard(k, phase, ops, &mut st),
            };
            if let Some(p) = st.phases.get_mut(k) {
                *p = next;
            }
        }
        drop(st);
        // Wake wait_all_alive / wait_tick watchers on every tick.
        self.cv.notify_all();
    }

    /// Run one recovery attempt for shard `k`, returning its next
    /// phase. Holds the state lock through the recover call — watchers
    /// block on the condvar, not the mutex, so kick/shutdown stores
    /// queue behind a recovery but never deadlock it.
    fn recover_shard(
        &self,
        k: usize,
        from: ShardPhase,
        ops: &GcnOperands,
        st: &mut MutexGuard<'_, SupState>,
    ) -> ShardPhase {
        if from != ShardPhase::Dead {
            self.log_transition(k, from, ShardPhase::Dead, "");
        }
        self.log_transition(k, ShardPhase::Dead, ShardPhase::Respawning, "");
        self.log_transition(k, ShardPhase::Respawning, ShardPhase::Reshipping, "");
        let t0 = self.clock.now();
        match self.transport.recover(k, ops) {
            Ok(kind) => {
                let took = self.clock.now().since(t0).as_secs_f64();
                st.counters.respawn_secs += took;
                match kind {
                    RecoveryKind::Respawned | RecoveryKind::Healed => {
                        st.counters.respawns += 1;
                    }
                    RecoveryKind::Reconnected => st.counters.reconnects += 1,
                    RecoveryKind::StandbyAdopted => st.counters.standby_adoptions += 1,
                }
                if let Some(s) = st.strikes.get_mut(k) {
                    *s = 0;
                }
                self.log_transition(
                    k,
                    ShardPhase::Reshipping,
                    ShardPhase::Serving,
                    &format!("{} in {:.1} ms", kind.name(), took * 1e3),
                );
                ShardPhase::Serving
            }
            Err(e) => {
                let strikes = match st.strikes.get_mut(k) {
                    Some(s) => {
                        *s += 1;
                        *s
                    }
                    None => 1,
                };
                if strikes >= self.cfg.max_recoveries_per_shard {
                    self.log_transition(
                        k,
                        ShardPhase::Reshipping,
                        ShardPhase::Failed,
                        &format!("recovery budget exhausted after {strikes} attempts: {e:#}"),
                    );
                    ShardPhase::Failed
                } else {
                    self.log_transition(
                        k,
                        ShardPhase::Reshipping,
                        ShardPhase::Dead,
                        &format!("recovery attempt {strikes} failed: {e:#}"),
                    );
                    ShardPhase::Dead
                }
            }
        }
    }

    /// Executor hint: a request just died on a shard. The next tick
    /// (woken immediately) skips the Suspect dwell and recovers at
    /// once, minimizing the replay window.
    pub fn kick(&self) {
        let mut st = lock_recover(&self.state);
        st.kicked = true;
        drop(st);
        self.cv.notify_all();
    }

    /// Ask the daemon thread to exit; wakes every waiter.
    pub fn shutdown(&self) {
        let mut st = lock_recover(&self.state);
        st.shutdown = true;
        drop(st);
        self.cv.notify_all();
    }

    pub fn is_shutdown(&self) -> bool {
        lock_recover(&self.state).shutdown
    }

    /// Sleep until the next heartbeat, a kick, or shutdown — the daemon
    /// thread's pacing.
    pub fn wait_tick(&self, heartbeat: Duration) {
        let deadline = self.clock.now().after(heartbeat);
        let mut st = lock_recover(&self.state);
        loop {
            if st.shutdown || st.kicked {
                return;
            }
            let left = deadline.since(self.clock.now());
            if left.is_zero() {
                return;
            }
            st = match self.cv.wait_timeout(st, left) {
                Ok((g, _)) => g,
                Err(poisoned) => poisoned.into_inner().0,
            };
        }
    }

    /// Block until every shard is Serving (true) or any shard is
    /// terminally Failed / the supervisor shut down / `timeout` elapsed
    /// (false). The executor parks here before replaying a batch that
    /// died on a shard.
    pub fn wait_all_alive(&self, timeout: Duration) -> bool {
        let deadline = self.clock.now().after(timeout);
        let mut st = lock_recover(&self.state);
        loop {
            if st.phases.iter().all(|p| *p == ShardPhase::Serving) {
                return true;
            }
            if st.shutdown || st.phases.iter().any(|p| *p == ShardPhase::Failed) {
                return false;
            }
            let left = deadline.since(self.clock.now());
            if left.is_zero() {
                return false;
            }
            st = match self.cv.wait_timeout(st, left) {
                Ok((g, _)) => g,
                Err(poisoned) => poisoned.into_inner().0,
            };
        }
    }

    /// Cumulative recovery counters (copied into the serve metrics at
    /// campaign end).
    pub fn counters(&self) -> SupCounters {
        lock_recover(&self.state).counters.clone()
    }

    /// Observability snapshot: per-shard phases, tick count, counters,
    /// remaining standbys.
    pub fn snapshot(&self) -> Json {
        let st = lock_recover(&self.state);
        Json::obj(vec![
            ("ticks", Json::from(st.ticks)),
            (
                "phases",
                Json::arr(st.phases.iter().map(|p| Json::from(p.name()))),
            ),
            ("respawns", Json::from(st.counters.respawns)),
            ("reconnects", Json::from(st.counters.reconnects)),
            ("standby_adoptions", Json::from(st.counters.standby_adoptions)),
            ("respawn_secs", Json::from(st.counters.respawn_secs)),
            ("standbys", Json::from(self.transport.standby_count())),
        ])
    }
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]

    use super::*;
    use crate::coordinator::shard::InProcTransport;
    use crate::graph::DatasetId;
    use crate::runtime::GcnOperands;

    fn workload(bands: usize) -> GcnOperands {
        let g = DatasetId::Tiny.build(11);
        let m = crate::gcn::GcnModel::two_layer(&g, 8, 3);
        GcnOperands::sparse(
            g.features.clone(),
            &m.adjacency,
            m.layers[0].weights.clone(),
            m.layers[1].weights.clone(),
            bands,
        )
        .unwrap()
    }

    #[test]
    fn dwell_then_heal_counts_a_respawn() {
        let ops = workload(2);
        let transport = Arc::new(InProcTransport::new(&ops).unwrap());
        let sup = Supervisor::new(
            transport.clone() as Arc<dyn ShardTransport>,
            SupervisorConfig::default(),
        );
        sup.tick_with_ops(&ops);
        assert!(sup.wait_all_alive(Duration::from_millis(10)));
        transport.kill_shard(1);
        // First tick: Serving -> Suspect (dwell, no recovery yet).
        sup.tick_with_ops(&ops);
        assert!(!sup.wait_all_alive(Duration::from_millis(1)));
        assert_eq!(sup.counters().respawns, 0);
        // Second tick: Suspect -> recovered.
        sup.tick_with_ops(&ops);
        assert!(sup.wait_all_alive(Duration::from_millis(10)));
        let c = sup.counters();
        assert_eq!(c.respawns, 1, "inproc heal counts as a respawn");
        assert_eq!(c.reconnects + c.standby_adoptions, 0);
        let snap = sup.snapshot().to_string();
        assert!(snap.contains("\"serving\""), "{snap}");
    }

    #[test]
    fn kick_skips_the_dwell_tick() {
        let ops = workload(2);
        let transport = Arc::new(InProcTransport::new(&ops).unwrap());
        let sup = Supervisor::new(
            transport.clone() as Arc<dyn ShardTransport>,
            SupervisorConfig::default(),
        );
        transport.kill_shard(0);
        sup.kick();
        sup.tick_with_ops(&ops);
        assert!(sup.wait_all_alive(Duration::from_millis(10)));
        assert_eq!(sup.counters().respawns, 1);
    }

    #[test]
    fn exhausted_recovery_budget_is_terminal() {
        let ops = workload(2);
        let transport = Arc::new(InProcTransport::new(&ops).unwrap());
        let sup = Supervisor::new(
            transport.clone() as Arc<dyn ShardTransport>,
            SupervisorConfig {
                heartbeat: Duration::from_millis(1),
                max_recoveries_per_shard: 2,
            },
        );
        transport.kill_shard(0);
        // Recovery against drifted operands (3 bands != 2 shards) can
        // never succeed; two strikes exhaust the budget.
        let drifted = workload(3);
        sup.kick();
        sup.tick_with_ops(&drifted);
        assert_eq!(sup.counters().respawns, 0);
        sup.tick_with_ops(&drifted);
        assert!(
            !sup.wait_all_alive(Duration::from_millis(50)),
            "a Failed shard must release waiters immediately"
        );
        // Even ticks with correct operands no longer touch it.
        sup.tick_with_ops(&ops);
        assert!(!sup.wait_all_alive(Duration::from_millis(1)));
        let snap = sup.snapshot().to_string();
        assert!(snap.contains("\"failed\""), "{snap}");
    }

    #[test]
    fn wait_tick_returns_on_shutdown_and_heartbeat() {
        let ops = workload(1);
        let transport = Arc::new(InProcTransport::new(&ops).unwrap());
        let sup = Supervisor::new(transport as Arc<dyn ShardTransport>, SupervisorConfig::default());
        // Heartbeat elapses.
        sup.wait_tick(Duration::from_millis(5));
        assert!(!sup.is_shutdown());
        sup.shutdown();
        // Returns immediately once shut down.
        sup.wait_tick(Duration::from_secs(60));
        assert!(sup.is_shutdown());
    }
}
