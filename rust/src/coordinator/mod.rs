//! L3 serving coordinator: request routing, priority-aware continuous
//! batching, worker pool over runtime executables, and **online
//! GCN-ABFT verification** of every response — the deployment shape the
//! paper's checker is built for (detect-before-release, re-execute on
//! transient faults).
//!
//! The whole coordinator is a **request path**: a fault must become a
//! `Failed` response, never a panic that takes the server down. That
//! fail-stop contract is enforced twice — by `gcn-abft analyze` (lint
//! rule F1) and by the clippy restriction lints below, which propagate
//! to every `coordinator::*` submodule.

#![deny(
    clippy::unwrap_used,
    clippy::expect_used,
    clippy::panic,
    clippy::unreachable
)]

pub mod batcher;
pub mod clock;
pub mod metrics;
pub mod net;
pub mod request;
pub mod server;
pub mod shard;
pub mod shard_proto;
pub mod supervisor;
pub mod verify;

pub use batcher::{
    AdaptiveWait, Admission, AdmissionControl, Batch, BatchPolicy, CloseReason, SchedStats,
    Scheduler, ShedReason, ShedRequest, SubmitOutcome,
};
pub use clock::{Clock, MonotonicClock, Tick, VirtualClock};
pub use metrics::{LatencyHistogram, PriorityLatency, ServeMetrics};
pub use request::{
    InferenceRequest, InferenceResponse, Perturbation, Priority, VerifyStatus,
};
pub use server::{
    overlay_groups, request_overlays, run_server, run_server_with_updates, ModelState,
    ServerConfig,
};
pub use net::{run_tcp_shard_worker, TcpTransport};
pub use shard::{
    run_shard_worker, InProcTransport, RecoveryKind, ShardPlan, ShardTransport,
    ShardTransportKind, ShardedBackend,
};
#[cfg(unix)]
pub use shard::ProcTransport;
pub use shard_proto::{FrameError, ShardDead};
pub use supervisor::{ShardPhase, Supervisor, SupervisorConfig};
pub use verify::{ServePolicy, VerifyReport};

use crate::graph::DatasetId;
use crate::runtime::mutate::{self, ScheduledDelta};
use crate::runtime::{BackendKind, ChecksumScheme, ExecMode, GraphDelta};
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::rng::Pcg64;
use anyhow::{anyhow, bail, Result};
use std::sync::{Mutex, MutexGuard};
use std::time::Duration;

/// Take a mutex even if a previous holder panicked. The coordinator's
/// shared state (metrics, histograms, the scheduler queue) is only ever
/// updated in small self-consistent critical sections, so a poisoned
/// lock means some worker died mid-section boundary — a fault the
/// fail-stop contract answers with `Failed` responses, never by
/// propagating the panic into the whole server.
pub(crate) fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// Synthetic client driver + server, used by `gcn-abft serve` and the
/// `serve_inference` example. Returns a human-readable summary.
pub fn serve_cli(args: &Args) -> Result<String> {
    let dataset = DatasetId::parse(&args.get_str("dataset", "tiny")).ok_or_else(|| {
        anyhow!("unknown dataset (serving supports tiny, cora, citeseer, pubmed, nell)")
    })?;
    let requests = args.get_usize("requests", 64).map_err(|e| anyhow!("{e}"))?;
    // `--max-batch` is the canonical spelling; `--batch` stays as an
    // alias for older scripts.
    let batch_alias = args.get_usize("batch", 8).map_err(|e| anyhow!("{e}"))?;
    let max_batch = args
        .get_usize("max-batch", batch_alias)
        .map_err(|e| anyhow!("{e}"))?;
    let max_wait_ms = args
        .get_f64("max-wait-ms", 5.0)
        .map_err(|e| anyhow!("{e}"))?;
    // Upper bound keeps Duration::from_secs_f64 panic-free (an absurd
    // wait would also mean a batch that may never close before drain).
    if !(max_wait_ms > 0.0 && max_wait_ms <= 3_600_000.0) {
        return Err(anyhow!(
            "--max-wait-ms must be in (0, 3600000] (got {max_wait_ms})"
        ));
    }
    let starvation_factor = args
        .get_usize("starvation-factor", 4)
        .map_err(|e| anyhow!("{e}"))?;
    if starvation_factor == 0 {
        return Err(anyhow!("--starvation-factor must be ≥ 1"));
    }
    if args.get("min-wait-ms").is_some() && !args.has_flag("adaptive-wait") {
        // A floor with no adaptive policy would silently do nothing.
        return Err(anyhow!(
            "--min-wait-ms only applies with --adaptive-wait"
        ));
    }
    let adaptive = if args.has_flag("adaptive-wait") {
        let min_wait_ms = args
            .get_f64("min-wait-ms", 0.2)
            .map_err(|e| anyhow!("{e}"))?;
        if !(min_wait_ms > 0.0 && min_wait_ms <= max_wait_ms) {
            return Err(anyhow!(
                "--min-wait-ms must be in (0, max-wait-ms] (got {min_wait_ms})"
            ));
        }
        Some(AdaptiveWait {
            min_wait: Duration::from_secs_f64(min_wait_ms / 1e3),
            ..Default::default()
        })
    } else {
        None
    };
    // Bounded admission (`--queue-cap*`): any cap switches the
    // scheduler from the legacy unbounded queue to fallible submission
    // with shed-from-the-bottom ordering; `--early-reject` additionally
    // refuses requests whose declared deadline provably cannot be met.
    let queue_cap = match args.get("queue-cap") {
        Some(v) => Some(v.parse::<usize>().map_err(|e| anyhow!("queue-cap: {e}"))?),
        None => None,
    };
    let mut class_caps = [usize::MAX; 3];
    let mut any_class_cap = false;
    let class_flags = [
        "queue-cap-interactive",
        "queue-cap-batch",
        "queue-cap-background",
    ];
    for (slot, name) in class_caps.iter_mut().zip(class_flags) {
        if let Some(v) = args.get(name) {
            *slot = v.parse::<usize>().map_err(|e| anyhow!("{name}: {e}"))?;
            any_class_cap = true;
        }
    }
    let early_reject = args.has_flag("early-reject");
    if early_reject && queue_cap.is_none() && !any_class_cap {
        // Early rejection is part of the admission policy; without a
        // bounded queue it would silently never engage.
        return Err(anyhow!(
            "--early-reject requires a bounded queue (--queue-cap or --queue-cap-<class>)"
        ));
    }
    let admission = if queue_cap.is_some() || any_class_cap {
        let total_cap = queue_cap.unwrap_or(usize::MAX);
        if total_cap == 0 || class_caps.iter().any(|&c| c == 0) {
            return Err(anyhow!("queue caps must be ≥ 1"));
        }
        Some(AdmissionControl {
            total_cap,
            class_caps,
            early_reject,
        })
    } else {
        None
    };
    let shards = args.get_usize("shards", 0).map_err(|e| anyhow!("{e}"))?;
    if shards > 256 {
        return Err(anyhow!("--shards must be ≤ 256 (got {shards})"));
    }
    let shard_transport = ShardTransportKind::parse(&args.get_str("shard-transport", "inproc"))
        .ok_or_else(|| anyhow!("unknown --shard-transport (inproc, proc, tcp)"))?;
    let kill_shard_after = match args.get("kill-shard-after") {
        Some(v) => Some(v.parse::<u64>().map_err(|e| anyhow!("kill-shard-after: {e}"))?),
        None => None,
    };
    if kill_shard_after.is_some() && shards == 0 {
        // A fail-stop rehearsal that silently cannot fire would let an
        // operator believe the drill ran.
        return Err(anyhow!("--kill-shard-after requires --shards"));
    }
    let shard_addrs = args.get_list("shard-addrs", &[]);
    if !shard_addrs.is_empty() && shard_transport != ShardTransportKind::Tcp {
        return Err(anyhow!(
            "--shard-addrs only applies with --shard-transport tcp"
        ));
    }
    let supervise = args.has_flag("supervise");
    if supervise && shards == 0 {
        // A supervisor with nothing to watch would silently report a
        // healthy tier that does not exist.
        return Err(anyhow!("--supervise requires --shards"));
    }
    let heartbeat_ms = args
        .get_u64("heartbeat-ms", 200)
        .map_err(|e| anyhow!("{e}"))?;
    if heartbeat_ms == 0 {
        return Err(anyhow!("--heartbeat-ms must be ≥ 1"));
    }
    if args.get("heartbeat-ms").is_some() && !supervise {
        return Err(anyhow!("--heartbeat-ms only applies with --supervise"));
    }
    let warm_standby = args
        .get_usize("warm-standby", 0)
        .map_err(|e| anyhow!("{e}"))?;
    if warm_standby > 0
        && !matches!(
            shard_transport,
            ShardTransportKind::Proc | ShardTransportKind::Tcp
        )
    {
        return Err(anyhow!(
            "--warm-standby needs a worker-process transport (proc or tcp)"
        ));
    }
    let priority_mix = parse_priority_mix(&args.get_str("priority-mix", "1,0,0"))?;
    let workers = args.get_usize("workers", 2).map_err(|e| anyhow!("{e}"))?;
    let seed = args.get_u64("seed", 7).map_err(|e| anyhow!("{e}"))?;
    let scale = args.get_f64("scale", 1.0).map_err(|e| anyhow!("{e}"))?;
    if !(scale > 0.0 && scale <= 1.0) {
        return Err(anyhow!("--scale must be in (0, 1], got {scale}"));
    }
    let mode = ExecMode::parse(&args.get_str("mode", "auto"))
        .ok_or_else(|| anyhow!("unknown --mode (auto, dense, sparse)"))?;
    let backend = BackendKind::parse(&args.get_str("backend", "native"))
        .ok_or_else(|| anyhow!("unknown --backend (native, instrumented, pjrt)"))?;
    let scheme = ChecksumScheme::parse(&args.get_str("scheme", "fused"))
        .ok_or_else(|| anyhow!("unknown --scheme (fused, split, auto)"))?;
    let mem_budget_mb = args
        .get_usize("mem-budget-mb", 512)
        .map_err(|e| anyhow!("{e}"))?;
    let train_epochs = args
        .get_usize("train-epochs", 10)
        .map_err(|e| anyhow!("{e}"))?;
    let inject_every = match args.get("inject-every") {
        Some(v) => Some(v.parse::<u64>().map_err(|e| anyhow!("inject-every: {e}"))?),
        None => None,
    };
    let delta_source = match args.get("deltas") {
        Some(path) => delta_source_from_path(std::path::Path::new(&path))?,
        None => DeltaSource::None,
    };
    // Open-loop pacing (`--arrival-interval-us`): one request per fixed
    // tick, regardless of service progress — the overload-bench driver.
    let pace = match args.get("arrival-interval-us") {
        Some(v) => {
            let us = v
                .parse::<u64>()
                .map_err(|e| anyhow!("arrival-interval-us: {e}"))?;
            if us == 0 {
                return Err(anyhow!("--arrival-interval-us must be ≥ 1"));
            }
            Some(Duration::from_micros(us))
        }
        None => None,
    };
    // `--deadline-ms` declares a latency budget on every driver
    // request; it is what deadline-aware early rejection inspects.
    let driver_deadline = match args.get("deadline-ms") {
        Some(v) => {
            let ms = v.parse::<f64>().map_err(|e| anyhow!("deadline-ms: {e}"))?;
            if !(ms > 0.0 && ms <= 3_600_000.0) {
                return Err(anyhow!("--deadline-ms must be in (0, 3600000] (got {ms})"));
            }
            Some(Duration::from_secs_f64(ms / 1e3))
        }
        None => None,
    };
    let cfg = ServerConfig {
        dataset,
        artifacts_dir: args.get_str("artifacts", "artifacts").into(),
        batch: BatchPolicy {
            max_batch,
            max_wait: Duration::from_secs_f64(max_wait_ms / 1e3),
            starvation_factor: starvation_factor as u32,
            adaptive,
            admission,
        },
        workers,
        inject_every,
        seed,
        scale,
        mode,
        mem_budget_mb,
        train_epochs,
        backend,
        scheme,
        priority_mix,
        shards,
        shard_transport,
        kill_shard_after,
        supervise,
        heartbeat_ms,
        warm_standby,
        shard_addrs,
        driver_deadline,
        ..Default::default()
    };
    let summary = serve_synthetic_inner(&cfg, requests, delta_source, pace)?;
    if args.has_flag("json") {
        Ok(summary.json().to_pretty())
    } else {
        Ok(summary.render())
    }
}

/// Classify `--deltas <path>`: a Unix domain socket streams deltas
/// live; a regular file is a JSONL schedule loaded up front (one delta
/// per line, `{"after_request": k, "add_edges": ...}` — see
/// [`crate::runtime::mutate::load_delta_file`]).
fn delta_source_from_path(path: &std::path::Path) -> Result<DeltaSource> {
    let meta = std::fs::metadata(path).map_err(|e| anyhow!("--deltas {path:?}: {e}"))?;
    #[cfg(unix)]
    {
        use std::os::unix::fs::FileTypeExt;
        if meta.file_type().is_socket() {
            return Ok(DeltaSource::Socket(path.to_path_buf()));
        }
    }
    if !meta.is_file() {
        bail!("--deltas {path:?} is neither a regular file nor a unix socket");
    }
    Ok(DeltaSource::Scheduled(mutate::load_delta_file(path)?))
}

/// Parse `--priority-mix i,b,bg` into the three driver weights.
fn parse_priority_mix(raw: &str) -> Result<[f64; 3]> {
    let parts: Vec<&str> = raw.split(',').collect();
    if parts.len() != 3 {
        return Err(anyhow!(
            "--priority-mix wants three comma-separated weights \
             (interactive,batch,background), got {raw:?}"
        ));
    }
    let mut mix = [0f64; 3];
    for (slot, part) in mix.iter_mut().zip(&parts) {
        *slot = part
            .trim()
            .parse::<f64>()
            .map_err(|e| anyhow!("priority-mix: {e}"))?;
        if !slot.is_finite() || *slot < 0.0 {
            return Err(anyhow!("priority-mix weights must be finite and ≥ 0"));
        }
    }
    if mix.iter().sum::<f64>() <= 0.0 {
        return Err(anyhow!("priority-mix must have a positive total"));
    }
    Ok(mix)
}

/// Outcome of a synthetic serving run.
#[derive(Debug, Clone)]
pub struct ServeSummary {
    pub dataset: String,
    /// Aggregated serving metrics (latency percentiles included:
    /// `p50_secs`/`p95_secs`/`p99_secs` serve-wide plus `by_priority`
    /// per class — the single source of truth).
    pub metrics: ServeMetrics,
    pub responses: usize,
    pub clean: usize,
    pub recovered: usize,
    pub failed: usize,
    /// Responses answered `Shed` by admission control — an availability
    /// outcome (bounded queue / eviction / unmeetable deadline), never
    /// counted with `failed` fault detections.
    pub shed: usize,
    /// Whether the run used CSR operands (row-band sharded aggregation).
    pub sparse: bool,
    /// Row bands of `S` (1 for dense).
    pub bands: usize,
    /// Row-band shards served through the shard tier (0 = the classic
    /// in-process path).
    pub shards: usize,
    /// Shard transport name when the shard tier is on.
    pub shard_transport: &'static str,
    /// Whether the shard tier ran under the recovery supervisor.
    pub supervised: bool,
    /// Resident graph-operand footprint (S + features) in bytes.
    pub operand_bytes: usize,
    /// Which execution backend served the run.
    pub backend: &'static str,
    /// The checksum scheme the run executed. A requested `auto`
    /// resolves before serving starts, so this is always a concrete
    /// scheme name (`metrics.scheme` carries the same record).
    pub scheme: &'static str,
    /// Mean of the `retry_after_ms` back-off hints carried on `Shed`
    /// responses (`None` when nothing was shed, or when every shed
    /// predated the first service-time observation).
    pub retry_after_ms_mean: Option<f64>,
}

impl ServeSummary {
    pub fn render(&self) -> String {
        let m = &self.metrics;
        let mut out = format!(
            "SERVE {} — {} requests in {:.2}s ({:.1} req/s)\n\
             backend: {} (scheme {}) | operands: {} ({:.1} MB resident{})\n\
             batches {} (mean size {:.1}, eff-wait {:.2} ms) | groups {} | executions {} | \
             p50 {:.2} ms  p95 {:.2} ms  p99 {:.2} ms\n\
             verification: {:.3}% of execute time | checks fired {} | injected {} | \
             retries {} | failures {} | starvation promotions {}\n\
             responses: {} clean, {} recovered-after-retry, {} failed, {} shed",
            self.dataset,
            m.requests,
            m.wall_secs,
            m.throughput_rps(),
            self.backend,
            self.scheme,
            if self.sparse { "sparse (CSR)" } else { "dense" },
            self.operand_bytes as f64 / (1u64 << 20) as f64,
            if self.sparse {
                format!(", {} row bands", self.bands)
            } else {
                String::new()
            },
            m.batches,
            m.mean_batch(),
            m.effective_wait_ms,
            m.overlay_groups,
            m.executions,
            m.p50_secs * 1e3,
            m.p95_secs * 1e3,
            m.p99_secs * 1e3,
            m.verify_overhead() * 100.0,
            m.checks_fired,
            m.injected_faults,
            m.retries,
            m.failures,
            m.starvation_promotions,
            self.clean,
            self.recovered,
            self.failed,
            self.shed,
        );
        if m.shed_total() > 0 {
            out.push_str(&format!(
                "\nadmission control: shed {} (interactive {}, batch {}, background {}) — \
                 served-latency percentiles cover goodput only",
                m.shed_total(),
                m.shed[0],
                m.shed[1],
                m.shed[2],
            ));
            if let Some(hint) = self.retry_after_ms_mean {
                out.push_str(&format!(" | retry-after hint mean {hint:.2} ms"));
            }
        }
        if self.shards > 0 {
            let m = &self.metrics;
            let waits: Vec<String> = m
                .shard_wait_secs
                .iter()
                .map(|s| format!("{:.2}", s * 1e3))
                .collect();
            out.push_str(&format!(
                "\nshard tier: {} shards over {} | stitch {:.2} ms | \
                 per-shard wait [{}] ms | shard failures {}",
                self.shards,
                self.shard_transport,
                m.shard_stitch_secs * 1e3,
                waits.join(", "),
                m.shard_failures,
            ));
        }
        if self.supervised {
            out.push_str(&format!(
                "\nsupervision: respawns {} | reconnects {} | standby adoptions {} | \
                 replayed requests {} | recovery time {:.1} ms",
                m.shard_respawns,
                m.shard_reconnects,
                m.standby_adoptions,
                m.replayed_requests,
                m.respawn_secs * 1e3,
            ));
        }
        if m.epoch > 0 || m.deltas_applied > 0 || m.delta_failures > 0 {
            out.push_str(&format!(
                "\ndynamic graph: epoch {} | deltas applied {} (rejected {}) | \
                 apply time {:.2} ms",
                m.epoch,
                m.deltas_applied,
                m.delta_failures,
                m.delta_apply_secs * 1e3,
            ));
        }
        let mut prio_line = String::new();
        for (rank, pl) in m.by_priority.iter().enumerate() {
            if pl.requests == 0 {
                continue;
            }
            if !prio_line.is_empty() {
                prio_line.push_str("  |  ");
            }
            prio_line.push_str(&format!(
                "{}: {} reqs  p50 {:.2} ms  p99 {:.2} ms",
                Priority::ALL[rank].name(),
                pl.requests,
                pl.p50_secs * 1e3,
                pl.p99_secs * 1e3,
            ));
        }
        if !prio_line.is_empty() {
            out.push_str("\nper-priority: ");
            out.push_str(&prio_line);
        }
        out
    }

    pub fn json(&self) -> Json {
        let m = &self.metrics;
        let by_priority: Vec<Json> = m
            .by_priority
            .iter()
            .enumerate()
            .filter(|(_, pl)| pl.requests > 0)
            .map(|(rank, pl)| {
                Json::obj(vec![
                    ("priority", Json::from(Priority::ALL[rank].name().to_string())),
                    ("requests", Json::from(pl.requests)),
                    ("p50_ms", Json::Num(pl.p50_secs * 1e3)),
                    ("p95_ms", Json::Num(pl.p95_secs * 1e3)),
                    ("p99_ms", Json::Num(pl.p99_secs * 1e3)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("dataset", Json::from(self.dataset.clone())),
            ("backend", Json::from(self.backend.to_string())),
            ("scheme", Json::from(self.scheme.to_string())),
            ("kernel", Json::from(m.kernel.to_string())),
            ("sparse", Json::Bool(self.sparse)),
            ("bands", Json::from(self.bands)),
            ("shards", Json::from(self.shards)),
            ("shard_transport", Json::from(self.shard_transport)),
            ("shard_failures", Json::from(m.shard_failures)),
            (
                "shard_wait_secs",
                Json::Arr(m.shard_wait_secs.iter().map(|&s| Json::Num(s)).collect()),
            ),
            ("shard_stitch_secs", Json::Num(m.shard_stitch_secs)),
            ("shard_aggregates", Json::from(m.shard_aggregates)),
            ("supervised", Json::Bool(self.supervised)),
            ("shard_respawns", Json::from(m.shard_respawns)),
            ("shard_reconnects", Json::from(m.shard_reconnects)),
            ("standby_adoptions", Json::from(m.standby_adoptions)),
            ("replayed_requests", Json::from(m.replayed_requests)),
            ("respawn_secs", Json::Num(m.respawn_secs)),
            ("effective_wait_ms", Json::Num(m.effective_wait_ms)),
            ("epoch", Json::from(m.epoch)),
            ("deltas_applied", Json::from(m.deltas_applied)),
            ("delta_failures", Json::from(m.delta_failures)),
            ("delta_apply_secs", Json::Num(m.delta_apply_secs)),
            ("operand_bytes", Json::from(self.operand_bytes)),
            ("requests", Json::from(m.requests)),
            ("wall_secs", Json::Num(m.wall_secs)),
            ("throughput_rps", Json::Num(m.throughput_rps())),
            ("batches", Json::from(m.batches)),
            ("mean_batch", Json::Num(m.mean_batch())),
            ("overlay_groups", Json::from(m.overlay_groups)),
            ("p50_ms", Json::Num(m.p50_secs * 1e3)),
            ("p95_ms", Json::Num(m.p95_secs * 1e3)),
            ("p99_ms", Json::Num(m.p99_secs * 1e3)),
            ("by_priority", Json::Arr(by_priority)),
            ("verify_overhead", Json::Num(m.verify_overhead())),
            ("checks_fired", Json::from(m.checks_fired)),
            ("injected_faults", Json::from(m.injected_faults)),
            ("retries", Json::from(m.retries)),
            ("failures", Json::from(m.failures)),
            ("starvation_promotions", Json::from(m.starvation_promotions)),
            (
                "shed_by_priority",
                Json::Arr(m.shed.iter().map(|&s| Json::from(s)).collect()),
            ),
            (
                "retry_after_ms_mean",
                match self.retry_after_ms_mean {
                    Some(v) => Json::Num(v),
                    None => Json::Null,
                },
            ),
            // Total responses sent (served + failed + shed). The CI
            // smokes assert on this key; `requests` above counts batch
            // members only (goodput).
            ("responses", Json::from(self.responses)),
            ("clean", Json::from(self.clean)),
            ("recovered", Json::from(self.recovered)),
            ("failed", Json::from(self.failed)),
            ("shed", Json::from(self.shed)),
        ])
    }
}

/// Where a serve run's graph deltas come from (`serve --deltas`).
#[derive(Debug)]
pub enum DeltaSource {
    /// Static graph (the default).
    None,
    /// A preloaded schedule: each delta is injected once the driver has
    /// submitted `after_request` requests, so the interleaving against
    /// the request stream is reproducible.
    Scheduled(Vec<ScheduledDelta>),
    /// A Unix domain socket the coordinator connects to; one delta JSON
    /// per line, applied as it arrives (`after_request` is ignored — the
    /// feed's own pacing is the schedule).
    #[cfg(unix)]
    Socket(std::path::PathBuf),
}

/// Drive the server with `n_requests` synthetic what-if queries.
pub fn serve_synthetic(cfg: &ServerConfig, n_requests: usize) -> Result<ServeSummary> {
    serve_synthetic_with_deltas(cfg, n_requests, DeltaSource::None)
}

/// As [`serve_synthetic`], but with **open-loop arrival pacing**: the
/// driver submits one request per `interval` tick regardless of how far
/// serving has fallen behind — the overload-bench shape, where the
/// offered rate is a controlled multiple of the service rate instead of
/// whatever the closed feedback loop settles to. `None` keeps the
/// default bursty near-flood driver.
pub fn serve_synthetic_paced(
    cfg: &ServerConfig,
    n_requests: usize,
    interval: Option<Duration>,
) -> Result<ServeSummary> {
    serve_synthetic_inner(cfg, n_requests, DeltaSource::None, interval)
}

/// As [`serve_synthetic`], with a graph-delta feed (dynamic graphs).
pub fn serve_synthetic_with_deltas(
    cfg: &ServerConfig,
    n_requests: usize,
    delta_source: DeltaSource,
) -> Result<ServeSummary> {
    serve_synthetic_inner(cfg, n_requests, delta_source, None)
}

fn serve_synthetic_inner(
    cfg: &ServerConfig,
    n_requests: usize,
    delta_source: DeltaSource,
    pace: Option<Duration>,
) -> Result<ServeSummary> {
    let state = ModelState::build(cfg)?;
    let feat_dim = state.ops.feat_dim();
    let n_nodes = state.ops.n_nodes();

    let mut schedule: Vec<ScheduledDelta> = Vec::new();
    #[cfg(unix)]
    let mut socket_path: Option<std::path::PathBuf> = None;
    match delta_source {
        DeltaSource::None => {}
        DeltaSource::Scheduled(s) => schedule = s,
        #[cfg(unix)]
        DeltaSource::Socket(p) => socket_path = Some(p),
    }
    // Deterministic injection order regardless of how the schedule was
    // assembled (load_delta_file already sorts; API callers may not).
    schedule.sort_by_key(|d| d.after_request);
    #[cfg(unix)]
    let dynamic = !schedule.is_empty() || socket_path.is_some();
    #[cfg(not(unix))]
    let dynamic = !schedule.is_empty();

    let (req_tx, req_rx) = std::sync::mpsc::channel();
    let (resp_tx, resp_rx) = std::sync::mpsc::channel();
    let (ready_tx, ready_rx) = std::sync::mpsc::channel();
    let (delta_tx, delta_rx) = std::sync::mpsc::channel::<GraphDelta>();
    let updates = if dynamic { Some(delta_rx) } else { None };

    // Client driver thread: bursty request arrivals with random what-if
    // perturbations, query sets and priorities. Held back until every
    // worker has compiled so latencies measure steady-state serving, not
    // executable warm-up. The driver runs on a scoped thread so its
    // lifetime is bounded by this function (contract C1: no detached
    // spawns); if the server errors out early, dropping `ready_tx` and
    // `req_rx` unblocks the driver immediately, so the scope exit never
    // deadlocks.
    let seed = cfg.seed;
    let priority_mix = cfg.priority_mix;
    let driver_deadline = cfg.driver_deadline;
    // Lets the socket feeder exit once serving has drained, even if the
    // external feed never closes its end.
    let feed_done = std::sync::atomic::AtomicBool::new(false);
    let metrics = std::thread::scope(|scope| -> Result<ServeMetrics> {
        #[cfg(unix)]
        if let Some(path) = socket_path {
            let delta_tx = delta_tx.clone();
            let feed_done = &feed_done;
            scope.spawn(move || feed_deltas_from_socket(&path, &delta_tx, feed_done));
        }
        let driver = scope.spawn(move || {
            let _ = ready_rx.recv_timeout(std::time::Duration::from_secs(120));
            let mut rng = Pcg64::from_seed(seed ^ 0xD21u64);
            let mix_total: f64 = priority_mix.iter().sum();
            // Scheduled deltas interleave with submission: everything
            // due at or before the submitted-request count is injected
            // right after that request goes in.
            let mut next_delta = 0usize;
            while next_delta < schedule.len() && schedule[next_delta].after_request == 0 {
                let _ = delta_tx.send(schedule[next_delta].delta.clone());
                next_delta += 1;
            }
            for id in 0..n_requests {
                let n_pert = rng.gen_index(3);
                let perturbations = (0..n_pert)
                    .map(|_| Perturbation {
                        node: rng.gen_index(n_nodes),
                        features: (0..feat_dim)
                            .map(|_| if rng.gen_bool(0.05) { 16.0 } else { 0.0 })
                            .collect(),
                    })
                    .collect();
                let k = 1 + rng.gen_index(4);
                let query_nodes = rng.sample_indices(n_nodes, k);
                let priority = if mix_total > 0.0 {
                    Priority::ALL[rng.gen_weighted(&priority_mix)]
                } else {
                    Priority::Interactive
                };
                let mut req = InferenceRequest::new(id as u64, query_nodes, perturbations)
                    .with_priority(priority);
                if let Some(d) = driver_deadline {
                    req = req.with_deadline(d);
                }
                if req_tx.send(req).is_err() {
                    return;
                }
                let submitted = id as u64 + 1;
                while next_delta < schedule.len()
                    && schedule[next_delta].after_request <= submitted
                {
                    let _ = delta_tx.send(schedule[next_delta].delta.clone());
                    next_delta += 1;
                }
                match pace {
                    // Open loop: a fixed inter-arrival gap that never
                    // waits on service progress — overload is sustained,
                    // not self-throttled.
                    Some(gap) => std::thread::sleep(gap),
                    // Bursty arrivals: small jitter between sends.
                    None => {
                        if rng.gen_bool(0.3) {
                            std::thread::sleep(std::time::Duration::from_micros(
                                rng.gen_range(400),
                            ));
                        }
                    }
                }
            }
            // Anything scheduled past the last request still applies
            // before the stream closes.
            while next_delta < schedule.len() {
                let _ = delta_tx.send(schedule[next_delta].delta.clone());
                next_delta += 1;
            }
        });

        let metrics = server::run_server_with_updates(
            cfg,
            &state,
            req_rx,
            resp_tx,
            Some(ready_tx),
            updates,
        );
        // Release the feeder before propagating any server error — the
        // scope joins it, and an open-ended external feed would
        // otherwise hold this function hostage.
        feed_done.store(true, std::sync::atomic::Ordering::SeqCst);
        let metrics = metrics?;
        if driver.join().is_err() {
            bail!("client driver panicked");
        }
        Ok(metrics)
    })?;

    let mut clean = 0;
    let mut recovered = 0;
    let mut failed = 0;
    let mut shed = 0;
    let mut responses = 0;
    let mut hint_sum = 0.0;
    let mut hint_count = 0u64;
    while let Ok(r) = resp_rx.recv() {
        responses += 1;
        match r.status {
            VerifyStatus::Clean => clean += 1,
            VerifyStatus::RecoveredAfterRetry => recovered += 1,
            VerifyStatus::Failed => failed += 1,
            VerifyStatus::Shed => shed += 1,
        }
        if let Some(h) = r.retry_after_ms {
            hint_sum += h;
            hint_count += 1;
        }
    }
    let dataset = if cfg.scale < 1.0 {
        format!("{}@{:.2}", cfg.dataset.name(), cfg.scale)
    } else {
        cfg.dataset.name().to_string()
    };
    Ok(ServeSummary {
        dataset,
        responses,
        clean,
        recovered,
        failed,
        shed,
        sparse: state.ops.is_sparse(),
        bands: state.ops.band_count(),
        // The achieved shard count: the row partition clamps a --shards
        // larger than the band arithmetic can honor (ceil(n/ceil(n/s))
        // bands), so report what actually serves, not what was asked.
        shards: if cfg.shards > 0 {
            state.ops.band_count()
        } else {
            0
        },
        shard_transport: if cfg.shards > 0 {
            cfg.shard_transport.name()
        } else {
            "-"
        },
        supervised: cfg.shards > 0 && cfg.supervise,
        operand_bytes: state.ops.operand_bytes(),
        backend: cfg.backend.name(),
        // Report the scheme the run executed (metrics.scheme records
        // the resolved decision; a requested `auto` never surfaces).
        scheme: if metrics.scheme.is_empty() {
            cfg.scheme.name()
        } else {
            metrics.scheme
        },
        retry_after_ms_mean: if hint_count > 0 {
            Some(hint_sum / hint_count as f64)
        } else {
            None
        },
        metrics,
    })
}

/// Feed deltas from a connected Unix-socket stream into the server's
/// update channel: newline-delimited delta JSON, forwarded as it
/// arrives. Read timeouts let the feeder notice `done` (set when
/// serving drains), so an external feed that never closes cannot wedge
/// the serve scope.
#[cfg(unix)]
fn feed_deltas_from_socket(
    path: &std::path::Path,
    deltas: &std::sync::mpsc::Sender<GraphDelta>,
    done: &std::sync::atomic::AtomicBool,
) {
    use std::io::Read as _;
    // gcn-lint: allow(N1, reason="delta-feed client socket, not shard-tier plumbing: it dials the operator's --deltas socket and never carries shard frames, so confining it to net.rs would couple graph feeds to the worker protocol")
    let mut stream = match std::os::unix::net::UnixStream::connect(path) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("serve: cannot connect to delta socket {path:?}: {e}");
            return;
        }
    };
    let _ = stream.set_read_timeout(Some(Duration::from_millis(50)));
    let mut pending: Vec<u8> = Vec::new();
    let mut chunk = [0u8; 4096];
    loop {
        if done.load(std::sync::atomic::Ordering::SeqCst) {
            return;
        }
        match stream.read(&mut chunk) {
            Ok(0) => break, // feed closed — flush whatever is buffered
            Ok(n) => {
                pending.extend_from_slice(&chunk[..n]);
                while let Some(nl) = pending.iter().position(|&b| b == b'\n') {
                    let line: Vec<u8> = pending.drain(..=nl).collect();
                    forward_delta_line(&line, deltas);
                }
            }
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock
                        | std::io::ErrorKind::TimedOut
                        | std::io::ErrorKind::Interrupted
                ) => {}
            Err(e) => {
                eprintln!("serve: delta socket read failed: {e}");
                return;
            }
        }
    }
    if !pending.is_empty() {
        forward_delta_line(&pending, deltas);
    }
}

/// Parse one socket line into a delta and forward it. A malformed line
/// is skipped loudly — a streamed feed must not take serving down.
#[cfg(unix)]
fn forward_delta_line(raw: &[u8], deltas: &std::sync::mpsc::Sender<GraphDelta>) {
    let Ok(text) = std::str::from_utf8(raw) else {
        eprintln!("serve: delta line is not UTF-8; skipped");
        return;
    };
    let line = text.trim();
    if line.is_empty() || line.starts_with('#') {
        return;
    }
    let parsed = Json::parse(line)
        .map_err(|e| anyhow!("{e}"))
        .and_then(|j| mutate::parse_scheduled(&j));
    match parsed {
        Ok(s) => {
            let _ = deltas.send(s.delta);
        }
        Err(e) => eprintln!("serve: bad delta line skipped ({e:#})"),
    }
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used, clippy::panic)]

    use super::*;
    use std::sync::Arc;

    #[test]
    fn lock_recover_yields_the_data_after_a_poisoning_panic() {
        let m = Arc::new(Mutex::new(41u32));
        let m2 = Arc::clone(&m);
        let joined = std::thread::spawn(move || {
            let _guard = m2.lock().unwrap();
            panic!("poison the mutex");
        })
        .join();
        assert!(joined.is_err(), "the poisoning thread must have panicked");
        assert!(m.lock().is_err(), "mutex must actually be poisoned");
        // The recovered guard sees the pre-panic data and the mutex
        // keeps working — fail-stop handles the *fault*, not the lock.
        *lock_recover(&m) += 1;
        assert_eq!(*lock_recover(&m), 42);
    }

    fn field<'a>(j: &'a Json, key: &str) -> &'a Json {
        match j {
            Json::Obj(pairs) => {
                &pairs
                    .iter()
                    .find(|(k, _)| k == key)
                    .unwrap_or_else(|| panic!("missing key {key}"))
                    .1
            }
            other => panic!("expected object, got {other:?}"),
        }
    }

    /// Regression: an empty-class serve has NaN percentiles
    /// (`PriorityLatency` docs), and NaN is not valid JSON. The summary
    /// writer must emit `null` — the whole document stays parseable by
    /// a strict reader, round-tripping through our own parser.
    #[test]
    fn empty_class_summary_json_parses_back_with_null_percentiles() {
        let mut metrics = ServeMetrics::default();
        // No responses at all: serve-wide and per-class percentiles NaN.
        metrics.set_latency_percentiles(&LatencyHistogram::new());
        assert!(metrics.p50_secs.is_nan());
        let summary = ServeSummary {
            dataset: "tiny".into(),
            metrics,
            responses: 0,
            clean: 0,
            recovered: 0,
            failed: 0,
            shed: 0,
            sparse: false,
            bands: 1,
            shards: 0,
            shard_transport: "-",
            supervised: false,
            operand_bytes: 0,
            backend: "native",
            scheme: "fused",
            retry_after_ms_mean: None,
        };
        let text = summary.json().to_pretty();
        assert!(!text.contains("NaN"), "NaN leaked into JSON: {text}");
        let parsed = Json::parse(&text).expect("summary JSON must parse back");
        assert_eq!(field(&parsed, "p50_ms"), &Json::Null);
        assert_eq!(field(&parsed, "p99_ms"), &Json::Null);
        // No sheds → no back-off hint; the key is still present (null).
        assert_eq!(field(&parsed, "retry_after_ms_mean"), &Json::Null);
        // Shed accounting is present and distinct from failures, and the
        // total response count round-trips (the CI smokes assert on it).
        assert_eq!(field(&parsed, "responses"), &Json::Int(0));
        assert_eq!(field(&parsed, "shed"), &Json::Int(0));
        assert_eq!(field(&parsed, "failed"), &Json::Int(0));
        match field(&parsed, "shed_by_priority") {
            Json::Arr(a) => assert_eq!(a.len(), 3),
            other => panic!("shed_by_priority should be an array, got {other:?}"),
        }
        // Classes with no traffic are omitted rather than emitted as
        // NaN-filled rows.
        assert_eq!(field(&parsed, "by_priority"), &Json::Arr(vec![]));
    }
}
