//! The scheduler's time base: a [`Clock`] trait with a real
//! [`MonotonicClock`] and a test [`VirtualClock`].
//!
//! Every scheduling decision in [`crate::coordinator::batcher`] is a pure
//! function of the admission queue and a [`Tick`] read from the clock —
//! never of `Instant::now()` directly. Production runs on
//! `MonotonicClock` (ticks are nanoseconds of real elapsed time); tests
//! run on `VirtualClock`, advance time explicitly, and drive the
//! scheduler with non-blocking polls, so every invariant — priority
//! ordering, deadline closes, the starvation bound — is checked
//! deterministically with **zero real sleeps**.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

/// A point on a [`Clock`]'s timeline: nanoseconds since the clock's
/// epoch. Ticks from different clocks are not comparable.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Tick(pub u64);

impl Tick {
    pub const ZERO: Tick = Tick(0);

    /// This tick advanced by `d` (saturating at the end of time).
    pub fn after(self, d: Duration) -> Tick {
        let ns = u64::try_from(d.as_nanos()).unwrap_or(u64::MAX);
        Tick(self.0.saturating_add(ns))
    }

    /// Elapsed duration since an earlier tick (zero if `earlier` is not
    /// actually earlier).
    pub fn since(self, earlier: Tick) -> Duration {
        Duration::from_nanos(self.0.saturating_sub(earlier.0))
    }
}

/// Source of scheduler time. Implementations must be cheap and
/// monotonic: `now()` never goes backwards.
pub trait Clock: Send + Sync {
    fn now(&self) -> Tick;
}

/// Real time: ticks are nanoseconds since the clock was constructed.
#[derive(Debug, Clone)]
pub struct MonotonicClock {
    epoch: Instant,
}

impl MonotonicClock {
    pub fn new() -> MonotonicClock {
        MonotonicClock {
            epoch: Instant::now(),
        }
    }
}

impl Default for MonotonicClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for MonotonicClock {
    fn now(&self) -> Tick {
        let ns = u64::try_from(self.epoch.elapsed().as_nanos()).unwrap_or(u64::MAX);
        Tick(ns)
    }
}

/// Test time: advances only when told to. Interior-mutable so tests can
/// advance it while the scheduler holds a shared reference.
#[derive(Debug, Default)]
pub struct VirtualClock {
    now_ns: AtomicU64,
}

impl VirtualClock {
    pub fn new() -> VirtualClock {
        VirtualClock::default()
    }

    /// Jump forward by `d`.
    pub fn advance(&self, d: Duration) {
        let ns = u64::try_from(d.as_nanos()).unwrap_or(u64::MAX);
        self.now_ns.fetch_add(ns, Ordering::SeqCst);
    }

    /// Jump to an absolute tick (must not move backwards).
    pub fn set(&self, t: Tick) {
        let prev = self.now_ns.swap(t.0, Ordering::SeqCst);
        assert!(prev <= t.0, "VirtualClock moved backwards: {prev} -> {}", t.0);
    }
}

impl Clock for VirtualClock {
    fn now(&self) -> Tick {
        Tick(self.now_ns.load(Ordering::SeqCst))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tick_arithmetic() {
        let t = Tick::ZERO.after(Duration::from_millis(5));
        assert_eq!(t, Tick(5_000_000));
        assert_eq!(t.since(Tick::ZERO), Duration::from_millis(5));
        // `since` an out-of-order tick saturates to zero.
        assert_eq!(Tick::ZERO.since(t), Duration::ZERO);
        // Saturating far-future arithmetic does not wrap.
        assert_eq!(Tick(u64::MAX).after(Duration::from_secs(1)), Tick(u64::MAX));
    }

    #[test]
    fn virtual_clock_advances_only_on_demand() {
        let c = VirtualClock::new();
        assert_eq!(c.now(), Tick::ZERO);
        c.advance(Duration::from_millis(3));
        assert_eq!(c.now(), Tick(3_000_000));
        c.advance(Duration::from_millis(3));
        assert_eq!(c.now(), Tick(6_000_000));
        c.set(Tick(10_000_000));
        assert_eq!(c.now(), Tick(10_000_000));
    }

    #[test]
    #[should_panic(expected = "moved backwards")]
    fn virtual_clock_rejects_time_travel() {
        let c = VirtualClock::new();
        c.advance(Duration::from_millis(2));
        c.set(Tick(1));
    }

    #[test]
    fn monotonic_clock_is_monotonic() {
        let c = MonotonicClock::new();
        let a = c.now();
        let b = c.now();
        assert!(b >= a);
    }
}
