//! The shard tier: location-transparent row-band sharding of the
//! propagation matrix.
//!
//! PR 2 proved the algebra: the fused checksum `eᵀ·(S·X·W)·e` and its
//! cached partials are **additive over row bands of `S`**, so a banded
//! aggregation stitches back exactly (logits by concatenation, checksum
//! partials by summation). Until now that blueprint lived as scoped
//! threads buried inside the operand kernel
//! ([`crate::runtime::operands::SOperand::aggregate`]); this module
//! makes the band/partial-checksum boundary a first-class interface:
//!
//! * [`ShardPlan`] — the row-band partition of a resident
//!   [`GcnOperands`] set (derived from the banded `S` the memory
//!   planner already builds), with per-shard resident and per-request
//!   payload footprints;
//! * [`ShardTransport`] — *where* the bands run: [`InProcTransport`]
//!   (today's scoped-thread fan-out, now a trait impl) or
//!   [`ProcTransport`] (spawned `gcn-abft shard-worker` subprocesses
//!   speaking a length-prefixed JSON + raw-little-endian-float protocol
//!   over Unix domain sockets — std-only, no serialization crates);
//! * [`ShardedBackend`] — a [`GcnBackend`] that runs the ordinary
//!   native forward ([`native::forward_with`]) with the two `S·X`
//!   aggregation phases routed through a transport.
//!
//! **Bit-identity.** Every transport computes each band with
//! [`RowBand::aggregate_into`] — the same serial per-row kernel the
//! in-process path uses — and the coordinator stitches in fixed band
//! order, so `serve --shards N --shard-transport inproc|proc` produces
//! logits bit-identical to unsharded serving and identical fused/split
//! alarm decisions (`tests/prop_shard_equivalence.rs`). The two
//! transports are bit-identical to *each other* including the stitched
//! checksum bits.
//!
//! **Fail-stop.** A shard that dies mid-request (socket error, killed
//! worker, poisoned in-proc band) fails the whole aggregation: the
//! coordinator answers the affected requests with
//! [`VerifyStatus::Failed`](super::request::VerifyStatus) and keeps
//! serving — never a silently stitched partial answer. A checksum
//! corrupted *inside* a shard surfaces through the ordinary GCN-ABFT
//! verification of the stitched sums, since the band partials add into
//! the global predicted/actual pair.
//!
//! The wire protocol (one frame = `u32` little-endian header length,
//! UTF-8 JSON header, raw payload of `header.payload` bytes):
//!
//! ```text
//! coordinator → worker   {"type":"init", shard, row0, rows, cols, nnz, payload}
//!                        payload = row_ptr u64[rows+1] · col_idx u64[nnz]
//!                                  · values f32[nnz] · s_c f64[cols]
//! worker → coordinator   {"type":"ready", shard}
//! coordinator → worker   {"type":"agg", rows, cols, payload}
//!                        payload = x f32[rows·cols] · x_r f32[rows]
//! worker → coordinator   {"type":"band", rows, cols, payload}
//!                        payload = z f32[rows·cols] · pred f64 · actual f64
//! coordinator → worker   {"type":"shutdown"}
//! coordinator → worker   {"type":"delta", shard, row0, rows, cols, nnz, payload}
//!                        payload = row_ptr u64[rows+1] · col_idx u64[nnz]
//!                                  · values f32[nnz] · s_c f64[cols]
//! worker → coordinator   {"type":"ack", shard}
//! ```
//!
//! The `delta`/`ack` pair is the dynamic-graph path
//! ([`crate::runtime::mutate`]): after the coordinator patches the
//! resident operands inside the epoch fence, it re-ships each mutated
//! band (same payload layout as `init`) and waits for the ack in the
//! same lockstep discipline as `agg`/`band` — a failed re-ship poisons
//! the shard so no later aggregate can stitch mixed-version bands.
//!
//! Floats cross the wire as raw little-endian bit patterns (never as
//! decimal text), which is what keeps the proc transport bit-identical.

use crate::runtime::backend::native;
use crate::runtime::backend::{self, ChecksumScheme, ExecPlan, GcnBackend, Overlay};
use crate::runtime::mutate::DeltaOutcome;
use crate::runtime::{GcnOperands, GcnOutputs, SOperand};
use crate::tensor::Dense;
use crate::util::json::Json;
use super::clock::{Clock, MonotonicClock};
use super::lock_recover;
use anyhow::{anyhow, bail, Result};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

/// Transport selector for configs and the `--shard-transport` CLI flag.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardTransportKind {
    /// Scoped threads inside the coordinator process (zero copies).
    InProc,
    /// One `gcn-abft shard-worker` subprocess per shard, over Unix
    /// domain sockets.
    Proc,
}

impl ShardTransportKind {
    pub fn name(&self) -> &'static str {
        match self {
            ShardTransportKind::InProc => "inproc",
            ShardTransportKind::Proc => "proc",
        }
    }

    pub fn parse(s: &str) -> Option<ShardTransportKind> {
        match s.to_ascii_lowercase().as_str() {
            "inproc" | "thread" | "threads" => Some(ShardTransportKind::InProc),
            "proc" | "process" | "uds" => Some(ShardTransportKind::Proc),
            _ => None,
        }
    }
}

/// Cumulative transport observability (surfaced in
/// [`super::metrics::ServeMetrics`] so proc-transport overhead is
/// measured, not guessed).
#[derive(Debug, Clone, Default)]
pub struct ShardTimings {
    /// Aggregation phases executed.
    pub aggregates: u64,
    /// Seconds the stitcher spent blocked on each shard (proc: socket
    /// round-trip; inproc: the band's compute on its scoped worker).
    pub wait_secs: Vec<f64>,
    /// Seconds spent stitching band results (row copies + partial sums).
    pub stitch_secs: f64,
}

/// One shard's slice of the [`ShardPlan`].
#[derive(Debug, Clone, Copy)]
pub struct ShardBand {
    /// First global row of `S` this shard owns.
    pub row0: usize,
    /// Rows of `S` this shard owns.
    pub rows: usize,
    /// Stored nonzeros of the band.
    pub nnz: usize,
    /// Resident bytes at the shard: the band CSR plus its `s_c` vector.
    pub resident_bytes: usize,
}

/// The row-band partition of one resident operand set across shards —
/// the deployment-facing view of what each worker holds and what each
/// request ships.
#[derive(Debug, Clone)]
pub struct ShardPlan {
    pub shards: usize,
    /// Total rows of `S` (= N nodes).
    pub n: usize,
    pub bands: Vec<ShardBand>,
}

impl ShardPlan {
    /// Derive the plan from a resident operand set. The operand planner
    /// already partitioned a CSR `S` into row bands (one per requested
    /// shard); dense operands have no band structure to distribute.
    pub fn for_operands(ops: &GcnOperands) -> Result<ShardPlan> {
        let SOperand::Banded(bands) = &ops.s else {
            bail!(
                "sharded serving needs CSR operands with a row-banded S \
                 (got dense operands; use --mode sparse)"
            );
        };
        let plan_bands = bands
            .iter()
            .map(|b| ShardBand {
                row0: b.row0,
                rows: b.s.rows(),
                nnz: b.s.nnz(),
                resident_bytes: b.s.heap_bytes() + b.s_c.len() * std::mem::size_of::<f64>(),
            })
            .collect();
        Ok(ShardPlan {
            shards: bands.len(),
            n: ops.n_nodes(),
            bands: plan_bands,
        })
    }

    /// Largest per-shard resident footprint (bytes).
    pub fn max_resident_bytes(&self) -> usize {
        self.bands.iter().map(|b| b.resident_bytes).max().unwrap_or(0)
    }

    /// Bytes shipped to **each** shard per request on the proc
    /// transport: both aggregation phases' `x` + `x_r` payloads.
    pub fn request_payload_bytes(&self, ops: &GcnOperands) -> usize {
        let per_phase = |width: usize| (self.n * width + self.n) * std::mem::size_of::<f32>();
        per_phase(ops.hidden_dim()) + per_phase(ops.num_classes())
    }
}

/// Where the row bands of `S` execute. One `aggregate` call is one
/// `z = S·x` phase: the transport computes every band (wherever its
/// shards live), stitches `z` by row-band concatenation and the fused
/// checksum partials `(s_c[band]·x_r, eᵀ·z[band]·e)` by summation in
/// band order, and returns the stitched triple. Any shard failing fails
/// the whole phase (fail-stop — the coordinator never sees a partial
/// stitch).
pub trait ShardTransport: Send + Sync {
    fn name(&self) -> &'static str;

    fn shards(&self) -> usize;

    /// One aggregation phase over the resident operands' band partition.
    fn aggregate(&self, ops: &GcnOperands, x: &Dense, x_r: &[f32]) -> Result<(Dense, f64, f64)>;

    /// Tear down one shard (fault injection for fail-stop tests): every
    /// subsequent `aggregate` touching the shard must error. Returns
    /// `false` when the shard index is out of range.
    fn kill_shard(&self, shard: usize) -> bool;

    /// Bring every shard onto a new graph version after a
    /// [`crate::runtime::mutate::GraphDelta`] patched the resident
    /// operands. The coordinator calls this *inside* the epoch fence —
    /// no `aggregate` can interleave — passing the already-patched
    /// operands plus the patch outcome naming which bands changed.
    /// Fail-stop: on error the delta is rejected (the epoch does not
    /// advance) and any shard whose resident version is now unknown is
    /// poisoned, so a later aggregate can never stitch mixed-version
    /// bands.
    fn apply_delta(&self, ops: &GcnOperands, outcome: &DeltaOutcome) -> Result<()>;

    /// Cumulative timings snapshot.
    fn timings(&self) -> ShardTimings;
}

/// Today's scoped-thread band fan-out, as a [`ShardTransport`]: each
/// band of the resident `S` aggregates on its own scoped worker writing
/// a disjoint row slice of `z`. This is the same machinery
/// [`SOperand::aggregate`] runs for the unsharded sparse path — one
/// band's compute is the serial [`RowBand::aggregate_into`] either way —
/// so the in-proc shard tier is bit-identical to unsharded serving,
/// checksum bits included, whenever the band partitions match.
///
/// [`RowBand::aggregate_into`]: crate::runtime::operands::RowBand::aggregate_into
#[derive(Debug)]
pub struct InProcTransport {
    shards: usize,
    /// Poisoned shards ([`ShardTransport::kill_shard`]): the in-proc
    /// analogue of a dead worker process.
    down: Vec<AtomicBool>,
    timings: Mutex<ShardTimings>,
    clock: MonotonicClock,
}

impl InProcTransport {
    /// Transport over an operand set whose `S` is banded into the
    /// desired shard count.
    pub fn new(ops: &GcnOperands) -> Result<InProcTransport> {
        let plan = ShardPlan::for_operands(ops)?;
        Ok(InProcTransport {
            shards: plan.shards,
            down: (0..plan.shards).map(|_| AtomicBool::new(false)).collect(),
            timings: Mutex::new(ShardTimings {
                wait_secs: vec![0.0; plan.shards],
                ..Default::default()
            }),
            clock: MonotonicClock::new(),
        })
    }
}

impl ShardTransport for InProcTransport {
    fn name(&self) -> &'static str {
        "inproc"
    }

    fn shards(&self) -> usize {
        self.shards
    }

    fn aggregate(&self, ops: &GcnOperands, x: &Dense, x_r: &[f32]) -> Result<(Dense, f64, f64)> {
        let SOperand::Banded(bands) = &ops.s else {
            bail!("inproc shard transport got dense operands");
        };
        if bands.len() != self.shards {
            bail!(
                "operand band count {} != shard count {}",
                bands.len(),
                self.shards
            );
        }
        for (k, d) in self.down.iter().enumerate() {
            if d.load(Ordering::SeqCst) {
                bail!("shard {k} is down");
            }
        }
        let mut out = Dense::zeros(ops.n_nodes(), x.cols());
        // THE band fan-out — the same helper the unsharded sparse path
        // runs, so inproc sharding is bit-identical by construction.
        let partials =
            crate::runtime::operands::aggregate_bands_timed(bands, x, x_r, out.data_mut());
        let t_stitch = self.clock.now();
        let pred = partials.iter().map(|p| p.0).sum();
        let actual = partials.iter().map(|p| p.1).sum();
        let stitch = self.clock.now().since(t_stitch).as_secs_f64();
        {
            let mut tm = lock_recover(&self.timings);
            tm.aggregates += 1;
            tm.stitch_secs += stitch;
            for (acc, p) in tm.wait_secs.iter_mut().zip(&partials) {
                *acc += p.2;
            }
        }
        Ok((out, pred, actual))
    }

    fn kill_shard(&self, shard: usize) -> bool {
        match self.down.get(shard) {
            Some(d) => {
                d.store(true, Ordering::SeqCst);
                true
            }
            None => false,
        }
    }

    fn apply_delta(&self, ops: &GcnOperands, _outcome: &DeltaOutcome) -> Result<()> {
        // In-proc shards read their bands straight from the resident
        // operands on every aggregate, so there is nothing to re-ship —
        // only the band-partition invariant to enforce now, rather than
        // letting a collapsed partition surface one request later.
        let SOperand::Banded(bands) = &ops.s else {
            bail!("inproc shard transport got dense operands");
        };
        if bands.len() != self.shards {
            bail!(
                "delta changed the band partition ({} bands != {} shards); \
                 restart the shard tier",
                bands.len(),
                self.shards
            );
        }
        Ok(())
    }

    fn timings(&self) -> ShardTimings {
        lock_recover(&self.timings).clone()
    }
}

/// A [`GcnBackend`] running the ordinary native forward with both `S·X`
/// aggregation phases routed through a [`ShardTransport`]. Combination
/// matmuls, overlay patching and (split scheme) phase-1 checks are the
/// exact in-process code ([`native::forward_with`]), so the transport
/// can change *where* bands run but never *what* a forward computes.
pub struct ShardedBackend {
    transport: Arc<dyn ShardTransport>,
    scheme: ChecksumScheme,
    threads: usize,
}

impl ShardedBackend {
    pub fn new(
        transport: Arc<dyn ShardTransport>,
        scheme: ChecksumScheme,
        threads: usize,
    ) -> ShardedBackend {
        ShardedBackend {
            transport,
            scheme,
            threads: threads.max(1),
        }
    }

    pub fn transport(&self) -> &Arc<dyn ShardTransport> {
        &self.transport
    }
}

impl GcnBackend for ShardedBackend {
    fn name(&self) -> &'static str {
        "native-sharded"
    }

    fn plan(&self, ops: &GcnOperands) -> Result<ExecPlan> {
        if ops.band_count() != self.transport.shards() {
            bail!(
                "operand band count {} != shard count {}",
                ops.band_count(),
                self.transport.shards()
            );
        }
        Ok(backend::plan_with_profile(
            self.name(),
            crate::opcount::backend::BackendProfile::Native,
            self.scheme,
            ops,
            self.transport.shards(),
            self.threads,
        ))
    }

    fn run(&self, ops: &GcnOperands, overlays: &[Overlay<'_>]) -> Result<GcnOutputs> {
        native::forward_with(ops, overlays, self.threads, self.scheme, |x, x_r| {
            self.transport.aggregate(ops, x, x_r)
        })
    }
}

/// Build the transport a server config selects, over the resident
/// operands. The band partition is derived from `--shards` at operand
/// build, but [`row_band_bounds`] may legitimately produce *fewer*
/// bands than requested (`ceil(n/ceil(n/shards))` — e.g. 64 nodes at
/// `--shards 48` yield 32 two-row bands); the operands' actual band
/// count is the source of truth, never a startup refusal.
///
/// [`row_band_bounds`]: crate::runtime::operands::row_band_bounds
pub fn build_transport(
    cfg: &super::server::ServerConfig,
    ops: &GcnOperands,
) -> Result<Arc<dyn ShardTransport>> {
    let plan = ShardPlan::for_operands(ops)?;
    // The operand build derives its bands from cfg.shards, and the
    // partition arithmetic can only clamp downward.
    debug_assert!(plan.shards <= cfg.shards.max(1));
    match cfg.shard_transport {
        ShardTransportKind::InProc => Ok(Arc::new(InProcTransport::new(ops)?)),
        #[cfg(unix)]
        ShardTransportKind::Proc => Ok(Arc::new(ProcTransport::spawn(
            ops,
            cfg.shard_worker_bin.as_deref(),
        )?)),
        #[cfg(not(unix))]
        ShardTransportKind::Proc => bail!("the proc shard transport is only available on unix"),
    }
}

// ---------------------------------------------------------------------
// Wire protocol (shared by the proc transport and the worker binary).
// ---------------------------------------------------------------------

/// Sanity ceiling on frame payloads (covers Nell-scale phases with slack;
/// a corrupt length must not trigger a huge allocation).
const MAX_PAYLOAD_BYTES: usize = 1 << 31;
const MAX_HEADER_BYTES: usize = 1 << 16;

fn push_f32s(buf: &mut Vec<u8>, xs: &[f32]) {
    for &x in xs {
        buf.extend_from_slice(&x.to_le_bytes());
    }
}

fn push_f64s(buf: &mut Vec<u8>, xs: &[f64]) {
    for &x in xs {
        buf.extend_from_slice(&x.to_le_bytes());
    }
}

fn push_u64s(buf: &mut Vec<u8>, xs: &[usize]) {
    for &x in xs {
        buf.extend_from_slice(&(x as u64).to_le_bytes());
    }
}

/// Sequential reader over a frame payload.
struct Wire<'a>(&'a [u8]);

impl<'a> Wire<'a> {
    fn chunk(&mut self, bytes: usize) -> Result<&'a [u8]> {
        if self.0.len() < bytes {
            bail!("frame payload truncated ({} < {bytes} bytes)", self.0.len());
        }
        let (head, tail) = self.0.split_at(bytes);
        self.0 = tail;
        Ok(head)
    }

    fn f32s(&mut self, n: usize) -> Result<Vec<f32>> {
        let raw = self.chunk(n * 4)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    fn f64s(&mut self, n: usize) -> Result<Vec<f64>> {
        let raw = self.chunk(n * 8)?;
        Ok(raw
            .chunks_exact(8)
            .map(|c| f64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]]))
            .collect())
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(self.f64s(1)?[0])
    }

    fn usizes(&mut self, n: usize) -> Result<Vec<usize>> {
        let raw = self.chunk(n * 8)?;
        raw.chunks_exact(8)
            .map(|c| {
                let raw = u64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]]);
                usize::try_from(raw).map_err(|_| anyhow!("index overflows usize"))
            })
            .collect()
    }

    fn done(&self) -> Result<()> {
        if !self.0.is_empty() {
            bail!("{} trailing bytes in frame payload", self.0.len());
        }
        Ok(())
    }
}

/// Encode one frame: header length, JSON header, raw payload. The
/// header's `payload` field must equal `payload.len()`.
fn encode_frame(header: &Json, payload: &[u8]) -> Vec<u8> {
    let h = header.to_string();
    let mut buf = Vec::with_capacity(4 + h.len() + payload.len());
    buf.extend_from_slice(&(h.len() as u32).to_le_bytes());
    buf.extend_from_slice(h.as_bytes());
    buf.extend_from_slice(payload);
    buf
}

/// Read one frame. `Ok(None)` on clean EOF at a frame boundary (the
/// peer hung up between requests).
fn read_frame(r: &mut impl std::io::Read) -> Result<Option<(Json, Vec<u8>)>> {
    let mut len4 = [0u8; 4];
    // Distinguish "no next frame" from "died mid-frame".
    let mut filled = 0;
    while filled < 4 {
        match r.read(&mut len4[filled..]) {
            Ok(0) if filled == 0 => return Ok(None),
            Ok(0) => bail!("peer closed mid-frame"),
            Ok(n) => filled += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e.into()),
        }
    }
    let hlen = u32::from_le_bytes(len4) as usize;
    if hlen == 0 || hlen > MAX_HEADER_BYTES {
        bail!("implausible frame header length {hlen}");
    }
    let mut hbuf = vec![0u8; hlen];
    r.read_exact(&mut hbuf)?;
    let header = Json::parse(std::str::from_utf8(&hbuf)?)
        .map_err(|e| anyhow!("bad frame header: {e}"))?;
    let plen = header
        .get("payload")
        .and_then(Json::as_usize)
        .unwrap_or(0);
    if plen > MAX_PAYLOAD_BYTES {
        bail!("implausible frame payload length {plen}");
    }
    let mut payload = vec![0u8; plen];
    r.read_exact(&mut payload)?;
    Ok(Some((header, payload)))
}

fn header_field(h: &Json, key: &str) -> Result<usize> {
    h.get(key)
        .and_then(Json::as_usize)
        .ok_or_else(|| anyhow!("frame header missing {key:?}"))
}

// ---------------------------------------------------------------------
// Proc transport (Unix domain sockets; unix-only).
// ---------------------------------------------------------------------

#[cfg(unix)]
pub use proc_transport::{run_shard_worker, ProcTransport};

#[cfg(unix)]
mod proc_transport {
    use super::*;
    use crate::runtime::operands::RowBand;
    use crate::sparse::Csr;
    use anyhow::{anyhow, bail};
    use std::io::Write as _;
    use std::os::unix::net::{UnixListener, UnixStream};
    use std::path::{Path, PathBuf};
    use std::process::{Child, Command, Stdio};
    use std::sync::atomic::{AtomicU64, AtomicUsize};
    use std::time::Duration;

    /// How long the coordinator waits for workers to connect and for
    /// per-request replies before declaring a shard dead.
    const IO_TIMEOUT: Duration = Duration::from_secs(60);
    const ACCEPT_TIMEOUT: Duration = Duration::from_secs(15);

    static SOCKET_COUNTER: AtomicU64 = AtomicU64::new(0);

    struct ProcShard {
        child: Child,
        /// `None` once the shard is known dead.
        stream: Option<UnixStream>,
        row0: usize,
        rows: usize,
    }

    /// Encode an `init` or `delta` frame carrying one band of `S` plus
    /// its cached `s_c` — the two frame types share the payload layout,
    /// so a worker's resident band is replaced by exactly the bytes the
    /// coordinator would have shipped at spawn.
    fn encode_band_frame(kind: &str, shard: usize, band: &RowBand) -> Vec<u8> {
        let mut payload = Vec::with_capacity(
            (band.s.rows() + 1) * 8 + band.s.nnz() * 12 + band.s_c.len() * 8,
        );
        push_u64s(&mut payload, band.s.row_ptr());
        push_u64s(&mut payload, band.s.col_idx());
        push_f32s(&mut payload, band.s.values());
        push_f64s(&mut payload, &band.s_c);
        let header = Json::obj(vec![
            ("type", Json::from(kind)),
            ("shard", Json::from(shard)),
            ("row0", Json::from(band.row0)),
            ("rows", Json::from(band.s.rows())),
            ("cols", Json::from(band.s.cols())),
            ("nnz", Json::from(band.s.nnz())),
            ("payload", Json::from(payload.len())),
        ]);
        encode_frame(&header, &payload)
    }

    /// Parse the band carried by an `init` or `delta` frame into the
    /// worker's resident form: `(rows, cols, band-with-local-row0)`.
    fn parse_band_frame(hdr: &Json, body: &[u8]) -> Result<(usize, usize, RowBand)> {
        let rows = header_field(hdr, "rows")?;
        let cols = header_field(hdr, "cols")?;
        let nnz = header_field(hdr, "nnz")?;
        let mut wire = Wire(body);
        let row_ptr = wire.usizes(rows + 1)?;
        let col_idx = wire.usizes(nnz)?;
        let values = wire.f32s(nnz)?;
        let s_c = wire.f64s(cols)?;
        wire.done()?;
        let band = RowBand {
            // Local band coordinates; the coordinator owns the global
            // row offset for stitching.
            row0: 0,
            s: Csr::from_raw_parts(rows, cols, row_ptr, col_idx, values)
                .map_err(|e| anyhow!("bad band CSR: {e}"))?,
            s_c,
        };
        Ok((rows, cols, band))
    }

    /// Ship one mutated band to its worker and wait for the ack —
    /// the same lockstep discipline as `agg`/`band`, so any failure
    /// names the culprit shard.
    fn ship_band_delta(stream: &mut UnixStream, shard: usize, band: &RowBand) -> Result<()> {
        stream.write_all(&encode_band_frame("delta", shard, band))?;
        let (ack, _) = read_frame(stream)?.ok_or_else(|| anyhow!("hung up"))?;
        match ack.get("type").and_then(Json::as_str) {
            Some("ack") => Ok(()),
            Some("error") => bail!(
                "worker reported: {}",
                ack.get("msg").and_then(Json::as_str).unwrap_or("?")
            ),
            other => bail!("unexpected frame type {other:?}"),
        }
    }

    /// Read and fully validate one `band` reply: `(z rows, pred,
    /// actual)`. Every failure mode — EOF, wire error, worker-reported
    /// error, wrong frame type, mismatched shape, short payload — is an
    /// `Err`, so the caller poisons the shard on any of them.
    fn read_band_reply(
        stream: &mut UnixStream,
        rows: usize,
        width: usize,
    ) -> Result<(Vec<f32>, f64, f64)> {
        let (hdr, body) = read_frame(stream)?.ok_or_else(|| anyhow!("hung up"))?;
        match hdr.get("type").and_then(Json::as_str) {
            Some("band") => {}
            Some("error") => {
                bail!(
                    "worker reported: {}",
                    hdr.get("msg").and_then(Json::as_str).unwrap_or("?")
                );
            }
            other => bail!("unexpected frame type {other:?}"),
        }
        if header_field(&hdr, "rows")? != rows || header_field(&hdr, "cols")? != width {
            bail!("mismatched band shape");
        }
        let mut wire = Wire(&body);
        let z = wire.f32s(rows * width)?;
        let p = wire.f64()?;
        let a = wire.f64()?;
        wire.done()?;
        Ok((z, p, a))
    }

    /// One `gcn-abft shard-worker` subprocess per shard, each holding
    /// only its band of `S` (plus the band's `s_c`), shipped once at
    /// spawn over a Unix domain socket. Per request the coordinator
    /// streams each phase's `x`/`x_r` and stitches the returned band
    /// rows + checksum partials — concat/sum, exactly like the in-proc
    /// path, and bit-identical to it because the worker computes its
    /// band with the same serial kernel.
    pub struct ProcTransport {
        shards_total: usize,
        /// Rows of the resident `S` (= N nodes); mutable because a
        /// node-adding delta grows the graph under a running transport.
        n: AtomicUsize,
        shards: Mutex<Vec<ProcShard>>,
        timings: Mutex<ShardTimings>,
        socket_dir: PathBuf,
        clock: MonotonicClock,
    }

    impl ProcTransport {
        /// Spawn one worker per band of the resident operands and ship
        /// each its band. `worker_bin` defaults to the running
        /// executable (correct for the `gcn-abft` binary itself; tests
        /// and benches pass `env!("CARGO_BIN_EXE_gcn-abft")`).
        pub fn spawn(ops: &GcnOperands, worker_bin: Option<&Path>) -> Result<ProcTransport> {
            let SOperand::Banded(bands) = &ops.s else {
                bail!("proc shard transport needs CSR operands with a banded S");
            };
            let bin = match worker_bin {
                Some(p) => p.to_path_buf(),
                None => std::env::current_exe()?,
            };
            let dir = std::env::temp_dir().join(format!(
                "gcn-abft-shards-{}-{}",
                std::process::id(),
                SOCKET_COUNTER.fetch_add(1, Ordering::Relaxed)
            ));
            // Mode 0700: connecting to the socket requires traversing
            // this directory, so only this user's processes can reach
            // the (otherwise unauthenticated) shard protocol — a forged
            // band would verify Clean, which is exactly what an
            // integrity checker must not allow.
            {
                use std::os::unix::fs::{DirBuilderExt, PermissionsExt};
                let mut builder = std::fs::DirBuilder::new();
                builder.mode(0o700);
                match builder.create(&dir) {
                    Ok(()) => {}
                    Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => {
                        // Stale dir from a crashed run under a recycled
                        // pid: reclaim it (same user — 0700) and clear
                        // the old socket so bind succeeds.
                        std::fs::set_permissions(
                            &dir,
                            std::fs::Permissions::from_mode(0o700),
                        )?;
                        let _ = std::fs::remove_file(dir.join("coordinator.sock"));
                    }
                    Err(e) => return Err(e.into()),
                }
            }
            let socket_path = dir.join("coordinator.sock");
            let clock = MonotonicClock::new();
            let mut children: Vec<Child> = Vec::new();
            let mut shards: Vec<ProcShard> = Vec::new();
            if let Err(e) = Self::spawn_and_init(
                bands,
                &bin,
                &socket_path,
                &clock,
                &mut children,
                &mut shards,
            ) {
                // Nothing of a failed spawn may outlive the error: no
                // orphan worker processes, no stale socket directory.
                for c in children
                    .iter_mut()
                    .chain(shards.iter_mut().map(|s| &mut s.child))
                {
                    let _ = c.kill();
                    let _ = c.wait();
                }
                let _ = std::fs::remove_file(&socket_path);
                let _ = std::fs::remove_dir(&dir);
                return Err(e);
            }

            Ok(ProcTransport {
                shards_total: shards.len(),
                n: AtomicUsize::new(ops.n_nodes()),
                timings: Mutex::new(ShardTimings {
                    wait_secs: vec![0.0; shards.len()],
                    ..Default::default()
                }),
                shards: Mutex::new(shards),
                socket_dir: dir,
                clock,
            })
        }

        /// The fallible part of [`ProcTransport::spawn`]: bind, launch
        /// one worker per band, accept each connection, ship its band
        /// and collect the ready/pid handshake. Children and completed
        /// shards accumulate in the caller's vectors so an error can
        /// tear everything down.
        fn spawn_and_init(
            bands: &[RowBand],
            bin: &Path,
            socket_path: &Path,
            clock: &MonotonicClock,
            children: &mut Vec<Child>,
            shards: &mut Vec<ProcShard>,
        ) -> Result<()> {
            let listener = UnixListener::bind(socket_path)?;
            listener.set_nonblocking(true)?;

            for _ in 0..bands.len() {
                let child = Command::new(bin)
                    .arg("shard-worker")
                    .arg("--socket")
                    .arg(socket_path)
                    .stdin(Stdio::null())
                    .stdout(Stdio::null())
                    .stderr(Stdio::inherit())
                    .spawn()
                    .map_err(|e| anyhow!("spawning shard worker {bin:?}: {e}"))?;
                children.push(child);
            }

            // Accept one connection per worker (workers are identical
            // until they receive their band, so accept order assigns
            // shard indices) and ship band k to the k-th connection.
            let deadline = clock.now().after(ACCEPT_TIMEOUT);
            for (k, band) in bands.iter().enumerate() {
                let mut stream = loop {
                    match listener.accept() {
                        Ok((s, _)) => break s,
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            for (ci, c) in children.iter_mut().enumerate() {
                                if let Ok(Some(status)) = c.try_wait() {
                                    bail!(
                                        "shard worker {ci} exited before connecting \
                                         ({status})"
                                    );
                                }
                            }
                            if clock.now() > deadline {
                                bail!("timed out waiting for shard workers to connect");
                            }
                            std::thread::sleep(Duration::from_millis(2));
                        }
                        Err(e) => return Err(e.into()),
                    }
                };
                stream.set_nonblocking(false)?;
                stream.set_read_timeout(Some(IO_TIMEOUT))?;
                stream.set_write_timeout(Some(IO_TIMEOUT))?;

                stream.write_all(&encode_band_frame("init", k, band))?;
                let (ready, _) = read_frame(&mut stream)?
                    .ok_or_else(|| anyhow!("shard {k} hung up during init"))?;
                if ready.get("type").and_then(Json::as_str) != Some("ready") {
                    bail!("shard {k} sent {:?} instead of ready", ready.to_string());
                }
                // Accept order is arbitrary, so pair this shard with the
                // child whose pid the worker echoed in its ready frame
                // (kill_shard must hit the process actually serving the
                // band).
                let pid = header_field(&ready, "pid")?;
                let ci = children
                    .iter()
                    .position(|c| c.id() as usize == pid)
                    .ok_or_else(|| anyhow!("shard {k} echoed unknown pid {pid}"))?;
                shards.push(ProcShard {
                    child: children.remove(ci),
                    stream: Some(stream),
                    row0: band.row0,
                    rows: band.s.rows(),
                });
            }
            Ok(())
        }

        /// Worker process ids, in shard order (fault-injection tests
        /// kill these externally).
        pub fn worker_pids(&self) -> Vec<u32> {
            lock_recover(&self.shards).iter().map(|s| s.child.id()).collect()
        }
    }

    impl ShardTransport for ProcTransport {
        fn name(&self) -> &'static str {
            "proc"
        }

        fn shards(&self) -> usize {
            self.shards_total
        }

        fn aggregate(
            &self,
            ops: &GcnOperands,
            x: &Dense,
            x_r: &[f32],
        ) -> Result<(Dense, f64, f64)> {
            let n = self.n.load(Ordering::SeqCst);
            if ops.n_nodes() != n {
                bail!(
                    "operands changed shape under a running proc transport \
                     (apply the delta through the transport first)"
                );
            }
            let width = x.cols();
            let mut payload = Vec::with_capacity(x.data().len() * 4 + x_r.len() * 4);
            push_f32s(&mut payload, x.data());
            push_f32s(&mut payload, x_r);
            let header = Json::obj(vec![
                ("type", Json::from("agg")),
                ("rows", Json::from(x.rows())),
                ("cols", Json::from(width)),
                ("payload", Json::from(payload.len())),
            ]);
            let frame = encode_frame(&header, &payload);

            let mut shards = match self.shards.lock() {
                Ok(g) => g,
                Err(poisoned) => {
                    // A panic while streaming leaves the request/reply
                    // lockstep in an unknown state; poison every shard
                    // so no later aggregate can stitch a stale queued
                    // reply (fail-stop, never a process abort).
                    let mut g = poisoned.into_inner();
                    for sh in g.iter_mut() {
                        sh.stream = None;
                    }
                    g
                }
            };
            // Nothing is sent unless every shard is believed alive: a
            // request half-streamed before discovering a dead shard
            // would leave orphan replies queued in the healthy workers'
            // sockets, and the transport must stay request/reply
            // lockstep to stay bit-exact.
            for (k, sh) in shards.iter().enumerate() {
                if sh.stream.is_none() {
                    bail!("shard {k} is down");
                }
            }
            // Phase 1: stream the request to every shard, concurrently —
            // sequential sends would add (shards−1) × transfer-time of
            // pure latency on wide phases (Nell's X₂ is ~60 MB). One
            // shared frame buffer; a worker only writes after reading a
            // full request, so sends cannot deadlock against replies.
            let send_errs: Vec<Option<String>> = std::thread::scope(|scope| {
                let handles: Vec<_> = shards
                    .iter_mut()
                    .map(|sh| {
                        let frame = &frame;
                        // Alive per the pre-check above; a None here is
                        // recorded as a dead send rather than a panic.
                        sh.stream.as_mut().map(|stream| {
                            scope.spawn(move || {
                                stream.write_all(frame).err().map(|e| e.to_string())
                            })
                        })
                    })
                    .collect();
                handles
                    .into_iter()
                    .map(|h| match h {
                        None => Some("shard stream missing".to_string()),
                        Some(h) => h
                            .join()
                            .unwrap_or_else(|_| Some("send thread panicked".to_string())),
                    })
                    .collect()
            });
            let mut first_err: Option<(usize, String)> = None;
            for (k, err) in send_errs.into_iter().enumerate() {
                if let Some(e) = err {
                    shards[k].stream = None;
                    if first_err.is_none() {
                        first_err = Some((k, e));
                    }
                }
            }
            if let Some((k, e)) = first_err {
                bail!("shard {k} died mid-request ({e})");
            }
            // Phase 2: collect band results in band order and stitch.
            // ANY reply-side failure — wire error, malformed frame,
            // short payload — permanently poisons the shard: with it
            // marked down, the all-alive pre-check blocks every later
            // aggregate, so a stale queued reply can never be stitched
            // into a subsequent forward (the lockstep/desync guarantee).
            let mut out = Dense::zeros(n, width);
            let mut pred = 0f64;
            let mut actual = 0f64;
            let mut waits = vec![0f64; shards.len()];
            let mut stitch = 0f64;
            for (k, sh) in shards.iter_mut().enumerate() {
                let t0 = self.clock.now();
                let Some(stream) = sh.stream.as_mut() else {
                    bail!("shard {k} is down");
                };
                let reply = read_band_reply(stream, sh.rows, width);
                waits[k] = self.clock.now().since(t0).as_secs_f64();
                let (z, p, a) = match reply {
                    Ok(v) => v,
                    Err(e) => {
                        sh.stream = None;
                        bail!("shard {k} failed mid-request ({e})");
                    }
                };
                let t1 = self.clock.now();
                out.data_mut()[sh.row0 * width..(sh.row0 + sh.rows) * width]
                    .copy_from_slice(&z);
                pred += p;
                actual += a;
                stitch += self.clock.now().since(t1).as_secs_f64();
            }
            drop(shards);
            {
                let mut tm = lock_recover(&self.timings);
                tm.aggregates += 1;
                tm.stitch_secs += stitch;
                for (acc, w) in tm.wait_secs.iter_mut().zip(&waits) {
                    *acc += w;
                }
            }
            Ok((out, pred, actual))
        }

        fn apply_delta(&self, ops: &GcnOperands, outcome: &DeltaOutcome) -> Result<()> {
            let SOperand::Banded(bands) = &ops.s else {
                bail!("proc shard transport needs CSR operands with a banded S");
            };
            if bands.len() != self.shards_total {
                bail!(
                    "delta changed the band partition ({} bands != {} shards); \
                     restart the shard tier",
                    bands.len(),
                    self.shards_total
                );
            }
            let mut shards = match self.shards.lock() {
                Ok(g) => g,
                Err(poisoned) => {
                    // Same recovery as aggregate: a panic mid-protocol
                    // leaves the lockstep state unknown, so poison
                    // everything rather than risk a stale reply.
                    let mut g = poisoned.into_inner();
                    for sh in g.iter_mut() {
                        sh.stream = None;
                    }
                    g
                }
            };
            // All-alive precheck, like aggregate: re-shipping to a
            // subset while a shard is down would leave the survivors on
            // a newer graph version than the epoch fence ever publishes.
            for (k, sh) in shards.iter().enumerate() {
                if sh.stream.is_none() {
                    bail!("shard {k} is down");
                }
            }
            // A resize moves band boundaries everywhere; a pure edge
            // patch touches only the bands the outcome names.
            let targets: Vec<usize> = if outcome.resized {
                (0..bands.len()).collect()
            } else {
                outcome.affected_bands.clone()
            };
            for &k in &targets {
                let (Some(band), Some(sh)) = (bands.get(k), shards.get_mut(k)) else {
                    bail!("delta outcome names band {k} of {}", bands.len());
                };
                let Some(stream) = sh.stream.as_mut() else {
                    bail!("shard {k} is down");
                };
                if let Err(e) = ship_band_delta(stream, k, band) {
                    sh.stream = None;
                    bail!("shard {k} failed during delta re-ship ({e})");
                }
                sh.row0 = band.row0;
                sh.rows = band.s.rows();
            }
            self.n.store(ops.n_nodes(), Ordering::SeqCst);
            Ok(())
        }

        fn kill_shard(&self, shard: usize) -> bool {
            let mut shards = lock_recover(&self.shards);
            match shards.get_mut(shard) {
                Some(sh) => {
                    // Kill the process but keep the (now broken) socket:
                    // the next aggregate experiences the wire-level
                    // failure exactly as an externally crashed worker.
                    let _ = sh.child.kill();
                    let _ = sh.child.wait();
                    true
                }
                None => false,
            }
        }

        fn timings(&self) -> ShardTimings {
            lock_recover(&self.timings).clone()
        }
    }

    impl Drop for ProcTransport {
        fn drop(&mut self) {
            // Even a poisoned registry still gets its children reaped.
            let mut shards = lock_recover(&self.shards);
            for sh in shards.iter_mut() {
                if let Some(mut stream) = sh.stream.take() {
                    let header = Json::obj(vec![
                        ("type", Json::from("shutdown")),
                        ("payload", Json::from(0usize)),
                    ]);
                    let _ = stream.write_all(&encode_frame(&header, &[]));
                    // Stream drops here: the worker sees EOF and exits.
                }
            }
            for sh in shards.iter_mut() {
                // Give the worker a moment to exit on its own, then
                // force the issue so drop never hangs.
                let deadline = self.clock.now().after(Duration::from_secs(2));
                loop {
                    match sh.child.try_wait() {
                        Ok(Some(_)) => break,
                        Ok(None) if self.clock.now() < deadline => {
                            std::thread::sleep(Duration::from_millis(5));
                        }
                        _ => {
                            let _ = sh.child.kill();
                            let _ = sh.child.wait();
                            break;
                        }
                    }
                }
            }
            let _ = std::fs::remove_file(self.socket_dir.join("coordinator.sock"));
            let _ = std::fs::remove_dir(&self.socket_dir);
        }
    }

    /// The `gcn-abft shard-worker` main loop: connect to the
    /// coordinator's socket, receive this worker's band of `S` (plus its
    /// `s_c`), then serve aggregation requests until shutdown/EOF. The
    /// band compute is [`RowBand::aggregate_into`] — the identical
    /// serial kernel one in-proc band runs — which is what makes the
    /// proc transport bit-identical to in-proc sharding.
    pub fn run_shard_worker(socket: &Path) -> Result<()> {
        let mut stream = UnixStream::connect(socket)
            .map_err(|e| anyhow!("connecting to coordinator at {socket:?}: {e}"))?;

        let (init, body) = read_frame(&mut stream)?
            .ok_or_else(|| anyhow!("coordinator hung up before init"))?;
        if init.get("type").and_then(Json::as_str) != Some("init") {
            bail!("expected init frame, got {}", init.to_string());
        }
        let shard = header_field(&init, "shard")?;
        let (mut rows, mut cols, mut band) = parse_band_frame(&init, &body)
            .map_err(|e| anyhow!("bad init frame: {e}"))?;
        let ready = Json::obj(vec![
            ("type", Json::from("ready")),
            ("shard", Json::from(shard)),
            ("pid", Json::from(std::process::id() as usize)),
            ("payload", Json::from(0usize)),
        ]);
        stream.write_all(&encode_frame(&ready, &[]))?;

        loop {
            let Some((hdr, body)) = read_frame(&mut stream)? else {
                return Ok(()); // coordinator hung up — normal shutdown
            };
            match hdr.get("type").and_then(Json::as_str) {
                Some("shutdown") => return Ok(()),
                Some("agg") => {
                    if let Err(e) = handle_agg(&mut stream, &band, cols, rows, &hdr, &body)
                    {
                        // Best-effort error frame so the coordinator
                        // logs the cause instead of a bare hang-up.
                        let msg = format!("{e:#}");
                        let err = Json::obj(vec![
                            ("type", Json::from("error")),
                            ("msg", Json::from(msg.as_str())),
                            ("payload", Json::from(0usize)),
                        ]);
                        let _ = stream.write_all(&encode_frame(&err, &[]));
                        return Err(e);
                    }
                }
                Some("delta") => match parse_band_frame(&hdr, &body) {
                    Ok((new_rows, new_cols, new_band)) => {
                        // The new band fully replaces the resident one —
                        // identical bytes to what an `init` at the new
                        // graph version would have shipped, which is what
                        // keeps post-delta serving bit-identical to a
                        // freshly spawned shard tier.
                        rows = new_rows;
                        cols = new_cols;
                        band = new_band;
                        let ack = Json::obj(vec![
                            ("type", Json::from("ack")),
                            ("shard", Json::from(shard)),
                            ("payload", Json::from(0usize)),
                        ]);
                        stream.write_all(&encode_frame(&ack, &[]))?;
                    }
                    Err(e) => {
                        // A malformed delta must not leave this worker
                        // serving a half-replaced band: report and exit
                        // (the coordinator poisons the shard on the
                        // failed ack — fail-stop).
                        let msg = format!("{e:#}");
                        let err = Json::obj(vec![
                            ("type", Json::from("error")),
                            ("msg", Json::from(msg.as_str())),
                            ("payload", Json::from(0usize)),
                        ]);
                        let _ = stream.write_all(&encode_frame(&err, &[]));
                        return Err(e);
                    }
                },
                other => bail!("unexpected frame type {other:?}"),
            }
        }
    }

    /// One `agg` request: validate, aggregate the band, reply.
    fn handle_agg(
        stream: &mut UnixStream,
        band: &RowBand,
        cols: usize,
        rows: usize,
        hdr: &Json,
        body: &[u8],
    ) -> Result<()> {
        let n = header_field(hdr, "rows")?;
        let width = header_field(hdr, "cols")?;
        if n != cols {
            bail!("agg frame rows {n} != band cols {cols}");
        }
        let mut wire = Wire(body);
        let x = Dense::from_vec(n, width, wire.f32s(n * width)?);
        let x_r = wire.f32s(n)?;
        wire.done()?;
        let mut z = vec![0f32; rows * width];
        let (pred, actual) = band.aggregate_into(&x, &x_r, &mut z);
        let mut payload = Vec::with_capacity(z.len() * 4 + 16);
        push_f32s(&mut payload, &z);
        push_f64s(&mut payload, &[pred, actual]);
        let reply = Json::obj(vec![
            ("type", Json::from("band")),
            ("rows", Json::from(rows)),
            ("cols", Json::from(width)),
            ("payload", Json::from(payload.len())),
        ]);
        stream.write_all(&encode_frame(&reply, &payload))?;
        Ok(())
    }
}

#[cfg(not(unix))]
mod proc_stub {
    use anyhow::{bail, Result};
    use std::path::Path;

    /// The proc transport needs Unix domain sockets.
    pub fn run_shard_worker(_socket: &Path) -> Result<()> {
        bail!("the proc shard transport is only available on unix")
    }
}

#[cfg(not(unix))]
pub use proc_stub::run_shard_worker;

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]

    use super::*;
    use crate::coordinator::ServePolicy;
    use crate::graph::DatasetId;
    use crate::runtime::backend::{for_operands, BackendKind};

    fn workload(bands: usize) -> GcnOperands {
        let g = DatasetId::Tiny.build(11);
        let m = crate::gcn::GcnModel::two_layer(&g, 8, 3);
        GcnOperands::sparse(
            g.features.clone(),
            &m.adjacency,
            m.layers[0].weights.clone(),
            m.layers[1].weights.clone(),
            bands,
        )
        .unwrap()
    }

    #[test]
    fn plan_partitions_all_rows_and_nnz() {
        let ops = workload(3);
        let plan = ShardPlan::for_operands(&ops).unwrap();
        assert_eq!(plan.shards, 3);
        assert_eq!(plan.bands.iter().map(|b| b.rows).sum::<usize>(), plan.n);
        assert_eq!(
            plan.bands.iter().map(|b| b.nnz).sum::<usize>(),
            ops.s.nnz()
        );
        assert!(plan.max_resident_bytes() > 0);
        assert!(plan.request_payload_bytes(&ops) > 0);
        // Dense operands have nothing to shard.
        let dense = GcnOperands::dense(
            crate::tensor::Dense::zeros(4, 3),
            crate::tensor::Dense::eye(4),
            crate::tensor::Dense::zeros(3, 2),
            crate::tensor::Dense::zeros(2, 2),
        )
        .unwrap();
        assert!(ShardPlan::for_operands(&dense).is_err());
    }

    #[test]
    fn inproc_sharded_backend_is_bit_identical_to_native_banded() {
        for shards in [1usize, 2, 4] {
            let ops = workload(shards);
            let reference = for_operands(BackendKind::Native, ChecksumScheme::Fused, &ops, 2, None)
                .unwrap();
            let transport: Arc<dyn ShardTransport> =
                Arc::new(InProcTransport::new(&ops).unwrap());
            let sharded = ShardedBackend::new(transport, ChecksumScheme::Fused, 2);
            let row: Vec<f32> = (0..ops.feat_dim()).map(|c| (c % 5) as f32 * 0.5).collect();
            for overlays in [&[][..], &[Overlay { node: 3, row: &row }][..]] {
                let a = reference.run(&ops, overlays).unwrap();
                let b = sharded.run(&ops, overlays).unwrap();
                assert_eq!(a.logits, b.logits, "shards={shards}");
                assert_eq!(a.predicted, b.predicted, "shards={shards}");
                assert_eq!(a.actual, b.actual, "shards={shards}");
                assert!(ServePolicy::default().verify(&b).ok);
            }
            let plan = sharded.plan(&ops).unwrap();
            assert_eq!(plan.bands, shards);
            assert_eq!(plan.backend, "native-sharded");
        }
    }

    #[test]
    fn killed_inproc_shard_fails_stop() {
        let ops = workload(2);
        let transport = Arc::new(InProcTransport::new(&ops).unwrap());
        let backend = ShardedBackend::new(
            transport.clone() as Arc<dyn ShardTransport>,
            ChecksumScheme::Fused,
            1,
        );
        assert!(backend.run(&ops, &[]).is_ok());
        assert!(transport.kill_shard(1));
        assert!(!transport.kill_shard(9), "out-of-range shard");
        let err = backend.run(&ops, &[]).unwrap_err();
        assert!(err.to_string().contains("down"), "{err}");
        let tm = transport.timings();
        assert_eq!(tm.aggregates, 2, "one run = two aggregation phases");
        assert_eq!(tm.wait_secs.len(), 2);
    }

    #[test]
    fn frames_round_trip_bit_exactly() {
        let header = Json::obj(vec![
            ("type", Json::from("agg")),
            ("rows", Json::from(3usize)),
            ("cols", Json::from(2usize)),
            ("payload", Json::from(32usize)),
        ]);
        let xs = [1.5f32, -0.0, f32::MIN_POSITIVE, 3.25e-20];
        let ys = [std::f64::consts::PI, -1e-300];
        let mut payload = Vec::new();
        push_f32s(&mut payload, &xs);
        push_f64s(&mut payload, &ys);
        let frame = encode_frame(&header, &payload);
        let mut cursor = std::io::Cursor::new(frame);
        let (h, body) = read_frame(&mut cursor).unwrap().unwrap();
        assert_eq!(h.get("type").and_then(Json::as_str), Some("agg"));
        assert_eq!(header_field(&h, "rows").unwrap(), 3);
        let mut wire = Wire(&body);
        let got32 = wire.f32s(4).unwrap();
        let got64 = wire.f64s(2).unwrap();
        wire.done().unwrap();
        for (a, b) in xs.iter().zip(&got32) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        for (a, b) in ys.iter().zip(&got64) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // Clean EOF at a frame boundary is None, not an error.
        let mut empty = std::io::Cursor::new(Vec::<u8>::new());
        assert!(read_frame(&mut empty).unwrap().is_none());
        // A truncated frame is an error.
        let mut trunc = std::io::Cursor::new(vec![9u8, 0, 0]);
        assert!(read_frame(&mut trunc).is_err());
    }

    #[test]
    fn inproc_delta_keeps_serving_and_rejects_partition_drift() {
        use crate::runtime::mutate::{self, GraphDelta};
        let mut ops = workload(2);
        let transport: Arc<dyn ShardTransport> = Arc::new(InProcTransport::new(&ops).unwrap());
        let backend = ShardedBackend::new(transport.clone(), ChecksumScheme::Fused, 1);
        let before = backend.run(&ops, &[]).unwrap();
        let delta = GraphDelta::Edges {
            add: vec![(0, 7, 0.4)],
            remove: vec![],
        };
        let outcome = mutate::apply(&mut ops, &delta).unwrap();
        transport.apply_delta(&ops, &outcome).unwrap();
        let after = backend.run(&ops, &[]).unwrap();
        assert_ne!(before.logits, after.logits, "edge add must change the forward");
        // Post-delta serving is bit-identical to a from-scratch rebuild
        // served over a fresh transport.
        let rebuilt = mutate::rebuild(&ops).unwrap();
        let fresh = ShardedBackend::new(
            Arc::new(InProcTransport::new(&rebuilt).unwrap()),
            ChecksumScheme::Fused,
            1,
        );
        let reference = fresh.run(&rebuilt, &[]).unwrap();
        assert_eq!(after.logits, reference.logits);
        assert_eq!(after.predicted, reference.predicted);
        assert_eq!(after.actual, reference.actual);
        // A band partition that no longer matches the shard count is
        // rejected fail-stop instead of surfacing one request later.
        let drifted = workload(3);
        let err = transport.apply_delta(&drifted, &outcome).unwrap_err();
        assert!(err.to_string().contains("band partition"), "{err}");
    }

    #[test]
    fn transport_kind_parses() {
        assert_eq!(ShardTransportKind::parse("inproc"), Some(ShardTransportKind::InProc));
        assert_eq!(ShardTransportKind::parse("PROC"), Some(ShardTransportKind::Proc));
        assert_eq!(ShardTransportKind::parse("tcp"), None);
        assert_eq!(ShardTransportKind::Proc.name(), "proc");
    }
}
