//! The shard tier: location-transparent row-band sharding of the
//! propagation matrix.
//!
//! PR 2 proved the algebra: the fused checksum `eᵀ·(S·X·W)·e` and its
//! cached partials are **additive over row bands of `S`**, so a banded
//! aggregation stitches back exactly (logits by concatenation, checksum
//! partials by summation). Until now that blueprint lived as scoped
//! threads buried inside the operand kernel
//! ([`crate::runtime::operands::SOperand::aggregate`]); this module
//! makes the band/partial-checksum boundary a first-class interface:
//!
//! * [`ShardPlan`] — the row-band partition of a resident
//!   [`GcnOperands`] set (derived from the banded `S` the memory
//!   planner already builds), with per-shard resident and per-request
//!   payload footprints;
//! * [`ShardTransport`] — *where* the bands run: [`InProcTransport`]
//!   (scoped-thread fan-out inside the coordinator), [`ProcTransport`]
//!   (spawned `gcn-abft shard-worker` subprocesses over Unix domain
//!   sockets) or [`TcpTransport`](super::net::TcpTransport) (workers on
//!   TCP — spawned locally or reached at `--shard-addrs`), all speaking
//!   the one wire protocol in [`super::shard_proto`] — std-only, no
//!   serialization crates;
//! * [`ShardedBackend`] — a [`GcnBackend`] that runs the ordinary
//!   native forward ([`native::forward_with`]) with the two `S·X`
//!   aggregation phases routed through a transport.
//!
//! **Bit-identity.** Every transport computes each band with
//! [`RowBand::aggregate_into`] — the same serial per-row kernel the
//! in-process path uses — and the coordinator stitches in fixed band
//! order, so `serve --shards N --shard-transport inproc|proc|tcp`
//! produces logits bit-identical to unsharded serving and identical
//! fused/split alarm decisions (`tests/prop_shard_equivalence.rs`). The
//! stream transports run the *same* engine
//! ([`shard_proto::aggregate_remote`](super::shard_proto)) over their
//! own socket type, so all transports are bit-identical to *each other*
//! including the stitched checksum bits.
//!
//! **Fail-stop, then heal.** A shard that dies mid-request (socket
//! error, killed worker, poisoned in-proc band) fails the whole
//! aggregation with a typed [`ShardDead`](super::shard_proto::ShardDead)
//! naming the culprit: the coordinator answers the affected requests
//! with [`VerifyStatus::Failed`](super::request::VerifyStatus) and keeps
//! serving — never a silently stitched partial answer. Under
//! `--supervise` the [`Supervisor`](super::supervisor::Supervisor)
//! consumes the death through [`ShardTransport::probe`] and heals it
//! through [`ShardTransport::recover`] — re-spawn (proc/tcp local),
//! re-connect (tcp remote), adopt a pre-shipped `--warm-standby` worker,
//! or un-poison (inproc) — re-shipping the band through the same `init`
//! path that spawned it. A checksum corrupted *inside* a shard surfaces
//! through the ordinary GCN-ABFT verification of the stitched sums,
//! since the band partials add into the global predicted/actual pair.
//!
//! The wire protocol (one frame = `u32` little-endian header length,
//! UTF-8 JSON header, raw payload of `header.payload` bytes; codec in
//! [`super::shard_proto`]):
//!
//! ```text
//! coordinator → worker   {"type":"init", shard, row0, rows, cols, nnz, payload}
//!                        payload = row_ptr u64[rows+1] · col_idx u64[nnz]
//!                                  · values f32[nnz] · s_c f64[cols]
//! worker → coordinator   {"type":"ready", shard, pid}
//! coordinator → worker   {"type":"agg", rows, cols, payload}
//!                        payload = x f32[rows·cols] · x_r f32[rows]
//! worker → coordinator   {"type":"band", rows, cols, payload}
//!                        payload = z f32[rows·cols] · pred f64 · actual f64
//! coordinator → worker   {"type":"shutdown"}
//! coordinator → worker   {"type":"delta", shard, row0, rows, cols, nnz, payload}
//!                        payload = row_ptr u64[rows+1] · col_idx u64[nnz]
//!                                  · values f32[nnz] · s_c f64[cols]
//! worker → coordinator   {"type":"ack", shard}
//! ```
//!
//! The `delta`/`ack` pair is the dynamic-graph path
//! ([`crate::runtime::mutate`]): after the coordinator patches the
//! resident operands inside the epoch fence, it re-ships each mutated
//! band (same payload layout as `init`) and waits for the ack in the
//! same lockstep discipline as `agg`/`band` — a failed re-ship poisons
//! the shard so no later aggregate can stitch mixed-version bands.
//!
//! Floats cross the wire as raw little-endian bit patterns (never as
//! decimal text), which is what keeps the stream transports
//! bit-identical.

use crate::runtime::backend::native;
use crate::runtime::backend::{self, ChecksumScheme, ExecPlan, GcnBackend, Overlay};
use crate::runtime::mutate::DeltaOutcome;
use crate::runtime::{GcnOperands, GcnOutputs, SOperand};
use crate::tensor::Dense;
use super::clock::{Clock, MonotonicClock};
use super::lock_recover;
use anyhow::{bail, Result};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

/// Transport selector for configs and the `--shard-transport` CLI flag.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardTransportKind {
    /// Scoped threads inside the coordinator process (zero copies).
    InProc,
    /// One `gcn-abft shard-worker` subprocess per shard, over Unix
    /// domain sockets.
    Proc,
    /// Workers over TCP: spawned locally (`shard-worker --listen`) or
    /// reached remotely at `--shard-addrs` — the multi-node tier.
    Tcp,
}

impl ShardTransportKind {
    pub fn name(&self) -> &'static str {
        match self {
            ShardTransportKind::InProc => "inproc",
            ShardTransportKind::Proc => "proc",
            ShardTransportKind::Tcp => "tcp",
        }
    }

    pub fn parse(s: &str) -> Option<ShardTransportKind> {
        match s.to_ascii_lowercase().as_str() {
            "inproc" | "thread" | "threads" => Some(ShardTransportKind::InProc),
            "proc" | "process" | "uds" => Some(ShardTransportKind::Proc),
            "tcp" | "net" => Some(ShardTransportKind::Tcp),
            _ => None,
        }
    }
}

/// Cumulative transport observability (surfaced in
/// [`super::metrics::ServeMetrics`] so proc-transport overhead is
/// measured, not guessed).
#[derive(Debug, Clone, Default)]
pub struct ShardTimings {
    /// Aggregation phases executed.
    pub aggregates: u64,
    /// Seconds the stitcher spent blocked on each shard (proc/tcp:
    /// socket round-trip; inproc: the band's compute on its scoped
    /// worker).
    pub wait_secs: Vec<f64>,
    /// Seconds spent stitching band results (row copies + partial sums).
    pub stitch_secs: f64,
}

/// One shard's slice of the [`ShardPlan`].
#[derive(Debug, Clone, Copy)]
pub struct ShardBand {
    /// First global row of `S` this shard owns.
    pub row0: usize,
    /// Rows of `S` this shard owns.
    pub rows: usize,
    /// Stored nonzeros of the band.
    pub nnz: usize,
    /// Resident bytes at the shard: the band CSR plus its `s_c` vector.
    pub resident_bytes: usize,
}

/// The row-band partition of one resident operand set across shards —
/// the deployment-facing view of what each worker holds and what each
/// request ships.
#[derive(Debug, Clone)]
pub struct ShardPlan {
    pub shards: usize,
    /// Total rows of `S` (= N nodes).
    pub n: usize,
    pub bands: Vec<ShardBand>,
}

impl ShardPlan {
    /// Derive the plan from a resident operand set. The operand planner
    /// already partitioned a CSR `S` into row bands (one per requested
    /// shard); dense operands have no band structure to distribute.
    pub fn for_operands(ops: &GcnOperands) -> Result<ShardPlan> {
        let SOperand::Banded(bands) = &ops.s else {
            bail!(
                "sharded serving needs CSR operands with a row-banded S \
                 (got dense operands; use --mode sparse)"
            );
        };
        let plan_bands = bands
            .iter()
            .map(|b| ShardBand {
                row0: b.row0,
                rows: b.s.rows(),
                nnz: b.s.nnz(),
                resident_bytes: b.s.heap_bytes() + b.s_c.len() * std::mem::size_of::<f64>(),
            })
            .collect();
        Ok(ShardPlan {
            shards: bands.len(),
            n: ops.n_nodes(),
            bands: plan_bands,
        })
    }

    /// Largest per-shard resident footprint (bytes).
    pub fn max_resident_bytes(&self) -> usize {
        self.bands.iter().map(|b| b.resident_bytes).max().unwrap_or(0)
    }

    /// Bytes shipped to **each** shard per request on the stream
    /// transports: both aggregation phases' `x` + `x_r` payloads.
    pub fn request_payload_bytes(&self, ops: &GcnOperands) -> usize {
        let per_phase = |width: usize| (self.n * width + self.n) * std::mem::size_of::<f32>();
        per_phase(ops.hidden_dim()) + per_phase(ops.num_classes())
    }
}

/// How [`ShardTransport::recover`] brought a dead shard back.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RecoveryKind {
    /// A fresh worker process was spawned and the band re-shipped
    /// through the `init` path.
    Respawned,
    /// An existing remote worker was re-connected and re-shipped.
    Reconnected,
    /// A pre-shipped `--warm-standby` worker took over (zero re-ship
    /// bytes).
    StandbyAdopted,
    /// The in-proc shard was un-poisoned (the in-process analogue of a
    /// respawn: the band is resident either way).
    Healed,
}

impl RecoveryKind {
    pub fn name(&self) -> &'static str {
        match self {
            RecoveryKind::Respawned => "respawned",
            RecoveryKind::Reconnected => "reconnected",
            RecoveryKind::StandbyAdopted => "standby-adopted",
            RecoveryKind::Healed => "healed",
        }
    }
}

/// Where the row bands of `S` execute. One `aggregate` call is one
/// `z = S·x` phase: the transport computes every band (wherever its
/// shards live), stitches `z` by row-band concatenation and the fused
/// checksum partials `(s_c[band]·x_r, eᵀ·z[band]·e)` by summation in
/// band order, and returns the stitched triple. Any shard failing fails
/// the whole phase (fail-stop — the coordinator never sees a partial
/// stitch).
pub trait ShardTransport: Send + Sync {
    fn name(&self) -> &'static str;

    fn shards(&self) -> usize;

    /// One aggregation phase over the resident operands' band partition.
    fn aggregate(&self, ops: &GcnOperands, x: &Dense, x_r: &[f32]) -> Result<(Dense, f64, f64)>;

    /// Tear down one shard (fault injection for fail-stop tests): every
    /// subsequent `aggregate` touching the shard must error. Returns
    /// `false` when the shard index is out of range.
    fn kill_shard(&self, shard: usize) -> bool;

    /// Bring every shard onto a new graph version after a
    /// [`crate::runtime::mutate::GraphDelta`] patched the resident
    /// operands. The coordinator calls this *inside* the epoch fence —
    /// no `aggregate` can interleave — passing the already-patched
    /// operands plus the patch outcome naming which bands changed.
    /// Fail-stop: on error the delta is rejected (the epoch does not
    /// advance) and any shard whose resident version is now unknown is
    /// poisoned, so a later aggregate can never stitch mixed-version
    /// bands.
    fn apply_delta(&self, ops: &GcnOperands, outcome: &DeltaOutcome) -> Result<()>;

    /// Liveness of every shard, in band order — the supervisor's
    /// heartbeat. `false` means the shard cannot serve the next
    /// aggregate: its stream is poisoned, or (local workers) its
    /// process is gone even though no request has touched the broken
    /// socket yet. The default says all alive, which is correct for
    /// transports with no death to detect.
    fn probe(&self) -> Vec<bool> {
        (0..self.shards()).map(|_| true).collect()
    }

    /// Bring one dead shard back: re-spawn or re-connect its worker and
    /// re-ship its resident band + `s_c` through the same `init` path
    /// that spawned it (or adopt a pre-shipped warm standby). Called by
    /// the supervisor *inside* the epoch fence with the current resident
    /// operands, so a recovery can never race a delta and a failed
    /// re-ship never publishes. The default refuses: unsupervisable
    /// transports stay fail-stop-forever, exactly as before.
    fn recover(&self, shard: usize, _ops: &GcnOperands) -> Result<RecoveryKind> {
        bail!("transport {} does not support shard recovery", self.name())
    }

    /// Pre-shipped `--warm-standby` workers still available for
    /// zero-reship failover.
    fn standby_count(&self) -> usize {
        0
    }

    /// Cumulative timings snapshot.
    fn timings(&self) -> ShardTimings;
}

/// Today's scoped-thread band fan-out, as a [`ShardTransport`]: each
/// band of the resident `S` aggregates on its own scoped worker writing
/// a disjoint row slice of `z`. This is the same machinery
/// [`SOperand::aggregate`] runs for the unsharded sparse path — one
/// band's compute is the serial [`RowBand::aggregate_into`] either way —
/// so the in-proc shard tier is bit-identical to unsharded serving,
/// checksum bits included, whenever the band partitions match.
///
/// [`RowBand::aggregate_into`]: crate::runtime::operands::RowBand::aggregate_into
#[derive(Debug)]
pub struct InProcTransport {
    shards: usize,
    /// Poisoned shards ([`ShardTransport::kill_shard`]): the in-proc
    /// analogue of a dead worker process.
    down: Vec<AtomicBool>,
    timings: Mutex<ShardTimings>,
    clock: MonotonicClock,
}

impl InProcTransport {
    /// Transport over an operand set whose `S` is banded into the
    /// desired shard count.
    pub fn new(ops: &GcnOperands) -> Result<InProcTransport> {
        let plan = ShardPlan::for_operands(ops)?;
        Ok(InProcTransport {
            shards: plan.shards,
            down: (0..plan.shards).map(|_| AtomicBool::new(false)).collect(),
            timings: Mutex::new(ShardTimings {
                wait_secs: vec![0.0; plan.shards],
                ..Default::default()
            }),
            clock: MonotonicClock::new(),
        })
    }
}

impl ShardTransport for InProcTransport {
    fn name(&self) -> &'static str {
        "inproc"
    }

    fn shards(&self) -> usize {
        self.shards
    }

    fn aggregate(&self, ops: &GcnOperands, x: &Dense, x_r: &[f32]) -> Result<(Dense, f64, f64)> {
        let SOperand::Banded(bands) = &ops.s else {
            bail!("inproc shard transport got dense operands");
        };
        if bands.len() != self.shards {
            bail!(
                "operand band count {} != shard count {}",
                bands.len(),
                self.shards
            );
        }
        for (k, d) in self.down.iter().enumerate() {
            if d.load(Ordering::SeqCst) {
                bail!("shard {k} is down");
            }
        }
        let mut out = Dense::zeros(ops.n_nodes(), x.cols());
        // THE band fan-out — the same helper the unsharded sparse path
        // runs, so inproc sharding is bit-identical by construction.
        let partials =
            crate::runtime::operands::aggregate_bands_timed(bands, x, x_r, out.data_mut());
        let t_stitch = self.clock.now();
        let pred = partials.iter().map(|p| p.0).sum();
        let actual = partials.iter().map(|p| p.1).sum();
        let stitch = self.clock.now().since(t_stitch).as_secs_f64();
        {
            let mut tm = lock_recover(&self.timings);
            tm.aggregates += 1;
            tm.stitch_secs += stitch;
            for (acc, p) in tm.wait_secs.iter_mut().zip(&partials) {
                *acc += p.2;
            }
        }
        Ok((out, pred, actual))
    }

    fn kill_shard(&self, shard: usize) -> bool {
        match self.down.get(shard) {
            Some(d) => {
                d.store(true, Ordering::SeqCst);
                true
            }
            None => false,
        }
    }

    fn apply_delta(&self, ops: &GcnOperands, _outcome: &DeltaOutcome) -> Result<()> {
        // In-proc shards read their bands straight from the resident
        // operands on every aggregate, so there is nothing to re-ship —
        // only the band-partition invariant to enforce now, rather than
        // letting a collapsed partition surface one request later.
        let SOperand::Banded(bands) = &ops.s else {
            bail!("inproc shard transport got dense operands");
        };
        if bands.len() != self.shards {
            bail!(
                "delta changed the band partition ({} bands != {} shards); \
                 restart the shard tier",
                bands.len(),
                self.shards
            );
        }
        Ok(())
    }

    fn probe(&self) -> Vec<bool> {
        self.down.iter().map(|d| !d.load(Ordering::SeqCst)).collect()
    }

    fn recover(&self, shard: usize, ops: &GcnOperands) -> Result<RecoveryKind> {
        // The band is resident in the shared operands, so recovery is
        // un-poisoning — but only if the partition still matches, for
        // the same reason apply_delta enforces it.
        let SOperand::Banded(bands) = &ops.s else {
            bail!("inproc shard transport got dense operands");
        };
        if bands.len() != self.shards {
            bail!(
                "band partition changed ({} bands != {} shards); \
                 restart the shard tier",
                bands.len(),
                self.shards
            );
        }
        match self.down.get(shard) {
            Some(d) => {
                d.store(false, Ordering::SeqCst);
                Ok(RecoveryKind::Healed)
            }
            None => bail!("shard {shard} out of range ({})", self.shards),
        }
    }

    fn timings(&self) -> ShardTimings {
        lock_recover(&self.timings).clone()
    }
}

/// A [`GcnBackend`] running the ordinary native forward with both `S·X`
/// aggregation phases routed through a [`ShardTransport`]. Combination
/// matmuls, overlay patching and (split scheme) phase-1 checks are the
/// exact in-process code ([`native::forward_with`]), so the transport
/// can change *where* bands run but never *what* a forward computes.
pub struct ShardedBackend {
    transport: Arc<dyn ShardTransport>,
    scheme: ChecksumScheme,
    threads: usize,
}

impl ShardedBackend {
    pub fn new(
        transport: Arc<dyn ShardTransport>,
        scheme: ChecksumScheme,
        threads: usize,
    ) -> ShardedBackend {
        ShardedBackend {
            transport,
            scheme,
            threads: threads.max(1),
        }
    }

    pub fn transport(&self) -> &Arc<dyn ShardTransport> {
        &self.transport
    }
}

impl GcnBackend for ShardedBackend {
    fn name(&self) -> &'static str {
        "native-sharded"
    }

    fn plan(&self, ops: &GcnOperands) -> Result<ExecPlan> {
        if ops.band_count() != self.transport.shards() {
            bail!(
                "operand band count {} != shard count {}",
                ops.band_count(),
                self.transport.shards()
            );
        }
        Ok(backend::plan_with_profile(
            self.name(),
            crate::opcount::backend::BackendProfile::Native,
            self.scheme,
            ops,
            self.transport.shards(),
            self.threads,
        ))
    }

    fn run(&self, ops: &GcnOperands, overlays: &[Overlay<'_>]) -> Result<GcnOutputs> {
        native::forward_with(ops, overlays, self.threads, self.scheme, |x, x_r| {
            self.transport.aggregate(ops, x, x_r)
        })
    }
}

/// Build the transport a server config selects, over the resident
/// operands. The band partition is derived from `--shards` at operand
/// build, but [`row_band_bounds`] may legitimately produce *fewer*
/// bands than requested (`ceil(n/ceil(n/shards))` — e.g. 64 nodes at
/// `--shards 48` yield 32 two-row bands); the operands' actual band
/// count is the source of truth, never a startup refusal.
///
/// [`row_band_bounds`]: crate::runtime::operands::row_band_bounds
pub fn build_transport(
    cfg: &super::server::ServerConfig,
    ops: &GcnOperands,
) -> Result<Arc<dyn ShardTransport>> {
    let plan = ShardPlan::for_operands(ops)?;
    // The operand build derives its bands from cfg.shards, and the
    // partition arithmetic can only clamp downward.
    debug_assert!(plan.shards <= cfg.shards.max(1));
    if !cfg.shard_addrs.is_empty() && cfg.shard_transport != ShardTransportKind::Tcp {
        bail!("--shard-addrs only applies with --shard-transport tcp");
    }
    match cfg.shard_transport {
        ShardTransportKind::InProc => {
            if cfg.warm_standby > 0 {
                bail!("--warm-standby needs a worker-process transport (proc or tcp)");
            }
            Ok(Arc::new(InProcTransport::new(ops)?))
        }
        #[cfg(unix)]
        ShardTransportKind::Proc => Ok(Arc::new(ProcTransport::spawn_with_standby(
            ops,
            cfg.shard_worker_bin.as_deref(),
            cfg.warm_standby,
        )?)),
        #[cfg(not(unix))]
        ShardTransportKind::Proc => bail!("the proc shard transport is only available on unix"),
        ShardTransportKind::Tcp => {
            if cfg.shard_addrs.is_empty() {
                Ok(Arc::new(super::net::TcpTransport::spawn(
                    ops,
                    cfg.shard_worker_bin.as_deref(),
                    cfg.warm_standby,
                )?))
            } else {
                if cfg.warm_standby > 0 {
                    bail!("--warm-standby applies to spawned workers, not --shard-addrs");
                }
                Ok(Arc::new(super::net::TcpTransport::connect(
                    ops,
                    &cfg.shard_addrs,
                )?))
            }
        }
    }
}

// ---------------------------------------------------------------------
// Proc transport (Unix domain sockets; unix-only).
// ---------------------------------------------------------------------

#[cfg(unix)]
pub use proc_transport::{run_shard_worker, ProcTransport};

#[cfg(unix)]
mod proc_transport {
    use super::*;
    use crate::coordinator::clock::Tick;
    use crate::coordinator::shard_proto::{
        aggregate_remote, apply_delta_remote, encode_frame, init_handshake,
        serve_shard_connection, ship_band_delta, RemoteShard, SessionEnd,
    };
    use crate::runtime::operands::RowBand;
    use crate::util::json::Json;
    use anyhow::anyhow;
    use std::io::Write as _;
    use std::os::unix::net::{UnixListener, UnixStream};
    use std::path::{Path, PathBuf};
    use std::process::{Child, Command, Stdio};
    use std::sync::atomic::{AtomicU64, AtomicUsize};
    use std::time::Duration;

    /// How long the coordinator waits for workers to connect and for
    /// per-request replies before declaring a shard dead.
    const IO_TIMEOUT: Duration = Duration::from_secs(60);
    const ACCEPT_TIMEOUT: Duration = Duration::from_secs(15);

    static SOCKET_COUNTER: AtomicU64 = AtomicU64::new(0);

    struct ProcShard {
        child: Child,
        link: RemoteShard<UnixStream>,
    }

    /// A pre-shipped `--warm-standby` worker: already holding band
    /// `band`'s CSR + `s_c`, kept current by `apply_delta`, ready to
    /// take over with zero re-ship bytes.
    struct ProcStandby {
        child: Child,
        link: RemoteShard<UnixStream>,
        band: usize,
    }

    /// Spawned-but-not-yet-initialized workers plus the shards and
    /// standbys already brought up, accumulated so a mid-spawn error can
    /// tear everything down.
    #[derive(Default)]
    struct TierBuild {
        children: Vec<Child>,
        shards: Vec<ProcShard>,
        standbys: Vec<ProcStandby>,
    }

    impl TierBuild {
        fn teardown(&mut self) {
            for c in self
                .children
                .iter_mut()
                .chain(self.shards.iter_mut().map(|s| &mut s.child))
                .chain(self.standbys.iter_mut().map(|s| &mut s.child))
            {
                let _ = c.kill();
                let _ = c.wait();
            }
        }
    }

    /// One `gcn-abft shard-worker` subprocess per shard, each holding
    /// only its band of `S` (plus the band's `s_c`), shipped once at
    /// spawn over a Unix domain socket. Per request the coordinator
    /// streams each phase's `x`/`x_r` and stitches the returned band
    /// rows + checksum partials — concat/sum, exactly like the in-proc
    /// path, and bit-identical to it because the worker computes its
    /// band with the same serial kernel. The listener is retained for
    /// the transport's whole life so supervised recovery can accept a
    /// re-spawned worker on the same socket path.
    pub struct ProcTransport {
        shards_total: usize,
        /// Rows of the resident `S` (= N nodes); mutable because a
        /// node-adding delta grows the graph under a running transport.
        n: AtomicUsize,
        shards: Mutex<Vec<ProcShard>>,
        standbys: Mutex<Vec<ProcStandby>>,
        timings: Mutex<ShardTimings>,
        listener: UnixListener,
        worker_bin: PathBuf,
        socket_dir: PathBuf,
        socket_path: PathBuf,
        clock: MonotonicClock,
    }

    impl ProcTransport {
        /// Spawn one worker per band of the resident operands and ship
        /// each its band. `worker_bin` defaults to the running
        /// executable (correct for the `gcn-abft` binary itself; tests
        /// and benches pass `env!("CARGO_BIN_EXE_gcn-abft")`).
        pub fn spawn(ops: &GcnOperands, worker_bin: Option<&Path>) -> Result<ProcTransport> {
            Self::spawn_with_standby(ops, worker_bin, 0)
        }

        /// As [`ProcTransport::spawn`], plus `warm_standby` extra
        /// workers pre-shipped bands round-robin (`i % shards`) for
        /// zero-reship failover. Standbys are not auto-replenished: an
        /// adopted or lost standby stays gone until the tier restarts.
        pub fn spawn_with_standby(
            ops: &GcnOperands,
            worker_bin: Option<&Path>,
            warm_standby: usize,
        ) -> Result<ProcTransport> {
            let SOperand::Banded(bands) = &ops.s else {
                bail!("proc shard transport needs CSR operands with a banded S");
            };
            let bin = match worker_bin {
                Some(p) => p.to_path_buf(),
                None => std::env::current_exe()?,
            };
            let dir = std::env::temp_dir().join(format!(
                "gcn-abft-shards-{}-{}",
                std::process::id(),
                SOCKET_COUNTER.fetch_add(1, Ordering::Relaxed)
            ));
            // Mode 0700: connecting to the socket requires traversing
            // this directory, so only this user's processes can reach
            // the (otherwise unauthenticated) shard protocol — a forged
            // band would verify Clean, which is exactly what an
            // integrity checker must not allow.
            {
                use std::os::unix::fs::{DirBuilderExt, PermissionsExt};
                let mut builder = std::fs::DirBuilder::new();
                builder.mode(0o700);
                match builder.create(&dir) {
                    Ok(()) => {}
                    Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => {
                        // Stale dir from a crashed run under a recycled
                        // pid: reclaim it (same user — 0700) and clear
                        // the old socket so bind succeeds.
                        std::fs::set_permissions(
                            &dir,
                            std::fs::Permissions::from_mode(0o700),
                        )?;
                        let _ = std::fs::remove_file(dir.join("coordinator.sock"));
                    }
                    Err(e) => return Err(e.into()),
                }
            }
            let socket_path = dir.join("coordinator.sock");
            let clock = MonotonicClock::new();
            let listener = match UnixListener::bind(&socket_path) {
                Ok(l) => l,
                Err(e) => {
                    let _ = std::fs::remove_dir(&dir);
                    return Err(e.into());
                }
            };
            let mut build = TierBuild::default();
            let init = listener
                .set_nonblocking(true)
                .map_err(anyhow::Error::from)
                .and_then(|()| {
                    Self::spawn_and_init(bands, &bin, &socket_path, &listener, &clock, warm_standby, &mut build)
                });
            if let Err(e) = init {
                // Nothing of a failed spawn may outlive the error: no
                // orphan worker processes, no stale socket directory.
                build.teardown();
                let _ = std::fs::remove_file(&socket_path);
                let _ = std::fs::remove_dir(&dir);
                return Err(e);
            }

            Ok(ProcTransport {
                shards_total: build.shards.len(),
                n: AtomicUsize::new(ops.n_nodes()),
                timings: Mutex::new(ShardTimings {
                    wait_secs: vec![0.0; build.shards.len()],
                    ..Default::default()
                }),
                shards: Mutex::new(build.shards),
                standbys: Mutex::new(build.standbys),
                listener,
                worker_bin: bin,
                socket_dir: dir,
                socket_path,
                clock,
            })
        }

        fn spawn_worker(bin: &Path, socket_path: &Path) -> Result<Child> {
            Command::new(bin)
                .arg("shard-worker")
                .arg("--socket")
                .arg(socket_path)
                .stdin(Stdio::null())
                .stdout(Stdio::null())
                .stderr(Stdio::inherit())
                .spawn()
                .map_err(|e| anyhow!("spawning shard worker {bin:?}: {e}"))
        }

        /// Accept one worker connection with IO timeouts applied,
        /// watching `children` for a worker that died before connecting.
        fn accept_one(
            listener: &UnixListener,
            clock: &MonotonicClock,
            deadline: Tick,
            children: &mut [Child],
        ) -> Result<UnixStream> {
            loop {
                match listener.accept() {
                    Ok((s, _)) => {
                        s.set_nonblocking(false)?;
                        s.set_read_timeout(Some(IO_TIMEOUT))?;
                        s.set_write_timeout(Some(IO_TIMEOUT))?;
                        return Ok(s);
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        for (ci, c) in children.iter_mut().enumerate() {
                            if let Ok(Some(status)) = c.try_wait() {
                                bail!(
                                    "shard worker {ci} exited before connecting \
                                     ({status})"
                                );
                            }
                        }
                        if clock.now() > deadline {
                            bail!("timed out waiting for shard workers to connect");
                        }
                        std::thread::sleep(Duration::from_millis(2));
                    }
                    Err(e) => return Err(e.into()),
                }
            }
        }

        /// The fallible part of [`ProcTransport::spawn_with_standby`]:
        /// launch one worker per band (plus standbys), accept each
        /// connection, ship its band and collect the ready/pid
        /// handshake. Everything accumulates in `build` so an error can
        /// tear the half-built tier down.
        fn spawn_and_init(
            bands: &[RowBand],
            bin: &Path,
            socket_path: &Path,
            listener: &UnixListener,
            clock: &MonotonicClock,
            warm_standby: usize,
            build: &mut TierBuild,
        ) -> Result<()> {
            let total = bands.len() + warm_standby;
            for _ in 0..total {
                build.children.push(Self::spawn_worker(bin, socket_path)?);
            }

            // Accept one connection per worker (workers are identical
            // until they receive their band, so accept order assigns
            // shard indices) and ship band k to the k-th connection;
            // connections past the band count become standbys holding
            // band i % shards.
            let deadline = clock.now().after(ACCEPT_TIMEOUT);
            for k in 0..total {
                let band_idx = if k < bands.len() { k } else { (k - bands.len()) % bands.len() };
                let Some(band) = bands.get(band_idx) else {
                    bail!("band {band_idx} out of range ({})", bands.len());
                };
                let mut stream =
                    Self::accept_one(listener, clock, deadline, &mut build.children)?;
                // A standby introduces itself as the shard whose band it
                // holds, so adoption needs no re-introduction.
                let pid = init_handshake(&mut stream, band_idx, band)?;
                // Accept order is arbitrary, so pair this connection
                // with the child whose pid the worker echoed in its
                // ready frame (kill_shard must hit the process actually
                // serving the band).
                let ci = build
                    .children
                    .iter()
                    .position(|c| c.id() as usize == pid)
                    .ok_or_else(|| anyhow!("shard {band_idx} echoed unknown pid {pid}"))?;
                let child = build.children.remove(ci);
                let link = RemoteShard {
                    stream: Some(stream),
                    row0: band.row0,
                    rows: band.s.rows(),
                };
                if k < bands.len() {
                    build.shards.push(ProcShard { child, link });
                } else {
                    build.standbys.push(ProcStandby {
                        child,
                        link,
                        band: band_idx,
                    });
                }
            }
            Ok(())
        }

        /// Worker process ids, in shard order (fault-injection tests
        /// kill these externally).
        pub fn worker_pids(&self) -> Vec<u32> {
            lock_recover(&self.shards).iter().map(|s| s.child.id()).collect()
        }
    }

    impl ShardTransport for ProcTransport {
        fn name(&self) -> &'static str {
            "proc"
        }

        fn shards(&self) -> usize {
            self.shards_total
        }

        fn aggregate(
            &self,
            ops: &GcnOperands,
            x: &Dense,
            x_r: &[f32],
        ) -> Result<(Dense, f64, f64)> {
            let n = self.n.load(Ordering::SeqCst);
            if ops.n_nodes() != n {
                bail!(
                    "operands changed shape under a running proc transport \
                     (apply the delta through the transport first)"
                );
            }
            let mut shards = match self.shards.lock() {
                Ok(g) => g,
                Err(poisoned) => {
                    // A panic while streaming leaves the request/reply
                    // lockstep in an unknown state; poison every shard
                    // so no later aggregate can stitch a stale queued
                    // reply (fail-stop, never a process abort).
                    let mut g = poisoned.into_inner();
                    for sh in g.iter_mut() {
                        sh.link.stream = None;
                    }
                    g
                }
            };
            let mut links: Vec<&mut RemoteShard<UnixStream>> =
                shards.iter_mut().map(|s| &mut s.link).collect();
            let agg = aggregate_remote(&mut links, n, x, x_r, &self.clock)?;
            drop(shards);
            {
                let mut tm = lock_recover(&self.timings);
                tm.aggregates += 1;
                tm.stitch_secs += agg.stitch_secs;
                for (acc, w) in tm.wait_secs.iter_mut().zip(&agg.waits) {
                    *acc += w;
                }
            }
            Ok((agg.out, agg.pred, agg.actual))
        }

        fn apply_delta(&self, ops: &GcnOperands, outcome: &DeltaOutcome) -> Result<()> {
            let SOperand::Banded(bands) = &ops.s else {
                bail!("proc shard transport needs CSR operands with a banded S");
            };
            if bands.len() != self.shards_total {
                bail!(
                    "delta changed the band partition ({} bands != {} shards); \
                     restart the shard tier",
                    bands.len(),
                    self.shards_total
                );
            }
            let mut shards = match self.shards.lock() {
                Ok(g) => g,
                Err(poisoned) => {
                    // Same recovery as aggregate: a panic mid-protocol
                    // leaves the lockstep state unknown, so poison
                    // everything rather than risk a stale reply.
                    let mut g = poisoned.into_inner();
                    for sh in g.iter_mut() {
                        sh.link.stream = None;
                    }
                    g
                }
            };
            // A resize moves band boundaries everywhere; a pure edge
            // patch touches only the bands the outcome names.
            let targets: Vec<usize> = if outcome.resized {
                (0..bands.len()).collect()
            } else {
                outcome.affected_bands.clone()
            };
            {
                let mut links: Vec<&mut RemoteShard<UnixStream>> =
                    shards.iter_mut().map(|s| &mut s.link).collect();
                apply_delta_remote(&mut links, bands, &targets)?;
            }
            drop(shards);
            // Keep warm standbys on the current graph version too —
            // adoption must be zero-reship *and* version-exact. Losing a
            // standby here degrades failover, not the delta: log and
            // discard, never reject the mutation.
            let mut standbys = lock_recover(&self.standbys);
            let mut lost: Vec<usize> = Vec::new();
            for (i, standby) in standbys.iter_mut().enumerate() {
                if !targets.contains(&standby.band) {
                    continue;
                }
                let (Some(band), Some(stream)) =
                    (bands.get(standby.band), standby.link.stream.as_mut())
                else {
                    lost.push(i);
                    continue;
                };
                match ship_band_delta(stream, standby.band, band) {
                    Ok(()) => {
                        standby.link.row0 = band.row0;
                        standby.link.rows = band.s.rows();
                    }
                    Err(e) => {
                        eprintln!(
                            "shard tier: warm standby for band {} lost on delta \
                             re-ship ({e:#}); discarded",
                            standby.band
                        );
                        lost.push(i);
                    }
                }
            }
            for i in lost.into_iter().rev() {
                let mut s = standbys.remove(i);
                let _ = s.child.kill();
                let _ = s.child.wait();
            }
            self.n.store(ops.n_nodes(), Ordering::SeqCst);
            Ok(())
        }

        fn kill_shard(&self, shard: usize) -> bool {
            let mut shards = lock_recover(&self.shards);
            match shards.get_mut(shard) {
                Some(sh) => {
                    // Kill the process but keep the (now broken) socket:
                    // the next aggregate experiences the wire-level
                    // failure exactly as an externally crashed worker.
                    let _ = sh.child.kill();
                    let _ = sh.child.wait();
                    true
                }
                None => false,
            }
        }

        fn probe(&self) -> Vec<bool> {
            let mut shards = lock_recover(&self.shards);
            shards
                .iter_mut()
                .map(|sh| {
                    // A poisoned stream is a known death; a gone pid is
                    // a death no request has tripped over yet (the
                    // "pid-gone" heartbeat for local workers).
                    sh.link.stream.is_some() && matches!(sh.child.try_wait(), Ok(None))
                })
                .collect()
        }

        fn recover(&self, shard: usize, ops: &GcnOperands) -> Result<RecoveryKind> {
            let SOperand::Banded(bands) = &ops.s else {
                bail!("proc shard transport needs CSR operands with a banded S");
            };
            if bands.len() != self.shards_total {
                bail!(
                    "band partition changed ({} bands != {} shards); \
                     restart the shard tier",
                    bands.len(),
                    self.shards_total
                );
            }
            if ops.n_nodes() != self.n.load(Ordering::SeqCst) {
                bail!(
                    "recover called with operands of a different shape \
                     (apply the delta through the transport first)"
                );
            }
            let Some(band) = bands.get(shard) else {
                bail!("shard {shard} out of range ({})", self.shards_total);
            };
            let mut shards = lock_recover(&self.shards);
            let Some(sh) = shards.get_mut(shard) else {
                bail!("shard {shard} out of range ({})", self.shards_total);
            };
            // Reap whatever is left of the dead worker first; a
            // half-dead process must not keep the socket path busy.
            let _ = sh.child.kill();
            let _ = sh.child.wait();
            sh.link.stream = None;
            // Zero-reship failover: adopt a standby already holding this
            // band (kept current by apply_delta).
            {
                let mut standbys = lock_recover(&self.standbys);
                if let Some(pos) = standbys
                    .iter()
                    .position(|s| s.band == shard && s.link.stream.is_some())
                {
                    let standby = standbys.remove(pos);
                    sh.child = standby.child;
                    sh.link = standby.link;
                    sh.link.row0 = band.row0;
                    sh.link.rows = band.s.rows();
                    return Ok(RecoveryKind::StandbyAdopted);
                }
            }
            // Re-spawn and re-ship through the same init path that
            // brought the tier up.
            let child = Self::spawn_worker(&self.worker_bin, &self.socket_path)?;
            let mut single = [child];
            let deadline = self.clock.now().after(ACCEPT_TIMEOUT);
            let handshake = Self::accept_one(&self.listener, &self.clock, deadline, &mut single)
                .and_then(|mut stream| {
                    init_handshake(&mut stream, shard, band).map(|pid| (stream, pid))
                });
            let [mut child] = single;
            let (stream, pid) = match handshake {
                Ok(v) => v,
                Err(e) => {
                    let _ = child.kill();
                    let _ = child.wait();
                    return Err(e);
                }
            };
            if pid != child.id() as usize {
                let _ = child.kill();
                let _ = child.wait();
                bail!("shard {shard} echoed unknown pid {pid}");
            }
            sh.child = child;
            sh.link = RemoteShard {
                stream: Some(stream),
                row0: band.row0,
                rows: band.s.rows(),
            };
            Ok(RecoveryKind::Respawned)
        }

        fn standby_count(&self) -> usize {
            lock_recover(&self.standbys)
                .iter()
                .filter(|s| s.link.stream.is_some())
                .count()
        }

        fn timings(&self) -> ShardTimings {
            lock_recover(&self.timings).clone()
        }
    }

    impl Drop for ProcTransport {
        fn drop(&mut self) {
            // Even a poisoned registry still gets its children reaped.
            let mut shards = lock_recover(&self.shards);
            let mut standbys = lock_recover(&self.standbys);
            let header = Json::obj(vec![
                ("type", Json::from("shutdown")),
                ("payload", Json::from(0usize)),
            ]);
            let frame = encode_frame(&header, &[]);
            for stream in shards
                .iter_mut()
                .map(|s| &mut s.link.stream)
                .chain(standbys.iter_mut().map(|s| &mut s.link.stream))
            {
                if let Some(mut s) = stream.take() {
                    let _ = s.write_all(&frame);
                    // Stream drops here: the worker sees EOF and exits.
                }
            }
            for child in shards
                .iter_mut()
                .map(|s| &mut s.child)
                .chain(standbys.iter_mut().map(|s| &mut s.child))
            {
                // Give the worker a moment to exit on its own, then
                // force the issue so drop never hangs.
                let deadline = self.clock.now().after(Duration::from_secs(2));
                loop {
                    match child.try_wait() {
                        Ok(Some(_)) => break,
                        Ok(None) if self.clock.now() < deadline => {
                            std::thread::sleep(Duration::from_millis(5));
                        }
                        _ => {
                            let _ = child.kill();
                            let _ = child.wait();
                            break;
                        }
                    }
                }
            }
            let _ = std::fs::remove_file(&self.socket_path);
            let _ = std::fs::remove_dir(&self.socket_dir);
        }
    }

    /// The `gcn-abft shard-worker --socket` main loop: connect to the
    /// coordinator's Unix socket and serve the session with the shared
    /// worker loop ([`serve_shard_connection`] — the same code the TCP
    /// worker runs, which is what keeps the transports bit-identical).
    pub fn run_shard_worker(socket: &Path) -> Result<()> {
        let mut stream = UnixStream::connect(socket)
            .map_err(|e| anyhow!("connecting to coordinator at {socket:?}: {e}"))?;
        match serve_shard_connection(&mut stream)? {
            // A proc worker serves exactly one coordinator connection;
            // EOF and explicit shutdown both end the process.
            SessionEnd::Shutdown | SessionEnd::Hangup => Ok(()),
        }
    }
}

#[cfg(not(unix))]
mod proc_stub {
    use anyhow::{bail, Result};
    use std::path::Path;

    /// The proc transport needs Unix domain sockets.
    pub fn run_shard_worker(_socket: &Path) -> Result<()> {
        bail!("the proc shard transport is only available on unix")
    }
}

#[cfg(not(unix))]
pub use proc_stub::run_shard_worker;

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]

    use super::*;
    use crate::coordinator::ServePolicy;
    use crate::graph::DatasetId;
    use crate::runtime::backend::{for_operands, BackendKind};

    fn workload(bands: usize) -> GcnOperands {
        let g = DatasetId::Tiny.build(11);
        let m = crate::gcn::GcnModel::two_layer(&g, 8, 3);
        GcnOperands::sparse(
            g.features.clone(),
            &m.adjacency,
            m.layers[0].weights.clone(),
            m.layers[1].weights.clone(),
            bands,
        )
        .unwrap()
    }

    #[test]
    fn plan_partitions_all_rows_and_nnz() {
        let ops = workload(3);
        let plan = ShardPlan::for_operands(&ops).unwrap();
        assert_eq!(plan.shards, 3);
        assert_eq!(plan.bands.iter().map(|b| b.rows).sum::<usize>(), plan.n);
        assert_eq!(
            plan.bands.iter().map(|b| b.nnz).sum::<usize>(),
            ops.s.nnz()
        );
        assert!(plan.max_resident_bytes() > 0);
        assert!(plan.request_payload_bytes(&ops) > 0);
        // Dense operands have nothing to shard.
        let dense = GcnOperands::dense(
            crate::tensor::Dense::zeros(4, 3),
            crate::tensor::Dense::eye(4),
            crate::tensor::Dense::zeros(3, 2),
            crate::tensor::Dense::zeros(2, 2),
        )
        .unwrap();
        assert!(ShardPlan::for_operands(&dense).is_err());
    }

    #[test]
    fn inproc_sharded_backend_is_bit_identical_to_native_banded() {
        for shards in [1usize, 2, 4] {
            let ops = workload(shards);
            let reference = for_operands(BackendKind::Native, ChecksumScheme::Fused, &ops, 2, None)
                .unwrap();
            let transport: Arc<dyn ShardTransport> =
                Arc::new(InProcTransport::new(&ops).unwrap());
            let sharded = ShardedBackend::new(transport, ChecksumScheme::Fused, 2);
            let row: Vec<f32> = (0..ops.feat_dim()).map(|c| (c % 5) as f32 * 0.5).collect();
            for overlays in [&[][..], &[Overlay { node: 3, row: &row }][..]] {
                let a = reference.run(&ops, overlays).unwrap();
                let b = sharded.run(&ops, overlays).unwrap();
                assert_eq!(a.logits, b.logits, "shards={shards}");
                assert_eq!(a.predicted, b.predicted, "shards={shards}");
                assert_eq!(a.actual, b.actual, "shards={shards}");
                assert!(ServePolicy::default().verify(&b).ok);
            }
            let plan = sharded.plan(&ops).unwrap();
            assert_eq!(plan.bands, shards);
            assert_eq!(plan.backend, "native-sharded");
        }
    }

    #[test]
    fn killed_inproc_shard_fails_stop() {
        let ops = workload(2);
        let transport = Arc::new(InProcTransport::new(&ops).unwrap());
        let backend = ShardedBackend::new(
            transport.clone() as Arc<dyn ShardTransport>,
            ChecksumScheme::Fused,
            1,
        );
        assert!(backend.run(&ops, &[]).is_ok());
        assert!(transport.kill_shard(1));
        assert!(!transport.kill_shard(9), "out-of-range shard");
        let err = backend.run(&ops, &[]).unwrap_err();
        assert!(err.to_string().contains("down"), "{err}");
        let tm = transport.timings();
        assert_eq!(tm.aggregates, 2, "one run = two aggregation phases");
        assert_eq!(tm.wait_secs.len(), 2);
    }

    #[test]
    fn inproc_recover_heals_and_matches_the_unkilled_run() {
        let ops = workload(2);
        let transport = Arc::new(InProcTransport::new(&ops).unwrap());
        let backend = ShardedBackend::new(
            transport.clone() as Arc<dyn ShardTransport>,
            ChecksumScheme::Fused,
            1,
        );
        let want = backend.run(&ops, &[]).unwrap();
        assert!(transport.kill_shard(0));
        assert_eq!(transport.probe(), vec![false, true]);
        assert!(backend.run(&ops, &[]).is_err(), "dead shard fail-stops");
        assert_eq!(
            transport.recover(0, &ops).unwrap(),
            RecoveryKind::Healed,
            "inproc recovery un-poisons the band"
        );
        assert_eq!(transport.probe(), vec![true, true]);
        let got = backend.run(&ops, &[]).unwrap();
        assert_eq!(want.logits, got.logits);
        assert_eq!(want.predicted, got.predicted);
        assert_eq!(want.actual, got.actual);
        assert_eq!(transport.standby_count(), 0, "inproc keeps no standbys");
        // Recovery against a drifted partition is refused fail-stop.
        let drifted = workload(3);
        assert!(transport.kill_shard(0));
        let err = transport.recover(0, &drifted).unwrap_err();
        assert!(err.to_string().contains("band partition"), "{err}");
    }

    #[test]
    fn inproc_delta_keeps_serving_and_rejects_partition_drift() {
        use crate::runtime::mutate::{self, GraphDelta};
        let mut ops = workload(2);
        let transport: Arc<dyn ShardTransport> = Arc::new(InProcTransport::new(&ops).unwrap());
        let backend = ShardedBackend::new(transport.clone(), ChecksumScheme::Fused, 1);
        let before = backend.run(&ops, &[]).unwrap();
        let delta = GraphDelta::Edges {
            add: vec![(0, 7, 0.4)],
            remove: vec![],
        };
        let outcome = mutate::apply(&mut ops, &delta).unwrap();
        transport.apply_delta(&ops, &outcome).unwrap();
        let after = backend.run(&ops, &[]).unwrap();
        assert_ne!(before.logits, after.logits, "edge add must change the forward");
        // Post-delta serving is bit-identical to a from-scratch rebuild
        // served over a fresh transport.
        let rebuilt = mutate::rebuild(&ops).unwrap();
        let fresh = ShardedBackend::new(
            Arc::new(InProcTransport::new(&rebuilt).unwrap()),
            ChecksumScheme::Fused,
            1,
        );
        let reference = fresh.run(&rebuilt, &[]).unwrap();
        assert_eq!(after.logits, reference.logits);
        assert_eq!(after.predicted, reference.predicted);
        assert_eq!(after.actual, reference.actual);
        // A band partition that no longer matches the shard count is
        // rejected fail-stop instead of surfacing one request later.
        let drifted = workload(3);
        let err = transport.apply_delta(&drifted, &outcome).unwrap_err();
        assert!(err.to_string().contains("band partition"), "{err}");
    }

    #[test]
    fn transport_kind_parses() {
        assert_eq!(ShardTransportKind::parse("inproc"), Some(ShardTransportKind::InProc));
        assert_eq!(ShardTransportKind::parse("PROC"), Some(ShardTransportKind::Proc));
        assert_eq!(ShardTransportKind::parse("tcp"), Some(ShardTransportKind::Tcp));
        assert_eq!(ShardTransportKind::parse("net"), Some(ShardTransportKind::Tcp));
        assert_eq!(ShardTransportKind::parse("carrier-pigeon"), None);
        assert_eq!(ShardTransportKind::Proc.name(), "proc");
        assert_eq!(ShardTransportKind::Tcp.name(), "tcp");
    }
}
