//! The multi-node shard transport: row-band workers over TCP.
//!
//! [`TcpTransport`] is the third [`ShardTransport`]: the same wire
//! protocol as the proc transport ([`super::shard_proto`] — the frames
//! have no unix-specific content), the same lockstep request/reply
//! discipline, the same stitch — over `TcpStream` instead of a Unix
//! domain socket. Two deployment modes:
//!
//! * **spawn-local** ([`TcpTransport::spawn`]) — the coordinator
//!   launches `gcn-abft shard-worker --listen 127.0.0.1:0` per band
//!   (plus `--warm-standby` extras); each worker binds an ephemeral
//!   port and reports it on stdout, the coordinator connects and ships
//!   the band. This is the localhost multi-node smoke: real sockets,
//!   real processes, no address bookkeeping.
//! * **connect-remote** ([`TcpTransport::connect`]) — workers were
//!   started out-of-band on other machines (`gcn-abft shard-worker
//!   --listen 0.0.0.0:port`) and the coordinator reaches them at
//!   `--shard-addrs host:port,...`, one per band in band order. This is
//!   how row bands of a graph no single box fits get held by boxes that
//!   fit one band each.
//!
//! **Bit-identity.** Both ends run the exact code the proc transport
//! runs — [`aggregate_remote`] on the coordinator,
//! [`serve_shard_connection`] in the worker — so tcp/proc/inproc logits
//! and checksum bits cannot drift apart
//! (`tests/prop_shard_equivalence.rs` pins all three).
//!
//! **Death and recovery.** A connection error poisons the shard's
//! stream with a typed [`ShardDead`](super::shard_proto::ShardDead) and
//! the whole aggregate fail-stops. [`ShardTransport::probe`] reports a
//! poisoned stream or (spawn-local) a worker process that exited;
//! [`ShardTransport::recover`] re-spawns local workers, re-connects to
//! remote ones (a TCP worker survives coordinator hangup and keeps
//! accepting — crash recovery needs no worker-side state), or adopts a
//! pre-shipped warm standby — always re-shipping through the same
//! `init` path that brought the tier up, under the caller's epoch
//! fence. No TCP authentication exists: bind workers to loopback or a
//! trusted network, because a forged band would verify Clean, which an
//! integrity checker must never allow.

use crate::runtime::mutate::DeltaOutcome;
use crate::runtime::operands::RowBand;
use crate::runtime::{GcnOperands, SOperand};
use crate::tensor::Dense;
use crate::util::json::Json;
use super::clock::{Clock, MonotonicClock};
use super::lock_recover;
use super::shard::{RecoveryKind, ShardTimings, ShardTransport};
use super::shard_proto::{
    aggregate_remote, apply_delta_remote, encode_frame, init_handshake, serve_shard_connection,
    ship_band_delta, RemoteShard, SessionEnd,
};
use anyhow::{anyhow, bail, Result};
use std::io::{BufRead, BufReader, Write as _};
use std::net::{TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// Per-request socket deadline before a shard is declared dead (same
/// budget as the proc transport).
const IO_TIMEOUT: Duration = Duration::from_secs(60);
/// How long [`ShardTransport::recover`] retries connecting to a remote
/// worker's known address before giving up (the worker may be
/// mid-restart under its own process supervisor).
const RECONNECT_TIMEOUT: Duration = Duration::from_secs(5);

/// The line a spawned worker prints once its listener is bound; the
/// coordinator parses the ephemeral port from it.
pub const WORKER_READY_PREFIX: &str = "gcn-abft-shard-worker listening ";

struct TcpShard {
    /// The worker process when this transport spawned it locally; None
    /// for remote workers reached via `--shard-addrs`.
    child: Option<Child>,
    /// The worker's address, kept current across respawns — this is
    /// what reconnect recovery dials.
    addr: String,
    link: RemoteShard<TcpStream>,
}

/// A pre-shipped `--warm-standby` worker: already holding band `band`,
/// kept current by `apply_delta`, adoptable with zero re-ship bytes.
struct TcpStandby {
    child: Option<Child>,
    addr: String,
    link: RemoteShard<TcpStream>,
    band: usize,
}

/// Row-band shard workers over TCP. See the module doc for the two
/// deployment modes; everything after setup — aggregate, delta
/// re-ship, fail-stop poisoning, recovery — is mode-agnostic except
/// that only spawn-local can re-*spawn* (remote recovery re-connects).
pub struct TcpTransport {
    shards_total: usize,
    /// Rows of the resident `S` (= N nodes); a node-adding delta grows
    /// the graph under a running transport.
    n: AtomicUsize,
    shards: Mutex<Vec<TcpShard>>,
    standbys: Mutex<Vec<TcpStandby>>,
    timings: Mutex<ShardTimings>,
    /// Worker binary for respawn; None in connect-remote mode.
    worker_bin: Option<PathBuf>,
    clock: MonotonicClock,
}

impl TcpTransport {
    /// Spawn one local worker per band (plus `warm_standby` extras
    /// pre-shipped bands round-robin) on ephemeral loopback ports and
    /// ship each its band. `worker_bin` defaults to the running
    /// executable.
    pub fn spawn(
        ops: &GcnOperands,
        worker_bin: Option<&Path>,
        warm_standby: usize,
    ) -> Result<TcpTransport> {
        let SOperand::Banded(bands) = &ops.s else {
            bail!("tcp shard transport needs CSR operands with a banded S");
        };
        let bin = match worker_bin {
            Some(p) => p.to_path_buf(),
            None => std::env::current_exe()?,
        };
        let mut shards: Vec<TcpShard> = Vec::new();
        let mut standbys: Vec<TcpStandby> = Vec::new();
        let total = bands.len() + warm_standby;
        for k in 0..total {
            let band_idx = if k < bands.len() {
                k
            } else {
                (k - bands.len()) % bands.len()
            };
            let Some(band) = bands.get(band_idx) else {
                Self::teardown(&mut shards, &mut standbys);
                bail!("band {band_idx} out of range ({})", bands.len());
            };
            match Self::spawn_and_init_one(&bin, band_idx, band) {
                Ok((child, addr, link)) => {
                    if k < bands.len() {
                        shards.push(TcpShard {
                            child: Some(child),
                            addr,
                            link,
                        });
                    } else {
                        standbys.push(TcpStandby {
                            child: Some(child),
                            addr,
                            link,
                            band: band_idx,
                        });
                    }
                }
                Err(e) => {
                    // Nothing of a failed spawn may outlive the error.
                    Self::teardown(&mut shards, &mut standbys);
                    return Err(e);
                }
            }
        }
        Ok(TcpTransport {
            shards_total: shards.len(),
            n: AtomicUsize::new(ops.n_nodes()),
            timings: Mutex::new(ShardTimings {
                wait_secs: vec![0.0; shards.len()],
                ..Default::default()
            }),
            shards: Mutex::new(shards),
            standbys: Mutex::new(standbys),
            worker_bin: Some(bin),
            clock: MonotonicClock::new(),
        })
    }

    /// Connect to already-running workers, one address per band in band
    /// order, and ship each its band. The workers keep accepting after
    /// a coordinator hangs up, so a crashed coordinator can simply be
    /// restarted against the same `--shard-addrs`.
    pub fn connect(ops: &GcnOperands, addrs: &[String]) -> Result<TcpTransport> {
        let SOperand::Banded(bands) = &ops.s else {
            bail!("tcp shard transport needs CSR operands with a banded S");
        };
        if addrs.len() != bands.len() {
            bail!(
                "--shard-addrs lists {} workers but the operands have {} bands \
                 (match --shards to the address count)",
                addrs.len(),
                bands.len()
            );
        }
        let mut shards: Vec<TcpShard> = Vec::new();
        for (k, (band, addr)) in bands.iter().zip(addrs).enumerate() {
            // On error the already-connected workers just see a hangup
            // and re-accept; they are not ours to kill.
            let (stream, _pid) = Self::connect_and_init(addr, k, band)?;
            shards.push(TcpShard {
                child: None,
                addr: addr.clone(),
                link: RemoteShard {
                    stream: Some(stream),
                    row0: band.row0,
                    rows: band.s.rows(),
                },
            });
        }
        Ok(TcpTransport {
            shards_total: shards.len(),
            n: AtomicUsize::new(ops.n_nodes()),
            timings: Mutex::new(ShardTimings {
                wait_secs: vec![0.0; shards.len()],
                ..Default::default()
            }),
            shards: Mutex::new(shards),
            standbys: Mutex::new(Vec::new()),
            worker_bin: None,
            clock: MonotonicClock::new(),
        })
    }

    fn teardown(shards: &mut [TcpShard], standbys: &mut [TcpStandby]) {
        for c in shards
            .iter_mut()
            .filter_map(|s| s.child.as_mut())
            .chain(standbys.iter_mut().filter_map(|s| s.child.as_mut()))
        {
            let _ = c.kill();
            let _ = c.wait();
        }
    }

    /// Launch one worker on an ephemeral loopback port and read the
    /// address it reports. A worker that dies before binding closes its
    /// stdout pipe, so the line read cannot hang on a crashed child.
    fn spawn_local_worker(bin: &Path) -> Result<(Child, String)> {
        let mut child = Command::new(bin)
            .arg("shard-worker")
            .arg("--listen")
            .arg("127.0.0.1:0")
            .stdin(Stdio::null())
            .stdout(Stdio::piped())
            .stderr(Stdio::inherit())
            .spawn()
            .map_err(|e| anyhow!("spawning shard worker {bin:?}: {e}"))?;
        let Some(stdout) = child.stdout.take() else {
            let _ = child.kill();
            let _ = child.wait();
            bail!("shard worker stdout was not piped");
        };
        let mut line = String::new();
        let addr = match BufReader::new(stdout).read_line(&mut line) {
            Ok(n) if n > 0 => line
                .trim()
                .strip_prefix(WORKER_READY_PREFIX)
                .map(str::to_string),
            _ => None,
        };
        match addr {
            Some(a) => Ok((child, a)),
            None => {
                let _ = child.kill();
                let _ = child.wait();
                bail!(
                    "shard worker did not report a listening address (got {:?})",
                    line.trim()
                );
            }
        }
    }

    fn connect_and_init(addr: &str, shard: usize, band: &RowBand) -> Result<(TcpStream, usize)> {
        let mut stream = TcpStream::connect(addr)
            .map_err(|e| anyhow!("connecting to shard worker at {addr}: {e}"))?;
        // One lockstep request/reply in flight at a time: Nagle only
        // adds latency here.
        stream.set_nodelay(true)?;
        stream.set_read_timeout(Some(IO_TIMEOUT))?;
        stream.set_write_timeout(Some(IO_TIMEOUT))?;
        let pid = init_handshake(&mut stream, shard, band)?;
        Ok((stream, pid))
    }

    fn spawn_and_init_one(
        bin: &Path,
        shard: usize,
        band: &RowBand,
    ) -> Result<(Child, String, RemoteShard<TcpStream>)> {
        let (mut child, addr) = Self::spawn_local_worker(bin)?;
        match Self::connect_and_init(&addr, shard, band) {
            Ok((stream, pid)) => {
                // The worker echoes its pid in the ready frame; a
                // mismatch means something else answered on the port.
                if pid != child.id() as usize {
                    let _ = child.kill();
                    let _ = child.wait();
                    bail!("shard {shard} echoed unknown pid {pid}");
                }
                Ok((
                    child,
                    addr,
                    RemoteShard {
                        stream: Some(stream),
                        row0: band.row0,
                        rows: band.s.rows(),
                    },
                ))
            }
            Err(e) => {
                let _ = child.kill();
                let _ = child.wait();
                Err(e)
            }
        }
    }

    /// Spawned worker process ids, in shard order (fault-injection
    /// tests kill these externally); empty in connect-remote mode.
    pub fn worker_pids(&self) -> Vec<u32> {
        lock_recover(&self.shards)
            .iter()
            .filter_map(|s| s.child.as_ref().map(Child::id))
            .collect()
    }

    /// Worker addresses, in shard order.
    pub fn worker_addrs(&self) -> Vec<String> {
        lock_recover(&self.shards).iter().map(|s| s.addr.clone()).collect()
    }
}

impl ShardTransport for TcpTransport {
    fn name(&self) -> &'static str {
        "tcp"
    }

    fn shards(&self) -> usize {
        self.shards_total
    }

    fn aggregate(&self, ops: &GcnOperands, x: &Dense, x_r: &[f32]) -> Result<(Dense, f64, f64)> {
        let n = self.n.load(Ordering::SeqCst);
        if ops.n_nodes() != n {
            bail!(
                "operands changed shape under a running tcp transport \
                 (apply the delta through the transport first)"
            );
        }
        let mut shards = match self.shards.lock() {
            Ok(g) => g,
            Err(poisoned) => {
                // A panic mid-stream leaves the lockstep in an unknown
                // state; poison every shard so no later aggregate can
                // stitch a stale queued reply.
                let mut g = poisoned.into_inner();
                for sh in g.iter_mut() {
                    sh.link.stream = None;
                }
                g
            }
        };
        let mut links: Vec<&mut RemoteShard<TcpStream>> =
            shards.iter_mut().map(|s| &mut s.link).collect();
        let agg = aggregate_remote(&mut links, n, x, x_r, &self.clock)?;
        drop(shards);
        {
            let mut tm = lock_recover(&self.timings);
            tm.aggregates += 1;
            tm.stitch_secs += agg.stitch_secs;
            for (acc, w) in tm.wait_secs.iter_mut().zip(&agg.waits) {
                *acc += w;
            }
        }
        Ok((agg.out, agg.pred, agg.actual))
    }

    fn apply_delta(&self, ops: &GcnOperands, outcome: &DeltaOutcome) -> Result<()> {
        let SOperand::Banded(bands) = &ops.s else {
            bail!("tcp shard transport needs CSR operands with a banded S");
        };
        if bands.len() != self.shards_total {
            bail!(
                "delta changed the band partition ({} bands != {} shards); \
                 restart the shard tier",
                bands.len(),
                self.shards_total
            );
        }
        let mut shards = match self.shards.lock() {
            Ok(g) => g,
            Err(poisoned) => {
                let mut g = poisoned.into_inner();
                for sh in g.iter_mut() {
                    sh.link.stream = None;
                }
                g
            }
        };
        let targets: Vec<usize> = if outcome.resized {
            (0..bands.len()).collect()
        } else {
            outcome.affected_bands.clone()
        };
        {
            let mut links: Vec<&mut RemoteShard<TcpStream>> =
                shards.iter_mut().map(|s| &mut s.link).collect();
            apply_delta_remote(&mut links, bands, &targets)?;
        }
        drop(shards);
        // Keep warm standbys on the current graph version — adoption
        // must be zero-reship *and* version-exact. Losing a standby
        // degrades failover, never the delta itself.
        let mut standbys = lock_recover(&self.standbys);
        let mut lost: Vec<usize> = Vec::new();
        for (i, standby) in standbys.iter_mut().enumerate() {
            if !targets.contains(&standby.band) {
                continue;
            }
            let (Some(band), Some(stream)) =
                (bands.get(standby.band), standby.link.stream.as_mut())
            else {
                lost.push(i);
                continue;
            };
            match ship_band_delta(stream, standby.band, band) {
                Ok(()) => {
                    standby.link.row0 = band.row0;
                    standby.link.rows = band.s.rows();
                }
                Err(e) => {
                    eprintln!(
                        "shard tier: warm standby for band {} lost on delta \
                         re-ship ({e:#}); discarded",
                        standby.band
                    );
                    lost.push(i);
                }
            }
        }
        for i in lost.into_iter().rev() {
            let mut s = standbys.remove(i);
            if let Some(c) = s.child.as_mut() {
                let _ = c.kill();
                let _ = c.wait();
            }
        }
        self.n.store(ops.n_nodes(), Ordering::SeqCst);
        Ok(())
    }

    fn kill_shard(&self, shard: usize) -> bool {
        let mut shards = lock_recover(&self.shards);
        match shards.get_mut(shard) {
            Some(sh) => {
                match sh.child.as_mut() {
                    Some(child) => {
                        // Kill the process but keep the broken stream:
                        // the next aggregate experiences the wire-level
                        // failure exactly as an external crash.
                        let _ = child.kill();
                        let _ = child.wait();
                    }
                    None => {
                        // A remote worker is not ours to kill; sever
                        // the link instead (the worker survives and
                        // re-accepts, which is the reconnect drill).
                        sh.link.stream = None;
                    }
                }
                true
            }
            None => false,
        }
    }

    fn probe(&self) -> Vec<bool> {
        let mut shards = lock_recover(&self.shards);
        shards
            .iter_mut()
            .map(|sh| {
                // A poisoned stream is a known death; a gone pid
                // (spawn-local) is one no request has tripped over yet.
                sh.link.stream.is_some()
                    && sh
                        .child
                        .as_mut()
                        .map_or(true, |c| matches!(c.try_wait(), Ok(None)))
            })
            .collect()
    }

    fn recover(&self, shard: usize, ops: &GcnOperands) -> Result<RecoveryKind> {
        let SOperand::Banded(bands) = &ops.s else {
            bail!("tcp shard transport needs CSR operands with a banded S");
        };
        if bands.len() != self.shards_total {
            bail!(
                "band partition changed ({} bands != {} shards); \
                 restart the shard tier",
                bands.len(),
                self.shards_total
            );
        }
        if ops.n_nodes() != self.n.load(Ordering::SeqCst) {
            bail!(
                "recover called with operands of a different shape \
                 (apply the delta through the transport first)"
            );
        }
        let Some(band) = bands.get(shard) else {
            bail!("shard {shard} out of range ({})", self.shards_total);
        };
        let mut shards = lock_recover(&self.shards);
        let Some(sh) = shards.get_mut(shard) else {
            bail!("shard {shard} out of range ({})", self.shards_total);
        };
        if let Some(child) = sh.child.as_mut() {
            let _ = child.kill();
            let _ = child.wait();
        }
        sh.link.stream = None;
        // Zero-reship failover: adopt a standby already holding this
        // band (kept current by apply_delta).
        {
            let mut standbys = lock_recover(&self.standbys);
            if let Some(pos) = standbys
                .iter()
                .position(|s| s.band == shard && s.link.stream.is_some())
            {
                let standby = standbys.remove(pos);
                sh.child = standby.child;
                sh.addr = standby.addr;
                sh.link = standby.link;
                sh.link.row0 = band.row0;
                sh.link.rows = band.s.rows();
                return Ok(RecoveryKind::StandbyAdopted);
            }
        }
        match &self.worker_bin {
            Some(bin) => {
                // Spawn-local: a fresh worker on a fresh ephemeral
                // port, re-shipped through the same init path.
                let (child, addr, link) = Self::spawn_and_init_one(bin, shard, band)?;
                sh.child = Some(child);
                sh.addr = addr;
                sh.link = link;
                Ok(RecoveryKind::Respawned)
            }
            None => {
                // Connect-remote: the worker (or its restart) should
                // reappear at the same address; retry within a deadline.
                let deadline = self.clock.now().after(RECONNECT_TIMEOUT);
                loop {
                    match Self::connect_and_init(&sh.addr, shard, band) {
                        Ok((stream, _pid)) => {
                            sh.link = RemoteShard {
                                stream: Some(stream),
                                row0: band.row0,
                                rows: band.s.rows(),
                            };
                            return Ok(RecoveryKind::Reconnected);
                        }
                        Err(e) => {
                            if self.clock.now() > deadline {
                                return Err(e);
                            }
                            std::thread::sleep(Duration::from_millis(20));
                        }
                    }
                }
            }
        }
    }

    fn standby_count(&self) -> usize {
        lock_recover(&self.standbys)
            .iter()
            .filter(|s| s.link.stream.is_some())
            .count()
    }

    fn timings(&self) -> ShardTimings {
        lock_recover(&self.timings).clone()
    }
}

impl Drop for TcpTransport {
    fn drop(&mut self) {
        let mut shards = lock_recover(&self.shards);
        let mut standbys = lock_recover(&self.standbys);
        let header = Json::obj(vec![
            ("type", Json::from("shutdown")),
            ("payload", Json::from(0usize)),
        ]);
        let frame = encode_frame(&header, &[]);
        for (child, stream) in shards
            .iter_mut()
            .map(|s| (&s.child, &mut s.link.stream))
            .chain(standbys.iter_mut().map(|s| (&s.child, &mut s.link.stream)))
        {
            if child.is_some() {
                if let Some(mut s) = stream.take() {
                    let _ = s.write_all(&frame);
                }
            } else {
                // Remote workers outlive this coordinator: dropping the
                // stream reads as a hangup and the worker re-accepts,
                // so a restarted coordinator can reconnect. Stop remote
                // workers out-of-band.
                *stream = None;
            }
        }
        for child in shards
            .iter_mut()
            .filter_map(|s| s.child.as_mut())
            .chain(standbys.iter_mut().filter_map(|s| s.child.as_mut()))
        {
            // Give the worker a moment to exit on its own, then force
            // the issue so drop never hangs.
            let deadline = self.clock.now().after(Duration::from_secs(2));
            loop {
                match child.try_wait() {
                    Ok(Some(_)) => break,
                    Ok(None) if self.clock.now() < deadline => {
                        std::thread::sleep(Duration::from_millis(5));
                    }
                    _ => {
                        let _ = child.kill();
                        let _ = child.wait();
                        break;
                    }
                }
            }
        }
    }
}

/// The `gcn-abft shard-worker --listen` main loop: bind, report the
/// bound address on stdout (the spawn path parses the ephemeral port
/// from it), then serve coordinator sessions forever with the shared
/// worker loop ([`serve_shard_connection`] — the same code the proc
/// worker runs). A hangup or a failed session keeps the worker alive
/// for the next coordinator (supervised reconnect lands here); only an
/// explicit shutdown frame ends the process.
pub fn run_tcp_shard_worker(listen: &str) -> Result<()> {
    let listener = TcpListener::bind(listen)
        .map_err(|e| anyhow!("binding shard worker listener on {listen}: {e}"))?;
    let addr = listener.local_addr()?;
    println!("{WORKER_READY_PREFIX}{addr}");
    // The coordinator blocks on this line; an unflushed buffer would
    // deadlock the handshake.
    std::io::stdout().flush()?;
    loop {
        let (mut stream, peer) = match listener.accept() {
            Ok(v) => v,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e.into()),
        };
        stream.set_nodelay(true)?;
        match serve_shard_connection(&mut stream) {
            Ok(SessionEnd::Shutdown) => return Ok(()),
            Ok(SessionEnd::Hangup) => {
                // Coordinator crashed or a port probe came and went:
                // wait for the next session.
            }
            Err(e) => {
                eprintln!("shard worker: session with {peer} failed: {e:#}");
            }
        }
    }
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]

    use super::*;
    use crate::graph::DatasetId;
    use crate::runtime::backend::ChecksumScheme;
    use crate::coordinator::shard::{InProcTransport, ShardedBackend};
    use crate::runtime::backend::GcnBackend as _;
    use std::sync::Arc;

    fn workload(bands: usize) -> GcnOperands {
        let g = DatasetId::Tiny.build(11);
        let m = crate::gcn::GcnModel::two_layer(&g, 8, 3);
        GcnOperands::sparse(
            g.features.clone(),
            &m.adjacency,
            m.layers[0].weights.clone(),
            m.layers[1].weights.clone(),
            bands,
        )
        .unwrap()
    }

    /// An in-thread stand-in for `gcn-abft shard-worker --listen`: same
    /// serve loop, no subprocess (unit tests have no worker binary;
    /// `tests/supervised_recovery.rs` exercises the real one).
    fn worker_thread() -> String {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        std::thread::spawn(move || loop {
            let Ok((mut stream, _)) = listener.accept() else {
                return;
            };
            match serve_shard_connection(&mut stream) {
                Ok(SessionEnd::Shutdown) => return,
                Ok(SessionEnd::Hangup) | Err(_) => {}
            }
        });
        addr
    }

    #[test]
    fn connect_transport_matches_inproc_fails_stop_and_reconnects() {
        let ops = workload(2);
        let addrs: Vec<String> = (0..2).map(|_| worker_thread()).collect();
        let tcp = Arc::new(TcpTransport::connect(&ops, &addrs).unwrap());
        assert_eq!(tcp.shards(), 2);
        assert_eq!(tcp.worker_pids(), Vec::<u32>::new(), "no spawned children");
        let backend = ShardedBackend::new(
            tcp.clone() as Arc<dyn ShardTransport>,
            ChecksumScheme::Fused,
            1,
        );
        let reference = ShardedBackend::new(
            Arc::new(InProcTransport::new(&ops).unwrap()),
            ChecksumScheme::Fused,
            1,
        );
        let want = reference.run(&ops, &[]).unwrap();
        let got = backend.run(&ops, &[]).unwrap();
        assert_eq!(want.logits, got.logits, "tcp must be bit-identical to inproc");
        assert_eq!(want.predicted, got.predicted);
        assert_eq!(want.actual, got.actual);

        // Sever one link: fail-stop, probe sees it, recover re-dials.
        assert!(tcp.kill_shard(0));
        assert_eq!(tcp.probe(), vec![false, true]);
        let err = backend.run(&ops, &[]).unwrap_err();
        assert!(err.to_string().contains("shard 0"), "{err}");
        assert_eq!(tcp.recover(0, &ops).unwrap(), RecoveryKind::Reconnected);
        assert_eq!(tcp.probe(), vec![true, true]);
        let healed = backend.run(&ops, &[]).unwrap();
        assert_eq!(want.logits, healed.logits, "post-recovery bits must match");
        assert_eq!(want.predicted, healed.predicted);
        assert_eq!(want.actual, healed.actual);
        let tm = tcp.timings();
        assert!(tm.aggregates >= 4, "two clean runs = four phases");
    }

    #[test]
    fn connect_refuses_mismatched_address_count() {
        let ops = workload(2);
        let addrs = vec![worker_thread()];
        let err = TcpTransport::connect(&ops, &addrs).unwrap_err();
        assert!(err.to_string().contains("--shard-addrs"), "{err}");
    }

    #[test]
    fn delta_reships_over_tcp_bit_identically() {
        use crate::runtime::mutate::{self, GraphDelta};
        let mut ops = workload(2);
        let addrs: Vec<String> = (0..2).map(|_| worker_thread()).collect();
        let tcp: Arc<dyn ShardTransport> = Arc::new(TcpTransport::connect(&ops, &addrs).unwrap());
        let backend = ShardedBackend::new(tcp.clone(), ChecksumScheme::Fused, 1);
        let before = backend.run(&ops, &[]).unwrap();
        let delta = GraphDelta::Edges {
            add: vec![(0, 7, 0.4)],
            remove: vec![],
        };
        let outcome = mutate::apply(&mut ops, &delta).unwrap();
        tcp.apply_delta(&ops, &outcome).unwrap();
        let after = backend.run(&ops, &[]).unwrap();
        assert_ne!(before.logits, after.logits);
        // Bit-identical to a fresh inproc tier on the mutated operands.
        let fresh = ShardedBackend::new(
            Arc::new(InProcTransport::new(&ops).unwrap()),
            ChecksumScheme::Fused,
            1,
        );
        let want = fresh.run(&ops, &[]).unwrap();
        assert_eq!(after.logits, want.logits);
        assert_eq!(after.predicted, want.predicted);
        assert_eq!(after.actual, want.actual);
    }
}
