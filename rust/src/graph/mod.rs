//! Graph substrate: dataset container, synthetic generator, and the
//! paper's four evaluation dataset specs.

pub mod datasets;
pub mod graph;
pub mod synth;

pub use datasets::DatasetId;
pub use graph::Graph;
pub use synth::{generate, SynthSpec};
