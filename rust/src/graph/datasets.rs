//! The four evaluation datasets of the paper (Cora, Citeseer, PubMed,
//! Nell), plus small variants used by tests and the XLA serving path.
//!
//! Each spec carries the published statistics of the real dataset; the
//! actual graphs are synthesized (see [`crate::graph::synth`] and
//! DESIGN.md §4 — the real data is not redistributable/available offline,
//! and ABFT behaviour depends on shapes/sparsity/magnitudes, which we
//! match). `scale` lets the fault-injection CLI shrink a dataset
//! proportionally for quick runs while keeping sparsity ratios.

use super::graph::Graph;
use super::synth::{generate, SynthSpec};

/// GCN hyperparameters used throughout the paper's evaluation: 2-layer
/// GCNs with a hidden width of 16 (the canonical Kipf–Welling setup for
/// all four node-classification benchmarks).
pub const HIDDEN_DIM: usize = 16;

/// Identifier for one of the paper's datasets.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DatasetId {
    Cora,
    Citeseer,
    Pubmed,
    Nell,
    /// Small dataset for tests/examples/XLA smoke runs.
    Tiny,
}

impl DatasetId {
    pub const ALL: [DatasetId; 4] = [
        DatasetId::Cora,
        DatasetId::Citeseer,
        DatasetId::Pubmed,
        DatasetId::Nell,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            DatasetId::Cora => "cora",
            DatasetId::Citeseer => "citeseer",
            DatasetId::Pubmed => "pubmed",
            DatasetId::Nell => "nell",
            DatasetId::Tiny => "tiny",
        }
    }

    pub fn parse(s: &str) -> Option<DatasetId> {
        match s.to_ascii_lowercase().as_str() {
            "cora" => Some(DatasetId::Cora),
            "citeseer" => Some(DatasetId::Citeseer),
            "pubmed" => Some(DatasetId::Pubmed),
            "nell" => Some(DatasetId::Nell),
            "tiny" => Some(DatasetId::Tiny),
            _ => None,
        }
    }

    /// Published statistics (see DESIGN.md §4 for sources; Nell feature
    /// nnz calibrated to the paper's op budget).
    pub fn spec(&self) -> SynthSpec {
        match self {
            DatasetId::Cora => SynthSpec {
                name: "cora".into(),
                num_nodes: 2708,
                num_edges: 5429,
                feat_dim: 1433,
                feat_nnz: 49_216,
                num_classes: 7,
                homophily: 0.81,
                binary_features: true,
                feature_scale: 256.0,
            },
            DatasetId::Citeseer => SynthSpec {
                name: "citeseer".into(),
                num_nodes: 3327,
                num_edges: 4732,
                feat_dim: 3703,
                feat_nnz: 105_165,
                num_classes: 6,
                homophily: 0.74,
                binary_features: true,
                feature_scale: 256.0,
            },
            DatasetId::Pubmed => SynthSpec {
                name: "pubmed".into(),
                num_nodes: 19_717,
                num_edges: 44_338,
                feat_dim: 500,
                feat_nnz: 988_031,
                num_classes: 3,
                homophily: 0.80,
                binary_features: false, // PubMed features are tf-idf reals
                feature_scale: 256.0,
            },
            DatasetId::Nell => SynthSpec {
                name: "nell".into(),
                num_nodes: 65_755,
                num_edges: 266_144,
                feat_dim: 5414,
                // Back-solved from the paper's Table-II op budget
                // (1745.9 M true ops with h=16 ⇒ nnz(H) ≈ 32.3 M); the
                // Kipf NELL preprocessing yields a similarly dense
                // entity-feature matrix. See DESIGN.md §4.
                feat_nnz: 32_300_000,
                num_classes: 210,
                homophily: 0.85,
                binary_features: true,
                // Lower magnitude calibration than the citation sets:
                // Nell's enormous nnz drives checksum magnitudes to ~1e8
                // at scale 256, where the f64 rounding floor crosses the
                // paper's tightest (absolute) threshold of 1e-7.
                feature_scale: 32.0,
            },
            DatasetId::Tiny => SynthSpec {
                name: "tiny".into(),
                num_nodes: 64,
                num_edges: 128,
                feat_dim: 32,
                feat_nnz: 256,
                num_classes: 4,
                homophily: 0.8,
                binary_features: true,
                feature_scale: 256.0,
            },
        }
    }

    /// Hidden width of the 2-layer GCN for this dataset.
    pub fn hidden_dim(&self) -> usize {
        match self {
            DatasetId::Tiny => 8,
            _ => HIDDEN_DIM,
        }
    }

    /// Build the dataset (deterministic for a given seed).
    pub fn build(&self, seed: u64) -> Graph {
        generate(&self.spec(), seed ^ fnv1a(self.name()))
    }

    /// Build a proportionally scaled-down variant: node/edge/nnz counts
    /// multiplied by `scale` (≤ 1.0), dims and class count preserved.
    /// Used by `--scale` on the fault-injection CLI for quick runs.
    pub fn build_scaled(&self, seed: u64, scale: f64) -> Graph {
        assert!(scale > 0.0 && scale <= 1.0, "scale must be in (0,1]");
        let base = self.spec();
        let s = |x: usize| ((x as f64 * scale).round() as usize).max(1);
        let spec = SynthSpec {
            name: format!("{}@{scale:.2}", base.name),
            num_nodes: s(base.num_nodes).max(base.num_classes),
            num_edges: s(base.num_edges),
            feat_nnz: s(base.feat_nnz),
            ..base
        };
        generate(&spec, seed ^ fnv1a(self.name()))
    }
}

/// FNV-1a hash for stable per-dataset seed derivation.
fn fnv1a(s: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x1_0000_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_specs_match_published_stats() {
        let cora = DatasetId::Cora.spec();
        assert_eq!(
            (cora.num_nodes, cora.num_edges, cora.feat_dim, cora.num_classes),
            (2708, 5429, 1433, 7)
        );
        let cite = DatasetId::Citeseer.spec();
        assert_eq!(
            (cite.num_nodes, cite.num_edges, cite.feat_dim, cite.num_classes),
            (3327, 4732, 3703, 6)
        );
        let pm = DatasetId::Pubmed.spec();
        assert_eq!(
            (pm.num_nodes, pm.num_edges, pm.feat_dim, pm.num_classes),
            (19_717, 44_338, 500, 3)
        );
        let nell = DatasetId::Nell.spec();
        assert_eq!(
            (nell.num_nodes, nell.num_edges, nell.feat_dim, nell.num_classes),
            (65_755, 266_144, 5414, 210)
        );
    }

    #[test]
    fn parse_roundtrip() {
        for id in DatasetId::ALL {
            assert_eq!(DatasetId::parse(id.name()), Some(id));
        }
        assert_eq!(DatasetId::parse("Tiny"), Some(DatasetId::Tiny));
        assert_eq!(DatasetId::parse("bogus"), None);
    }

    #[test]
    fn tiny_builds_and_validates() {
        let g = DatasetId::Tiny.build(0);
        assert!(g.validate().is_ok());
        assert_eq!(g.num_nodes, 64);
        assert_eq!(g.num_classes, 4);
    }

    #[test]
    fn cora_builds_with_exact_statistics() {
        let g = DatasetId::Cora.build(0);
        assert!(g.validate().is_ok());
        assert_eq!(g.num_nodes, 2708);
        assert_eq!(g.num_edges(), 5429);
        assert_eq!(g.feat_dim(), 1433);
        let nnz = g.features.nnz();
        assert!((nnz as i64 - 49_216).abs() < 500, "nnz {nnz}");
        // S nnz = 2E + N when no explicit self loops collide
        assert_eq!(g.adjacency_nnz(), 2 * 5429 + 2708);
    }

    #[test]
    fn scaled_build_shrinks_proportionally() {
        let g = DatasetId::Pubmed.build_scaled(0, 0.1);
        assert!((g.num_nodes as f64 - 1971.7).abs() < 2.0);
        assert!(g.validate().is_ok());
        assert_eq!(g.feat_dim(), 500); // dims preserved
        assert_eq!(g.num_classes, 3);
    }

    #[test]
    fn per_dataset_seeds_differ() {
        let a = DatasetId::Cora.build(0);
        let b = DatasetId::Citeseer.build(0);
        assert_ne!(a.edges.len(), b.edges.len());
    }
}
