//! Graph container: topology + node features + labels, plus the derived
//! normalized adjacency used by GCN layers.

use crate::sparse::{normalized_adjacency, Csr};

/// A node-classification graph dataset instance.
#[derive(Debug, Clone)]
pub struct Graph {
    /// Human-readable name ("cora", "citeseer", …).
    pub name: String,
    /// Number of nodes.
    pub num_nodes: usize,
    /// Undirected edge list (deduplicated, u < v canonical order).
    pub edges: Vec<(usize, usize)>,
    /// Sparse node features, `num_nodes × feat_dim`.
    pub features: Csr,
    /// Class label per node.
    pub labels: Vec<usize>,
    /// Number of classes.
    pub num_classes: usize,
}

impl Graph {
    /// Feature dimensionality.
    pub fn feat_dim(&self) -> usize {
        self.features.cols()
    }

    /// Number of undirected edges.
    pub fn num_edges(&self) -> usize {
        self.edges.len()
    }

    /// Build the GCN propagation matrix `S = D^{-1/2}(A+I)D^{-1/2}`.
    pub fn normalized_adjacency(&self) -> Csr {
        normalized_adjacency(self.num_nodes, &self.edges)
    }

    /// nnz of `S` (each undirected edge contributes 2 plus N self-loops,
    /// minus any explicit self-loop duplicates).
    pub fn adjacency_nnz(&self) -> usize {
        self.normalized_adjacency().nnz()
    }

    /// Basic structural sanity checks; returns an error string on the
    /// first violation (used by tests and dataset loaders).
    pub fn validate(&self) -> Result<(), String> {
        if self.labels.len() != self.num_nodes {
            return Err(format!(
                "labels len {} != num_nodes {}",
                self.labels.len(),
                self.num_nodes
            ));
        }
        if self.features.rows() != self.num_nodes {
            return Err(format!(
                "feature rows {} != num_nodes {}",
                self.features.rows(),
                self.num_nodes
            ));
        }
        for &(u, v) in &self.edges {
            if u >= self.num_nodes || v >= self.num_nodes {
                return Err(format!("edge ({u},{v}) out of bounds"));
            }
        }
        if let Some(&l) = self.labels.iter().find(|&&l| l >= self.num_classes) {
            return Err(format!("label {l} >= num_classes {}", self.num_classes));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::Csr;

    fn tiny() -> Graph {
        Graph {
            name: "tiny".into(),
            num_nodes: 3,
            edges: vec![(0, 1), (1, 2)],
            features: Csr::from_coo(3, 4, vec![(0, 0, 1.), (1, 2, 1.), (2, 3, 1.)]),
            labels: vec![0, 1, 0],
            num_classes: 2,
        }
    }

    #[test]
    fn accessors() {
        let g = tiny();
        assert_eq!(g.feat_dim(), 4);
        assert_eq!(g.num_edges(), 2);
        assert!(g.validate().is_ok());
    }

    #[test]
    fn adjacency_shape_and_nnz() {
        let g = tiny();
        let s = g.normalized_adjacency();
        assert_eq!(s.shape(), (3, 3));
        // path graph: 3 self loops + 2*2 edge entries = 7
        assert_eq!(s.nnz(), 7);
        assert_eq!(g.adjacency_nnz(), 7);
    }

    #[test]
    fn validation_catches_errors() {
        let mut g = tiny();
        g.labels = vec![0, 1];
        assert!(g.validate().is_err());

        let mut g = tiny();
        g.edges.push((0, 9));
        assert!(g.validate().is_err());

        let mut g = tiny();
        g.labels[0] = 5;
        assert!(g.validate().is_err());
    }
}
