//! Synthetic graph generation.
//!
//! The paper evaluates on Cora/Citeseer/PubMed/Nell loaded through the
//! `graphlearning` package; those datasets are not available in this
//! offline environment, so we synthesize graphs that match each dataset's
//! *published statistics* — node count, undirected edge count, feature
//! dimension, feature nnz, class count — with a degree profile and a
//! community structure qualitatively similar to citation networks (see
//! DESIGN.md §4 for why this preserves the behaviours ABFT cares about:
//! shapes, sparsity, value magnitudes).
//!
//! Generator: a planted-partition (stochastic block–flavoured) graph with
//! preferential attachment inside communities, bag-of-words-style sparse
//! binary/tf-idf-ish features correlated with the community, and labels =
//! community ids. All draws come from a seeded [`Pcg64`].

use super::graph::Graph;
use crate::sparse::Csr;
use crate::util::rng::Pcg64;

/// Parameters for the synthetic generator.
#[derive(Debug, Clone)]
pub struct SynthSpec {
    pub name: String,
    pub num_nodes: usize,
    pub num_edges: usize,
    pub feat_dim: usize,
    /// Total nonzeros in the feature matrix.
    pub feat_nnz: usize,
    pub num_classes: usize,
    /// Probability that an edge stays inside its community.
    pub homophily: f64,
    /// Feature value model: `true` → binary bag-of-words {1.0};
    /// `false` → tf-idf-like positive reals in (0, 1].
    pub binary_features: bool,
    /// Multiplier applied to every feature value. The paper uses the raw
    /// (unnormalized) dataset features, whose magnitudes put the GCN's
    /// intermediate values at O(10²–10³); its Table-I thresholds are
    /// *absolute* (1e-4…1e-7), so matching that magnitude regime matters
    /// for silent-fault rates (DESIGN.md §6). Synthetic features are unit
    /// valued, hence this calibration scale.
    pub feature_scale: f32,
}

/// Generate a synthetic graph matching `spec`, deterministically from
/// `seed`.
pub fn generate(spec: &SynthSpec, seed: u64) -> Graph {
    let mut rng = Pcg64::from_seed(seed);
    let n = spec.num_nodes;
    let k = spec.num_classes.max(1);

    // --- labels: roughly balanced communities with random sizes ---------
    let mut labels: Vec<usize> = (0..n).map(|i| i % k).collect();
    rng.shuffle(&mut labels);

    // Group nodes per community for fast intra-community sampling.
    let mut members: Vec<Vec<usize>> = vec![Vec::new(); k];
    for (node, &c) in labels.iter().enumerate() {
        members[c].push(node);
    }

    // --- edges: preferential attachment with homophily ------------------
    // Track degree+1 as attachment weight (cheap preferential attachment:
    // sample from an endpoint pool that grows with every accepted edge).
    let mut edges: std::collections::HashSet<(usize, usize)> =
        std::collections::HashSet::with_capacity(spec.num_edges * 2);
    let mut endpoint_pool: Vec<usize> = (0..n).collect(); // every node once
    let mut attempts = 0usize;
    let max_attempts = spec.num_edges * 50 + 1000;
    while edges.len() < spec.num_edges && attempts < max_attempts {
        attempts += 1;
        // u: preferential (degree-weighted) pick.
        let u = endpoint_pool[rng.gen_index(endpoint_pool.len())];
        // v: same community with prob homophily, else anywhere.
        let v = if rng.gen_bool(spec.homophily) {
            let comm = &members[labels[u]];
            comm[rng.gen_index(comm.len())]
        } else {
            rng.gen_index(n)
        };
        if u == v {
            continue;
        }
        let key = (u.min(v), u.max(v));
        if edges.insert(key) {
            endpoint_pool.push(u);
            endpoint_pool.push(v);
        }
    }
    let mut edges: Vec<(usize, usize)> = edges.into_iter().collect();
    edges.sort_unstable();

    // --- features: sparse bag-of-words correlated with community --------
    // Each community owns a preferred band of the vocabulary; each node
    // draws most of its terms from its community band.
    let per_node = (spec.feat_nnz / n).max(1);
    let extra = spec.feat_nnz.saturating_sub(per_node * n);
    let band = (spec.feat_dim / k).max(1);
    let mut coo: Vec<(usize, usize, f32)> = Vec::with_capacity(spec.feat_nnz + n);
    // Dedup per node (NELL-scale feature matrices have tens of millions
    // of nonzeros; a global (node, col) set would dominate memory).
    let mut node_cols: std::collections::HashSet<usize> =
        std::collections::HashSet::with_capacity(per_node * 2);
    for node in 0..n {
        node_cols.clear();
        let mut want = per_node + usize::from(node < extra);
        let band_lo = (labels[node] * band).min(spec.feat_dim - 1);
        let mut guard = 0;
        while want > 0 && guard < 100 * per_node + 100 {
            guard += 1;
            // 70% of terms from the community band, 30% anywhere.
            let col = if rng.gen_bool(0.7) {
                band_lo + rng.gen_index(band.min(spec.feat_dim - band_lo))
            } else {
                rng.gen_index(spec.feat_dim)
            };
            if node_cols.insert(col) {
                let v = if spec.binary_features {
                    spec.feature_scale
                } else {
                    rng.gen_f32_range(0.05, 1.0) * spec.feature_scale
                };
                coo.push((node, col, v));
                want -= 1;
            }
        }
    }
    let features = Csr::from_coo(n, spec.feat_dim, coo);

    Graph {
        name: spec.name.clone(),
        num_nodes: n,
        edges,
        features,
        labels,
        num_classes: k,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> SynthSpec {
        SynthSpec {
            name: "test".into(),
            num_nodes: 200,
            num_edges: 400,
            feat_dim: 64,
            feat_nnz: 1200,
            num_classes: 4,
            homophily: 0.8,
            binary_features: true,
            feature_scale: 1.0,
        }
    }

    #[test]
    fn matches_requested_statistics() {
        let g = generate(&spec(), 1);
        assert_eq!(g.num_nodes, 200);
        assert_eq!(g.num_edges(), 400);
        assert_eq!(g.feat_dim(), 64);
        assert_eq!(g.num_classes, 4);
        // nnz within 1% of requested (rounding of per-node quota).
        let nnz = g.features.nnz();
        assert!(
            (nnz as i64 - 1200i64).abs() <= 12,
            "feature nnz {nnz} too far from 1200"
        );
        assert!(g.validate().is_ok());
    }

    #[test]
    fn deterministic_per_seed() {
        let a = generate(&spec(), 42);
        let b = generate(&spec(), 42);
        assert_eq!(a.edges, b.edges);
        assert_eq!(a.labels, b.labels);
        assert_eq!(a.features, b.features);
        let c = generate(&spec(), 43);
        assert_ne!(a.edges, c.edges);
    }

    #[test]
    fn labels_cover_all_classes() {
        let g = generate(&spec(), 7);
        let mut seen = vec![false; g.num_classes];
        for &l in &g.labels {
            seen[l] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn homophily_moves_intra_community_edge_share() {
        let hi = generate(
            &SynthSpec {
                homophily: 0.95,
                ..spec()
            },
            3,
        );
        let lo = generate(
            &SynthSpec {
                homophily: 0.05,
                ..spec()
            },
            3,
        );
        let share = |g: &Graph| {
            let intra = g
                .edges
                .iter()
                .filter(|&&(u, v)| g.labels[u] == g.labels[v])
                .count();
            intra as f64 / g.num_edges() as f64
        };
        assert!(
            share(&hi) > share(&lo) + 0.2,
            "homophily had no effect: hi={} lo={}",
            share(&hi),
            share(&lo)
        );
    }

    #[test]
    fn binary_vs_weighted_features() {
        let gb = generate(&spec(), 5);
        assert!(gb.features.values().iter().all(|&v| v == 1.0));  // scale 1.0
        let gw = generate(
            &SynthSpec {
                binary_features: false,
                ..spec()
            },
            5,
        );
        assert!(gw.features.values().iter().any(|&v| v != 1.0));
        assert!(gw.features.values().iter().all(|&v| v > 0.0 && v <= 1.0));
    }

    #[test]
    fn degree_distribution_is_skewed() {
        // Preferential attachment should create a heavier tail than the
        // minimum degree; check max degree >> mean degree.
        let g = generate(&spec(), 9);
        let mut deg = vec![0usize; g.num_nodes];
        for &(u, v) in &g.edges {
            deg[u] += 1;
            deg[v] += 1;
        }
        let mean = 2.0 * g.num_edges() as f64 / g.num_nodes as f64;
        let max = *deg.iter().max().unwrap() as f64;
        assert!(max > 2.5 * mean, "max degree {max} vs mean {mean}");
    }
}
