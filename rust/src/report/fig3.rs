//! Fig. 3 reproduction: runtime split between the two matmul phases of
//! each GCN layer.
//!
//! The paper's point: the first (combination) step dominates each layer's
//! runtime, so GCN-ABFT's end-of-layer (rather than end-of-phase) error
//! report costs almost no detection latency. We measure wall-clock of the
//! two phases on the native engine and report per-phase fractions of the
//! total 2-layer runtime, mirroring the stacked bars of the figure.

use crate::gcn::GcnModel;
use crate::sparse::Csr;
use crate::tensor::{ops, Dense};
use std::time::Instant;

/// Phase timing for one layer.
#[derive(Debug, Clone, Copy)]
pub struct LayerPhaseTimes {
    pub combination_secs: f64,
    pub aggregation_secs: f64,
}

/// Full measurement for a 2-layer model.
#[derive(Debug, Clone)]
pub struct Fig3Row {
    pub dataset: String,
    pub layers: Vec<LayerPhaseTimes>,
}

impl Fig3Row {
    pub fn total_secs(&self) -> f64 {
        self.layers
            .iter()
            .map(|l| l.combination_secs + l.aggregation_secs)
            .sum()
    }

    /// Fraction of total runtime spent in combination (phase 1), summed
    /// over layers — the paper's headline number per application
    /// (e.g. ≥ 90 % for PubMed, ≈ 95 % for Nell).
    pub fn combination_fraction(&self) -> f64 {
        let comb: f64 = self.layers.iter().map(|l| l.combination_secs).sum();
        comb / self.total_secs().max(1e-12)
    }

    /// Per-segment fractions in paper order:
    /// [comb L1, agg L1, comb L2, agg L2].
    pub fn segment_fractions(&self) -> Vec<f64> {
        let total = self.total_secs().max(1e-12);
        self.layers
            .iter()
            .flat_map(|l| [l.combination_secs / total, l.aggregation_secs / total])
            .collect()
    }
}

/// Measure phase times of a model on a dataset (median of `reps` runs).
pub fn measure(name: &str, model: &GcnModel, features: &Csr, reps: usize) -> Fig3Row {
    let reps = reps.max(1);
    let mut all: Vec<Vec<LayerPhaseTimes>> = Vec::with_capacity(reps);
    for _ in 0..reps {
        let mut layers = Vec::with_capacity(model.num_layers());
        let mut dense_input: Option<Dense> = None;
        for (i, layer) in model.layers.iter().enumerate() {
            // Phase 1: combination X = H·W.
            // gcn-lint: allow(D1, reason="phase wall time is the figure's measurement, not a scheduling input")
            let t0 = Instant::now();
            let x = match &dense_input {
                None => features.spmm(&layer.weights),
                Some(h) => ops::matmul(h, &layer.weights),
            };
            let combination_secs = t0.elapsed().as_secs_f64();
            // Phase 2: aggregation H_out = S·X.
            // gcn-lint: allow(D1, reason="phase wall time is the figure's measurement, not a scheduling input")
            let t1 = Instant::now();
            let mut out = model.adjacency.spmm(&x);
            let aggregation_secs = t1.elapsed().as_secs_f64();
            if i + 1 < model.num_layers() {
                layer.activate(&mut out);
                dense_input = Some(out);
            }
            layers.push(LayerPhaseTimes {
                combination_secs,
                aggregation_secs,
            });
        }
        all.push(layers);
    }
    // Median per phase.
    let num_layers = all[0].len();
    let med = |mut xs: Vec<f64>| -> f64 {
        xs.sort_by(|a, b| a.total_cmp(b));
        xs[xs.len() / 2]
    };
    let layers = (0..num_layers)
        .map(|l| LayerPhaseTimes {
            combination_secs: med(all.iter().map(|r| r[l].combination_secs).collect()),
            aggregation_secs: med(all.iter().map(|r| r[l].aggregation_secs).collect()),
        })
        .collect();
    Fig3Row {
        dataset: name.to_string(),
        layers,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::DatasetId;

    #[test]
    fn fractions_sum_to_one() {
        let g = DatasetId::Tiny.build(0);
        let m = GcnModel::two_layer(&g, 8, 1);
        let row = measure("tiny", &m, &g.features, 3);
        assert_eq!(row.layers.len(), 2);
        let sum: f64 = row.segment_fractions().iter().sum();
        assert!((sum - 1.0).abs() < 1e-9, "fractions sum to {sum}");
        assert!(row.total_secs() > 0.0);
    }

    #[test]
    fn combination_dominates_when_features_are_wide() {
        // Cora-like shape: F=1433 ≫ h=16 means phase 1 does far more work.
        let g = DatasetId::Cora.build(0);
        let m = GcnModel::two_layer(&g, 16, 1);
        let row = measure("cora", &m, &g.features, 3);
        assert!(
            row.combination_fraction() > 0.5,
            "combination fraction {} unexpectedly small",
            row.combination_fraction()
        );
    }
}
