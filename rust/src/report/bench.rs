//! `gcn-abft report bench` — the machine-readable serving benchmark.
//!
//! Aggregates two sweeps into one stable JSON document
//! (`BENCH_serve.json` at the repo root by default):
//!
//! * **serve** — end-to-end coordinator throughput/latency on a static
//!   graph, on a dynamic graph (scheduled deltas streaming in behind
//!   the epoch fence), on the sharded tier with deltas routed to the
//!   row bands, and under open-loop overload (bounded admission, the
//!   arrival rate a large multiple of the service rate) where goodput
//!   holds while lower classes shed;
//! * **delta_sweep** — the dynamic-graph cost model: incremental
//!   patch (`runtime::mutate::apply`) vs from-scratch rebuild
//!   (`runtime::mutate::rebuild`) over growing delta batches and band
//!   counts, with the bit-identity verdict recorded per cell.
//!
//! The same rows back `bench_coordinator --json`, so the cargo bench
//! target and the CLI aggregator cannot drift apart.

use crate::coordinator::{
    serve_synthetic_paced, serve_synthetic_with_deltas, AdmissionControl, BatchPolicy, Clock,
    DeltaSource, MonotonicClock, ServeSummary, ServerConfig, ShardTransportKind,
};
use crate::graph::DatasetId;
use crate::report::{build_workload, ExperimentOpts};
use crate::runtime::{mutate, ExecMode, GcnOperands, ScheduledDelta};
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::rng::Pcg64;
use anyhow::{anyhow, Context, Result};

/// Schema version of the `BENCH_serve.json` document.
///
/// v2: serve rows gained `shed`, `shed_by_priority` and
/// `interactive_p99_ms`, and the sweep gained the open-loop `overload`
/// row (bounded admission under arrival rate ≫ service rate).
pub const BENCH_SCHEMA_VERSION: u32 = 2;

/// One serve-sweep row as stable JSON — shared by `report bench` and
/// `bench_coordinator --json`.
pub fn serve_row_json(label: &str, shards: usize, transport: &str, s: &ServeSummary) -> Json {
    let m = &s.metrics;
    Json::obj(vec![
        ("label", Json::from(label)),
        ("dataset", Json::from(s.dataset.clone())),
        ("shards", Json::from(shards)),
        ("transport", Json::from(transport)),
        ("responses", Json::from(s.responses)),
        // Goodput: shed requests are excluded from requests/latency, so
        // throughput and the percentiles cover answered traffic only.
        ("throughput_rps", Json::Num(m.throughput_rps())),
        ("shed", Json::from(s.shed)),
        (
            "shed_by_priority",
            Json::Arr(m.shed.iter().map(|&x| Json::from(x)).collect()),
        ),
        ("p50_ms", Json::Num(m.p50_secs * 1e3)),
        ("p95_ms", Json::Num(m.p95_secs * 1e3)),
        (
            "interactive_p99_ms",
            Json::Num(m.by_priority[0].p99_secs * 1e3),
        ),
        ("verify_overhead", Json::Num(m.verify_overhead())),
        ("epoch", Json::from(m.epoch)),
        ("deltas_applied", Json::from(m.deltas_applied)),
        ("delta_failures", Json::from(m.delta_failures)),
        ("delta_apply_ms", Json::Num(m.delta_apply_secs * 1e3)),
        ("shard_respawns", Json::from(m.shard_respawns)),
        ("shard_reconnects", Json::from(m.shard_reconnects)),
        ("standby_adoptions", Json::from(m.standby_adoptions)),
        ("replayed_requests", Json::from(m.replayed_requests)),
        ("respawn_ms", Json::Num(m.respawn_secs * 1e3)),
    ])
}

/// A reproducible schedule of `count` random deltas spread across the
/// request stream (one delta after every few requests).
fn delta_schedule(
    dataset: DatasetId,
    opts: &ExperimentOpts,
    requests: usize,
    count: usize,
) -> Result<Vec<ScheduledDelta>> {
    let (graph, model) = build_workload(dataset, opts);
    let ops = GcnOperands::sparse(
        graph.features.clone(),
        &model.adjacency,
        model.layers[0].weights.clone(),
        model.layers[1].weights.clone(),
        2,
    )?;
    // Track the node count a graph following this schedule would have,
    // so node-referencing deltas stay in range as the graph grows.
    let mut n = ops.n_nodes();
    let mut rng = Pcg64::from_seed(opts.seed ^ 0xBE4C_0DE5);
    let stride = (requests / count.max(1)).max(1);
    let mut out = Vec::with_capacity(count);
    for i in 0..count {
        let delta = mutate::random_delta(
            &mut rng,
            n,
            ops.feat_dim(),
            ops.hidden_dim(),
            ops.num_classes(),
        );
        if let mutate::GraphDelta::AddNodes(adds) = &delta {
            n += adds.len();
        }
        out.push(ScheduledDelta {
            after_request: ((i + 1) * stride) as u64,
            delta,
        });
    }
    Ok(out)
}

/// The dynamic-graph cost model: apply `count` random deltas
/// incrementally, then rebuild once from scratch; report both times
/// and the bit-identity verdict. One row per band count.
pub fn delta_sweep(
    dataset: DatasetId,
    opts: &ExperimentOpts,
    bands_list: &[usize],
    count: usize,
) -> Result<Vec<Json>> {
    let (graph, model) = build_workload(dataset, opts);
    let clock = MonotonicClock::new();
    let mut rows = Vec::new();
    for &bands in bands_list {
        let mut ops = GcnOperands::sparse(
            graph.features.clone(),
            &model.adjacency,
            model.layers[0].weights.clone(),
            model.layers[1].weights.clone(),
            bands,
        )?;
        let n0 = ops.n_nodes();
        let mut rng = Pcg64::from_seed(opts.seed ^ 0xD317_A5EE);
        let mut apply_secs = 0.0f64;
        for _ in 0..count {
            let delta = mutate::random_delta(
                &mut rng,
                ops.n_nodes(),
                ops.feat_dim(),
                ops.hidden_dim(),
                ops.num_classes(),
            );
            let t0 = clock.now();
            // gcn-lint: allow(M1, reason="the timing sweep owns these operands; it measures the sanctioned primitive itself")
            mutate::apply(&mut ops, &delta)
                .map_err(|e| anyhow!("delta rejected during sweep: {e:#}"))?;
            apply_secs += clock.now().since(t0).as_secs_f64();
        }
        let t0 = clock.now();
        let rebuilt = mutate::rebuild(&ops)?;
        let rebuild_secs = clock.now().since(t0).as_secs_f64();
        let identical = mutate::bit_identical(&ops, &rebuilt).is_ok();
        rows.push(Json::obj(vec![
            ("dataset", Json::from(dataset.name())),
            ("bands", Json::from(bands)),
            ("deltas", Json::from(count)),
            ("nodes_before", Json::from(n0)),
            ("nodes_after", Json::from(ops.n_nodes())),
            ("apply_ms_total", Json::Num(apply_secs * 1e3)),
            (
                "apply_ms_per_delta",
                Json::Num(apply_secs * 1e3 / count.max(1) as f64),
            ),
            ("rebuild_ms", Json::Num(rebuild_secs * 1e3)),
            (
                "rebuild_over_apply_per_delta",
                Json::Num(rebuild_secs / (apply_secs / count.max(1) as f64).max(1e-12)),
            ),
            ("bit_identical", Json::from(identical)),
        ]));
    }
    Ok(rows)
}

/// Assemble the full `BENCH_serve.json` document.
pub fn bench_document(
    dataset: DatasetId,
    opts: &ExperimentOpts,
    requests: usize,
    delta_count: usize,
) -> Result<Json> {
    let base_cfg = |shards: usize| ServerConfig {
        dataset,
        seed: opts.seed,
        scale: opts.scale,
        train_epochs: opts.train_epochs,
        mode: ExecMode::Sparse,
        batch: BatchPolicy {
            max_batch: 8,
            ..Default::default()
        },
        workers: 2,
        shards,
        shard_transport: ShardTransportKind::InProc,
        ..Default::default()
    };

    let mut serve_rows = Vec::new();
    let s = serve_synthetic_with_deltas(&base_cfg(0), requests, DeltaSource::None)?;
    serve_rows.push(serve_row_json("static", 0, "none", &s));

    let sched = delta_schedule(dataset, opts, requests, delta_count)?;
    let s = serve_synthetic_with_deltas(
        &base_cfg(0),
        requests,
        DeltaSource::Scheduled(sched.clone()),
    )?;
    serve_rows.push(serve_row_json("dynamic", 0, "none", &s));

    let s = serve_synthetic_with_deltas(&base_cfg(2), requests, DeltaSource::Scheduled(sched))?;
    serve_rows.push(serve_row_json("dynamic-sharded", 2, "inproc", &s));

    // Supervised kill-and-recover drill: shard 0 dies before batch 2,
    // the supervisor heals the tier, the in-flight batch replays. The
    // row records the recovery cost (respawn latency, replayed
    // requests) next to the throughput it was paid under.
    let kill_cfg = ServerConfig {
        supervise: true,
        heartbeat_ms: 20,
        kill_shard_after: Some(2),
        ..base_cfg(2)
    };
    let s = serve_synthetic_with_deltas(&kill_cfg, requests, DeltaSource::None)?;
    serve_rows.push(serve_row_json("supervised-recovery", 2, "inproc", &s));

    // Open-loop overload: the driver paces arrivals on a fixed 1 µs
    // grid regardless of service progress (offered rate ≫ capacity),
    // against a single serial executor and a 4-deep bounded queue — the
    // classic SLO shape: goodput pins at capacity and Interactive p99
    // stays bounded by the short queue while lower classes shed first.
    let overload_cfg = ServerConfig {
        priority_mix: [0.6, 0.25, 0.15],
        workers: 1,
        batch: BatchPolicy {
            max_batch: 4,
            admission: Some(AdmissionControl {
                total_cap: 4,
                ..Default::default()
            }),
            ..Default::default()
        },
        ..base_cfg(0)
    };
    let s = serve_synthetic_paced(
        &overload_cfg,
        requests.max(64),
        Some(std::time::Duration::from_micros(1)),
    )?;
    serve_rows.push(serve_row_json("overload", 0, "none", &s));

    let sweep = delta_sweep(dataset, opts, &[1, 2, 4], delta_count.max(4))?;

    Ok(Json::obj(vec![
        ("type", Json::from("bench_serve")),
        (
            "data",
            Json::obj(vec![
                ("version", Json::from(BENCH_SCHEMA_VERSION as usize)),
                ("dataset", Json::from(dataset.name())),
                ("requests", Json::from(requests)),
                ("seed", Json::from(opts.seed)),
                ("scale", Json::Num(opts.scale)),
                ("serve", Json::Arr(serve_rows)),
                ("delta_sweep", Json::Arr(sweep)),
            ]),
        ),
    ]))
}

/// Default output path: `BENCH_serve.json` at the repo root (the
/// crate's parent directory), falling back to the working directory.
fn default_out() -> std::path::PathBuf {
    let crate_root = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    match crate_root.parent() {
        Some(p) if p.is_dir() => p.join("BENCH_serve.json"),
        _ => std::path::PathBuf::from("BENCH_serve.json"),
    }
}

/// `gcn-abft report bench` entry point.
pub fn run_cli(a: &Args) -> i32 {
    match run(a) {
        Ok(msg) => {
            println!("{msg}");
            0
        }
        Err(e) => {
            eprintln!("report bench failed: {e:#}");
            1
        }
    }
}

fn run(a: &Args) -> Result<String> {
    let name = a.get_str("dataset", "tiny");
    let dataset = DatasetId::parse(&name).ok_or_else(|| anyhow!("unknown dataset: {name}"))?;
    let err = |e: crate::util::cli::CliError| anyhow!("{e}");
    let opts = ExperimentOpts {
        datasets: vec![dataset],
        seed: a.get_u64("seed", 7).map_err(err)?,
        scale: a.get_f64("scale", 1.0).map_err(err)?,
        train_epochs: a.get_usize("train-epochs", 0).map_err(err)?,
    };
    let requests = a.get_usize("requests", 48).map_err(err)?;
    let delta_count = a.get_usize("deltas", 6).map_err(err)?;
    let out_path = match a.get("out") {
        Some(p) => std::path::PathBuf::from(p),
        None => default_out(),
    };

    let doc = bench_document(dataset, &opts, requests, delta_count)?;
    let text = doc.to_pretty();
    std::fs::write(&out_path, format!("{text}\n"))
        .with_context(|| format!("writing {}", out_path.display()))?;
    if a.has_flag("json") {
        Ok(text)
    } else {
        let rows = |key: &str| {
            doc.get("data")
                .and_then(|d| d.get(key))
                .and_then(Json::items)
                .map(|v| v.len())
                .unwrap_or(0)
        };
        Ok(format!(
            "wrote {} ({} serve rows, {} delta-sweep rows)",
            out_path.display(),
            rows("serve"),
            rows("delta_sweep"),
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_opts() -> ExperimentOpts {
        ExperimentOpts {
            datasets: vec![DatasetId::Tiny],
            seed: 7,
            scale: 1.0,
            train_epochs: 0,
        }
    }

    #[test]
    fn delta_sweep_rows_are_bit_identical() {
        let rows = delta_sweep(DatasetId::Tiny, &quick_opts(), &[1, 2], 4).unwrap();
        assert_eq!(rows.len(), 2);
        for r in &rows {
            assert_eq!(r.get("bit_identical"), Some(&Json::Bool(true)));
            assert!(r.get("apply_ms_total").and_then(Json::as_f64).unwrap() >= 0.0);
        }
    }

    #[test]
    fn bench_document_shape() {
        let doc = bench_document(DatasetId::Tiny, &quick_opts(), 12, 2).unwrap();
        assert_eq!(doc.get("type").and_then(Json::as_str), Some("bench_serve"));
        let data = doc.get("data").unwrap();
        let serve = data.get("serve").and_then(Json::items).unwrap();
        assert_eq!(serve.len(), 5);
        // The dynamic rows actually applied deltas; the static row did not.
        let applied = |i: usize| {
            serve[i]
                .get("deltas_applied")
                .and_then(Json::as_usize)
                .unwrap()
        };
        assert_eq!(applied(0), 0);
        assert!(applied(1) > 0, "dynamic row applied no deltas");
        assert!(applied(2) > 0, "sharded dynamic row applied no deltas");
        // The supervised drill killed a shard and healed it.
        let recovery = &serve[3];
        assert_eq!(
            recovery.get("label").and_then(Json::as_str),
            Some("supervised-recovery")
        );
        let respawns = recovery
            .get("shard_respawns")
            .and_then(Json::as_usize)
            .unwrap();
        assert!(respawns >= 1, "supervised drill recorded no respawn");
        // The overload row: every paced request got exactly one
        // response (served or shed — conservation is timing-free even
        // though the shed count itself depends on machine speed), and
        // shedding is an availability outcome, never a failure.
        let overload = &serve[4];
        assert_eq!(
            overload.get("label").and_then(Json::as_str),
            Some("overload")
        );
        assert_eq!(
            overload.get("responses").and_then(Json::as_usize),
            Some(64),
            "overload row lost or duplicated responses"
        );
        let shed = overload.get("shed").and_then(Json::as_usize).unwrap();
        let by_prio = match overload.get("shed_by_priority") {
            Some(Json::Arr(a)) => a,
            other => panic!("shed_by_priority missing: {other:?}"),
        };
        assert_eq!(by_prio.len(), 3);
        let by_prio_total: usize = by_prio.iter().filter_map(Json::as_usize).sum();
        assert_eq!(shed, by_prio_total, "per-class shed counters must add up");
        assert!(
            overload.get("interactive_p99_ms").is_some(),
            "overload row must report the Interactive p99"
        );
    }

    #[test]
    fn schedule_is_sorted_and_sized() {
        let sched = delta_schedule(DatasetId::Tiny, &quick_opts(), 48, 6).unwrap();
        assert_eq!(sched.len(), 6);
        assert!(sched.windows(2).all(|w| w[0].after_request <= w[1].after_request));
    }
}
