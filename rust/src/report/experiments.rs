//! Experiment orchestration: builds datasets + models and renders the
//! paper's Table I, Table II and Fig. 3 from this repo's engines.
//! Shared by the `gcn-abft` CLI, the examples, and the bench targets.

use super::fig3;
use super::table::{bar, Table};
use crate::abft::Scheme;
use crate::fault::{run_campaigns, CampaignConfig, CampaignReport, FaultModelKind};
use crate::gcn::{train_two_layer, GcnModel, TrainConfig};
use crate::graph::{DatasetId, Graph};
use crate::opcount::backend::{backend_matrix, check_saving, BackendOpsRow};
use crate::opcount::ModelOps;
use crate::runtime::InstrumentedEngine;
use crate::util::json::Json;
use crate::util::{fmt_millions, fmt_pct};

/// Common experiment options.
#[derive(Debug, Clone)]
pub struct ExperimentOpts {
    pub datasets: Vec<DatasetId>,
    pub seed: u64,
    /// Proportional shrink of big datasets (1.0 = paper scale).
    pub scale: f64,
    /// Brief training to make criticality meaningful (0 = random weights).
    pub train_epochs: usize,
}

impl Default for ExperimentOpts {
    fn default() -> Self {
        Self {
            datasets: DatasetId::ALL.to_vec(),
            seed: 7,
            scale: 1.0,
            train_epochs: 20,
        }
    }
}

/// Build (and briefly train) the 2-layer GCN the paper evaluates.
pub fn build_workload(id: DatasetId, opts: &ExperimentOpts) -> (Graph, GcnModel) {
    let graph = if opts.scale < 1.0 {
        id.build_scaled(opts.seed, opts.scale)
    } else {
        id.build(opts.seed)
    };
    let mut model = GcnModel::two_layer(&graph, id.hidden_dim(), opts.seed ^ 0x5EED);
    if opts.train_epochs > 0 {
        train_two_layer(
            &mut model,
            &graph.features,
            &graph.labels,
            &TrainConfig {
                epochs: opts.train_epochs,
                ..Default::default()
            },
        );
    }
    (graph, model)
}

// ---------------------------------------------------------------- Table I

/// Result of Table I for one dataset: both schemes' campaign reports.
#[derive(Debug, Clone)]
pub struct Table1Entry {
    pub dataset: String,
    pub split: CampaignReport,
    pub fused: CampaignReport,
}

/// Run the Table-I experiment with the paper's single-bit-flip model.
pub fn run_table1(
    opts: &ExperimentOpts,
    campaigns: usize,
    faults: usize,
    threads: usize,
) -> Vec<Table1Entry> {
    run_table1_with_model(opts, campaigns, faults, threads, FaultModelKind::BitFlip)
}

/// Run the Table-I experiment under any fault model (`--fault-model`).
/// Campaigns run on the instrumented backend's engine — the same banded
/// f64 execution the `--backend instrumented` serving mode uses.
pub fn run_table1_with_model(
    opts: &ExperimentOpts,
    campaigns: usize,
    faults: usize,
    threads: usize,
    fault_model: FaultModelKind,
) -> Vec<Table1Entry> {
    let mut out = Vec::new();
    for &id in &opts.datasets {
        let (graph, model) = build_workload(id, opts);
        let engine = InstrumentedEngine::from_model(&model, &graph.features);
        let mut cfg = CampaignConfig {
            campaigns,
            faults_per_campaign: faults,
            seed: opts.seed,
            threads,
            fault_model,
            ..Default::default()
        };
        cfg.scheme = Scheme::Split;
        let split = run_campaigns(&engine, &cfg);
        cfg.scheme = Scheme::Fused;
        let fused = run_campaigns(&engine, &cfg);
        out.push(Table1Entry {
            dataset: graph.name.clone(),
            split,
            fused,
        });
    }
    out
}

/// Render Table I in the paper's layout (plus the benign column we report
/// for transparency — see EXPERIMENTS.md).
pub fn render_table1(entries: &[Table1Entry]) -> String {
    let mut s = String::new();
    s.push_str("TABLE I — fault-detection accuracy (one fault per campaign unless noted)\n\n");
    for e in entries {
        s.push_str(&format!(
            "{}: {} campaigns/scheme | critical faults {} | avg nodes affected {} \
             | class-flips {} (avg {} of nodes) | fault sites: {} data-path, {} checksum\n",
            e.dataset,
            e.split.campaigns,
            fmt_pct(e.split.critical_rate()),
            fmt_pct(e.split.avg_nodes_affected),
            fmt_pct(e.split.class_critical as f64 / e.split.campaigns.max(1) as f64),
            fmt_pct(e.split.avg_classes_changed),
            e.split.data_faults + e.fused.data_faults,
            e.split.checksum_faults + e.fused.checksum_faults,
        ));
        let mut t = Table::new(vec![
            "threshold", "metric", "Split", "GCN-ABFT",
        ]);
        for (i, (tau, st)) in e.split.per_threshold.iter().enumerate() {
            let ft = e.fused.per_threshold[i].1;
            t.row(vec![
                format!("{tau:.0e}"),
                "Detected".to_string(),
                fmt_pct(st.detected_rate()),
                fmt_pct(ft.detected_rate()),
            ]);
            t.row(vec![
                String::new(),
                "False Pos".to_string(),
                fmt_pct(st.false_positive_rate()),
                fmt_pct(ft.false_positive_rate()),
            ]);
            t.row(vec![
                String::new(),
                "Silent".to_string(),
                fmt_pct(st.silent_rate()),
                fmt_pct(ft.silent_rate()),
            ]);
            t.row(vec![
                String::new(),
                "Benign".to_string(),
                fmt_pct(st.benign_rate()),
                fmt_pct(ft.benign_rate()),
            ]);
        }
        s.push_str(&t.render());
        s.push('\n');
    }
    s
}

/// Machine-readable Table I.
pub fn table1_json(entries: &[Table1Entry]) -> Json {
    Json::arr(entries.iter().map(|e| {
        let scheme_json = |r: &CampaignReport| {
            Json::obj(vec![
                ("campaigns", Json::from(r.campaigns)),
                ("critical_rate", Json::Num(r.critical_rate())),
                ("avg_nodes_affected", Json::Num(r.avg_nodes_affected)),
                ("data_faults", Json::from(r.data_faults)),
                ("checksum_faults", Json::from(r.checksum_faults)),
                (
                    "per_threshold",
                    Json::arr(r.per_threshold.iter().map(|(tau, t)| {
                        Json::obj(vec![
                            ("threshold", Json::Num(*tau)),
                            ("detected", Json::Num(t.detected_rate())),
                            ("false_positive", Json::Num(t.false_positive_rate())),
                            ("silent", Json::Num(t.silent_rate())),
                            ("benign", Json::Num(t.benign_rate())),
                        ])
                    })),
                ),
            ])
        };
        Json::obj(vec![
            ("dataset", Json::from(e.dataset.clone())),
            ("split", scheme_json(&e.split)),
            ("gcn_abft", scheme_json(&e.fused)),
        ])
    }))
}

// --------------------------------------------------------------- Table II

/// One rendered row of Table II.
#[derive(Debug, Clone)]
pub struct Table2Entry {
    pub dataset: String,
    pub row: crate::opcount::TableRow,
}

/// Run the Table-II experiment (pure analytic model over real dataset
/// statistics; cross-validated against the instrumented engine in tests).
pub fn run_table2(opts: &ExperimentOpts) -> Vec<Table2Entry> {
    opts.datasets
        .iter()
        .map(|&id| {
            let graph = if opts.scale < 1.0 {
                id.build_scaled(opts.seed, opts.scale)
            } else {
                id.build(opts.seed)
            };
            let row = ModelOps::two_layer(&graph, id.hidden_dim()).table_row();
            Table2Entry {
                dataset: graph.name.clone(),
                row,
            }
        })
        .collect()
}

/// Render Table II in the paper's layout (millions of operations).
pub fn render_table2(entries: &[Table2Entry]) -> String {
    let mut t = Table::new(vec![
        "GCN",
        "True Out",
        "Split Check",
        "Split Total",
        "ABFT Check",
        "ABFT Total",
        "Check Save",
        "Total Save",
    ]);
    for e in entries {
        t.row(vec![
            e.dataset.clone(),
            fmt_millions(e.row.true_out),
            fmt_millions(e.row.split_check),
            fmt_millions(e.row.split_total()),
            fmt_millions(e.row.fused_check),
            fmt_millions(e.row.fused_total()),
            fmt_pct(e.row.check_saving()),
            fmt_pct(e.row.total_saving()),
        ]);
    }
    format!(
        "TABLE II — millions of arithmetic operations for executing and validating\n\n{}",
        t.render()
    )
}

/// Machine-readable Table II.
pub fn table2_json(entries: &[Table2Entry]) -> Json {
    Json::arr(entries.iter().map(|e| {
        Json::obj(vec![
            ("dataset", Json::from(e.dataset.clone())),
            ("true_out", Json::from(e.row.true_out)),
            ("split_check", Json::from(e.row.split_check)),
            ("fused_check", Json::from(e.row.fused_check)),
            ("check_saving", Json::Num(e.row.check_saving())),
            ("total_saving", Json::Num(e.row.total_saving())),
        ])
    }))
}

// --------------------------------------------- opcount backend matrix

/// The per-(backend, scheme) checksum-overhead matrix for a dataset set
/// (analytic, paper-scale statistics — no graph build).
pub fn run_opcount_matrix(datasets: &[DatasetId]) -> Vec<BackendOpsRow> {
    backend_matrix(datasets)
}

/// Render the matrix: one block per dataset, split vs fused per backend
/// profile, with the fused-vs-split checking saving the paper claims
/// (>21% on the accelerator accounting for the feature-heavy graphs).
pub fn render_opcount_matrix(rows: &[BackendOpsRow]) -> String {
    let mut t = Table::new(vec![
        "GCN",
        "backend",
        "scheme",
        "true ops",
        "check ops",
        "overhead",
        "fused saves",
    ]);
    for r in rows {
        let saving = if r.scheme == Scheme::Fused {
            fmt_pct(check_saving(rows, &r.dataset, r.profile))
        } else {
            String::from("-")
        };
        t.row(vec![
            r.dataset.clone(),
            r.profile.name().to_string(),
            r.scheme.name().to_string(),
            fmt_millions(r.true_ops),
            fmt_millions(r.check_ops),
            fmt_pct(r.overhead()),
            saving,
        ]);
    }
    format!(
        "OPCOUNT — checksum overhead per (backend, scheme), millions of ops \
         at paper scale\n\n{}",
        t.render()
    )
}

/// Machine-readable matrix.
pub fn opcount_matrix_json(rows: &[BackendOpsRow]) -> Json {
    Json::arr(rows.iter().map(|r| {
        Json::obj(vec![
            ("dataset", Json::from(r.dataset.clone())),
            ("backend", Json::from(r.profile.name().to_string())),
            ("scheme", Json::from(r.scheme.name().to_string())),
            ("true_ops", Json::from(r.true_ops)),
            ("check_ops", Json::from(r.check_ops)),
            ("overhead", Json::Num(r.overhead())),
            (
                "fused_check_saving",
                Json::Num(check_saving(rows, &r.dataset, r.profile)),
            ),
        ])
    }))
}

// ----------------------------------------------------------------- Fig. 3

/// Run the Fig. 3 experiment (phase-time split).
pub fn run_fig3(opts: &ExperimentOpts, reps: usize) -> Vec<fig3::Fig3Row> {
    opts.datasets
        .iter()
        .map(|&id| {
            let (graph, model) = build_workload(id, opts);
            fig3::measure(&graph.name, &model, &graph.features, reps)
        })
        .collect()
}

/// Render Fig. 3 as stacked text bars.
pub fn render_fig3(rows: &[fig3::Fig3Row]) -> String {
    let mut s = String::new();
    s.push_str(
        "FIG. 3 — share of layer runtime per matmul phase \
         (textured = combination/phase-1, plain = aggregation/phase-2)\n\n",
    );
    for r in rows {
        let fr = r.segment_fractions();
        s.push_str(&format!(
            "{:<10} comb-L1 {:>5.1}% | agg-L1 {:>5.1}% | comb-L2 {:>5.1}% | agg-L2 {:>5.1}%  (total {:.3} s)\n",
            r.dataset,
            fr[0] * 100.0,
            fr[1] * 100.0,
            fr[2] * 100.0,
            fr[3] * 100.0,
            r.total_secs(),
        ));
        s.push_str(&format!(
            "{:<10} [{}]  combination share {:.1}%\n\n",
            "",
            bar(r.combination_fraction(), 50),
            r.combination_fraction() * 100.0
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_opts() -> ExperimentOpts {
        ExperimentOpts {
            datasets: vec![DatasetId::Tiny],
            seed: 3,
            scale: 1.0,
            train_epochs: 5,
        }
    }

    #[test]
    fn table1_runs_and_renders() {
        let entries = run_table1(&tiny_opts(), 40, 1, 2);
        assert_eq!(entries.len(), 1);
        let text = render_table1(&entries);
        assert!(text.contains("TABLE I"));
        assert!(text.contains("GCN-ABFT"));
        let j = table1_json(&entries).to_string();
        assert!(j.contains("\"detected\""));
    }

    #[test]
    fn table2_runs_and_renders() {
        let entries = run_table2(&tiny_opts());
        let text = render_table2(&entries);
        assert!(text.contains("TABLE II"));
        assert!(text.contains("tiny"));
        let j = table2_json(&entries).to_string();
        assert!(j.contains("check_saving"));
    }

    #[test]
    fn opcount_matrix_runs_and_renders() {
        let rows = run_opcount_matrix(&[DatasetId::Cora, DatasetId::Pubmed]);
        assert_eq!(rows.len(), 8, "2 datasets × 2 backends × 2 schemes");
        let text = render_opcount_matrix(&rows);
        assert!(text.contains("OPCOUNT"));
        assert!(text.contains("instrumented"));
        assert!(text.contains("native"));
        let j = opcount_matrix_json(&rows).to_string();
        assert!(j.contains("fused_check_saving"));
    }

    #[test]
    fn table1_supports_alternate_fault_models() {
        let entries = run_table1_with_model(
            &tiny_opts(),
            30,
            1,
            2,
            FaultModelKind::MultiBit { bits: 2 },
        );
        assert_eq!(entries.len(), 1);
        for (_, t) in &entries[0].fused.per_threshold {
            assert_eq!(t.total(), 30);
        }
        assert_eq!(entries[0].fused.fault_model, FaultModelKind::MultiBit { bits: 2 });
    }

    #[test]
    fn fig3_runs_and_renders() {
        let rows = run_fig3(&tiny_opts(), 2);
        let text = render_fig3(&rows);
        assert!(text.contains("FIG. 3"));
        assert!(text.contains("comb-L1"));
    }

    #[test]
    fn workload_build_is_deterministic() {
        let (g1, m1) = build_workload(DatasetId::Tiny, &tiny_opts());
        let (g2, m2) = build_workload(DatasetId::Tiny, &tiny_opts());
        assert_eq!(g1.edges, g2.edges);
        assert_eq!(m1.layers[0].weights, m2.layers[0].weights);
    }
}
