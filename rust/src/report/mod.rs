//! Rendering and orchestration of the paper's evaluation artifacts
//! (Table I, Table II, Fig. 3).

pub mod bench;
pub mod experiments;
pub mod fig3;
pub mod layer;
pub mod table;

pub use experiments::{
    build_workload, render_fig3, render_opcount_matrix, render_table1, render_table2, run_fig3,
    run_opcount_matrix, run_table1, run_table1_with_model, run_table2, ExperimentOpts,
};
pub use table::Table;
