//! ASCII table rendering for experiment reports.

/// A simple column-aligned table builder.
#[derive(Debug, Clone, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>>(header: Vec<S>) -> Self {
        Self {
            header: header.into_iter().map(Into::into).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) -> &mut Self {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width {} != header width {}",
            cells.len(),
            self.header.len()
        );
        self.rows.push(cells);
        self
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Render with column alignment (first column left, rest right).
    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, cell) in widths.iter_mut().zip(row) {
                *w = (*w).max(cell.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for (i, (cell, w)) in cells.iter().zip(widths).enumerate() {
                if i > 0 {
                    line.push_str("  ");
                }
                if i == 0 {
                    line.push_str(&format!("{cell:<w$}"));
                } else {
                    line.push_str(&format!("{cell:>w$}"));
                }
            }
            line
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        let total: usize = widths.iter().sum::<usize>() + 2 * (ncols - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }
}

/// Render a horizontal percentage bar of width `width` (Fig. 3 style).
pub fn bar(frac: f64, width: usize) -> String {
    let filled = ((frac.clamp(0.0, 1.0)) * width as f64).round() as usize;
    let mut s = String::with_capacity(width);
    for i in 0..width {
        s.push(if i < filled { '#' } else { '.' });
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(vec!["name", "value"]);
        t.row(vec!["a", "1"]);
        t.row(vec!["long-name", "12345"]);
        let r = t.render();
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("name"));
        assert!(lines[1].chars().all(|c| c == '-'));
        // Right-aligned numeric column.
        assert!(lines[2].ends_with("    1"));
        assert!(lines[3].ends_with("12345"));
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn width_mismatch_panics() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["only-one"]);
    }

    #[test]
    fn bar_rendering() {
        assert_eq!(bar(0.0, 4), "....");
        assert_eq!(bar(1.0, 4), "####");
        assert_eq!(bar(0.5, 4), "##..");
        assert_eq!(bar(2.0, 4), "####"); // clamped
    }
}
