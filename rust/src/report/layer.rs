//! `gcn-abft report layer` — the machine-readable kernel benchmark.
//!
//! Aggregates the kernels area into one stable JSON document
//! (`BENCH_layer.json` at the repo root by default):
//!
//! * **kernels** — scalar-vs-vector A/Bs of the three dispatched inner
//!   kernels (dense matmul, CSR spmm, the f64 column-sum reduction)
//!   over representative shapes/sparsities, with achieved GFLOP/s per
//!   lane width, the x8-over-scalar speedup, and the modelled
//!   arithmetic intensity of each shape. Both widths run through the
//!   same [`crate::tensor::kernels::force`] override the property
//!   tests use, so the numbers measure exactly the dispatch the tree
//!   serves with — and the outputs are bit-identical by contract, so
//!   the A/B compares throughput and nothing else.
//! * **check_placement** — the measured check-op cost behind
//!   `--scheme auto`: per (dataset, backend profile), the fused and
//!   split checking ops at paper scale and the concrete scheme
//!   [`resolve_scheme`] picks (always the argmin; ties break fused).
//!
//! The document is what CI asserts a measurable vectorized speedup
//! against, next to the per-lane bit-identity property tests.

use crate::abft::Scheme;
use crate::graph::DatasetId;
use crate::opcount::backend::{check_ops_for, resolve_scheme, spec_layer_shapes, BackendProfile};
use crate::sparse::Csr;
use crate::tensor::{kernels, ops, Dense};
use crate::util::bench::{black_box, Bencher};
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::rng::Pcg64;
use anyhow::{Context, Result};

/// Schema version of the `BENCH_layer.json` document.
pub const LAYER_SCHEMA_VERSION: u32 = 1;

fn rand_dense(rng: &mut Pcg64, rows: usize, cols: usize) -> Dense {
    let data = (0..rows * cols).map(|_| rng.gen_f32_range(-1.0, 1.0)).collect();
    Dense::from_vec(rows, cols, data)
}

/// A random CSR with approximately `density` stored fraction (plus a
/// guaranteed diagonal so no row is empty).
fn rand_csr(rng: &mut Pcg64, n: usize, density: f64) -> Csr {
    let mut d = Dense::zeros(n, n);
    for r in 0..n {
        d.set(r, r, rng.gen_f32_range(0.1, 1.0));
        for c in 0..n {
            if rng.gen_bool(density) {
                d.set(r, c, rng.gen_f32_range(-1.0, 1.0));
            }
        }
    }
    Csr::from_dense(&d)
}

/// Run one closure under every selectable lane width and report the
/// per-width minimum seconds (scalar first, [`kernels::Lanes::ALL`]
/// order). Restores the environment dispatch afterwards.
fn ab_secs<T>(b: &Bencher, label: &str, mut work: impl FnMut() -> T) -> Vec<(kernels::Lanes, f64)> {
    let mut out = Vec::with_capacity(kernels::Lanes::ALL.len());
    for lanes in kernels::Lanes::ALL {
        kernels::force(Some(lanes));
        let stats = b.run(&format!("{label}/{}", lanes.name()), || black_box(work()));
        // Min, not median: the least noise-contaminated estimate of the
        // true per-iteration cost (same reasoning as bench_layer).
        out.push((lanes, stats.min()));
    }
    kernels::force(None);
    out
}

fn kernel_row(
    kernel: &str,
    shape: String,
    sparsity: Json,
    flops: f64,
    intensity: f64,
    timed: &[(kernels::Lanes, f64)],
) -> Json {
    let secs_of = |want: kernels::Lanes| {
        timed
            .iter()
            .find(|(l, _)| *l == want)
            .map(|&(_, s)| s)
            .unwrap_or(f64::NAN)
    };
    let scalar = secs_of(kernels::Lanes::Scalar);
    let x8 = secs_of(kernels::Lanes::X8);
    Json::obj(vec![
        ("kernel", Json::from(kernel)),
        ("shape", Json::from(shape)),
        ("sparsity", sparsity),
        ("arithmetic_intensity", Json::Num(intensity)),
        ("scalar_gflops", Json::Num(flops / scalar.max(1e-12) / 1e9)),
        ("x8_gflops", Json::Num(flops / x8.max(1e-12) / 1e9)),
        ("speedup_x8", Json::Num(scalar / x8.max(1e-12))),
    ])
}

/// The scalar-vs-vector kernel A/B rows.
pub fn kernel_rows(b: &Bencher, seed: u64) -> Vec<Json> {
    let mut rng = Pcg64::from_seed(seed ^ 0x4C41_9E52);
    let mut rows = Vec::new();

    // Dense matmul: the layer-2 XW shape class (tall-skinny) and a
    // squarer tile where the axpy rows are long enough to vectorize.
    for (m, k, n) in [(512, 64, 48), (192, 192, 192)] {
        let a = rand_dense(&mut rng, m, k);
        let bm = rand_dense(&mut rng, k, n);
        let timed = ab_secs(b, &format!("matmul/{m}x{k}x{n}"), || {
            ops::matmul_par(&a, &bm, 1)
        });
        rows.push(kernel_row(
            "matmul",
            format!("{m}x{k}x{n}"),
            Json::Null,
            2.0 * (m * k * n) as f64,
            kernels::matmul_intensity(m, k, n),
            &timed,
        ));
    }

    // CSR spmm: the S·H aggregation shape class, at two sparsities.
    for (n, density, cols) in [(512, 0.01, 64), (384, 0.05, 96)] {
        let s = rand_csr(&mut rng, n, density);
        let h = rand_dense(&mut rng, n, cols);
        let nnz = s.nnz();
        let timed = ab_secs(b, &format!("spmm/{n}x{n}({nnz}nnz)x{cols}"), || {
            s.spmm_par(&h, 1)
        });
        rows.push(kernel_row(
            "spmm",
            format!("{n}x{n}x{cols}"),
            Json::Num(nnz as f64 / (n * n) as f64),
            2.0 * (nnz * cols) as f64,
            kernels::spmm_intensity(nnz, cols),
            &timed,
        ));
    }

    // f64 column-sum reduction: the checksum ingredient (one widening
    // add per element — flops = elements).
    for (m, n) in [(2048, 96)] {
        let d = rand_dense(&mut rng, m, n);
        let timed = ab_secs(b, &format!("col_sums_f64/{m}x{n}"), || d.col_sums_f64());
        // Traffic model: every f32 read once, the f64 accumulator row
        // re-read/re-written per input row.
        let intensity = (m * n) as f64 / (4.0 * (m * n) as f64 + 16.0 * (m * n) as f64);
        rows.push(kernel_row(
            "col_sums_f64",
            format!("{m}x{n}"),
            Json::Null,
            (m * n) as f64,
            intensity,
            &timed,
        ));
    }

    rows
}

/// The `--scheme auto` decision table: measured fused/split check-op
/// cost per (dataset, backend profile) at paper scale, and the concrete
/// scheme Auto resolves to (the argmin by construction).
pub fn check_placement_rows() -> Vec<Json> {
    let mut rows = Vec::new();
    for id in DatasetId::ALL {
        let shapes = spec_layer_shapes(id);
        let true_ops: u64 = shapes.iter().map(|l| l.true_ops()).sum();
        for profile in [BackendProfile::Native, BackendProfile::Instrumented] {
            let total = |s: Scheme| -> u64 {
                shapes.iter().map(|l| check_ops_for(profile, s, l)).sum()
            };
            let (fused, split) = (total(Scheme::Fused), total(Scheme::Split));
            let auto = resolve_scheme(profile, Scheme::Auto, &shapes);
            rows.push(Json::obj(vec![
                ("dataset", Json::from(id.name())),
                ("backend", Json::from(profile.name())),
                ("true_ops", Json::from(true_ops)),
                ("fused_check_ops", Json::from(fused)),
                ("split_check_ops", Json::from(split)),
                ("fused_overhead", Json::Num(fused as f64 / true_ops.max(1) as f64)),
                ("split_overhead", Json::Num(split as f64 / true_ops.max(1) as f64)),
                ("auto_scheme", Json::from(auto.name())),
                ("auto_check_ops", Json::from(total(auto))),
            ]));
        }
    }
    rows
}

/// Assemble the full `BENCH_layer.json` document.
pub fn layer_document(b: &Bencher, seed: u64) -> Json {
    Json::obj(vec![
        ("type", Json::from("bench_layer")),
        (
            "data",
            Json::obj(vec![
                ("version", Json::from(LAYER_SCHEMA_VERSION as usize)),
                ("seed", Json::from(seed)),
                ("kernels", Json::Arr(kernel_rows(b, seed))),
                ("check_placement", Json::Arr(check_placement_rows())),
            ]),
        ),
    ])
}

/// Default output path: `BENCH_layer.json` at the repo root (the
/// crate's parent directory), falling back to the working directory.
fn default_out() -> std::path::PathBuf {
    let crate_root = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    match crate_root.parent() {
        Some(p) if p.is_dir() => p.join("BENCH_layer.json"),
        _ => std::path::PathBuf::from("BENCH_layer.json"),
    }
}

/// `gcn-abft report layer` entry point.
pub fn run_cli(a: &Args) -> i32 {
    match run(a) {
        Ok(msg) => {
            println!("{msg}");
            0
        }
        Err(e) => {
            eprintln!("report layer failed: {e:#}");
            1
        }
    }
}

fn run(a: &Args) -> Result<String> {
    let err = |e: crate::util::cli::CliError| anyhow::anyhow!("{e}");
    let reps = a.get_usize("reps", 5).map_err(err)?.max(2);
    let out_path = match a.get("out") {
        Some(p) => std::path::PathBuf::from(p),
        None => default_out(),
    };
    let bencher = Bencher {
        samples: reps,
        ..Bencher::quick()
    };

    let doc = layer_document(&bencher, 7);
    let text = doc.to_pretty();
    std::fs::write(&out_path, format!("{text}\n"))
        .with_context(|| format!("writing {}", out_path.display()))?;
    if a.has_flag("json") {
        Ok(text)
    } else {
        let rows = |key: &str| {
            doc.get("data")
                .and_then(|d| d.get(key))
                .and_then(Json::items)
                .map(|v| v.len())
                .unwrap_or(0)
        };
        Ok(format!(
            "wrote {} ({} kernel rows, {} check-placement rows)",
            out_path.display(),
            rows("kernels"),
            rows("check_placement"),
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fast_bencher() -> Bencher {
        Bencher {
            warmup: std::time::Duration::from_millis(1),
            samples: 2,
            min_sample_time: std::time::Duration::from_micros(200),
        }
    }

    #[test]
    fn check_placement_auto_is_the_argmin() {
        let rows = check_placement_rows();
        assert_eq!(rows.len(), DatasetId::ALL.len() * 2);
        for r in &rows {
            let fused = r.get("fused_check_ops").and_then(Json::as_usize).unwrap();
            let split = r.get("split_check_ops").and_then(Json::as_usize).unwrap();
            let auto = r.get("auto_check_ops").and_then(Json::as_usize).unwrap();
            assert_eq!(auto, fused.min(split), "{r:?}");
            let name = r.get("auto_scheme").and_then(Json::as_str).unwrap();
            assert!(name == "fused" || name == "split", "unresolved auto: {name}");
        }
    }

    #[test]
    fn layer_document_shape_and_dispatch_restored() {
        let before = kernels::active();
        let doc = layer_document(&fast_bencher(), 7);
        // The A/Bs force both widths; the document build must restore
        // the environment dispatch for the rest of the process.
        assert_eq!(kernels::active(), before);
        assert_eq!(doc.get("type").and_then(Json::as_str), Some("bench_layer"));
        let data = doc.get("data").unwrap();
        let kernels_rows = data.get("kernels").and_then(Json::items).unwrap();
        assert_eq!(kernels_rows.len(), 5);
        for r in kernels_rows {
            for key in ["scalar_gflops", "x8_gflops", "speedup_x8"] {
                let v = r.get(key).and_then(Json::as_f64).unwrap();
                assert!(v.is_finite() && v > 0.0, "{key} in {r:?}");
            }
            assert!(
                r.get("arithmetic_intensity")
                    .and_then(Json::as_f64)
                    .unwrap()
                    .is_finite()
            );
        }
    }
}
