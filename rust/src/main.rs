//! `gcn-abft` — CLI for the GCN-ABFT reproduction.
//!
//! Subcommands (per-experiment index in DESIGN.md §8):
//! * `table1`  — fault-injection campaign sweep (paper Table I), with
//!   `--fault-model bitflip|multibit[:B]|stuckat[:D]`;
//! * `table2`  — operation-count accounting (paper Table II);
//! * `opcount` — checksum-overhead matrix per (backend, scheme);
//! * `fig3`    — phase-runtime split (paper Fig. 3);
//! * `serve`   — end-to-end serving demo: priority-aware continuous
//!   batching with online GCN-ABFT verification (`--backend
//!   native|instrumented|pjrt`, `--scheme fused|split`, `--max-batch
//!   --max-wait-ms --starvation-factor --priority-mix --adaptive-wait`,
//!   no artifacts needed for native), optionally row-band-sharded
//!   across subprocesses (`--shards N --shard-transport inproc|proc`);
//! * `shard-worker` — one shard of a sharded serve (spawned by the
//!   coordinator, not invoked by hand);
//! * `mutate`  — offline dynamic-graph verification: apply a delta
//!   sequence incrementally and prove the patched operands + checksum
//!   state bit-identical to a from-scratch rebuild;
//! * `report`  — machine-readable report artifacts (`report bench`
//!   writes `BENCH_serve.json`, `report layer` writes `BENCH_layer.json`
//!   with scalar-vs-vector kernel A/Bs and the measured check-op cost
//!   behind `--scheme auto`);
//! * `train`   — train the synthetic workloads and print the curves;
//! * `info`    — dataset statistics;
//! * `analyze` — architectural lint pass enforcing the determinism,
//!   fail-stop and f64-checksum contracts (`--json` for the stable
//!   tagged-enum report schema).

use gcn_abft::fault::FaultModelKind;
use gcn_abft::graph::DatasetId;
use gcn_abft::report::{self, ExperimentOpts};
use gcn_abft::util::cli::{Args, Spec};
use gcn_abft::util::json::Json;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let (cmd, rest) = match argv.split_first() {
        Some((c, r)) => (c.clone(), r.to_vec()),
        None => {
            print_usage();
            std::process::exit(2);
        }
    };
    let code = match cmd.as_str() {
        "analyze" => cmd_analyze(rest),
        "table1" => cmd_table1(rest),
        "table2" => cmd_table2(rest),
        "opcount" => cmd_opcount(rest),
        "fig3" => cmd_fig3(rest),
        "serve" => cmd_serve(rest),
        "shard-worker" => cmd_shard_worker(rest),
        "mutate" => cmd_mutate(rest),
        "report" => cmd_report(rest),
        "train" => cmd_train(rest),
        "info" => cmd_info(rest),
        "help" | "--help" | "-h" => {
            print_usage();
            0
        }
        other => {
            eprintln!("unknown subcommand: {other}\n");
            print_usage();
            2
        }
    };
    std::process::exit(code);
}

fn print_usage() {
    eprintln!(
        "gcn-abft — low-cost online error checking for GCNs (paper reproduction)

USAGE: gcn-abft <subcommand> [options]

SUBCOMMANDS
  table1   fault-detection accuracy sweep (paper Table I)
           --datasets cora,citeseer,pubmed,nell|tiny  --campaigns N (500)
           --faults K (1)  --seed S (7)  --scale F (dataset scale, 1.0)
           --threads T  --train-epochs E (20)  --json
           --fault-model bitflip|multibit[:BITS]|stuckat[:OPS] (bitflip)
  table2   operation counts for executing + validating (paper Table II)
           --datasets ...  --seed S  --scale F  --json
  opcount  checksum-overhead ops per (backend, scheme) pair, with the
           fused-vs-split saving per backend (paper-scale statistics)
           --datasets ...  --json
  fig3     runtime split across the two matmul phases (paper Fig. 3)
           --datasets ...  --seed S  --scale F  --reps R (5)
  serve    serve inference with online GCN-ABFT verification (shapes
           validated against artifacts/ when present). Operands are
           memory-planned: small graphs densify, PubMed/Nell serve on
           CSR with S row-band-sharded across the workers. Scheduling is
           priority-aware continuous batching: requests coalesce into
           the next batch while the current one executes, and a request
           older than starvation-factor x max-wait is force-included
           over any priority pressure.
           --dataset tiny|cora|citeseer|pubmed|nell  --requests N (64)
           --max-batch B (8, alias --batch)  --max-wait-ms T (5)
           --starvation-factor K (4)
           --adaptive-wait (auto-tune the hold budget from an EWMA of
           inter-arrival times, clamped to [--min-wait-ms (0.2),
           --max-wait-ms])
           --priority-mix I,B,BG (1,0,0 — client-driver weights for
           interactive/batch/background requests)
           --queue-cap N bounds the admission queue: when full, the
           youngest request of the *worst* class strictly below the
           arrival is evicted (shed-from-the-bottom: Background first,
           Interactive last); with no lower class to evict the arrival
           itself is refused. --queue-cap-interactive/-batch/-background
           add per-class caps (tail-drop within the class). Shed
           requests get a Shed response — an availability outcome kept
           strictly apart from Failed fault detections — and never
           execute a forward.
           --early-reject (requires a queue cap) also refuses requests
           whose declared deadline provably cannot be met, estimated
           from the scheduler's EWMA service time, at admission and
           again at batch close. --deadline-ms D declares that budget
           on every synthetic-driver request.
           --arrival-interval-us T switches the synthetic driver to
           open-loop pacing: one request every T µs regardless of
           service progress (the overload-bench arrival shape).
           --workers W (2)  --artifacts DIR (artifacts)
           --inject-every K  --scale F (1.0)  --mode auto|dense|sparse
           --mem-budget-mb M (512)  --train-epochs E (10)
           --backend native|instrumented|pjrt (native)
           --scheme fused|split|auto (fused; auto resolves to the
           cheapest measured check-op scheme for the backend/shapes and
           the summary reports the concrete decision). Inner kernels
           are lane-dispatched (GCN_ABFT_KERNEL=scalar|x8 overrides;
           bit-identical either way).
           --shards N (0 = unsharded)  --shard-transport
           inproc|proc|tcp (inproc). Sharding splits the CSR S into N
           row bands, one per shard; proc spawns one shard-worker
           subprocess per band over Unix sockets; tcp spawns localhost
           workers (or dials --shard-addrs HOST:PORT,... — one running
           `shard-worker --listen` per band) with the identical frame
           protocol. Bit-identical to unsharded serving; a dead shard
           fail-stops (Failed responses, coordinator keeps serving).
           --kill-shard-after B tears down shard 0 before batch B
           (fail-stop fault injection).
           --supervise runs the shard supervisor: dead shards are
           re-spawned/re-connected on a --heartbeat-ms (200) tick, the
           resident band + checksum re-ship behind the epoch fence, and
           the in-flight batch replays after recovery — answers stay
           fail-stop (Failed), never wrong or silent.
           --warm-standby K pre-ships K spare workers (proc/tcp) so a
           failover adopts a standby with zero re-ship bytes.
           --deltas PATH streams graph mutations into the running
           server: a JSONL file of scheduled deltas (applied after the
           request id they name has been submitted) or a Unix socket
           producing delta lines live. Each delta is applied behind an
           epoch fence — in-flight batches drain, the patched operands
           publish atomically, and every response records the epoch it
           executed against. A rejected delta leaves the epoch and the
           graph unchanged (fail-stop).
  shard-worker  (internal) one shard of a sharded serve: receives its
           row band of S, serves aggregation requests until shutdown
           --socket PATH (dial the coordinator's Unix domain socket) |
           --listen ADDR (bind a TCP address, print the bound address
           on stdout, and accept coordinators — survives coordinator
           restarts, so one worker can serve successive runs)
  mutate   offline dynamic-graph verification: apply a delta sequence
           incrementally (patching only the touched CSR rows and their
           additive checksum contributions), then rebuild the operands
           from scratch and require *bit* identity — raw matrices,
           per-band s_c, x_r1, h_c1, everything. Prints patch-vs-rebuild
           timing; exits 0 on bit-identity, 1 on divergence.
           --dataset tiny|cora|citeseer|pubmed|nell (tiny)
           --random N (8 seeded random deltas) | --deltas FILE (JSONL)
           --mode sparse|dense (sparse)  --bands B (2)  --seed S (7)
           --scale F (1.0)  --train-epochs E (0)  --json
  report   machine-readable report artifacts
           bench  aggregate serve throughput + delta patch-vs-rebuild
                  timing sweep into BENCH_serve.json (repo root)
                  --dataset D (tiny)  --requests N (48)  --seed S (7)
                  --scale F (1.0)  --deltas K (6)  --out PATH  --json
           layer  scalar-vs-vector kernel A/Bs (dense matmul, CSR spmm,
                  f64 column-sum reduction) with GFLOP/s + arithmetic
                  intensity per shape/sparsity, plus the per-dataset
                  check-op overhead of fused vs split and the scheme
                  `--scheme auto` resolves to; writes BENCH_layer.json
                  (repo root)  --reps R (5)  --out PATH  --json
  train    train the synthetic 2-layer GCNs, print loss/accuracy curves
           --datasets ...  --epochs E (30)  --seed S
  info     dataset statistics (nodes/edges/features/classes/nnz)
  analyze  architectural lint pass: enforce the determinism, fail-stop
           and f64-checksum contracts over the source tree (lexer-level,
           std-only; rules D1 no-raw-clock, D2 deterministic-iteration,
           D3 f64-accumulation, D4 no-float-eq, F1 fail-stop-not-panic,
           C1 scoped-threads-only, M1 mutation-only-in-mutate,
           N1 sockets-only-in-net, K1 kernels-confine-lane-code).
           Suppress a finding inline with
           `gcn-lint: allow(RULE, reason=\"...\")` (reason mandatory).
           Exits 0 clean, 1 on unsuppressed findings, 2 on usage error.
           [paths...] (default: the crate's src and tests trees)  --json
"
    );
}

fn common_opts(a: &Args) -> Result<ExperimentOpts, String> {
    let names = a.get_list("datasets", &["cora", "citeseer", "pubmed", "nell"]);
    let mut datasets = Vec::new();
    for n in &names {
        match DatasetId::parse(n) {
            Some(d) => datasets.push(d),
            None => return Err(format!("unknown dataset: {n}")),
        }
    }
    Ok(ExperimentOpts {
        datasets,
        seed: a.get_u64("seed", 7).map_err(|e| e.to_string())?,
        scale: a.get_f64("scale", 1.0).map_err(|e| e.to_string())?,
        train_epochs: a
            .get_usize("train-epochs", 20)
            .map_err(|e| e.to_string())?,
    })
}

fn parse_or_die(rest: Vec<String>, spec: &Spec) -> Args {
    match Args::parse(rest, spec) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e}");
            std::process::exit(2);
        }
    }
}

fn cmd_analyze(rest: Vec<String>) -> i32 {
    let spec = Spec {
        options: vec![],
        flags: vec!["json"],
    };
    let a = parse_or_die(rest, &spec);
    gcn_abft::analysis::run_cli(&a)
}

fn cmd_table1(rest: Vec<String>) -> i32 {
    let spec = Spec {
        options: vec![
            "datasets",
            "campaigns",
            "faults",
            "seed",
            "scale",
            "threads",
            "train-epochs",
            "fault-model",
        ],
        flags: vec!["json"],
    };
    let a = parse_or_die(rest, &spec);
    let opts = match common_opts(&a) {
        Ok(o) => o,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let campaigns = a.get_usize("campaigns", 500).unwrap_or(500);
    let faults = a.get_usize("faults", 1).unwrap_or(1);
    let threads = a
        .get_usize("threads", gcn_abft::fault::campaign::default_threads())
        .unwrap_or(8);
    let Some(fault_model) = FaultModelKind::parse(&a.get_str("fault-model", "bitflip")) else {
        eprintln!("unknown --fault-model (bitflip, multibit[:BITS], stuckat[:OPS])");
        return 2;
    };
    eprintln!(
        "table1: datasets={:?} campaigns={campaigns} faults={faults} scale={} threads={threads} \
         fault-model={}",
        opts.datasets.iter().map(|d| d.name()).collect::<Vec<_>>(),
        opts.scale,
        fault_model.name()
    );
    let entries = report::run_table1_with_model(&opts, campaigns, faults, threads, fault_model);
    if a.has_flag("json") {
        println!("{}", report::experiments::table1_json(&entries).to_pretty());
    } else {
        println!("{}", report::render_table1(&entries));
    }
    0
}

fn cmd_table2(rest: Vec<String>) -> i32 {
    let spec = Spec {
        options: vec!["datasets", "seed", "scale", "train-epochs"],
        flags: vec!["json"],
    };
    let a = parse_or_die(rest, &spec);
    let opts = match common_opts(&a) {
        Ok(o) => o,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let entries = report::run_table2(&opts);
    if a.has_flag("json") {
        println!("{}", report::experiments::table2_json(&entries).to_pretty());
    } else {
        println!("{}", report::render_table2(&entries));
    }
    0
}

fn cmd_opcount(rest: Vec<String>) -> i32 {
    let spec = Spec {
        options: vec!["datasets"],
        flags: vec!["json"],
    };
    let a = parse_or_die(rest, &spec);
    let names = a.get_list("datasets", &["cora", "citeseer", "pubmed", "nell"]);
    let mut datasets = Vec::new();
    for n in &names {
        match DatasetId::parse(n) {
            Some(d) => datasets.push(d),
            None => {
                eprintln!("unknown dataset: {n}");
                return 2;
            }
        }
    }
    let rows = report::run_opcount_matrix(&datasets);
    if a.has_flag("json") {
        println!(
            "{}",
            report::experiments::opcount_matrix_json(&rows).to_pretty()
        );
    } else {
        println!("{}", report::render_opcount_matrix(&rows));
    }
    0
}

fn cmd_fig3(rest: Vec<String>) -> i32 {
    let spec = Spec {
        options: vec!["datasets", "seed", "scale", "reps", "train-epochs"],
        flags: vec![],
    };
    let a = parse_or_die(rest, &spec);
    let opts = match common_opts(&a) {
        Ok(o) => o,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let reps = a.get_usize("reps", 5).unwrap_or(5);
    let rows = report::run_fig3(&opts, reps);
    println!("{}", report::render_fig3(&rows));
    0
}

fn cmd_train(rest: Vec<String>) -> i32 {
    let spec = Spec {
        options: vec!["datasets", "seed", "scale", "epochs"],
        flags: vec![],
    };
    let a = parse_or_die(rest, &spec);
    let opts = match common_opts(&a) {
        Ok(o) => o,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let epochs = a.get_usize("epochs", 30).unwrap_or(30);
    for &id in &opts.datasets {
        let graph = if opts.scale < 1.0 {
            id.build_scaled(opts.seed, opts.scale)
        } else {
            id.build(opts.seed)
        };
        let mut model = gcn_abft::gcn::GcnModel::two_layer(&graph, id.hidden_dim(), opts.seed);
        let log = gcn_abft::gcn::train_two_layer(
            &mut model,
            &graph.features,
            &graph.labels,
            &gcn_abft::gcn::TrainConfig {
                epochs,
                ..Default::default()
            },
        );
        println!("== {} ==", graph.name);
        for e in log.iter().step_by((epochs / 10).max(1)) {
            println!(
                "  epoch {:>3}  loss {:>8.4}  acc {:>6.2}%",
                e.epoch,
                e.loss,
                e.accuracy * 100.0
            );
        }
        let last = log.last().unwrap();
        println!(
            "  final     loss {:>8.4}  acc {:>6.2}%",
            last.loss,
            last.accuracy * 100.0
        );
    }
    0
}

fn cmd_info(rest: Vec<String>) -> i32 {
    let spec = Spec {
        options: vec!["datasets", "seed", "scale"],
        flags: vec!["json"],
    };
    let a = parse_or_die(rest, &spec);
    let mut opts = match common_opts(&a) {
        Ok(o) => o,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    opts.train_epochs = 0;
    let mut t = gcn_abft::report::Table::new(vec![
        "dataset", "nodes", "edges", "feat dim", "feat nnz", "classes", "S nnz",
    ]);
    let mut items = Vec::new();
    for &id in &opts.datasets {
        let g = if opts.scale < 1.0 {
            id.build_scaled(opts.seed, opts.scale)
        } else {
            id.build(opts.seed)
        };
        t.row(vec![
            g.name.clone(),
            g.num_nodes.to_string(),
            g.num_edges().to_string(),
            g.feat_dim().to_string(),
            g.features.nnz().to_string(),
            g.num_classes.to_string(),
            g.adjacency_nnz().to_string(),
        ]);
        items.push(Json::obj(vec![
            ("dataset", Json::from(g.name.clone())),
            ("nodes", Json::from(g.num_nodes)),
            ("edges", Json::from(g.num_edges())),
            ("feat_dim", Json::from(g.feat_dim())),
            ("feat_nnz", Json::from(g.features.nnz())),
            ("classes", Json::from(g.num_classes)),
            ("adjacency_nnz", Json::from(g.adjacency_nnz())),
        ]));
    }
    if a.has_flag("json") {
        println!("{}", Json::Arr(items).to_pretty());
    } else {
        println!("{}", t.render());
    }
    0
}

fn cmd_serve(rest: Vec<String>) -> i32 {
    let spec = Spec {
        options: vec![
            "dataset",
            "requests",
            "batch",
            "max-batch",
            "max-wait-ms",
            "min-wait-ms",
            "starvation-factor",
            "priority-mix",
            "workers",
            "artifacts",
            "seed",
            "inject-every",
            "scale",
            "mode",
            "mem-budget-mb",
            "train-epochs",
            "backend",
            "scheme",
            "shards",
            "shard-transport",
            "shard-addrs",
            "kill-shard-after",
            "heartbeat-ms",
            "warm-standby",
            "deltas",
            "queue-cap",
            "queue-cap-interactive",
            "queue-cap-batch",
            "queue-cap-background",
            "arrival-interval-us",
            "deadline-ms",
        ],
        flags: vec!["json", "adaptive-wait", "supervise", "early-reject"],
    };
    let a = parse_or_die(rest, &spec);
    match gcn_abft::coordinator::serve_cli(&a) {
        Ok(summary) => {
            println!("{summary}");
            0
        }
        Err(e) => {
            eprintln!("serve failed: {e:#}");
            1
        }
    }
}

fn cmd_shard_worker(rest: Vec<String>) -> i32 {
    let spec = Spec {
        options: vec!["socket", "listen"],
        flags: vec![],
    };
    let a = parse_or_die(rest, &spec);
    match (a.get("socket"), a.get("listen")) {
        (Some(_), Some(_)) => {
            eprintln!("shard-worker takes --socket PATH or --listen ADDR, not both");
            2
        }
        (Some(socket), None) => {
            match gcn_abft::coordinator::run_shard_worker(std::path::Path::new(socket)) {
                Ok(()) => 0,
                Err(e) => {
                    eprintln!("shard-worker failed: {e:#}");
                    1
                }
            }
        }
        (None, Some(addr)) => match gcn_abft::coordinator::run_tcp_shard_worker(addr) {
            Ok(()) => 0,
            Err(e) => {
                eprintln!("shard-worker failed: {e:#}");
                1
            }
        },
        (None, None) => {
            eprintln!("shard-worker requires --socket PATH or --listen ADDR");
            2
        }
    }
}

fn cmd_mutate(rest: Vec<String>) -> i32 {
    let spec = Spec {
        options: vec![
            "dataset",
            "seed",
            "scale",
            "bands",
            "mode",
            "deltas",
            "random",
            "train-epochs",
        ],
        flags: vec!["json"],
    };
    let a = parse_or_die(rest, &spec);
    match run_mutate(&a) {
        Ok((out, identical)) => {
            println!("{out}");
            if identical {
                0
            } else {
                1
            }
        }
        Err(e) => {
            eprintln!("mutate failed: {e:#}");
            2
        }
    }
}

/// Offline patch-vs-rebuild verification: build a workload, run a delta
/// sequence through the incremental path, rebuild from scratch, and
/// demand bit identity. Returns (rendered report, bit-identical?).
fn run_mutate(a: &Args) -> anyhow::Result<(String, bool)> {
    use gcn_abft::coordinator::{Clock, MonotonicClock};
    use gcn_abft::runtime::{mutate, GcnOperands};
    use gcn_abft::util::rng::Pcg64;

    let name = a.get_str("dataset", "tiny");
    let dataset =
        DatasetId::parse(&name).ok_or_else(|| anyhow::anyhow!("unknown dataset: {name}"))?;
    let err = |e: gcn_abft::util::cli::CliError| anyhow::anyhow!("{e}");
    let opts = ExperimentOpts {
        datasets: vec![dataset],
        seed: a.get_u64("seed", 7).map_err(err)?,
        scale: a.get_f64("scale", 1.0).map_err(err)?,
        train_epochs: a.get_usize("train-epochs", 0).map_err(err)?,
    };
    let bands = a.get_usize("bands", 2).map_err(err)?.max(1);
    let mode = a.get_str("mode", "sparse");

    let (graph, model) = report::build_workload(dataset, &opts);
    let w1 = model.layers[0].weights.clone();
    let w2 = model.layers[1].weights.clone();
    let mut ops = match mode.as_str() {
        "dense" => GcnOperands::dense(
            graph.features.to_dense(),
            model.adjacency.to_dense(),
            w1,
            w2,
        )?,
        "sparse" => GcnOperands::sparse(graph.features.clone(), &model.adjacency, w1, w2, bands)?,
        other => anyhow::bail!("--mode must be sparse or dense (got {other})"),
    };
    let n0 = ops.n_nodes();

    let from_file = match a.get("deltas") {
        Some(path) => Some(
            mutate::load_delta_file(std::path::Path::new(path))?
                .into_iter()
                .map(|s| s.delta)
                .collect::<Vec<_>>(),
        ),
        None => None,
    };
    let count = match &from_file {
        Some(v) => v.len(),
        None => a.get_usize("random", 8).map_err(err)?,
    };

    let clock = MonotonicClock::new();
    let mut rng = Pcg64::from_seed(opts.seed ^ 0x4D55_5441);
    let mut apply_secs = 0.0f64;
    let (mut edges_added, mut edges_removed, mut nodes_added, mut swaps) = (0usize, 0, 0, 0);
    for i in 0..count {
        let delta = match &from_file {
            Some(v) => v[i].clone(),
            None => mutate::random_delta(
                &mut rng,
                ops.n_nodes(),
                ops.feat_dim(),
                ops.hidden_dim(),
                ops.num_classes(),
            ),
        };
        let t0 = clock.now();
        // gcn-lint: allow(M1, reason="offline patch-vs-rebuild verifier owns these operands; no server attached")
        let outcome = mutate::apply(&mut ops, &delta)
            .map_err(|e| anyhow::anyhow!("delta {i} ({}) rejected: {e:#}", delta.kind()))?;
        apply_secs += clock.now().since(t0).as_secs_f64();
        edges_added += outcome.edges_added;
        edges_removed += outcome.edges_removed;
        nodes_added += outcome.nodes_added;
        swaps += usize::from(outcome.weights_swapped);
    }

    let t0 = clock.now();
    let rebuilt = mutate::rebuild(&ops)?;
    let rebuild_secs = clock.now().since(t0).as_secs_f64();
    let verdict = mutate::bit_identical(&ops, &rebuilt);

    if a.has_flag("json") {
        let j = Json::obj(vec![
            ("dataset", Json::from(dataset.name())),
            ("mode", Json::from(mode.clone())),
            ("bands", Json::from(bands)),
            ("deltas", Json::from(count)),
            ("edges_added", Json::from(edges_added)),
            ("edges_removed", Json::from(edges_removed)),
            ("nodes_added", Json::from(nodes_added)),
            ("weight_swaps", Json::from(swaps)),
            ("nodes_before", Json::from(n0)),
            ("nodes_after", Json::from(ops.n_nodes())),
            ("apply_secs", Json::Num(apply_secs)),
            ("rebuild_secs", Json::Num(rebuild_secs)),
            ("bit_identical", Json::from(verdict.is_ok())),
            (
                "divergence",
                match &verdict {
                    Ok(()) => Json::Null,
                    Err(d) => Json::from(d.clone()),
                },
            ),
        ]);
        return Ok((j.to_pretty(), verdict.is_ok()));
    }
    let mut out = format!(
        "MUTATE {} ({mode}, {bands} band{}) — {count} deltas: +{edges_added}/-{edges_removed} \
         edges, +{nodes_added} nodes ({n0} -> {}), {swaps} weight swap{}\n\
         patch {:.3} ms total ({:.3} ms/delta) vs rebuild {:.3} ms",
        dataset.name(),
        if bands == 1 { "" } else { "s" },
        ops.n_nodes(),
        if swaps == 1 { "" } else { "s" },
        apply_secs * 1e3,
        apply_secs * 1e3 / count.max(1) as f64,
        rebuild_secs * 1e3,
    );
    match &verdict {
        Ok(()) => out.push_str("\npatch vs rebuild: bit-identical"),
        Err(d) => out.push_str(&format!("\npatch vs rebuild: DIVERGED — {d}")),
    }
    Ok((out, verdict.is_ok()))
}

fn cmd_report(rest: Vec<String>) -> i32 {
    let (sub, rest) = match rest.split_first() {
        Some((s, r)) => (s.clone(), r.to_vec()),
        None => {
            eprintln!("report requires a subcommand (bench)");
            return 2;
        }
    };
    match sub.as_str() {
        "bench" => {
            let spec = Spec {
                options: vec![
                    "dataset",
                    "requests",
                    "seed",
                    "scale",
                    "deltas",
                    "train-epochs",
                    "out",
                ],
                flags: vec!["json"],
            };
            let a = parse_or_die(rest, &spec);
            gcn_abft::report::bench::run_cli(&a)
        }
        "layer" => {
            let spec = Spec {
                options: vec!["reps", "out"],
                flags: vec!["json"],
            };
            let a = parse_or_die(rest, &spec);
            gcn_abft::report::layer::run_cli(&a)
        }
        other => {
            eprintln!("unknown report subcommand: {other} (expected: bench, layer)");
            2
        }
    }
}
