//! # GCN-ABFT
//!
//! Production-grade reproduction of *GCN-ABFT: Low-Cost Online Error
//! Checking for Graph Convolutional Networks* (Peltekis & Dimitrakopoulos,
//! cs.AR 2024).
//!
//! A GCN layer computes the three-matrix product `H_out = S·H·W`. Baseline
//! ABFT checks each of the two matmul phases separately; **GCN-ABFT**
//! exploits `eᵀ(SHW)e = (eᵀS)·H·(W·e) = s_c·H·w_r` to verify the whole
//! layer with a single fused checksum, cutting checking cost by 12–29 %
//! with equal-or-better fault detection.
//!
//! ## Layout
//!
//! | module | role |
//! |---|---|
//! | [`util`] | PRNG / JSON / bench harness / property-testing / CLI substrates |
//! | [`tensor`] | dense matrices + MAC-level instrumented engine |
//! | [`sparse`] | CSR, normalization, instrumented SpMM |
//! | [`graph`] | dataset container, synthesis, the paper's 4 dataset specs |
//! | [`gcn`] | GCN layers/models, init, tiny trainer |
//! | [`abft`] | split (baseline) and fused (GCN-ABFT) checkers |
//! | [`opcount`] | analytic op-count model (Table II) + per-(backend, scheme) overhead matrix |
//! | [`fault`] | pluggable fault models (bit-flip/multi-bit/stuck-at) + campaign runner (Table I) |
//! | [`runtime`] | the `GcnBackend` trait + its implementations: native dense/banded f32, instrumented f64 (band-parallel, deterministic fault timeline), optional PJRT (`pjrt` feature) |
//! | [`coordinator`] | serving layer: priority-aware continuous-batching scheduler (virtual-clock-testable, adaptive hold budget) + workers + shard tier (multi-process row-band sharding over a pluggable transport) + online verification |
//! | [`report`] | table/figure rendering (Table I/II, Fig. 3) |
//! | [`analysis`] | `gcn-abft analyze`: lexer-level lint pass mechanizing the determinism / fail-stop / f64-checksum contracts |
//!
//! The Python side (`python/compile/`) authors the L1 Pallas kernels and
//! the L2 JAX model and AOT-lowers them to HLO text whose shape manifest
//! [`runtime`] validates against; Python never runs at serving time. The
//! offline build environment has no `xla` crate, so the default runtime
//! backend executes natively on the repo's own row-parallel kernels.

// Style lints that fight the codebase's explicit-index numeric-kernel
// idiom; correctness lints stay on (CI runs clippy with -D warnings).
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::inherent_to_string,
    clippy::manual_range_contains,
    clippy::type_complexity
)]

pub mod abft;
pub mod analysis;
pub mod opcount;
pub mod coordinator;
pub mod fault;
pub mod gcn;
pub mod graph;
pub mod report;
pub mod runtime;
pub mod sparse;
pub mod tensor;
pub mod util;
