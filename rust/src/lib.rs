//! # GCN-ABFT
//!
//! Production-grade reproduction of *GCN-ABFT: Low-Cost Online Error
//! Checking for Graph Convolutional Networks* (Peltekis & Dimitrakopoulos,
//! cs.AR 2024).
//!
//! A GCN layer computes the three-matrix product `H_out = S·H·W`. Baseline
//! ABFT checks each of the two matmul phases separately; **GCN-ABFT**
//! exploits `eᵀ(SHW)e = (eᵀS)·H·(W·e) = s_c·H·w_r` to verify the whole
//! layer with a single fused checksum, cutting checking cost by 12–29 %
//! with equal-or-better fault detection.
//!
//! ## Layout
//!
//! | module | role |
//! |---|---|
//! | [`util`] | PRNG / JSON / bench harness / property-testing / CLI substrates |
//! | [`tensor`] | dense matrices + MAC-level instrumented engine |
//! | [`sparse`] | CSR, normalization, instrumented SpMM |
//! | [`graph`] | dataset container, synthesis, the paper's 4 dataset specs |
//! | [`gcn`] | GCN layers/models, init, tiny trainer |
//! | [`abft`] | split (baseline) and fused (GCN-ABFT) checkers |
//! | [`opcount`] | analytic op-count model (Table II) |
//! | [`fault`] | bit-flip fault injection + campaign runner (Table I) |
//! | [`runtime`] | PJRT/XLA artifact loading & execution (AOT from JAX) |
//! | [`coordinator`] | serving layer: batcher + workers + online verification |
//! | [`report`] | table/figure rendering (Table I/II, Fig. 3) |
//!
//! The Python side (`python/compile/`) authors the L1 Pallas kernels and
//! the L2 JAX model and AOT-lowers them to HLO text consumed by
//! [`runtime`]; Python never runs at serving time.

pub mod abft;
pub mod opcount;
pub mod coordinator;
pub mod fault;
pub mod gcn;
pub mod graph;
pub mod report;
pub mod runtime;
pub mod sparse;
pub mod tensor;
pub mod util;
