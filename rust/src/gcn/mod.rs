//! GCN substrate: layers, models, initialization, and a tiny trainer used
//! to produce meaningful class margins for fault-criticality analysis.

pub mod init;
pub mod layer;
pub mod model;
pub mod train;

pub use layer::{Activation, Dataflow, GcnLayer, LayerInput};
pub use model::{ForwardResult, GcnModel};
pub use train::{train_two_layer, EpochStats, TrainConfig};
