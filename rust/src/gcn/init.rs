//! Weight initialization (Glorot/Xavier uniform, the Kipf–Welling GCN
//! default) from the repo's seeded PRNG.

use crate::tensor::Dense;
use crate::util::rng::Pcg64;

/// Glorot-uniform init: U(-a, a) with a = sqrt(6 / (fan_in + fan_out)).
pub fn glorot_uniform(rng: &mut Pcg64, fan_in: usize, fan_out: usize) -> Dense {
    let a = (6.0 / (fan_in + fan_out) as f64).sqrt() as f32;
    Dense::from_fn(fan_in, fan_out, |_, _| rng.gen_f32_range(-a, a))
}

/// Small-normal init (used by ablations).
pub fn normal(rng: &mut Pcg64, rows: usize, cols: usize, std: f32) -> Dense {
    Dense::from_fn(rows, cols, |_, _| (rng.gen_normal() as f32) * std)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn glorot_bounds_and_determinism() {
        let mut r1 = Pcg64::from_seed(1);
        let mut r2 = Pcg64::from_seed(1);
        let w1 = glorot_uniform(&mut r1, 100, 50);
        let w2 = glorot_uniform(&mut r2, 100, 50);
        assert_eq!(w1, w2);
        let a = (6.0f64 / 150.0).sqrt() as f32;
        assert!(w1.data().iter().all(|&v| v >= -a && v < a));
        // Not degenerate: mean near zero, spread non-trivial.
        let mean: f64 = w1.data().iter().map(|&v| v as f64).sum::<f64>() / 5000.0;
        assert!(mean.abs() < 0.01);
    }

    #[test]
    fn normal_scales_with_std() {
        let mut rng = Pcg64::from_seed(2);
        let w = normal(&mut rng, 50, 50, 0.1);
        let var: f64 = w.data().iter().map(|&v| (v as f64).powi(2)).sum::<f64>() / 2500.0;
        assert!((var.sqrt() - 0.1).abs() < 0.02, "std {}", var.sqrt());
    }
}
