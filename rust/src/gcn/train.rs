//! Tiny full-batch gradient-descent trainer for the synthetic datasets.
//!
//! The paper uses trained GCNs; we cannot ship the original checkpoints,
//! so a few epochs of cross-entropy training on the synthetic labels give
//! weights for which "fault criticality" (does a bit flip change some
//! node's argmax class?) is meaningful rather than an artifact of random
//! logits. Exactness of the optimum is irrelevant to ABFT — only that the
//! class margins are realistic.

use super::model::GcnModel;
use crate::sparse::Csr;
use crate::tensor::{ops, Dense};

/// Training configuration.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub epochs: usize,
    pub lr: f32,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self {
            epochs: 30,
            lr: 0.05,
        }
    }
}

/// Per-epoch training log entry.
#[derive(Debug, Clone)]
pub struct EpochStats {
    pub epoch: usize,
    pub loss: f64,
    pub accuracy: f64,
}

/// Train a 2-layer GCN in place with full-batch gradient descent.
/// Returns the per-epoch loss/accuracy curve.
///
/// Only supports the 2-layer architecture (which is all the paper
/// evaluates); asserts otherwise.
pub fn train_two_layer(
    model: &mut GcnModel,
    features: &Csr,
    labels: &[usize],
    cfg: &TrainConfig,
) -> Vec<EpochStats> {
    assert_eq!(model.num_layers(), 2, "trainer supports 2-layer GCNs");
    let n = features.rows();
    assert_eq!(labels.len(), n);
    let s = model.adjacency.clone();
    let mut log = Vec::with_capacity(cfg.epochs);

    // Hᵀ once, for the sparse weight-gradient contraction.
    let h_t = features.transpose();

    for epoch in 0..cfg.epochs {
        // ---- forward (combination-first: never materializes the dense
        // N×F aggregate, which would be ~1.4 GB for Nell) ------------------
        let x1 = features.spmm(&model.layers[0].weights); // H·W1, N×h
        let z1 = s.spmm(&x1); // S·(H·W1), N×h
        let h1 = ops::relu(&z1);
        let x2 = ops::matmul(&h1, &model.layers[1].weights); // H1·W2, N×C
        let z2 = s.spmm(&x2); // logits
        let logp = ops::log_softmax_rows(&z2);

        // ---- loss & accuracy -------------------------------------------
        let mut loss = 0f64;
        let mut correct = 0usize;
        for (r, &y) in labels.iter().enumerate() {
            loss -= logp.get(r, y) as f64;
            let row = logp.row(r);
            let pred = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            if pred == y {
                correct += 1;
            }
        }
        loss /= n as f64;
        log.push(EpochStats {
            epoch,
            loss,
            accuracy: correct as f64 / n as f64,
        });

        // ---- backward ---------------------------------------------------
        // dZ2 = softmax - onehot, scaled by 1/N
        let mut dz2 = Dense::zeros(n, logp.cols());
        for r in 0..n {
            for c in 0..logp.cols() {
                let p = (logp.get(r, c) as f64).exp() as f32;
                let t = if labels[r] == c { 1.0 } else { 0.0 };
                dz2.set(r, c, (p - t) / n as f32);
            }
        }
        // Z2 = S·(H1·W2) ⇒ dX2 = Sᵀ·dZ2 = S·dZ2 (S symmetric).
        let dx2 = s.spmm(&dz2);
        // dW2 = H1ᵀ · dX2
        let dw2 = ops::matmul(&h1.transpose(), &dx2);
        // dH1 = dX2 · W2ᵀ, masked by relu'(Z1) to get dZ1.
        let mut dz1 = ops::matmul(&dx2, &model.layers[1].weights.transpose());
        for (g, &z) in dz1.data_mut().iter_mut().zip(z1.data()) {
            if z <= 0.0 {
                *g = 0.0;
            }
        }
        // Z1 = S·(H·W1) ⇒ dX1 = S·dZ1; dW1 = Hᵀ·dX1 (sparse contraction).
        let dx1 = s.spmm(&dz1);
        let dw1 = h_t.spmm(&dx1);

        // ---- relative RMS-normalized SGD update ---------------------------
        // Feature magnitudes vary by orders of magnitude across datasets
        // (DESIGN.md §4 feature_scale), so raw gradients are badly scaled.
        // Each update moves the weights by `lr × rms(W)` in the gradient
        // direction — a bounded *relative* step, which keeps wide-class
        // heads (Nell: 210 classes) from driving layer 1 into dead-ReLU
        // collapse the way an absolute step size can.
        let rms = |d: &[f32]| -> f32 {
            (d.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>() / d.len().max(1) as f64)
                .sqrt() as f32
        };
        let rms_step = |w: &mut Dense, g: &Dense, lr: f32| {
            let scale = lr * (rms(w.data()) + 1e-8) / (rms(g.data()) + 1e-12);
            for (wv, gv) in w.data_mut().iter_mut().zip(g.data()) {
                *wv -= scale * gv;
            }
        };
        rms_step(&mut model.layers[0].weights, &dw1, cfg.lr);
        rms_step(&mut model.layers[1].weights, &dw2, cfg.lr);
    }
    log
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gcn::layer::Dataflow;
    use crate::graph::DatasetId;

    #[test]
    fn loss_decreases_and_accuracy_improves() {
        let g = DatasetId::Tiny.build(1);
        let mut m = GcnModel::two_layer(&g, 8, 2);
        let log = train_two_layer(
            &mut m,
            &g.features,
            &g.labels,
            &TrainConfig {
                epochs: 60,
                lr: 0.05,
            },
        );
        let first = &log[0];
        let last = log.last().unwrap();
        assert!(
            last.loss < first.loss * 0.8,
            "loss did not decrease: {} -> {}",
            first.loss,
            last.loss
        );
        assert!(
            last.accuracy > first.accuracy,
            "accuracy did not improve: {} -> {}",
            first.accuracy,
            last.accuracy
        );
        // Homophilous synthetic labels are learnable well above chance (25%).
        assert!(last.accuracy > 0.4, "accuracy {}", last.accuracy);
    }

    #[test]
    fn training_is_deterministic() {
        let g = DatasetId::Tiny.build(1);
        let mut m1 = GcnModel::two_layer(&g, 8, 2);
        let mut m2 = GcnModel::two_layer(&g, 8, 2);
        let cfg = TrainConfig {
            epochs: 5,
            lr: 0.02,
        };
        train_two_layer(&mut m1, &g.features, &g.labels, &cfg);
        train_two_layer(&mut m2, &g.features, &g.labels, &cfg);
        assert_eq!(m1.layers[0].weights, m2.layers[0].weights);
        assert_eq!(m1.layers[1].weights, m2.layers[1].weights);
    }

    #[test]
    fn trained_forward_still_matches_both_dataflows() {
        let g = DatasetId::Tiny.build(1);
        let mut m = GcnModel::two_layer(&g, 8, 2);
        train_two_layer(&mut m, &g.features, &g.labels, &TrainConfig::default());
        let a = m.forward(&g.features, Dataflow::CombinationFirst);
        let b = m.forward(&g.features, Dataflow::AggregationFirst);
        assert!(a.logits.max_abs_diff(&b.logits) < 1e-4);
    }
}
