//! Multi-layer GCN model: the paper's workload is the canonical 2-layer
//! node-classification GCN (`softmax(S·relu(S·H·W¹)·W²)`), but the model
//! container supports arbitrary depth.

use super::init::glorot_uniform;
use super::layer::{Activation, Dataflow, GcnLayer, LayerInput};
use crate::graph::Graph;
use crate::sparse::Csr;
use crate::tensor::{ops, Dense};
use crate::util::rng::Pcg64;

/// A GCN model: normalized adjacency + a stack of layers.
#[derive(Debug, Clone)]
pub struct GcnModel {
    pub adjacency: Csr,
    pub layers: Vec<GcnLayer>,
}

/// Result of a full forward pass.
#[derive(Debug, Clone)]
pub struct ForwardResult {
    /// Final pre-activation logits (N × num_classes).
    pub logits: Dense,
    /// Pre-activation output of every layer (for checker tests).
    pub preacts: Vec<Dense>,
}

impl GcnModel {
    /// Build a 2-layer model for a dataset graph with Glorot weights.
    pub fn two_layer(graph: &Graph, hidden: usize, seed: u64) -> Self {
        let mut rng = Pcg64::from_seed(seed);
        let adjacency = graph.normalized_adjacency();
        let layers = vec![
            GcnLayer::new(
                glorot_uniform(&mut rng, graph.feat_dim(), hidden),
                Activation::Relu,
            ),
            GcnLayer::new(
                glorot_uniform(&mut rng, hidden, graph.num_classes),
                Activation::None,
            ),
        ];
        Self { adjacency, layers }
    }

    /// Build an arbitrary-depth model (`dims = [in, h1, h2, …, out]`).
    pub fn with_dims(graph: &Graph, dims: &[usize], seed: u64) -> Self {
        assert!(dims.len() >= 2, "need at least input and output dims");
        assert_eq!(dims[0], graph.feat_dim(), "dims[0] must be feat_dim");
        let mut rng = Pcg64::from_seed(seed);
        let adjacency = graph.normalized_adjacency();
        let layers = dims
            .windows(2)
            .enumerate()
            .map(|(i, w)| {
                let act = if i + 2 == dims.len() {
                    Activation::None
                } else {
                    Activation::Relu
                };
                GcnLayer::new(glorot_uniform(&mut rng, w[0], w[1]), act)
            })
            .collect();
        Self { adjacency, layers }
    }

    pub fn num_layers(&self) -> usize {
        self.layers.len()
    }

    /// Clean (uninstrumented) forward pass. This is the golden run used as
    /// ground truth for fault-criticality classification.
    pub fn forward(&self, features: &Csr, dataflow: Dataflow) -> ForwardResult {
        let mut preacts = Vec::with_capacity(self.layers.len());
        let mut input = LayerInput::Sparse(features.clone());
        for layer in &self.layers {
            let pre = layer.forward_preact(&self.adjacency, &input, dataflow);
            preacts.push(pre.clone());
            let mut act = pre;
            layer.activate(&mut act);
            input = LayerInput::Dense(act);
        }
        let logits = match input {
            LayerInput::Dense(d) => d,
            LayerInput::Sparse(_) => unreachable!("model has at least one layer"),
        };
        ForwardResult { logits, preacts }
    }

    /// Predicted class per node.
    pub fn predict(&self, features: &Csr, dataflow: Dataflow) -> Vec<usize> {
        ops::argmax_rows(&self.forward(features, dataflow).logits)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::DatasetId;

    #[test]
    fn two_layer_shapes() {
        let g = DatasetId::Tiny.build(0);
        let m = GcnModel::two_layer(&g, 8, 1);
        let fr = m.forward(&g.features, Dataflow::CombinationFirst);
        assert_eq!(fr.logits.shape(), (64, 4));
        assert_eq!(fr.preacts.len(), 2);
        assert_eq!(fr.preacts[0].shape(), (64, 8));
    }

    #[test]
    fn forward_deterministic() {
        let g = DatasetId::Tiny.build(0);
        let m = GcnModel::two_layer(&g, 8, 1);
        let a = m.forward(&g.features, Dataflow::CombinationFirst);
        let b = m.forward(&g.features, Dataflow::CombinationFirst);
        assert_eq!(a.logits, b.logits);
    }

    #[test]
    fn dataflow_equivalence_full_model() {
        let g = DatasetId::Tiny.build(2);
        let m = GcnModel::two_layer(&g, 8, 3);
        let comb = m.forward(&g.features, Dataflow::CombinationFirst);
        let agg = m.forward(&g.features, Dataflow::AggregationFirst);
        assert!(comb.logits.max_abs_diff(&agg.logits) < 1e-4);
    }

    #[test]
    fn deep_model() {
        let g = DatasetId::Tiny.build(4);
        let m = GcnModel::with_dims(&g, &[32, 16, 8, 4], 5);
        assert_eq!(m.num_layers(), 3);
        assert_eq!(m.layers[0].activation, Activation::Relu);
        assert_eq!(m.layers[2].activation, Activation::None);
        let fr = m.forward(&g.features, Dataflow::CombinationFirst);
        assert_eq!(fr.logits.shape(), (64, 4));
    }

    #[test]
    fn predictions_in_range() {
        let g = DatasetId::Tiny.build(5);
        let m = GcnModel::two_layer(&g, 8, 6);
        let preds = m.predict(&g.features, Dataflow::CombinationFirst);
        assert_eq!(preds.len(), 64);
        assert!(preds.iter().all(|&p| p < 4));
    }

    #[test]
    #[should_panic(expected = "dims[0] must be feat_dim")]
    fn wrong_input_dim_panics() {
        let g = DatasetId::Tiny.build(0);
        GcnModel::with_dims(&g, &[99, 4], 0);
    }
}
