//! A single GCN layer: `H_out = σ(S · H · W)`.
//!
//! Two dataflows, as discussed in §II of the paper:
//! * **combination-first** (`X = H·W`, then `H_out = S·X`) — the preferred
//!   order in recent accelerators [9] and the default everywhere in this
//!   repo (lowest arithmetic intensity when `feat_dim > hidden`);
//! * **aggregation-first** (`H̃ = S·H`, then `H_out = H̃·W`) — provided
//!   because GCN-ABFT's fused checksum is dataflow-independent (§III) and
//!   the test suite verifies that.

use crate::sparse::Csr;
use crate::tensor::{ops, Dense};

/// Layer input: the first layer sees the sparse feature matrix, deeper
/// layers see the dense activations of the previous layer.
#[derive(Debug, Clone)]
pub enum LayerInput {
    Sparse(Csr),
    Dense(Dense),
}

impl LayerInput {
    pub fn rows(&self) -> usize {
        match self {
            LayerInput::Sparse(m) => m.rows(),
            LayerInput::Dense(m) => m.rows(),
        }
    }
    pub fn cols(&self) -> usize {
        match self {
            LayerInput::Sparse(m) => m.cols(),
            LayerInput::Dense(m) => m.cols(),
        }
    }
    /// Nonzero count (dense inputs count every element, matching how the
    /// accelerator would schedule a dense operand).
    pub fn nnz(&self) -> usize {
        match self {
            LayerInput::Sparse(m) => m.nnz(),
            LayerInput::Dense(m) => m.len(),
        }
    }
    /// `M · v` with the natural engine for the storage format.
    pub fn matvec(&self, v: &[f32]) -> Vec<f32> {
        match self {
            LayerInput::Sparse(m) => m.matvec(v),
            LayerInput::Dense(m) => ops::matvec_f64(m, v),
        }
    }
    /// Per-column sums (`eᵀM`).
    pub fn col_sums(&self) -> Vec<f32> {
        match self {
            LayerInput::Sparse(m) => m.col_sums(),
            LayerInput::Dense(m) => m.col_sums(),
        }
    }
    /// `M · B` with the natural engine.
    pub fn matmul(&self, b: &Dense) -> Dense {
        match self {
            LayerInput::Sparse(m) => m.spmm(b),
            LayerInput::Dense(m) => ops::matmul(m, b),
        }
    }
}

/// Dataflow order for the two matmuls of a layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dataflow {
    CombinationFirst,
    AggregationFirst,
}

/// Activation applied at the end of a layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Activation {
    Relu,
    /// Final layers emit raw logits.
    None,
}

/// One GCN layer's parameters.
#[derive(Debug, Clone)]
pub struct GcnLayer {
    pub weights: Dense,
    pub activation: Activation,
}

impl GcnLayer {
    pub fn new(weights: Dense, activation: Activation) -> Self {
        Self {
            weights,
            activation,
        }
    }

    pub fn in_dim(&self) -> usize {
        self.weights.rows()
    }
    pub fn out_dim(&self) -> usize {
        self.weights.cols()
    }

    /// Pre-activation output `S·H·W` with the given dataflow (clean
    /// reference path, no instrumentation). Returns the pre-activation
    /// matrix — the value ABFT checks (§II-B: "before the application of
    /// the activation function").
    pub fn forward_preact(&self, s: &Csr, h: &LayerInput, dataflow: Dataflow) -> Dense {
        assert_eq!(h.cols(), self.in_dim(), "layer input dim mismatch");
        assert_eq!(s.cols(), h.rows(), "adjacency/input dim mismatch");
        match dataflow {
            Dataflow::CombinationFirst => {
                let x = h.matmul(&self.weights); // X = H W
                s.spmm(&x) // H_out = S X
            }
            Dataflow::AggregationFirst => {
                let agg = match h {
                    LayerInput::Sparse(m) => s.spmm(&m.to_dense()),
                    LayerInput::Dense(m) => s.spmm(m),
                };
                ops::matmul(&agg, &self.weights)
            }
        }
    }

    /// Apply this layer's activation in place.
    pub fn activate(&self, m: &mut Dense) {
        if self.activation == Activation::Relu {
            ops::relu_inplace(m);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::DatasetId;
    use crate::util::rng::Pcg64;

    fn setup() -> (Csr, LayerInput, GcnLayer) {
        let g = DatasetId::Tiny.build(3);
        let s = g.normalized_adjacency();
        let mut rng = Pcg64::from_seed(5);
        let w = crate::gcn::init::glorot_uniform(&mut rng, g.feat_dim(), 8);
        (
            s,
            LayerInput::Sparse(g.features),
            GcnLayer::new(w, Activation::Relu),
        )
    }

    #[test]
    fn dataflows_agree() {
        let (s, h, layer) = setup();
        let comb = layer.forward_preact(&s, &h, Dataflow::CombinationFirst);
        let agg = layer.forward_preact(&s, &h, Dataflow::AggregationFirst);
        assert!(
            comb.max_abs_diff(&agg) < 1e-4,
            "dataflow order changed the result by {}",
            comb.max_abs_diff(&agg)
        );
    }

    #[test]
    fn output_shape() {
        let (s, h, layer) = setup();
        let out = layer.forward_preact(&s, &h, Dataflow::CombinationFirst);
        assert_eq!(out.shape(), (64, 8));
    }

    #[test]
    fn relu_applied() {
        let (s, h, layer) = setup();
        let mut out = layer.forward_preact(&s, &h, Dataflow::CombinationFirst);
        layer.activate(&mut out);
        assert!(out.data().iter().all(|&v| v >= 0.0));
    }

    #[test]
    fn dense_input_layer() {
        let (s, _, _) = setup();
        let mut rng = Pcg64::from_seed(6);
        let h = LayerInput::Dense(crate::gcn::init::normal(&mut rng, 64, 8, 0.5));
        let w = crate::gcn::init::glorot_uniform(&mut rng, 8, 4);
        let layer = GcnLayer::new(w, Activation::None);
        let out = layer.forward_preact(&s, &h, Dataflow::CombinationFirst);
        assert_eq!(out.shape(), (64, 4));
    }

    #[test]
    #[should_panic(expected = "input dim mismatch")]
    fn dim_mismatch_panics() {
        let (s, h, _) = setup();
        let w = Dense::zeros(3, 4); // wrong in_dim
        let layer = GcnLayer::new(w, Activation::None);
        layer.forward_preact(&s, &h, Dataflow::CombinationFirst);
    }
}
