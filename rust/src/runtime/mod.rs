//! XLA/PJRT runtime: loads the HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the request path.
//! Python never runs at serving time.

pub mod artifact;
pub mod client;

pub use artifact::{Manifest, ModelEntry};
pub use client::{GcnExecutable, GcnOutputs, Runtime};
