//! Serving runtime: executes the 2-layer GCN-ABFT forward on the request
//! path and validates shapes against the artifact manifest produced by
//! `python/compile/aot.py`. Python never runs at serving time.
//!
//! The default backend is native (the repo's own row-parallel f32
//! kernels); the original PJRT/XLA path is kept behind the `pjrt`
//! feature because the `xla` crate is absent from the offline registry —
//! see [`client`] for the full story.

pub mod artifact;
pub mod client;

pub use artifact::{Manifest, ModelEntry};
pub use client::{GcnExecutable, GcnOutputs, Runtime};
