//! Serving runtime: executes the 2-layer GCN-ABFT forward on the request
//! path and validates shapes against the artifact manifest produced by
//! `python/compile/aot.py`. Python never runs at serving time.
//!
//! Every forward path implements the [`backend::GcnBackend`] trait over
//! resident [`operands::GcnOperands`]: `NativeDense`/`NativeBanded` (the
//! repo's own row-parallel f32 kernels — sparse operands are what let
//! PubMed/Nell serve at all, and row-band sharding is the multi-node
//! blueprint), the MAC-instrumented f64 `Instrumented` backend with
//! pluggable fault models, and the PJRT/XLA path behind the `pjrt`
//! feature (the `xla` crate is absent from the offline registry — see
//! [`client`] for the full story). The checksum scheme (fused GCN-ABFT
//! vs the split baseline) is selected per backend, not per call site.

pub mod artifact;
pub mod backend;
pub mod client;
pub mod mutate;
pub mod operands;

pub use artifact::{Manifest, ModelEntry};
pub use backend::{
    BackendKind, ChecksumScheme, ExecPlan, GcnBackend, Instrumented, InstrumentedEngine,
    NativeBanded, NativeDense, Overlay,
};
pub use client::{GcnExecutable, GcnOutputs, Runtime};
pub use mutate::{
    DeltaOutcome, EpochFence, GraphDelta, NodeAddition, ScheduledDelta,
};
pub use operands::{
    CheckState, ExecMode, GcnOperands, Operand, OperandPlan, RowBand, SOperand,
};
