//! Serving runtime: executes the 2-layer GCN-ABFT forward on the request
//! path and validates shapes against the artifact manifest produced by
//! `python/compile/aot.py`. Python never runs at serving time.
//!
//! The default backend is native (the repo's own row-parallel f32
//! kernels); it consumes either dense or CSR operands (see
//! [`operands`] — sparse operands are what let PubMed/Nell serve at
//! all, and row-band sharding is the multi-node blueprint). The
//! original PJRT/XLA path is kept behind the `pjrt` feature because the
//! `xla` crate is absent from the offline registry — see [`client`] for
//! the full story.

pub mod artifact;
pub mod client;
pub mod operands;

pub use artifact::{Manifest, ModelEntry};
pub use client::{GcnExecutable, GcnOutputs, Runtime};
pub use operands::{
    CheckState, ExecMode, GcnOperands, Operand, OperandPlan, RowBand, SOperand,
};
